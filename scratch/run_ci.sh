#!/usr/bin/env bash
# Tier-1 CI: unit-test suite + DVFS-benchmark smoke passes.
#
#   bash scratch/run_ci.sh
#
# The suite must COLLECT cleanly with or without `hypothesis` installed
# (property tests skip when it's absent — see tests/hypothesis_compat.py).
# Two benchmark smoke passes assert the paper's headline results end-to-end:
#   * bench_dvfs:          lower energy than the no-early-exit baseline at
#                          equal target latency (per-sentence Alg. 1);
#   * bench_batched_dvfs:  shared-clock arbitration (one LDO/ADPLL) below
#                          per-sentence max-V/f replay at equal target
#                          latency, with exactly one compile per length
#                          bucket — including the INTERLEAVED EDF scenario
#                          (late tight-SLO shorts preempting a deep drain).
# Grep-gates re-check the emitted telemetry even if the benchmark's own
# asserts were loosened:
#   * EVERY `step_traces=N;bucket_count=M` pair (sequential drain,
#     interleaved stepping AND the preemption-enabled admission storm) must
#     satisfy N <= M — N > M means the fused step recompiled inside a
#     bucket;
#   * `edf_deadline_misses=K` from the interleaved scenario must be 0 —
#     a tight per-request SLO admitted mid-drain may not be missed;
#   * admission storm: `accepted_slo_misses` must be 0 (an admitted SLO is a
#     contract), `rejected` must be > 0 (the storm IS oversubscribed — the
#     infeasible tail must be refused at submit time, not accepted and
#     missed), and `best_effort_completed` must be > 0 (the bounded queue
#     sheds instead of letting contracts starve best-effort forever);
#   * decode early exit: under the mixed classifier+decoder storm,
#     `exit_beats_full` must be 1 (per-token exit strictly cheaper than
#     full-depth decode) at 0 accepted-SLO misses on BOTH decode runs;
#   * speculative decode: under the same mixed storm, `spec_parity=1`
#     (self-speculative block decode emits tokens bit-identical to the
#     per-token EE baseline), `tps_ratio` >= 1.5 (accepted tokens per fused
#     step vs the per-token baseline's 1.0) at 0 accepted-SLO misses on
#     both runs, plus a schema-valid `speculative_decode` entry in the
#     BENCH_serving.json history;
#   * pallas serving step: `parity=1` and `exit_parity=1` (use_pallas=True
#     numerically interchangeable with the ref path over a full drain) at
#     `pallas_slo_misses=0`, and the run must append a well-formed entry to
#     the versioned BENCH_serving.json HISTORY (step wall-clock p50/p95,
#     energy/request, accepted-SLO miss rate, trace counts, ref-vs-pallas
#     speedup).  The newest entry is diffed against the previous comparable
#     one (same scenario + backend) instead of only shape-checked.  No
#     speedup gate: on CPU the kernels run in interpret mode.
#   * sharded serving (bench_sharded_serving, forced host devices): warm
#     requests retired per fused step must scale >= 3x from 1 to 4 replicas
#     at `accepted_slo_misses=0`, `warm_added_traces=0`, and at most ONE
#     compile per (bucket, replica) pair;
#   * multitask residency: under N compressed task deployments that do not
#     co-fit in the SRAM working set, `affinity_beats_blind=1` (task-affinity
#     scheduling at lower energy/request than residency-blind EDF, swap
#     energy included) at zero accepted-SLO misses on both runs, with
#     `swaps_bounded=1` (affinity swaps each task in once) and the
#     step_traces<=bucket_count pair still holding;
#   * nvm power-on (bench_nvm_poweron): the Fig. 11 eNVM-vs-DRAM read
#     advantage must reproduce (latency advantage >= 10x), the task-swap
#     cost line must emit, and the run must append an `nvm_poweron` entry to
#     the BENCH_serving.json history.
#   * workload replay harness (benchmarks/harness, --smoke): 10^4 requests
#     of the bursty-MMPP x skewed-multi-task scenario driven through the
#     FULL admission -> residency -> schedule -> DVFS path on the modeled
#     clock, twice with the same seed.  Gates: `accepted_slo_misses=0` (the
#     admission contract holds under statistically-shaped open-loop load,
#     not just hand-tuned storms), `shed_bounded=1` (request conservation:
#     completed + rejected + shed == submitted), `requests>=10000`,
#     `max_traces_per_bucket_replica<=1` (zero new jit traces beyond one
#     compile per (bucket, replica)), `deterministic=1` (bit-identical
#     summary across same-seed replays), and a schema-valid
#     `workload_replay` entry appended to the BENCH_serving.json history.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -q
tier1=$?

echo "== bench_dvfs --smoke =="
python benchmarks/bench_dvfs.py --smoke
smoke=$?

echo "== bench_batched_dvfs --smoke =="
batched_log=$(mktemp)
python benchmarks/bench_batched_dvfs.py --smoke | tee "$batched_log"
batched=$?

echo "== bench_sharded_serving --smoke (1 vs 4 forced host devices) =="
sharded_log=$(mktemp)
python benchmarks/bench_sharded_serving.py --smoke | tee "$sharded_log"
sharded=$?

echo "== bench_nvm_poweron --smoke =="
nvm_log=$(mktemp)
python benchmarks/bench_nvm_poweron.py --smoke | tee "$nvm_log"
nvm=$?

echo "== workload replay harness --smoke (10^4 MMPP x multi-task, full path) =="
harness_log=$(mktemp)
python benchmarks/harness/run_harness.py --smoke | tee "$harness_log"
harness=$?

echo "== grep-gate: step_traces <= bucket_count (all scenarios) =="
gate=0
pairs=$(grep -o 'step_traces=[0-9]*;bucket_count=[0-9]*' "$batched_log")
if [ -z "$pairs" ]; then
    echo "GATE FAIL: no step_traces/bucket_count telemetry emitted"
    gate=1
else
    npairs=0
    while IFS= read -r pair; do
        npairs=$((npairs + 1))
        traces=${pair#step_traces=}; traces=${traces%%;*}
        count=${pair##*bucket_count=}
        if [ "$traces" -gt "$count" ]; then
            echo "GATE FAIL: fused step traced ${traces}x for ${count} buckets"
            gate=1
        else
            echo "gate ok: ${traces} traces / ${count} buckets"
        fi
    done <<< "$pairs"
    if [ "$npairs" -lt 5 ]; then
        echo "GATE FAIL: expected trace telemetry from the sequential, the"
        echo "           interleaved, the admission-storm, the"
        echo "           decode-early-exit AND the speculative-decode"
        echo "           scenario, got ${npairs} pair(s)"
        gate=1
    fi
fi

echo "== grep-gate: edf_deadline_misses == 0 =="
edf=$(grep -o 'edf_deadline_misses=[0-9]*' "$batched_log" | head -1)
if [ -z "$edf" ]; then
    echo "GATE FAIL: no edf_deadline_misses telemetry emitted (interleaved"
    echo "           EDF scenario missing from bench_batched_dvfs)"
    gate=1
else
    misses=${edf#edf_deadline_misses=}
    if [ "$misses" -gt 0 ]; then
        echo "GATE FAIL: ${misses} tight-SLO requests missed their deadline"
        echo "           under interleaved EDF stepping"
        gate=1
    else
        echo "gate ok: 0 EDF deadline misses"
    fi
fi
echo "== grep-gate: admission storm (accepted_slo_misses=0, rejected>0, best-effort alive) =="
storm=$(grep -o 'accepted_slo_misses=[0-9]*' "$batched_log" | head -1)
if [ -z "$storm" ]; then
    echo "GATE FAIL: no accepted_slo_misses telemetry emitted (admission"
    echo "           storm scenario missing from bench_batched_dvfs)"
    gate=1
else
    misses=${storm#accepted_slo_misses=}
    if [ "$misses" -gt 0 ]; then
        echo "GATE FAIL: ${misses} ADMITTED SLOs were missed — the feasibility"
        echo "           quote accepted contracts it could not honor"
        gate=1
    else
        echo "gate ok: 0 accepted-SLO misses"
    fi
fi
# anchor to the admission_storm line: the baseline line hardcodes rejected=0
rejected=$(grep '^admission_storm,' "$batched_log" | grep -o 'rejected=[0-9]*' | head -1)
rejected=${rejected#rejected=}
if [ -z "$rejected" ] || [ "$rejected" -eq 0 ]; then
    echo "GATE FAIL: the oversubscribed storm rejected nothing — infeasible"
    echo "           SLOs must be refused at submit time"
    gate=1
else
    echo "gate ok: ${rejected} infeasible SLOs rejected at admission"
fi
be=$(grep -o 'best_effort_completed=[0-9]*' "$batched_log" | head -1)
be=${be#best_effort_completed=}
if [ -z "$be" ] || [ "$be" -eq 0 ]; then
    echo "GATE FAIL: best-effort traffic starved to zero under the storm"
    gate=1
else
    echo "gate ok: ${be} best-effort completions under the storm"
fi
echo "== grep-gate: decode_early_exit (exit beats full depth, 0 accepted misses) =="
dee=$(grep '^decode_early_exit,' "$batched_log" | head -1)
if [ -z "$dee" ]; then
    echo "GATE FAIL: no decode_early_exit telemetry emitted (mixed"
    echo "           classifier+decoder storm missing from bench_batched_dvfs)"
    gate=1
else
    beats=$(echo "$dee" | grep -o 'exit_beats_full=[0-9]*'); beats=${beats#*=}
    if [ "$beats" != "1" ]; then
        echo "GATE FAIL: exit-enabled decode did not beat full-depth decode"
        echo "           on modeled energy under the mixed storm"
        gate=1
    else
        echo "gate ok: exit-enabled decode below full-depth energy"
    fi
    # key anchored on the leading ';' so it cannot match inside
    # 'full_accepted_slo_misses=' regardless of emit order
    dmiss=$(echo "$dee" | grep -o ';accepted_slo_misses=[0-9]*' | head -1)
    dmiss=${dmiss#*=}
    fmiss=$(echo "$dee" | grep -o 'full_accepted_slo_misses=[0-9]*')
    fmiss=${fmiss#*=}
    if [ -z "$dmiss" ] || [ "$dmiss" -gt 0 ] || [ -z "$fmiss" ] || [ "$fmiss" -gt 0 ]; then
        echo "GATE FAIL: decode storm missed accepted SLOs (exit=${dmiss:-?},"
        echo "           full=${fmiss:-?}) — the energy win must hold at equal"
        echo "           (zero) deadline-miss count"
        gate=1
    else
        echo "gate ok: 0 accepted-SLO misses on both decode runs"
    fi
fi
echo "== grep-gate: speculative_decode (parity, >=1.5x tokens/step, 0 misses) =="
sdl=$(grep '^speculative_decode,' "$batched_log" | head -1)
if [ -z "$sdl" ]; then
    echo "GATE FAIL: no speculative_decode telemetry emitted (self-speculative"
    echo "           decode scenario missing from bench_batched_dvfs)"
    gate=1
else
    spar=$(echo "$sdl" | grep -o 'spec_parity=[0-9]*'); spar=${spar#*=}
    if [ "$spar" != "1" ]; then
        echo "GATE FAIL: speculative decode tokens diverged from the per-token"
        echo "           EE baseline — accepted tokens must be bit-identical"
        gate=1
    else
        echo "gate ok: speculative decode bit-identical to per-token baseline"
    fi
    tpsr=$(echo "$sdl" | grep -o 'tps_ratio=[0-9.]*'); tpsr=${tpsr#*=}
    if [ -z "$tpsr" ] || ! awk -v r="$tpsr" 'BEGIN { exit !(r >= 1.5) }'; then
        echo "GATE FAIL: speculative decode reached only ${tpsr:-?}x the"
        echo "           per-token baseline's tokens/fused-step (want >= 1.5x)"
        gate=1
    else
        echo "gate ok: ${tpsr}x tokens/fused-step over the per-token baseline"
    fi
    # anchored on the leading ';' so it cannot match a prefixed key
    smiss=$(echo "$sdl" | grep -o ';accepted_slo_misses=[0-9]*' | head -1)
    smiss=${smiss#*=}
    if [ -z "$smiss" ] || [ "$smiss" -gt 0 ]; then
        echo "GATE FAIL: speculative storm missed ${smiss:-?} accepted SLOs —"
        echo "           the throughput win must hold at zero misses"
        gate=1
    else
        echo "gate ok: 0 accepted-SLO misses on both speculative-storm runs"
    fi
fi
echo "== grep-gate: pallas_serving_step (parity, 0 accepted misses) + BENCH_serving.json =="
psl=$(grep '^pallas_serving_step,' "$batched_log" | head -1)
if [ -z "$psl" ]; then
    echo "GATE FAIL: no pallas_serving_step telemetry emitted (ref-vs-pallas"
    echo "           serving scenario missing from bench_batched_dvfs)"
    gate=1
else
    for key in parity exit_parity; do
        val=$(echo "$psl" | grep -o ";${key}=[0-9]*" | head -1); val=${val#*=}
        if [ "$val" != "1" ]; then
            echo "GATE FAIL: pallas serving ${key}=${val:-?} — use_pallas=True"
            echo "           must be numerically interchangeable with ref"
            gate=1
        else
            echo "gate ok: pallas serving ${key}=1"
        fi
    done
    pmiss=$(echo "$psl" | grep -o 'pallas_slo_misses=[0-9]*'); pmiss=${pmiss#*=}
    if [ -z "$pmiss" ] || [ "$pmiss" -gt 0 ]; then
        echo "GATE FAIL: pallas serving drain missed ${pmiss:-?} accepted SLOs"
        gate=1
    else
        echo "gate ok: 0 accepted-SLO misses under use_pallas=True"
    fi
fi
echo "== grep-gate: multitask_residency (affinity beats blind EDF at 0 misses) =="
mtr=$(grep '^multitask_residency,' "$batched_log" | head -1)
if [ -z "$mtr" ]; then
    echo "GATE FAIL: no multitask_residency telemetry emitted (residency"
    echo "           scenario missing from bench_batched_dvfs)"
    gate=1
else
    beats=$(echo "$mtr" | grep -o 'affinity_beats_blind=[0-9]*'); beats=${beats#*=}
    if [ "$beats" != "1" ]; then
        echo "GATE FAIL: task-affinity scheduling did not beat residency-blind"
        echo "           EDF on energy/request under the multi-task storm"
        gate=1
    else
        echo "gate ok: affinity below blind-EDF energy/request"
    fi
    # anchored on the leading ';' so it cannot match a prefixed key
    rmiss=$(echo "$mtr" | grep -o ';accepted_slo_misses=[0-9]*' | head -1)
    rmiss=${rmiss#*=}
    if [ -z "$rmiss" ] || [ "$rmiss" -gt 0 ]; then
        echo "GATE FAIL: multitask residency storm missed ${rmiss:-?} accepted"
        echo "           SLOs — the energy win must hold at zero misses"
        gate=1
    else
        echo "gate ok: 0 accepted-SLO misses under both residency policies"
    fi
    sb=$(echo "$mtr" | grep -o 'swaps_bounded=[0-9]*'); sb=${sb#*=}
    if [ "$sb" != "1" ]; then
        echo "GATE FAIL: affinity-aware stepping swapped more than once per"
        echo "           task — residency batching is broken"
        gate=1
    else
        echo "gate ok: affinity task_swaps bounded by the task count"
    fi
fi
echo "== grep-gate: nvm_poweron (Fig. 11 advantage, task-swap cost) =="
nvl=$(grep '^fig11_paper_size,' "$nvm_log" | head -1)
if [ -z "$nvl" ]; then
    echo "GATE FAIL: no fig11_paper_size telemetry emitted by bench_nvm_poweron"
    gate=1
else
    ladv=$(echo "$nvl" | grep -o 'latency_advantage=[0-9]*' | head -1); ladv=${ladv#*=}
    if [ -z "$ladv" ] || [ "$ladv" -lt 10 ]; then
        echo "GATE FAIL: eNVM power-on latency advantage ${ladv:-?}x < 10x"
        echo "           (paper Fig. 11 reports ~50x)"
        gate=1
    else
        echo "gate ok: ${ladv}x eNVM power-on latency advantage"
    fi
fi
if ! grep -q '^nvm_task_swap,' "$nvm_log"; then
    echo "GATE FAIL: no nvm_task_swap telemetry (per-task swap cost missing)"
    gate=1
else
    echo "gate ok: per-task eNVM swap cost emitted"
fi
echo "== grep-gate: sharded_serving (scaling >= 3x, 0 misses, warm traces) =="
shl=$(grep '^sharded_serving,' "$sharded_log" | head -1)
if [ -z "$shl" ]; then
    echo "GATE FAIL: no sharded_serving telemetry emitted (multi-device"
    echo "           scaling scenario missing from bench_sharded_serving)"
    gate=1
else
    scal=$(echo "$shl" | grep -o 'scaling=[0-9.]*'); scal=${scal#*=}
    if [ -z "$scal" ] || ! awk -v s="$scal" 'BEGIN { exit !(s >= 3.0) }'; then
        echo "GATE FAIL: warm requests/step scaled only ${scal:-?}x from 1 to"
        echo "           4 replicas (want >= 3.0x near-linear scaling)"
        gate=1
    else
        echo "gate ok: ${scal}x step-throughput scaling 1 -> 4 replicas"
    fi
    smiss=$(echo "$shl" | grep -o 'accepted_slo_misses=[0-9]*'); smiss=${smiss#*=}
    if [ -z "$smiss" ] || [ "$smiss" -gt 0 ]; then
        echo "GATE FAIL: ${smiss:-?} accepted SLOs missed across sharded drains"
        gate=1
    else
        echo "gate ok: 0 accepted-SLO misses under replica-routed admission"
    fi
    wtr=$(echo "$shl" | grep -o 'warm_added_traces=[0-9]*'); wtr=${wtr#*=}
    mtr=$(echo "$shl" | grep -o 'max_traces_per_bucket_replica=[0-9]*'); mtr=${mtr#*=}
    if [ -z "$wtr" ] || [ "$wtr" -gt 0 ] || [ -z "$mtr" ] || [ "$mtr" -gt 1 ]; then
        echo "GATE FAIL: sharded fused step recompiled (warm_added=${wtr:-?},"
        echo "           max per (bucket, replica)=${mtr:-?})"
        gate=1
    else
        echo "gate ok: one compile per (bucket, replica), zero warm traces"
    fi
fi
echo "== grep-gate: workload_replay (contract, conservation, traces, determinism) =="
wrl=$(grep '^workload_replay,' "$harness_log" | head -1)
if [ -z "$wrl" ]; then
    echo "GATE FAIL: no workload_replay telemetry emitted (harness smoke run"
    echo "           produced no summary row)"
    gate=1
else
    wreq=$(echo "$wrl" | grep -o 'requests=[0-9]*' | head -1); wreq=${wreq#*=}
    if [ -z "$wreq" ] || [ "$wreq" -lt 10000 ]; then
        echo "GATE FAIL: harness smoke replayed only ${wreq:-?} requests"
        echo "           (the CI configuration is >= 10^4)"
        gate=1
    else
        echo "gate ok: ${wreq} requests replayed through the full path"
    fi
    wmiss=$(echo "$wrl" | grep -o ';accepted_slo_misses=[0-9]*' | head -1)
    wmiss=${wmiss#*=}
    if [ -z "$wmiss" ] || [ "$wmiss" -gt 0 ]; then
        echo "GATE FAIL: ${wmiss:-?} ADMITTED SLOs missed under shaped MMPP"
        echo "           multi-task load — the admission contract must hold"
        echo "           under open-loop traffic, not just hand-tuned storms"
        gate=1
    else
        echo "gate ok: 0 accepted-SLO misses under shaped open-loop load"
    fi
    wshed=$(echo "$wrl" | grep -o 'shed_bounded=[0-9]*'); wshed=${wshed#*=}
    if [ "$wshed" != "1" ]; then
        echo "GATE FAIL: request conservation broken (completed + rejected +"
        echo "           shed != submitted, or shed exploded)"
        gate=1
    else
        echo "gate ok: request conservation holds (shed bounded)"
    fi
    wtrc=$(echo "$wrl" | grep -o 'max_traces_per_bucket_replica=[0-9]*')
    wtrc=${wtrc#*=}
    if [ -z "$wtrc" ] || [ "$wtrc" -gt 1 ]; then
        echo "GATE FAIL: replay recompiled inside a bucket (max traces per"
        echo "           (bucket, replica) = ${wtrc:-?}, want <= 1)"
        gate=1
    else
        echo "gate ok: one compile per (bucket, replica) across the replay"
    fi
    wdet=$(echo "$wrl" | grep -o 'deterministic=[0-9]*'); wdet=${wdet#*=}
    if [ "$wdet" != "1" ]; then
        echo "GATE FAIL: same-seed replays diverged (deterministic=${wdet:-?})"
        gate=1
    else
        echo "gate ok: bit-identical summary across same-seed replays"
    fi
fi
if python - <<'EOF'
import json, sys
try:
    with open("BENCH_serving.json") as f:
        b = json.load(f)
except Exception as e:
    print(f"GATE FAIL: BENCH_serving.json unreadable: {e}")
    sys.exit(1)
if b.get("version", 0) < 2 or not isinstance(b.get("history"), list) or not b["history"]:
    print("GATE FAIL: BENCH_serving.json is not a v2 bounded-history artifact")
    sys.exit(1)
hist = b["history"]
pallas = [e for e in hist if e.get("scenario") == "pallas_serving"]
if not pallas:
    print("GATE FAIL: no pallas_serving entry in BENCH_serving.json history")
    sys.exit(1)
cur = pallas[-1]
need = {"scenario", "backend", "device_count", "tag", "ref", "pallas",
        "speedup_ref_over_pallas_p50", "logit_parity", "exit_depth_parity"}
missing = need - cur.keys()
if missing:
    print(f"GATE FAIL: newest pallas_serving entry missing {sorted(missing)}")
    sys.exit(1)
sk = {"step_wall_p50_ms", "step_wall_p95_ms", "energy_per_request_j",
      "accepted_slo_miss_rate", "step_traces"}
for side in ("ref", "pallas"):
    if sk - cur[side].keys():
        print(f"GATE FAIL: newest entry {side} missing {sorted(sk - cur[side].keys())}")
        sys.exit(1)
spec = [e for e in hist if e.get("scenario") == "speculative_decode"]
if not spec:
    print("GATE FAIL: no speculative_decode entry in BENCH_serving.json history")
    sys.exit(1)
sd = spec[-1]
sdneed = {"scenario", "backend", "device_count", "tag", "spec_window",
          "tokens_per_fused_step", "baseline_tokens_per_step",
          "tokens_per_step_ratio", "avg_accepted_block", "spec_parity",
          "accepted_slo_misses", "energy_per_token_j",
          "baseline_energy_per_token_j", "step_traces", "bucket_count"}
sdmissing = sdneed - sd.keys()
if sdmissing:
    print(f"GATE FAIL: newest speculative_decode entry missing {sorted(sdmissing)}")
    sys.exit(1)
if not sd["spec_parity"] or sd["accepted_slo_misses"]:
    print(f"GATE FAIL: speculative_decode entry regressed (parity="
          f"{sd['spec_parity']}, misses={sd['accepted_slo_misses']})")
    sys.exit(1)
print(f"gate ok: speculative_decode entry "
      f"({sd['tokens_per_fused_step']:.2f} tokens/step, "
      f"{sd['tokens_per_step_ratio']:.2f}x baseline, W={sd['spec_window']})")
if not any(e.get("scenario") == "sharded_serving" for e in hist):
    print("GATE FAIL: no sharded_serving entry in BENCH_serving.json history")
    sys.exit(1)
if not any(e.get("scenario") == "nvm_poweron" for e in hist):
    print("GATE FAIL: no nvm_poweron entry in BENCH_serving.json history")
    sys.exit(1)
replay = [e for e in hist if e.get("scenario") == "workload_replay"]
if not replay:
    print("GATE FAIL: no workload_replay entry in BENCH_serving.json history"
          " (harness smoke run did not append)")
    sys.exit(1)
wr = replay[-1]
wneed = {"scenario", "backend", "device_count", "tag", "workload", "seed",
         "requests", "completed", "accepted_slo_misses",
         "accepted_slo_miss_rate", "throughput_rps", "energy_per_request_j",
         "queue_delay_steps_p50", "queue_delay_steps_p95",
         "queue_delay_steps_p99", "max_traces_per_bucket_replica",
         "peak_outstanding", "deterministic", "per_tier", "per_task"}
wmissing = wneed - wr.keys()
if wmissing:
    print(f"GATE FAIL: newest workload_replay entry missing {sorted(wmissing)}")
    sys.exit(1)
print(f"gate ok: workload_replay entry ({wr['workload']}, "
      f"{wr['requests']} requests, tag {wr['tag']}, "
      f"deterministic={wr['deterministic']})")
print(f"gate ok: BENCH_serving.json v{b['version']} history "
      f"({len(hist)} entries, newest pallas_serving tag {cur['tag']}, "
      f"speedup {cur['speedup_ref_over_pallas_p50']:.2f}x)")
# diff newest vs previous comparable entry (same scenario + backend): trend
# telemetry, plus a hard brake on parity regressions slipping through
prev = [e for e in pallas[:-1] if e.get("backend") == cur["backend"]]
if not prev:
    print("diff: no previous comparable pallas_serving entry (first run)")
    sys.exit(0)
old = prev[-1]
for side in ("ref", "pallas"):
    for k in ("step_wall_p50_ms", "energy_per_request_j"):
        a, c = old[side][k], cur[side][k]
        rel = (c - a) / a if a else 0.0
        print(f"diff {side}.{k}: {a:.4g} -> {c:.4g} ({rel:+.1%})")
for k in ("logit_parity", "exit_depth_parity"):
    if old.get(k) and not cur.get(k):
        print(f"GATE FAIL: {k} regressed from previous comparable run")
        sys.exit(1)
EOF
then :; else gate=1; fi
rm -f "$batched_log" "$sharded_log" "$nvm_log" "$harness_log"

echo "== summary: tier1=$tier1 smoke=$smoke batched=$batched sharded=$sharded nvm=$nvm harness=$harness gate=$gate =="
exit $(( tier1 || smoke || batched || sharded || nvm || harness || gate ))
