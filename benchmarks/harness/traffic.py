"""Shared request-queue builders for the serving benchmarks.

This is the storm boilerplate the per-scenario benchmarks each hand-rolled;
``bench_batched_dvfs`` (and anything new) imports it from here so every
benchmark shapes its queues identically."""
from __future__ import annotations

import numpy as np

from repro.serving.engine import Request


def mixed_queue(data, buckets, n_queue: int, seed: int = 0):
    """Requests with lengths spread across (and inside) the buckets —
    round-robin over buckets, uniform length inside each, tokens drawn from
    the dataset so the content distribution matches training."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_queue):
        b = data.batch(200 + i // data.global_batch)
        toks = b["tokens"][i % data.global_batch]
        bucket = buckets[i % len(buckets)]
        length = int(rng.integers(max(4, bucket // 2 + 1), bucket + 1))
        reqs.append(Request(uid=i, tokens=np.asarray(toks[:length], np.int32)))
    return reqs
