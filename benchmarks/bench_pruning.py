"""Paper Fig. 5: movement vs magnitude pruning across sparsity levels —
accuracy after identical fine-tuning budgets on the toy classification task.
(Paper finding: movement wins in the high-sparsity regime, >= 70%.)"""
from __future__ import annotations

from benchmarks.common import emit, eval_accuracy, trained_albert


def main() -> None:
    for sparsity in (0.5, 0.7, 0.9):
        for method in ("magnitude", "movement"):
            model, params, st, data, cfg = trained_albert(
                phase1_steps=60, phase2_steps=0, sparsity=sparsity, method=method,
                span_coef=0.0,
            )
            acc = eval_accuracy(model, params, data)
            from repro.core.pruning import measured_sparsity

            ms = measured_sparsity(params, st)["sparsity"]
            emit(
                f"fig5_{method}_s{int(sparsity*100)}", 0.0,
                f"target={sparsity};achieved={ms:.2f};acc={acc:.3f}",
            )
            trained_albert.cache_clear()  # each point trains fresh


if __name__ == "__main__":
    main()
