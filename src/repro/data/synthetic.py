"""Deterministic synthetic data pipelines (the substrate EdgeBERT fine-tunes on;
GLUE corpora are not available offline, so tasks are *planted-structure*
synthetics that are actually learnable — loss decrease and early-exit /
span / pruning behaviour are all measurable on them).

* SyntheticLM  — Zipf-distributed tokens + induction patterns (``A B ... A B``)
  so a real LM can beat the unigram entropy floor.
* SyntheticCLS — sentence classification: class c plants tokens from a
  class-specific vocabulary band at random positions; CLS token at position 0.
  Difficulty is tunable via ``signal_ratio`` (fraction of planted positions):
  easy sentences exit early, hard ones late — giving the entropy-threshold
  sweep (Fig. 4) real spread.

Both are: deterministic in (seed, step) — restart-exact for fault tolerance —
and host-shardable: ``shard=(host_index, host_count)`` slices the global batch,
matching a multi-host data-parallel launch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard: Tuple[int, int] = (0, 1)
    zipf_a: float = 1.2
    induction_period: int = 64

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        host, n_hosts = self.shard
        assert self.global_batch % n_hosts == 0
        local = self.global_batch // n_hosts
        rng = np.random.default_rng((self.seed, step, host))
        # zipf body (clipped to vocab)
        toks = rng.zipf(self.zipf_a, size=(local, self.seq_len)).astype(np.int64)
        toks = np.minimum(toks, self.vocab_size - 1)
        # plant induction: repeat the first half-period later in the sequence
        p = self.induction_period
        if self.seq_len >= 2 * p:
            n_rep = self.seq_len // (2 * p)
            for i in range(n_rep):
                src = slice(2 * p * i, 2 * p * i + p)
                dst = slice(2 * p * i + p, 2 * p * (i + 1))
                toks[:, dst] = toks[:, src]
        return {"tokens": toks.astype(np.int32)}


@dataclass
class SyntheticCLS:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_classes: int = 3
    seed: int = 0
    shard: Tuple[int, int] = (0, 1)
    signal_ratio_range: Tuple[float, float] = (0.05, 0.4)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        host, n_hosts = self.shard
        local = self.global_batch // n_hosts
        rng = np.random.default_rng((self.seed + 1, step, host))
        labels = rng.integers(0, self.num_classes, size=(local,))
        toks = rng.integers(4, self.vocab_size, size=(local, self.seq_len))
        # class-c signal band: tokens in [band_c, band_c + band) — planted at a
        # per-sentence signal ratio (easy/hard spread for early exit)
        band = max((self.vocab_size - 4) // (4 * self.num_classes), 2)
        ratios = rng.uniform(*self.signal_ratio_range, size=(local,))
        for i in range(local):
            n_sig = max(int(self.seq_len * ratios[i]), 1)
            pos = rng.choice(np.arange(1, self.seq_len), size=n_sig, replace=False)
            base = 4 + int(labels[i]) * band
            toks[i, pos] = rng.integers(base, base + band, size=n_sig)
        toks[:, 0] = 1  # CLS
        return {
            "tokens": toks.astype(np.int32),
            "labels": labels.astype(np.int32),
            "signal_ratio": ratios.astype(np.float32),
        }


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.int32):
    """ShapeDtypeStruct stand-ins for one global batch (the dry-run inputs)."""
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.num_classes:
            specs["labels"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        if cfg.family == "encdec":
            specs["enc_input"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq_len, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        if cfg.family == "vlm":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
            )
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.family == "encdec":
            specs["enc_input"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq_len, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        if cfg.family == "vlm":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
            )
    else:  # decode: one new token, cache of length S supplied separately
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return specs
