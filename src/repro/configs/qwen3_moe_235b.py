"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) expert d_ff=1536
vocab=151936, MoE 128 experts top-8, no shared expert. [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,               # = moe_d_ff (per-expert)
    moe_d_ff=1536,
    n_experts=128,
    top_k=8,
    vocab_size=151936,
    act="swiglu",
    norm="rms",
    pos="rope",
    rope_theta=1000000.0,
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        name="qwen3-moe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=64,
        moe_d_ff=64,
        n_experts=8,
        top_k=2,
        vocab_size=512,
        max_seq_len=256,
    )
