"""Knowledge distillation loss (paper Fig. 6 phase 1: the base task-finetuned
ALBERT acts as teacher while pruning/span-learning the student)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kd_loss(student_logits: jnp.ndarray, teacher_logits: jnp.ndarray, temperature: float = 2.0):
    """KL(teacher || student) with temperature scaling, mean over batch."""
    t = temperature
    sp = jax.nn.log_softmax(student_logits.astype(jnp.float32) / t, axis=-1)
    tp = jax.nn.softmax(teacher_logits.astype(jnp.float32) / t, axis=-1)
    kl = jnp.sum(tp * (jnp.log(jnp.maximum(tp, 1e-20)) - sp), axis=-1)
    return (t * t) * jnp.mean(kl)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray):
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def distill_objective(student_logits, teacher_logits, labels, alpha: float, temperature: float = 2.0):
    """(1-alpha)*CE + alpha*KD — the phase-1 fine-tuning objective."""
    ce = cross_entropy(student_logits, labels)
    if alpha <= 0:
        return ce
    kd = kd_loss(student_logits, teacher_logits, temperature)
    return (1.0 - alpha) * ce + alpha * kd
