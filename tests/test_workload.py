"""Trace-driven workload harness: seeded arrival-process statistics,
generator determinism and mix proportions, JSONL round-trips, bounded-memory
replay at 10^5 requests, full-path replay determinism with zero extra jit
traces, and the benchmark-history schema/diff machinery."""
import dataclasses
import json
import os
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.model import build_model
from repro.serving.admission import AdmissionController
from repro.serving.engine import ClassifierServer, Request
from repro.serving.scheduler import LaneScheduler
from repro.serving.workload import (
    AdmissionServerTarget,
    DiurnalArrivals,
    MMPPArrivals,
    PoissonArrivals,
    TierSpec,
    TraceReplayer,
    WorkloadConfig,
    generate_trace,
    load_trace,
    save_trace,
    summaries_identical,
)

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def _take(proc, n, seed):
    it = proc.times(np.random.default_rng(seed))
    return np.array([t for _, t in zip(range(n), it)])


class TestArrivalProcesses:
    def test_poisson_determinism_and_rate(self):
        a = _take(PoissonArrivals(100.0), 20_000, seed=1)
        b = _take(PoissonArrivals(100.0), 20_000, seed=1)
        assert np.array_equal(a, b)                      # seeded => identical
        assert not np.array_equal(a, _take(PoissonArrivals(100.0), 20_000, 2))
        assert np.all(np.diff(a) > 0)                    # strictly increasing
        rate = len(a) / a[-1]
        assert abs(rate - 100.0) / 100.0 < 0.05          # empirical ~ configured

    def test_mmpp_determinism_rate_and_burstiness(self):
        proc = MMPPArrivals((50.0, 500.0), (2.0, 0.5))
        a = _take(proc, 30_000, seed=2)
        assert np.array_equal(a, _take(proc, 30_000, seed=2))
        assert np.all(np.diff(a) > 0)
        rate = len(a) / a[-1]
        expect = proc.long_run_rate_hz                   # 140 Hz here
        assert abs(rate - expect) / expect < 0.15
        # the point of MMPP: burstier than Poisson.  Squared coefficient of
        # variation of inter-arrival gaps is 1 for Poisson, >> 1 here.
        gaps = np.diff(a)
        cv2 = float(np.var(gaps) / np.mean(gaps) ** 2)
        assert cv2 > 1.5

    def test_diurnal_determinism_rate_and_modulation(self):
        proc = DiurnalArrivals(100.0, period_s=10.0, depth=0.6)
        a = _take(proc, 40_000, seed=3)
        assert np.array_equal(a, _take(proc, 40_000, seed=3))
        assert np.all(np.diff(a) > 0)
        # over whole periods the mean rate is the base rate
        whole = a[a < 10.0 * int(a[-1] / 10.0)]
        rate = len(whole) / whole[-1]
        assert abs(rate - 100.0) / 100.0 < 0.05
        # and the envelope actually modulates: with phase 0 the first half of
        # each period (sin > 0) must be visibly denser than the second half
        phase = np.mod(whole, 10.0)
        first, second = np.sum(phase < 5.0), np.sum(phase >= 5.0)
        assert first / second > 1.3


def _mixed_config(seed=7):
    return WorkloadConfig(
        arrivals=PoissonArrivals(200.0),
        lengths=((16, 0.7), (32, 0.3)),
        tiers=(TierSpec("explicit", 0.35, 80.0), TierSpec("best_effort", 0.65)),
        tasks=(("mnli", 0.48), ("qqp", 0.24), ("sst2", 0.16), ("qnli", 0.12)),
        seed=seed,
    )


class TestTraceGeneration:
    def test_seeded_determinism_and_seed_sensitivity(self):
        svc = lambda L: 0.001 * L
        a = list(generate_trace(_mixed_config(7), 2000, service_s=svc))
        b = list(generate_trace(_mixed_config(7), 2000, service_s=svc))
        assert all(vars(x) == vars(y) for x, y in zip(a, b))
        c = list(generate_trace(_mixed_config(8), 2000, service_s=svc))
        assert any(vars(x) != vars(y) for x, y in zip(a, c))

    def test_mix_proportions_and_deadline_pricing(self):
        svc = lambda L: 0.001 * L
        evs = list(generate_trace(_mixed_config(), 20_000, service_s=svc))
        n = len(evs)
        tiers = {t: sum(1 for e in evs if e.tier == t) / n
                 for t in ("explicit", "best_effort")}
        assert abs(tiers["explicit"] - 0.35) < 0.02
        assert abs(tiers["best_effort"] - 0.65) < 0.02
        tasks = {t: sum(1 for e in evs if e.task == t) / n
                 for t, _ in _mixed_config().tasks}
        for (t, w) in _mixed_config().tasks:
            assert abs(tasks[t] - w) < 0.02, (t, tasks[t], w)
        for e in evs[:500]:
            bucket = 16 if e.length <= 16 else 32
            assert max(4, bucket // 2 + 1) <= e.length <= bucket
            if e.tier == "explicit":                 # slo_mult x own service
                assert e.deadline_s == pytest.approx(80.0 * 0.001 * e.length)
            else:
                assert e.deadline_s is None

    def test_jsonl_roundtrip(self, tmp_path):
        svc = lambda L: 0.001 * L
        evs = list(generate_trace(_mixed_config(), 500, service_s=svc))
        path = str(tmp_path / "trace.jsonl")
        assert save_trace(path, evs) == 500
        back = list(load_trace(path))
        assert len(back) == 500
        assert all(vars(a) == vars(b) for a, b in zip(evs, back))


class _NullEngine:
    """Host-only engine: every request retires after one fused step, so the
    replayer can churn 10^5 requests in seconds (clock: 1.0 s per step)."""

    def bucket_key(self, req):
        return len(req.tokens)

    def bucket_begin(self, bucket):
        pass

    def lane_load(self, bucket, lane, req):
        pass

    def lanes_step(self, bucket, active):
        return None

    def lane_advance(self, bucket, lane, req, out, depth):
        return True

    def lane_finish(self, bucket, lane, req, depth):
        pass

    def bucket_end(self, bucket):
        pass


class TestBoundedMemoryReplay:
    def test_hundred_thousand_requests_stay_bounded(self):
        """10^5 requests through the replay loop: retained state must be
        O(outstanding) — the done map high-water mark is ~zero (poll every
        step), outstanding is bounded by the queueing regime, and the delay
        reservoirs never exceed their cap."""
        total = 100_000
        lanes = 4
        # lanes/step capacity at 1 s/step vs 3 req/s offered: stable queue
        cfg = WorkloadConfig(
            arrivals=PoissonArrivals(3.0),
            lengths=((8, 1.0),),
            tiers=(TierSpec("explicit", 0.3, 40.0), TierSpec("best_effort", 0.7)),
            seed=11,
        )
        sched = LaneScheduler(lanes, _NullEngine(), buckets=(8,))
        target = AdmissionServerTarget(sched)
        rep = TraceReplayer(target, vocab_size=64, token_seed=0)
        s = rep.replay(generate_trace(cfg, total, service_s=lambda L: 1.0))
        assert s["requests"] == total
        assert s["submitted"] == total
        assert s["completed"] == total                   # no admission: all run
        assert s["completed"] + s["rejected"] + s["shed"] == total
        # boundedness: nothing retained scales with the trace length
        assert s["peak_done"] <= lanes                   # polled every step
        assert s["peak_outstanding"] < total // 100
        assert len(sched.done) == 0
        assert len(sched._delays.buf) <= sched._delays.cap
        # the summary's reservoirs are bounded too (internal to the replayer,
        # asserted via the percentiles being finite and ordered)
        assert (
            s["queue_delay_steps_p99"]
            >= s["queue_delay_steps_p95"]
            >= s["queue_delay_steps_p50"]
            >= 0.0
        )
        assert s["modeled_span_s"] > 0.0
        assert s["per_tier"]["explicit"]["completed"] > 0
        assert s["per_tier"]["best_effort"]["completed"] > 0

    def test_replay_is_deterministic_on_stub(self):
        cfg = WorkloadConfig(
            arrivals=MMPPArrivals((1.0, 10.0), (30.0, 6.0)),
            lengths=((8, 1.0),),
            tiers=(TierSpec("best_effort", 1.0),),
            seed=5,
        )

        def run():
            sched = LaneScheduler(4, _NullEngine(), buckets=(8,))
            rep = TraceReplayer(AdmissionServerTarget(sched), vocab_size=64)
            return rep.replay(generate_trace(cfg, 20_000))

        assert summaries_identical(run(), run())


class TestFullPathReplay:
    """Real jitted model through admission + scheduler + DVFS arbiter."""

    @pytest.fixture(scope="class")
    def stack(self):
        from repro.hwmodel.edgebert_accel import albert_layer_stats
        from repro.serving.dvfs import (
            LatencyAwareDVFSController,
            no_early_exit_baseline,
        )

        cfg = dataclasses.replace(
            get_smoke_config("albert_edgebert"), dtype="float32",
            remat_policy="none",
        )
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        buckets = (16, 32)
        stats = albert_layer_stats(seq_len=max(buckets))
        stats.n_layers = cfg.n_layers
        target = no_early_exit_baseline(stats)["latency_s"] * 1.5

        def ctrl_factory():
            return LatencyAwareDVFSController(stats, target)

        return model, params, cfg, buckets, ctrl_factory

    def _run(self, stack, n=300, seed=0):
        from repro.serving.dvfs import BatchedDVFSArbiter

        model, params, cfg, buckets, ctrl_factory = stack
        ctrl = ctrl_factory()
        svc = lambda L: cfg.n_layers * ctrl.cycles_for_seq_len(
            16 if L <= 16 else 32
        ) / ctrl.max_op.freq_hz
        wl = WorkloadConfig(
            arrivals=MMPPArrivals(
                (0.35 * 4 / svc(32), 1.5 * 4 / svc(32)), (0.08, 0.02)
            ),
            lengths=((16, 0.6), (32, 0.4)),
            tiers=(TierSpec("explicit", 0.4, 80.0), TierSpec("best_effort", 0.6)),
            seed=seed,
        )
        server = ClassifierServer(
            model, params, batch_lanes=4,
            arbiter=BatchedDVFSArbiter(ctrl_factory()), buckets=buckets,
        )
        target = AdmissionServerTarget(
            server, AdmissionController(server, max_best_effort_queue=16)
        )
        rep = TraceReplayer(target, vocab_size=cfg.vocab_size, token_seed=seed)
        return rep.replay(generate_trace(wl, n, service_s=svc))

    def test_zero_extra_traces_bit_identical_and_conserved(self, stack):
        s1 = self._run(stack)
        # zero new traces beyond one compile per (bucket, replica) — the
        # fixed-shape invariant must survive trace-driven traffic
        assert s1["max_traces_per_bucket_replica"] == 1
        assert s1["step_traces"] == len(stack[3])
        # request conservation: every submission is completed, rejected at
        # admission, or shed from the bounded best-effort queue
        assert s1["completed"] + s1["rejected"] + s1["shed"] == s1["submitted"]
        assert s1["submitted"] == s1["requests"]
        # the admission contract holds under bursty trace-driven load
        assert s1["accepted_slo_misses"] == 0
        assert s1["completed_best_effort"] > 0
        assert s1["energy_j"] > 0.0
        # same seed, fresh stack => bit-identical structured summary
        s2 = self._run(stack)
        assert summaries_identical(s1, s2)
        # different seed => different trace => different summary
        s3 = self._run(stack, seed=1)
        assert not summaries_identical(s1, s3)


class TestBenchHistoryValidation:
    def test_malformed_entry_fails_loudly(self, tmp_path):
        from benchmarks.common import append_bench_history, validate_bench_entry

        path = str(tmp_path / "BENCH.json")
        with pytest.raises(ValueError, match="missing required keys"):
            append_bench_history(path, {"scenario": "x", "tag": "t"})
        assert not os.path.exists(path)              # nothing written
        with pytest.raises(ValueError):
            validate_bench_entry({"scenario": "", "backend": "cpu",
                                  "device_count": 1, "tag": "t"})
        with pytest.raises(ValueError, match="not JSON-serializable"):
            validate_bench_entry({"scenario": "x", "backend": "cpu",
                                  "device_count": 1, "tag": "t",
                                  "bad": object()})

    def test_appends_diff_against_previous_same_scenario(self, tmp_path, capsys):
        from benchmarks.common import append_bench_history

        path = str(tmp_path / "BENCH.json")
        base = {"scenario": "workload_replay", "backend": "cpu",
                "device_count": 1, "tag": "aaa", "throughput_rps": 100.0,
                "accepted_slo_misses": 0}
        append_bench_history(path, dict(base))
        append_bench_history(path, {"scenario": "other", "backend": "cpu",
                                    "device_count": 1, "tag": "aab"})
        newer = dict(base, tag="bbb", throughput_rps=110.0)
        append_bench_history(path, newer)
        out = capsys.readouterr().out
        # the diff is against the previous entry of the SAME scenario,
        # skipping the unrelated one in between
        assert "aaa -> bbb" in out
        assert "throughput_rps: 100 -> 110" in out
        payload = json.loads(open(path).read())
        assert payload["version"] == 2
        assert [e["tag"] for e in payload["history"]] == ["aaa", "aab", "bbb"]

    def test_history_stays_bounded(self, tmp_path):
        from benchmarks.common import append_bench_history

        path = str(tmp_path / "BENCH.json")
        for i in range(30):
            append_bench_history(
                path,
                {"scenario": "s", "backend": "cpu", "device_count": 1,
                 "tag": f"t{i}"},
                limit=10,
            )
        payload = json.loads(open(path).read())
        assert len(payload["history"]) == 10
        assert payload["history"][-1]["tag"] == "t29"
