"""jit'd dispatch wrappers around the Pallas kernels.

On CPU (this container) kernels run in interpret mode — the kernel body
executes in Python for correctness validation; on TPU the same code emits
Mosaic.  `span_attention_op` implements the full EdgeBERT deploy path: dead
heads (span 0) are gathered out of the graph, survivors run the windowed
kernel bucketed by span.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptivfloat import AFFormat
from repro.core.adaptive_span import active_head_indices
from repro.kernels import adaptivfloat_k, block_sparse, layernorm, softmax_entropy, span_attention


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("eps",))
def layernorm_op(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float = 1e-6):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = layernorm.layernorm(x2, gamma, beta, eps=eps, interpret=_interpret())
    return out.reshape(shape)


@jax.jit
def softmax_entropy_op(logits: jnp.ndarray, mask: Optional[jnp.ndarray] = None):
    """Fused softmax + entropy over the last axis.

    Mask semantics (audited, see tests/test_kernels.py): the kernel computes
    the entropy of the FULL softmax distribution and applies `mask` only to
    the returned probs — it does NOT renormalize over unmasked entries.
    `mask=None` therefore means "no positions are padding", which is exactly
    the serving off-ramp case: the engines call this on [lanes, C] class
    logits where every class column is real (lane padding is masked upstream
    in attention via per-lane kv_len, so padded positions never reach the
    off-ramp logits).  Callers with genuinely padded logit columns must mask
    or slice BEFORE the softmax; passing `mask` here only zeroes probs.
    """
    shape = logits.shape
    x2 = logits.reshape(-1, shape[-1])
    if mask is None:
        mask = jnp.ones_like(x2)
    else:
        assert mask.shape == logits.shape, (
            f"mask shape {mask.shape} must match logits shape {logits.shape}"
        )
        mask = mask.reshape(-1, shape[-1])
    p, h = softmax_entropy.softmax_entropy(x2, mask, interpret=_interpret())
    return p.reshape(shape), h.reshape(shape[:-1])


@functools.partial(jax.jit, static_argnames=("n_bits", "n_exp"))
def af_quantize_op(x: jnp.ndarray, n_bits: int = 8, n_exp: int = 3):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    out = adaptivfloat_k.quantize(x2, fmt=AFFormat(n_bits, n_exp), interpret=_interpret())
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("n_bits", "n_exp"))
def af_matmul_op(x: jnp.ndarray, w_codes: jnp.ndarray, e_min: jnp.ndarray,
                 n_bits: int = 8, n_exp: int = 3):
    return adaptivfloat_k.af_matmul(
        x, w_codes, e_min, fmt=AFFormat(n_bits, n_exp), interpret=_interpret()
    )


def block_sparse_matmul_op(x, w, block_mask, bk: int = 128, bn: int = 128):
    """block_mask must be a STATIC numpy occupancy array (deploy-time masks)."""
    return block_sparse.block_sparse_matmul(
        x, w, np.asarray(block_mask), bk=bk, bn=bn, interpret=_interpret()
    )


def span_attention_op(
    q: jnp.ndarray,            # [B, S, H, dh]
    k: jnp.ndarray,            # [B, S, KV, dh]
    v: jnp.ndarray,            # [B, S, KV, dh]
    spans,                     # per-head integer spans (len H; 0 = off) —
                               # static sequence OR a traced int array
    *,
    causal: bool,
    bq: int = 128,
    bk: int = 128,
) -> jnp.ndarray:
    """EdgeBERT deployed attention: dead heads skipped, survivors windowed.

    Returns [B, S, H, dh] with zero context vectors for span-0 heads (the
    accelerator writes zeros to the UAB for those heads, §V-D1).

    With STATIC spans, dead heads are gathered out host-side and the kernel
    window shrinks to the max surviving span (the deploy fast path).  With
    TRACED spans (called under jit with spans as an operand) no host-side
    numpy indexing is possible: all heads run with a full static window and
    the exact spans ride in via scalar prefetch — span-0 heads come back as
    zero rows from the kernel itself, so semantics match the gather path.
    """
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV

    if isinstance(spans, jax.core.Tracer):
        qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
        kh = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
        vh = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
        sp = jnp.tile(spans.astype(jnp.int32), B)
        Sk = k.shape[1]
        out = span_attention.span_attention(
            qh,
            kh.reshape(B * H, Sk, dh),
            vh.reshape(B * H, Sk, dh),
            sp,
            Sk,                      # window covers any span; exact spans
            causal=causal,           # still mask element-wise in the kernel
            bq=bq,
            bk=bk,
            interpret=_interpret(),
        ).reshape(B, H, Sq, dh)
        return out.transpose(0, 2, 1, 3)

    spans_np = np.asarray(spans, np.int32)
    active, window = active_head_indices(spans_np)
    if len(active) == 0:
        return jnp.zeros_like(q)

    # gather active heads; expand K/V per head (XLA fuses the gather)
    qh = q.transpose(0, 2, 1, 3)[:, active]                   # [B, Ha, S, dh]
    kv_idx = (active // G).astype(np.int32)
    kh = k.transpose(0, 2, 1, 3)[:, kv_idx]
    vh = v.transpose(0, 2, 1, 3)[:, kv_idx]
    Ha = len(active)
    sp = jnp.asarray(np.tile(spans_np[active], B))

    out = span_attention.span_attention(
        qh.reshape(B * Ha, Sq, dh),
        kh.reshape(B * Ha, -1, dh),
        vh.reshape(B * Ha, -1, dh),
        sp,
        int(window),
        causal=causal,
        bq=bq,
        bk=bk,
        interpret=_interpret(),
    ).reshape(B, Ha, Sq, dh)

    full = jnp.zeros((B, H, Sq, dh), q.dtype)
    full = full.at[:, active].set(out)
    return full.transpose(0, 2, 1, 3)
