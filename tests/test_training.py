"""Training integration: loss decreases; the EdgeBERT two-phase procedure
(prune + span + distill, then off-ramp) works end to end on CPU."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config, PruneConfig, SpanConfig
from repro.core import pruning
from repro.data.synthetic import SyntheticCLS, SyntheticLM
from repro.models.model import build_model
from repro.training.optim import AdamWConfig, adamw_init, adamw_update, lr_schedule
from repro.training.train_loop import EdgeBertTrainer, TrainerConfig, make_train_step


def _albert(**eb):
    cfg = get_smoke_config("albert_edgebert")
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    if eb:
        cfg = cfg.with_edgebert(**eb)
    return cfg


class TestOptim:
    def test_adamw_minimizes_quadratic(self):
        target = jnp.array([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        opt_cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200)
        state = adamw_init(params)
        for _ in range(200):
            grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            params, state, _ = adamw_update(grads, state, params, opt_cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=0.05)

    def test_schedules(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="cosine")
        assert float(lr_schedule(cfg, jnp.array(0))) == 0.0
        assert abs(float(lr_schedule(cfg, jnp.array(10))) - 1.0) < 1e-6
        assert float(lr_schedule(cfg, jnp.array(100))) < 1e-6

    def test_weight_decay_mask(self):
        from repro.training.optim import _decay_mask

        class P:
            ndim = 2
        assert not _decay_mask((jax.tree_util.DictKey("norm1"),), P())


class TestLMTraining:
    def test_loss_decreases(self):
        cfg = dataclasses.replace(
            get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none"
        )
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        data = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
        step_fn = jax.jit(
            make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60))
        )
        opt_state = adamw_init(params)
        losses = []
        for step in range(60):
            batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
            params, opt_state, m = step_fn(params, opt_state, batch)
            losses.append(float(m["loss"]))
        assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3

    def test_microbatching_equivalent_loss_scale(self):
        cfg = dataclasses.replace(
            get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none"
        )
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        data = SyntheticLM(cfg.vocab_size, 32, 8, seed=1)
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        opt = AdamWConfig(lr=1e-3)
        f1 = jax.jit(make_train_step(model, opt, microbatches=1))
        f4 = jax.jit(make_train_step(model, opt, microbatches=4))
        p1, _, m1 = f1(params, adamw_init(params), batch)
        p4, _, m4 = f4(params, adamw_init(params), batch)
        # same data -> nearly identical updates (fp accumulation differences)
        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4
        )
        assert max(jax.tree_util.tree_leaves(d)) < 5e-3


class TestEdgeBertPhases:
    def test_phase1_prunes_and_learns(self):
        cfg = _albert(
            prune=PruneConfig(
                enabled=True, method="magnitude", encoder_sparsity=0.5,
                embedding_sparsity=0.5, end_step=30, update_every=5,
            ),
            span=SpanConfig(enabled=True, max_span=128, ramp=16, loss_coef=0.05,
                            init_span=100.0),
        )
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        data = SyntheticCLS(cfg.vocab_size, 32, 8, num_classes=3, seed=0)
        trainer = EdgeBertTrainer(
            model, TrainerConfig(phase1_steps=40, phase2_steps=0,
                                 opt=AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=40))
        )
        params, prune_state, hist = trainer.phase1(params, data, log_every=1000)
        # sparsity reached
        m = pruning.measured_sparsity(params, prune_state)
        assert m["sparsity"] > 0.4
        # spans shrank under the regularizer
        assert float(jnp.mean(params["span_z"])) < 100.0
        # loss finite and improving-ish
        assert np.isfinite(hist[-1]["loss"])

    def test_phase2_trains_offramp(self):
        cfg = _albert()
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(1))
        data = SyntheticCLS(cfg.vocab_size, 32, 8, num_classes=3, seed=1)
        trainer = EdgeBertTrainer(
            model, TrainerConfig(phase1_steps=0, phase2_steps=30,
                                 opt=AdamWConfig(lr=2e-3, warmup_steps=3, total_steps=30))
        )
        params2, hist = trainer.phase2(params, data)
        assert hist[-1]["loss"] < hist[0]["loss"]
        # backbone untouched
        np.testing.assert_array_equal(
            np.asarray(params["layer"]["attn"]["wq"]),
            np.asarray(params2["layer"]["attn"]["wq"]),
        )

    def test_movement_pruning_path(self):
        cfg = _albert(
            prune=PruneConfig(
                enabled=True, method="movement", encoder_sparsity=0.6,
                end_step=20, update_every=4,
            )
        )
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(2))
        data = SyntheticCLS(cfg.vocab_size, 32, 8, num_classes=3, seed=2)
        trainer = EdgeBertTrainer(
            model, TrainerConfig(phase1_steps=25, phase2_steps=0,
                                 opt=AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=25))
        )
        params, prune_state, hist = trainer.phase1(params, data, log_every=1000)
        m = pruning.measured_sparsity(params, prune_state)
        assert m["sparsity"] > 0.5
