"""Trace-driven serving harness CLI: generate (or load) a seeded request
trace and replay it through the FULL admission -> residency -> schedule ->
DVFS path on the modeled clock, in bounded memory, then emit a structured
summary and append it as a tagged entry to the versioned BENCH_serving.json
history (newest-vs-previous diff printed by the history writer).

Usage:
  python benchmarks/harness/run_harness.py                         # default
  python benchmarks/harness/run_harness.py --scenario mmpp_multitask \
      --requests 100000 --verify-determinism
  python benchmarks/harness/run_harness.py --smoke                 # CI gate
  python benchmarks/harness/run_harness.py --save-trace /tmp/t.jsonl
  python benchmarks/harness/run_harness.py --trace /tmp/t.jsonl    # replay

``--smoke`` is the CI configuration: 10^4 requests of the (bursty MMPP x
skewed multi-task) scenario plus a second same-seed replay to prove the
summary is bit-identical.  The emitted ``workload_replay`` row carries the
keys ``scratch/run_ci.sh`` grep-gates on: ``accepted_slo_misses`` (the
admission contract), ``shed_bounded``, ``max_traces_per_bucket_replica``
(the zero-new-traces invariant), and ``deterministic``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import numpy as np

from benchmarks.common import append_bench_history, emit, git_tag
from benchmarks.harness.scenarios import (
    SCENARIOS,
    build_workload,
    full_depth_service_s,
)
from repro.hwmodel.edgebert_accel import albert_layer_stats
from repro.serving.admission import AdmissionController
from repro.serving.dvfs import (
    BatchedDVFSArbiter,
    LatencyAwareDVFSController,
    calibrate_predictor,
    no_early_exit_baseline,
)
from repro.serving.engine import ClassifierServer
from repro.serving.workload import (
    AdmissionServerTarget,
    ResidencyRouterTarget,
    TraceReplayer,
    generate_trace,
    load_trace,
    save_trace,
    summaries_identical,
)

LANES = 4
TARGET_MULT = 1.5                      # deployment-style latency headroom
BEST_EFFORT_QUEUE = 8 * LANES          # bounded; overflow sheds oldest


def _model_and_controller(spec, *, trained: bool, target_mult: float):
    """The serving stack's model + calibrated DVFS controller factory, built
    once per process (jit caches are per-server, so fresh targets recompile
    but share the model/params)."""
    from benchmarks.bench_batched_dvfs import _setup

    model, params, cfg, data, _thr = _setup(smoke=not trained)
    buckets = tuple(int(b) for b in spec["buckets"])
    stats = albert_layer_stats(seq_len=max(buckets))
    stats.n_layers = cfg.n_layers
    target = no_early_exit_baseline(stats)["latency_s"] * target_mult
    predictor = calibrate_predictor(
        model, params, [data.batch(100 + i) for i in range(2)], quantile=1.0
    )

    def ctrl_factory():
        return LatencyAwareDVFSController(stats, target, predictor=predictor)

    return model, params, cfg, buckets, ctrl_factory


def build_target(spec, model, params, cfg, buckets, ctrl_factory):
    """One fresh replay target for this scenario: a single admitted server,
    or the full multi-task residency router with per-task admission.

    Two contract-safety knobs the multi-task path needs under SUSTAINED
    bursty load (the storm benches never hit these because their deadlines
    are hand-picked): ``admission_headroom`` prices quotes extra-
    conservatively (the per-task quote cannot see how long the affinity
    policy will legally defer a non-resident task), and
    ``affinity_margin_services`` gives ``TaskAffinityPolicy`` a positive
    preemption margin — at the default 0 it swaps an urgent non-resident
    task in only once its discounted slack is ALREADY negative, too late to
    cover the task's remaining compute."""
    tasks = [t for t, _ in spec.get("tasks", [])]
    headroom = float(spec.get("admission_headroom", 1.25))
    adm_kwargs = {"max_best_effort_queue": BEST_EFFORT_QUEUE,
                  "headroom": headroom}
    if not tasks:
        server = ClassifierServer(
            model, params, batch_lanes=LANES,
            arbiter=BatchedDVFSArbiter(ctrl_factory()), buckets=buckets,
        )
        return AdmissionServerTarget(
            server, AdmissionController(server, **adm_kwargs)
        )
    from repro.serving.residency import (
        ResidencyRouter,
        TaskAffinityPolicy,
        TaskDeployment,
        TaskResidencyManager,
    )

    ctrl = ctrl_factory()
    svc = full_depth_service_s(ctrl, cfg.n_layers, buckets)
    margin = float(spec.get("affinity_margin_services", 4.0)) * svc(max(buckets))
    deps = {
        t: TaskDeployment(
            t, n_params=11e6, pruning_occupancy=0.4, spans=(0,) * 6 + (64,) * 6
        )
        for t in tasks
    }
    sram_tasks = float(spec.get("sram_tasks", 2))
    res = TaskResidencyManager(
        deps, sram_bytes=sram_tasks * deps[tasks[0]].storage()["total_bytes"]
    )
    router = ResidencyRouter(
        model, params["embed"], {t: params for t in tasks},
        residency=res, deployments=deps,
        task_policy=TaskAffinityPolicy(preempt_slack_s=margin),
        arbiter=BatchedDVFSArbiter(ctrl_factory()), buckets=buckets,
        batch_lanes=LANES,
    )
    return ResidencyRouterTarget(router, admission_kwargs=adm_kwargs)


def run_once(spec, n, seed, model, params, cfg, buckets, ctrl_factory,
             *, trace_path=None):
    ctrl = ctrl_factory()
    svc = full_depth_service_s(ctrl, cfg.n_layers, buckets)
    target = build_target(spec, model, params, cfg, buckets, ctrl_factory)
    replayer = TraceReplayer(target, vocab_size=cfg.vocab_size, token_seed=seed)
    if trace_path is not None:
        events = load_trace(trace_path)
    else:
        wl = build_workload(spec, ctrl=ctrl, n_layers=cfg.n_layers,
                            lanes=LANES, seed=seed)
        events = generate_trace(wl, n, service_s=svc)
    return replayer.replay(events)


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--scenario", default="mmpp_multitask",
                        choices=sorted(SCENARIOS))
    parser.add_argument("--requests", type=int, default=None,
                        help="override the scenario's trace length")
    parser.add_argument("--seed", type=int, default=None)
    parser.add_argument("--smoke", action="store_true",
                        help="CI config: 10^4 requests + determinism check")
    parser.add_argument("--verify-determinism", action="store_true",
                        help="replay the same seed twice on a fresh stack "
                             "and require a bit-identical summary")
    parser.add_argument("--trained", action="store_true",
                        help="use the phase-1+2 trained toy model")
    parser.add_argument("--target-mult", type=float, default=TARGET_MULT)
    parser.add_argument("--trace", default=None,
                        help="replay a saved JSONL trace instead of generating")
    parser.add_argument("--save-trace", default=None,
                        help="generate the trace, save it as JSONL, and exit")
    parser.add_argument("--no-bench-append", action="store_true",
                        help="skip the BENCH_serving.json history append")
    args = parser.parse_args()

    spec = SCENARIOS[args.scenario]
    n = args.requests if args.requests is not None else int(spec["requests"])
    if args.smoke:
        n = min(n, 10_000)
    seed = args.seed if args.seed is not None else int(spec.get("seed", 0))
    verify = args.verify_determinism or args.smoke

    model, params, cfg, buckets, ctrl_factory = _model_and_controller(
        spec, trained=args.trained, target_mult=args.target_mult
    )

    if args.save_trace is not None:
        ctrl = ctrl_factory()
        wl = build_workload(spec, ctrl=ctrl, n_layers=cfg.n_layers,
                            lanes=LANES, seed=seed)
        svc = full_depth_service_s(ctrl, cfg.n_layers, buckets)
        wrote = save_trace(args.save_trace, generate_trace(wl, n, service_s=svc))
        print(f"saved {wrote} events to {args.save_trace}", flush=True)
        return

    summary = run_once(spec, n, seed, model, params, cfg, buckets,
                       ctrl_factory, trace_path=args.trace)
    deterministic = None
    if verify:
        again = run_once(spec, n, seed, model, params, cfg, buckets,
                         ctrl_factory, trace_path=args.trace)
        deterministic = summaries_identical(summary, again)

    shed_bounded = int(summary["shed"] <= summary["submitted"]
                       and summary["completed"] + summary["rejected"]
                       + summary["shed"] == summary["submitted"])
    emit(
        "workload_replay", 0.0,
        f"scenario={args.scenario};requests={summary['requests']};"
        f"completed={summary['completed']};accepted={summary['accepted']};"
        f"rejected={summary['rejected']};requoted={summary['requoted']};"
        f"shed={summary['shed']};shed_bounded={shed_bounded};"
        f"accepted_slo_misses={summary['accepted_slo_misses']};"
        f"throughput_rps={summary['throughput_rps']:.1f};"
        f"energy_per_request_j={summary['energy_per_request_j']:.3e};"
        f"queue_delay_steps_p99={summary['queue_delay_steps_p99']:.1f};"
        f"max_traces_per_bucket_replica={summary['max_traces_per_bucket_replica']};"
        f"peak_outstanding={summary['peak_outstanding']};"
        f"task_swaps={summary.get('task_swaps', 0)};"
        + (f"deterministic={int(deterministic)};" if deterministic is not None
           else "")
        + f"seed={seed}",
    )
    print(json.dumps(summary, indent=2, sort_keys=True), flush=True)

    if not args.no_bench_append:
        entry = {
            "scenario": "workload_replay",
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "tag": git_tag(),
            "workload": args.scenario,
            "seed": seed,
            "smoke": bool(args.smoke),
            "trained": bool(args.trained),
            "target_mult": float(args.target_mult),
            "lanes": LANES,
            "bucket_count": len(buckets),
        }
        if deterministic is not None:
            entry["deterministic"] = bool(deterministic)
        for k, v in summary.items():
            if isinstance(v, (int, float, bool)) or k in ("per_tier", "per_task"):
                entry[k] = v
        append_bench_history(os.path.join(_ROOT, "BENCH_serving.json"), entry)

    if deterministic is False:
        print("FAIL: same-seed replays diverged", flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
