"""Multi-task serving with eNVM-shared embeddings (paper §III-D / Fig. 11).

One frozen, pruned embedding table serves N task-specific encoder+classifier
weight sets; task switches never touch the embeddings (they live in on-chip
ReRAM in the paper; here: a single shared array). Prints the power-on cost
advantage from the hardware model.

    PYTHONPATH=src python examples/serve_multitask.py
"""
import dataclasses
import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core import bitmask as bm
from repro.data.synthetic import SyntheticCLS
from repro.hwmodel.edgebert_accel import poweron_embedding_cost
from repro.models.model import build_model
from repro.serving.engine import MultiTaskRouter, Request

cfg = dataclasses.replace(
    get_smoke_config("albert_edgebert"), dtype="float32", remat_policy="none"
)
model = build_model(cfg)

# four "GLUE tasks": task-specific encoder/classifier, SHARED embeddings
base = model.init_params(jax.random.PRNGKey(0))
tasks = {}
for i, task in enumerate(("mnli", "qqp", "sst2", "qnli")):
    tasks[task] = model.init_params(jax.random.PRNGKey(i))
router = MultiTaskRouter(model, shared_embed=base["embed"], task_params=tasks)

data = SyntheticCLS(cfg.vocab_size, 32, 16, num_classes=3)
b = data.batch(0)
for i, task in enumerate(("mnli", "qqp", "sst2", "qnli")):
    for j in range(4):
        router.submit(task, Request(uid=i * 4 + j, tokens=b["tokens"][(i * 4 + j) % 16]))

stats = router.run_all()
for task, st in stats.items():
    print(f"{task}: {st['sentences']} sentences, avg exit "
          f"{st['avg_exit_layer']:.1f}/{cfg.n_layers}, savings {st['runtime_savings']:.0%}")
print(f"task switches: {router.switches}, embedding reloads: {router.embed_reloads} "
      "(embeddings are eNVM-resident)")

enc = bm.encode(np.asarray(base["embed"]["tok"]))
s = bm.storage_bytes(enc, value_bits=8)
c = poweron_embedding_cost(s["value_bytes"], s["mask_bytes"])
print(f"power-on embedding load: eNVM {c['envm_latency_s']*1e6:.1f}us vs "
      f"DRAM->SRAM {c['conventional_latency_s']*1e6:.1f}us "
      f"({c['latency_advantage']:.0f}x latency, {c['energy_advantage']:.0f}x energy)")
