"""Multi-device lane sharding: replica parity, placement routing, and
per-replica clock domains.

The tentpole guarantee is layered:

* IN-PROCESS (single real CPU device): a 1-replica ``shard_map`` drain must
  be BIT-IDENTICAL to the unsharded path for both engines — logits, exit
  depths, and every trace-count telemetry counter.  Plus pure units for the
  placement policies, the scheduler's replica-pinned refill, and the
  cross-arbiter lane-clock round-trip (checkpoint on replica A's arbiter,
  restore on replica B's, re-checkpoint: the frozen budget is unchanged).
* SUBPROCESS (forced host devices, ``multidevice`` marker, same idiom as
  test_dryrun_small.py): real 4-replica drains — classifier results still
  bitwise-match the unsharded reference (lane math is embarrassingly
  parallel; only the per-shard batch shape could differ, and the classifier
  step vmaps per lane), one step trace per (bucket, mesh), and a mid-flight
  preemption checkpointed on replica A restored on replica B reproducing the
  uninterrupted run exactly.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.common.jax_compat import make_auto_mesh
from repro.configs.base import get_smoke_config
from repro.data.synthetic import SyntheticCLS, SyntheticLM
from repro.models.model import build_model
from repro.serving.admission import (
    AdmissionController,
    DeadlinePackedPlacement,
    LeastLoadedPlacement,
    Quote,
)
from repro.serving.dvfs import (
    BatchedDVFSArbiter,
    LatencyAwareDVFSController,
    no_early_exit_baseline,
)
from repro.serving.engine import ClassifierServer, DecoderServer, Request
from repro.hwmodel.edgebert_accel import albert_layer_stats

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _albert_model(threshold=0.6):
    cfg = get_smoke_config("albert_edgebert")
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    cfg = cfg.with_edgebert(
        early_exit=dataclasses.replace(
            cfg.edgebert.early_exit, entropy_threshold=threshold
        )
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params, cfg


def _decoder_model():
    cfg = dataclasses.replace(
        get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none"
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    return model, params, cfg


def _mesh1():
    return make_auto_mesh((1,), ("data",))


# ===========================================================================
# Acceptance bit: 1-replica shard_map == unsharded, bit for bit
# ===========================================================================


class TestOneReplicaParity:
    def test_classifier_sharded_r1_bit_identical(self):
        model, params, cfg = _albert_model(threshold=0.5)
        batch = SyntheticCLS(cfg.vocab_size, 32, 8, num_classes=3, seed=0).batch(0)
        ref = ClassifierServer(model, params, batch_lanes=2, buckets=(16, 32))
        shd = ClassifierServer(
            model, params, batch_lanes=2, buckets=(16, 32), mesh=_mesh1()
        )
        assert shd._mesh is not None and shd.replicas == 1
        for s in (ref, shd):
            for i, L in enumerate((10, 16, 24, 32, 12, 30)):
                s.submit(Request(uid=i, tokens=batch["tokens"][i][:L]))
        t_ref, t_shd = ref.run(), shd.run()
        for i in range(6):
            assert shd.done[i].exit_layer == ref.done[i].exit_layer, i
            assert np.array_equal(shd.done[i].result, ref.done[i].result), i
        # telemetry counters bit-identical, including the per-(bucket, mesh)
        # trace counts: both paths key (S, 1)
        for k in (
            "sentences", "layer_calls", "dense_steps", "avg_exit_layer",
            "step_traces", "embed_traces", "insert_traces",
            "step_traces_per_bucket", "step_traces_per_bucket_replica",
        ):
            assert t_shd[k] == t_ref[k], k
        assert t_shd["replicas"] == 1

    def test_classifier_sharded_r1_pallas_eligible(self):
        """The Pallas-dispatch path must stay eligible INSIDE shard_map
        (pallas_call has no replication rule — shard_map_norep turns the
        check off), and stay bit-identical to the unsharded Pallas run."""
        model, params, cfg = _albert_model(threshold=0.5)
        batch = SyntheticCLS(cfg.vocab_size, 32, 4, num_classes=3, seed=3).batch(0)
        ref = ClassifierServer(
            model, params, batch_lanes=2, buckets=(16,), use_pallas=True
        )
        shd = ClassifierServer(
            model, params, batch_lanes=2, buckets=(16,), use_pallas=True,
            mesh=_mesh1(),
        )
        for s in (ref, shd):
            for i in range(4):
                s.submit(Request(uid=i, tokens=batch["tokens"][i][:12]))
        ref.run(), shd.run()
        for i in range(4):
            assert shd.done[i].exit_layer == ref.done[i].exit_layer, i
            assert np.array_equal(shd.done[i].result, ref.done[i].result), i

    def test_decoder_sharded_r1_bit_identical(self):
        model, params, cfg = _decoder_model()
        batch = SyntheticLM(cfg.vocab_size, 16, 4, seed=0).batch(0)
        ref = DecoderServer(
            model, params, batch_lanes=2, max_seq=48, eos_id=-1, buckets=(16,)
        )
        shd = DecoderServer(
            model, params, batch_lanes=2, max_seq=48, eos_id=-1, buckets=(16,),
            mesh=_mesh1(),
        )
        assert shd._mesh is not None and shd.replicas == 1
        for s in (ref, shd):
            for i in range(3):
                s.submit(
                    Request(uid=i, tokens=batch["tokens"][i][:8], max_new_tokens=4)
                )
        t_ref, t_shd = ref.run(), shd.run()
        for i in range(3):
            assert shd.done[i].generated == ref.done[i].generated, i
        for k in (
            "completed", "tokens", "decode_steps", "decode_traces",
            "prefill_traces", "step_traces_per_bucket",
            "step_traces_per_bucket_replica",
        ):
            assert t_shd[k] == t_ref[k], k

    def test_decoder_ee_sharded_r1_bit_identical(self):
        """Early-exit decode (per-token exit depths) through the sharded
        wrapper: generated tokens AND exit-depth telemetry must match."""
        model, params, cfg = _decoder_model()
        batch = SyntheticLM(cfg.vocab_size, 16, 4, seed=1).batch(0)
        kw = dict(batch_lanes=2, max_seq=48, eos_id=-1, buckets=(16,),
                  exit_threshold=2.0)
        ref = DecoderServer(model, params, **kw)
        shd = DecoderServer(model, params, mesh=_mesh1(), **kw)
        for s in (ref, shd):
            for i in range(3):
                s.submit(
                    Request(uid=i, tokens=batch["tokens"][i][:8], max_new_tokens=4)
                )
        t_ref, t_shd = ref.run(), shd.run()
        for i in range(3):
            assert shd.done[i].generated == ref.done[i].generated, i
        for k in ("tokens", "token_layer_calls", "avg_token_exit_layer",
                  "decode_traces", "step_traces_per_bucket_replica"):
            assert t_shd[k] == t_ref[k], k


# ===========================================================================
# Placement policies (pure units)
# ===========================================================================


def _q(replica, min_deadline, wait=0.0, feasible=True):
    return Quote(bucket=16, service_s=0.1, wait_s=wait,
                 min_deadline_s=min_deadline, feasible=feasible,
                 replica=replica)


class TestPlacementPolicies:
    def test_least_loaded_picks_earliest_feasible_deadline(self):
        quotes = [_q(0, 3.0), _q(1, 1.5), _q(2, 2.0)]
        assert LeastLoadedPlacement().choose(quotes).replica == 1

    def test_deadline_packed_picks_busiest_feasible(self):
        quotes = [_q(0, 3.0), _q(1, 1.5), _q(2, 2.0)]
        assert DeadlinePackedPlacement().choose(quotes).replica == 0

    def test_wait_breaks_ties(self):
        quotes = [_q(0, 2.0, wait=0.5), _q(1, 2.0, wait=0.1)]
        assert LeastLoadedPlacement().choose(quotes).replica == 1
        assert DeadlinePackedPlacement().choose(quotes).replica == 0


# ===========================================================================
# Replica-pinned refill on the bare scheduler
# ===========================================================================


class _RecordingEngine:
    """Bare-scheduler stub: retires every lane after one step and records
    ``(step_index, lane, uid)`` for each ``lane_load``."""

    def __init__(self, lanes_per_replica):
        self.lpr = lanes_per_replica
        self.loads = []
        self._steps = 0

    def bucket_key(self, req):
        return len(req.tokens)

    def lane_domain(self, lane):
        return lane // self.lpr

    def bucket_begin(self, bucket):
        pass

    def lane_load(self, bucket, lane, req):
        self.loads.append((self._steps, lane, req.uid))

    def lanes_step(self, bucket, active):
        self._steps += 1
        return None

    def lane_advance(self, bucket, lane, req, out, depth):
        return True                          # retire after one fused step

    def lane_finish(self, bucket, lane, req, depth):
        pass

    def bucket_end(self, bucket):
        pass


class TestDomainRouting:
    def _sched(self, lanes_per_replica=1, replicas=2):
        from repro.serving.scheduler import LaneScheduler

        eng = _RecordingEngine(lanes_per_replica)
        return (
            LaneScheduler(lanes_per_replica * replicas, eng, buckets=(16,)),
            eng,
        )

    def test_pinned_request_only_fills_its_domain(self):
        sched, eng = self._sched()
        toks = np.arange(1, 9, dtype=np.int32)
        r0 = Request(uid=0, tokens=toks)
        r0.replica = 1                       # pinned to domain 1 (lane 1)
        sched.submit(r0)
        rep = sched.step()
        assert rep is not None and rep.n_active == 1
        # lane 0 (domain 0) must stay empty; lane 1 carries the request
        assert [(l, u) for _, l, u in eng.loads] == [(1, 0)]

    def test_unpinned_requests_fill_any_domain(self):
        sched, eng = self._sched()
        toks = np.arange(1, 9, dtype=np.int32)
        for i in range(2):
            sched.submit(Request(uid=i, tokens=toks))
        rep = sched.step()
        assert rep.n_active == 2
        assert sorted(l for _, l, _ in eng.loads) == [0, 1]

    def test_incompatible_pin_does_not_block_compatible_younger(self):
        """Two requests pinned to domain 0 ahead of one pinned to domain 1:
        the domain-1 lane must take the YOUNGER compatible request instead
        of idling behind the incompatible queue head."""
        sched, eng = self._sched()
        toks = np.arange(1, 9, dtype=np.int32)
        pins = [0, 0, 1]
        for i, pin in enumerate(pins):
            r = Request(uid=i, tokens=toks)
            r.replica = pin
            sched.submit(r)
        rep = sched.step()
        assert rep.n_active == 2
        first = {(l, u) for s, l, u in eng.loads if s == 0}
        assert first == {(0, 0), (1, 2)}
        sched.step()                         # uid 1 takes domain 0 next
        assert (1, 0, 1) in eng.loads


# ===========================================================================
# Cross-replica lane-clock round-trip (per-replica DVFS domains)
# ===========================================================================


class TestCrossReplicaClockCheckpoint:
    def test_restore_on_either_replica_bit_identical(self):
        """Restoring a checkpointed lane clock is a pure function of the
        payload and the (barrier-synced) fleet clock — NO replica-local
        state leaks in.  After the ``advance_to`` barrier both arbiters sit
        at the same now_s, and restoring A's checkpoint on A or on B yields
        bit-identical lane state field for field."""
        import copy

        stats = albert_layer_stats(seq_len=16)
        ctrl = LatencyAwareDVFSController(
            stats, no_early_exit_baseline(stats)["latency_s"] * 1.5
        )
        arb_a, arb_b = BatchedDVFSArbiter(ctrl), BatchedDVFSArbiter(ctrl)
        arb_a.admit("lane", deadline_s=0.5)
        for _ in range(3):
            arb_a.step(["lane"])
        clk = arb_a.checkpoint_lane("lane")
        # lockstep barrier: both replicas fast-forward to the fleet max,
        # exactly what the engines do after every fused step
        t = max(arb_a.now_s, arb_b.now_s)
        arb_a.advance_to(t)
        arb_b.advance_to(t)
        assert arb_a.now_s == arb_b.now_s
        pay_a, pay_b = copy.deepcopy(clk), copy.deepcopy(clk)
        arb_a.restore_lane("lane", pay_a)
        arb_b.restore_lane("lane", pay_b)
        sa, sb = arb_a._lanes["lane"], arb_b._lanes["lane"]
        for f in ("admit_s", "deadline_s", "target_s", "cycles_per_layer",
                  "depth", "energy_j", "pred_layers_remaining"):
            assert getattr(sa, f) == getattr(sb, f), f
        assert sa.slowest_op == sb.slowest_op

    def test_advance_to_is_monotone_noop_when_behind(self):
        stats = albert_layer_stats(seq_len=16)
        ctrl = LatencyAwareDVFSController(
            stats, no_early_exit_baseline(stats)["latency_s"] * 1.5
        )
        arb = BatchedDVFSArbiter(ctrl)
        arb.advance_to(1.0)
        assert arb.now_s == 1.0
        arb.advance_to(0.5)                  # never rewinds
        assert arb.now_s == 1.0

    def test_expanded_arbiters_share_controller_not_clocks(self):
        """``replicas`` arbiters from one seed share the controller (one
        op table / hw model) but are INDEPENDENT clock domains."""
        from repro.serving.engine import _expand_arbiters

        stats = albert_layer_stats(seq_len=16)
        ctrl = LatencyAwareDVFSController(
            stats, no_early_exit_baseline(stats)["latency_s"] * 1.5
        )
        arbs = _expand_arbiters(BatchedDVFSArbiter(ctrl), 3)
        assert len(arbs) == 3
        assert len({id(a) for a in arbs}) == 3
        assert all(a.c is ctrl for a in arbs)
        arbs[0].admit("lane", deadline_s=0.5)
        arbs[0].step(["lane"])
        assert arbs[0].now_s > 0.0 and arbs[1].now_s == 0.0


# ===========================================================================
# Per-replica admission quoting
# ===========================================================================


class _StubSharded:
    """Minimal sharded-server facade over a bare LaneScheduler: exposes the
    attributes the admission controller prices with (replicas, lane slabs)
    without needing a device mesh."""

    def __init__(self, sched, replicas, lanes_per_replica):
        self.sched = sched
        self.replicas = replicas
        self.lanes_per_replica = lanes_per_replica

    def submit(self, req):
        req.bucket = self.sched.submit(req)


class TestPerReplicaQuoting:
    def _make(self, replicas=2, lpr=1):
        from repro.serving.scheduler import LaneScheduler

        class _E:
            def bucket_key(self, req):
                return len(req.tokens)

            def lane_domain(self, lane, lpr=lpr):
                return lane // lpr

            def bucket_begin(self, bucket):
                pass

            def lane_load(self, bucket, lane, req):
                pass

            def lanes_step(self, bucket, active):
                return None

            def lane_advance(self, bucket, lane, req, out, depth):
                return False                 # contracts stay in flight

            def lane_finish(self, bucket, lane, req, depth):
                pass

            def bucket_end(self, bucket):
                pass

        sched = LaneScheduler(replicas * lpr, _E(), buckets=(16,),
                              step_time_fn=lambda b: 1.0)
        return _StubSharded(sched, replicas, lpr)

    def test_quotes_fan_out_and_route_least_loaded(self):
        srv = self._make()
        ac = AdmissionController(srv, fallback_steps=2.0)
        toks = np.arange(1, 9, dtype=np.int32)
        # occupy replica 0's lane with a long outstanding contract
        busy = Request(uid=0, tokens=toks, deadline_s=50.0)
        busy.replica = 0
        d0 = ac.submit(busy)
        assert d0.admitted
        srv.sched.step()                     # in flight on lane 0
        q = ac.quote(Request(uid=1, tokens=toks, deadline_s=1e9))
        # replica 1 is idle: the routed quote must come from it and be
        # cheaper than replica 0's (which waits behind the contract)
        assert q.replica == 1
        assert q.min_deadline_s < ac.quote(
            Request(uid=2, tokens=toks, deadline_s=1e9), replica=0
        ).min_deadline_s

    def test_accept_pins_request_to_quoted_replica(self):
        srv = self._make()
        ac = AdmissionController(srv, fallback_steps=2.0)
        toks = np.arange(1, 9, dtype=np.int32)
        busy = Request(uid=0, tokens=toks, deadline_s=50.0)
        busy.replica = 0
        ac.submit(busy)
        srv.sched.step()
        req = Request(uid=1, tokens=toks, deadline_s=1e9)
        d = ac.submit(req)
        assert d.admitted and d.quote.replica == 1
        assert req.replica == 1

    def test_single_replica_quote_unchanged(self):
        """replicas == 1 must price exactly the legacy single-domain path
        (replica stays None — no pinning, no fan-out)."""
        srv = self._make(replicas=1, lpr=2)
        ac = AdmissionController(srv, fallback_steps=2.0)
        toks = np.arange(1, 9, dtype=np.int32)
        q = ac.quote(Request(uid=0, tokens=toks, deadline_s=1e9))
        assert q.replica is None
        d = ac.submit(Request(uid=1, tokens=toks, deadline_s=1e9))
        assert d.admitted and getattr(d.quote, "replica", None) is None


# ===========================================================================
# Forced-multi-device end-to-end (subprocess; multidevice marker)
# ===========================================================================


def _run(code: str, devices: int = 4, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, (
        f"stderr:\n{r.stderr[-3000:]}\nstdout:\n{r.stdout[-1000:]}"
    )
    return r.stdout


@pytest.mark.multidevice
class TestForcedFourDevices:
    """Unlike test_dryrun_small.py these need no ``jax.sharding.AxisType``:
    the engines build their mesh through ``make_auto_mesh``, which handles
    both jax generations, so the subprocess snippets run wherever shard_map
    itself exists."""

    def test_classifier_r4_matches_unsharded_zero_extra_traces(self):
        _run("""
            import dataclasses, json
            import jax, numpy as np
            from repro.configs.base import get_smoke_config
            from repro.data.synthetic import SyntheticCLS
            from repro.models.model import build_model
            from repro.serving.engine import ClassifierServer, Request

            cfg = get_smoke_config("albert_edgebert")
            cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
            cfg = cfg.with_edgebert(early_exit=dataclasses.replace(
                cfg.edgebert.early_exit, entropy_threshold=0.5))
            model = build_model(cfg)
            params = model.init_params(jax.random.PRNGKey(0))
            batch = SyntheticCLS(cfg.vocab_size, 32, 16, num_classes=3,
                                 seed=0).batch(0)

            ref = ClassifierServer(model, params, batch_lanes=8, buckets=(16,))
            shd = ClassifierServer(model, params, batch_lanes=2, buckets=(16,),
                                   replicas=4)
            assert shd.lanes == 8 and shd.replicas == 4
            for s in (ref, shd):
                for i in range(16):
                    s.submit(Request(uid=i, tokens=batch["tokens"][i][:12]))
            t_ref, t_shd = ref.run(), shd.run()
            # per-lane vmap means the shard batch shape does not change the
            # per-lane math: R=4 stays bitwise-equal to the flat 8-lane run
            for i in range(16):
                assert shd.done[i].exit_layer == ref.done[i].exit_layer, i
                assert np.array_equal(shd.done[i].result, ref.done[i].result), i
            # one fused-step trace per (bucket, mesh)
            assert t_shd["step_traces_per_bucket_replica"] == {"16x4": 1}, (
                t_shd["step_traces_per_bucket_replica"])
        """)

    def test_decoder_r4_drains_zero_extra_traces(self):
        _run("""
            import dataclasses
            import jax, numpy as np
            from repro.configs.base import get_smoke_config
            from repro.data.synthetic import SyntheticLM
            from repro.models.model import build_model
            from repro.serving.engine import DecoderServer, Request

            cfg = dataclasses.replace(get_smoke_config("deepseek_7b"),
                                      dtype="float32", remat_policy="none")
            model = build_model(cfg)
            params = model.init_params(jax.random.PRNGKey(1))
            batch = SyntheticLM(cfg.vocab_size, 16, 8, seed=0).batch(0)

            shd = DecoderServer(model, params, batch_lanes=2, max_seq=48,
                                eos_id=-1, buckets=(16,), replicas=4)
            ref = DecoderServer(model, params, batch_lanes=2, max_seq=48,
                                eos_id=-1, buckets=(16,))
            for s in (shd, ref):
                for i in range(8):
                    s.submit(Request(uid=i, tokens=batch["tokens"][i][:8],
                                     max_new_tokens=4))
            t_shd, t_ref = shd.run(), ref.run()
            assert t_shd["completed"] == 8
            assert all(len(shd.done[i].generated) == 4 for i in range(8))
            # greedy argmax decode is robust to the fp drift of different
            # shard batch shapes on this smoke config
            for i in range(8):
                assert shd.done[i].generated == ref.done[i].generated, i
            assert t_shd["step_traces_per_bucket_replica"] == {"16x4": 1}, (
                t_shd["step_traces_per_bucket_replica"])
        """)

    def test_checkpoint_on_replica_a_restores_on_replica_b(self):
        _run("""
            import dataclasses
            import jax, numpy as np
            from repro.configs.base import get_smoke_config
            from repro.data.synthetic import SyntheticCLS
            from repro.models.model import build_model
            from repro.serving.engine import ClassifierServer, Request

            cfg = get_smoke_config("albert_edgebert")
            cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
            cfg = cfg.with_edgebert(early_exit=dataclasses.replace(
                cfg.edgebert.early_exit, entropy_threshold=1e-9))
            model = build_model(cfg)
            params = model.init_params(jax.random.PRNGKey(0))
            batch = SyntheticCLS(cfg.vocab_size, 32, 8, num_classes=3,
                                 seed=0).batch(0)

            # uninterrupted reference (unsharded, single lane)
            ref = ClassifierServer(model, params, batch_lanes=1, buckets=(16,))
            ref.submit(Request(uid=0, tokens=batch["tokens"][0][:12]))
            ref.run()

            # sharded run: uid 0 starts on replica 0's only lane, an explicit
            # arrival pinned there evicts it mid-flight, and the checkpoint
            # resumes on replica 1's lane
            srv = ClassifierServer(model, params, batch_lanes=1, buckets=(16,),
                                   replicas=2, preempt=True)
            srv.submit(Request(uid=0, tokens=batch["tokens"][0][:12]))
            srv.step()
            srv.step()                       # a few layers deep on lane 0
            tight = Request(uid=99, tokens=batch["tokens"][1][:12],
                            deadline_s=float(cfg.n_layers * 6))
            tight.replica = 0
            srv.submit(tight)
            # ONE step: domain-0 eviction checkpoints uid 0 off replica 0,
            # and the same refill restores it into replica 1's free lane —
            # checkpoint on A, restore on B, through the real machinery
            srv.step()
            assert srv.telemetry()["preemptions"] == 1
            run = srv.sched._open[16]
            assert run.lane_req[0].uid == 99      # replica 0: the contract
            assert run.lane_req[1].uid == 0       # replica 1: the restoree
            assert srv.done.get(0) is None
            while srv.step() is not None:
                pass
            assert 0 in srv.done and 99 in srv.done
            assert srv.done[0].exit_layer == ref.done[0].exit_layer
            assert np.array_equal(srv.done[0].result, ref.done[0].result)
        """)
