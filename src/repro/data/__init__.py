from repro.data.synthetic import SyntheticLM, SyntheticCLS, make_batch_specs
