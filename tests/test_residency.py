"""Multi-task residency: compression-aware deployment pricing, eNVM swap
costs on the shared clock, fault-injected readback detection, and
task-affinity-aware scheduling (serving/residency.py)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core import bitmask as bm
from repro.core.adaptivfloat import AFFormat
from repro.data.synthetic import SyntheticCLS
from repro.hwmodel.edgebert_accel import (
    albert_layer_stats,
    layer_cycles,
    layer_energy_j,
    scale_stats_to_seq_len,
    task_swap_cost,
)
from repro.models.model import build_model
from repro.serving.admission import AdmissionController
from repro.serving.dvfs import (
    BatchedDVFSArbiter,
    LatencyAwareDVFSController,
    no_early_exit_baseline,
)
from repro.serving.engine import ClassifierServer, Request
from repro.serving.residency import (
    BlindEDFTaskPolicy,
    ResidencyRouter,
    TaskAffinityPolicy,
    TaskDeployment,
    TaskResidencyManager,
    deployment_controller,
    deployment_energy_scale,
    deployment_stats,
    measured_footprint,
)

N_LAYERS = 12


def _stats(seq_len=64):
    s = albert_layer_stats(seq_len=seq_len)
    s.n_layers = N_LAYERS
    return s


def _controller(target_mult=2.0):
    target = no_early_exit_baseline(_stats())["latency_s"] * target_mult
    return LatencyAwareDVFSController(_stats(), target)


def _dep(task="mnli", occupancy=0.4, spans=(0,) * 6 + (64,) * 6):
    return TaskDeployment(
        task, n_params=11e6, pruning_occupancy=occupancy,
        spans=spans, n_heads=12, span_seq_len=128,
    )


# ===========================================================================
# Deployment pricing: the hwmodel sees the COMPRESSED network
# ===========================================================================


class TestDeploymentPricing:
    def test_compressed_deployment_lowers_cycles_and_power(self):
        ctrl = _controller()
        dep = _dep()
        dc = deployment_controller(ctrl, dep)
        for S in (16, 32, 64, 128):
            assert dc.cycles_for_seq_len(S) < ctrl.cycles_for_seq_len(S)
        # sparsity/span gate power too — the arbiter's energy_scale < 1
        assert deployment_energy_scale(ctrl, dep) < 1.0

    def test_dense_deployment_prices_identically(self):
        ctrl = _controller()
        dense = TaskDeployment("t", n_params=11e6)  # occupancy 1, no spans
        dc = deployment_controller(ctrl, dense)
        assert dc.cycles_for_seq_len(64) == ctrl.cycles_for_seq_len(64)
        assert deployment_energy_scale(ctrl, dense) == pytest.approx(1.0)

    def test_cycles_energy_monotone_in_pruning_occupancy(self):
        """A deployment that keeps FEWER weights can never price more cycles
        or more energy — a misconfigured deployment can't quote cheaper than
        it runs (checked across seq-len rescaling too)."""
        base = _stats()
        for S in (32, 64, 128):
            prev_c, prev_e = None, None
            for occ in (1.0, 0.8, 0.6, 0.4, 0.2):
                st = scale_stats_to_seq_len(
                    deployment_stats(base, _dep(occupancy=occ, spans=None)), S
                )
                c = layer_cycles(st, use_span=True)
                e = layer_energy_j(st, vdd=0.80)
                if prev_c is not None:
                    assert c <= prev_c + 1e-9
                    assert e < prev_e          # power gating strictly helps
                prev_c, prev_e = c, e

    def test_cycles_energy_monotone_in_span_budget(self):
        """Tighter attention spans (and fewer active heads) are monotone
        nonincreasing in cycles AND energy."""
        base = _stats()
        budgets = [
            (64,) * 12,                  # full spans, all heads
            (32,) * 12,
            (0,) * 4 + (32,) * 8,        # 4 heads gated off
            (0,) * 8 + (16,) * 4,
        ]
        for S in (32, 64):
            prev_c, prev_e = None, None
            for spans in budgets:
                st = scale_stats_to_seq_len(
                    deployment_stats(
                        base, _dep(occupancy=1.0, spans=spans)
                    ),
                    S,
                )
                c = layer_cycles(st, use_span=True)
                e = layer_energy_j(st, vdd=0.80)
                if prev_c is not None:
                    assert c <= prev_c + 1e-9
                    assert e <= prev_e + 1e-15
                prev_c, prev_e = c, e

    def test_analytic_storage_matches_bitmask_accounting(self):
        """TaskDeployment.storage() is the analytic mirror of
        bitmask.storage_bytes over the actual pruned arrays."""
        rng = np.random.default_rng(0)
        w = rng.standard_normal((256, 128)).astype(np.float32)
        w[rng.random(w.shape) < 0.6] = 0.0          # ~60% pruned
        occ = float((w != 0).mean())
        dep = TaskDeployment("t", n_params=w.size, pruning_occupancy=occ)
        measured = measured_footprint({"w": w}, dep.fmt)
        analytic = dep.storage()
        assert measured["mask_bytes"] == analytic["mask_bytes"]
        assert measured["value_bytes"] == pytest.approx(
            analytic["value_bytes"], rel=1e-6
        )


# ===========================================================================
# Residency manager: bounded SRAM working set over eNVM
# ===========================================================================


class TestResidencyManager:
    def _three_tasks(self):
        deps = [_dep(t, occupancy=0.4, spans=None) for t in ("a", "b", "c")]
        foot = deps[0].storage()["total_bytes"]
        # SRAM fits exactly two of the three tasks
        return TaskResidencyManager(deps, sram_bytes=2 * foot), foot

    def test_lru_eviction_and_swap_telemetry(self):
        m, foot = self._three_tasks()
        assert m.pending_swap_stall_s("a") > 0.0      # nothing resident yet
        s1 = m.acquire("a")
        assert s1 == pytest.approx(m.swap_cost("a")["latency_s"])
        assert m.acquire("a") == 0.0                  # hit, LRU-touched
        assert m.pending_swap_stall_s("a") == 0.0
        m.acquire("b")
        assert m.resident_set == ("a", "b")
        m.acquire("c")                                # evicts LRU = a
        assert m.resident_set == ("b", "c")
        m.acquire("a")                                # evicts b
        assert m.resident_set == ("c", "a")
        t = m.telemetry()
        assert t["task_swaps"] == 4
        assert t["evictions"] == 2
        assert t["residency_hits"] == 1
        assert t["swap_stall_s"] == pytest.approx(4 * s1)
        assert t["swap_energy_j"] == pytest.approx(
            4 * m.swap_cost("a")["energy_j"]
        )
        assert t["resident_bytes"] <= t["sram_bytes"]

    def test_sparser_deployment_swaps_cheaper(self):
        """The swap prices the SPARSE-ENCODED footprint: heavier pruning /
        narrower AdaptivFloat moves fewer bytes off the eNVM."""
        lo = _dep("lo", occupancy=0.2, spans=None).swap_cost()
        hi = _dep("hi", occupancy=0.8, spans=None).swap_cost()
        assert lo["bytes"] < hi["bytes"]
        assert lo["latency_s"] < hi["latency_s"]
        assert lo["energy_j"] < hi["energy_j"]
        # and it is exactly the hwmodel's task_swap_cost of that footprint
        s = _dep("lo", occupancy=0.2, spans=None).storage()
        assert lo == task_swap_cost(s["value_bytes"], s["mask_bytes"])

    def test_unmanaged_task_is_free(self):
        m, _ = self._three_tasks()
        assert m.acquire(None) == 0.0
        assert m.acquire("unknown") == 0.0
        assert m.pending_swap_stall_s("unknown") == 0.0
        assert m.task_swaps == 0


# ===========================================================================
# eNVM fault injection against the serving path (never silent)
# ===========================================================================


class TestEnvmReadback:
    def _manager(self):
        return TaskResidencyManager(
            [_dep("t", occupancy=0.5, spans=None)], sram_bytes=1e9
        )

    def test_paper_cell_config_roundtrips_clean(self):
        """SLC mask + MLC2 data (the paper's deployment): the readback of a
        realistic weight array injects no faults at these BERs and the task
        is NOT flagged degraded — zeros exact, values AF-quantized."""
        m = self._manager()
        rng = np.random.default_rng(1)
        w = rng.standard_normal((96, 64)).astype(np.float32)
        w[rng.random(w.shape) < 0.5] = 0.0
        out, stats = m.load_from_envm(
            "t", {"w": w}, data_cell="MLC2", mask_cell="SLC", seed=0
        )
        assert stats["n_mask_bit_flips"] == 0
        assert stats["n_code_faults"] == 0
        assert "t" not in m.degraded_tasks
        # pruned zeros survive exactly (the bitmask IS the pruning mask);
        # tiny nonzeros may flush to AdaptivFloat's smallest level
        assert np.all(out["w"][w == 0] == 0)
        nz = w != 0
        rel = np.abs(out["w"][nz] - w[nz]) / np.abs(w[nz])
        assert np.median(rel) < 0.05          # 8-bit AdaptivFloat quantization

    def test_mlc3_degrades_detectably_not_silently(self):
        """MLC3's BER injects real faults: the readback is corrupted AND the
        degraded_tasks telemetry flag raises — never silent corruption."""
        m = self._manager()
        rng = np.random.default_rng(2)
        w = rng.standard_normal((128, 128)).astype(np.float32)
        _, stats = m.load_from_envm("t", {"w": w}, data_cell="MLC3", seed=0)
        assert stats["n_code_faults"] > 0
        assert "t" in m.degraded_tasks
        assert "t" in m.telemetry()["degraded_tasks"]


# ===========================================================================
# Serving integration: quotes, the shared clock, and affinity stepping
# ===========================================================================


def _albert_model(threshold=0.6):
    cfg = get_smoke_config("albert_edgebert")
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    cfg = cfg.with_edgebert(
        early_exit=dataclasses.replace(
            cfg.edgebert.early_exit, entropy_threshold=threshold
        )
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params, cfg


def _smoke_controller(cfg, target_mult=4.0):
    s = albert_layer_stats(seq_len=32)
    s.n_layers = cfg.n_layers
    target = no_early_exit_baseline(s)["latency_s"] * target_mult
    return LatencyAwareDVFSController(s, target)


class TestServingIntegration:
    def test_resident_task_quotes_strictly_cheaper(self):
        """Acceptance criterion: the identical explicit-SLO request is quoted
        strictly cheaper once its task is SRAM-resident — the non-resident
        quote carries exactly the modeled swap stall (x headroom)."""
        model, params, cfg = _albert_model()
        dep = _dep("mnli", occupancy=0.4, spans=None)
        res = TaskResidencyManager([dep], sram_bytes=1e9)
        server = ClassifierServer(
            model, params, batch_lanes=2, buckets=(32,),
            arbiter=BatchedDVFSArbiter(_smoke_controller(cfg)),
            task="mnli", residency=res, deployment=dep,
        )
        adm = AdmissionController(server, headroom=1.25)
        req = Request(uid=0, tokens=np.arange(8), deadline_s=10.0)
        q_miss = adm.quote(req)
        res.acquire("mnli")                    # swap the task in
        q_hit = adm.quote(req)
        assert q_hit.min_deadline_s < q_miss.min_deadline_s
        stall = dep.swap_cost()["latency_s"]
        assert q_miss.min_deadline_s - q_hit.min_deadline_s == pytest.approx(
            stall * adm.headroom
        )

    def test_compressed_deployment_lowers_quoted_service(self):
        """Acceptance criterion: a compressed TaskDeployment measurably
        lowers the quoted cycles/service time vs pricing dense work."""
        model, params, cfg = _albert_model()
        dep = _dep("mnli")                     # pruned + span-budgeted
        mk = lambda d: ClassifierServer(
            model, params, batch_lanes=2, buckets=(32,),
            arbiter=BatchedDVFSArbiter(_smoke_controller(cfg)),
            task="mnli", deployment=d,
        )
        dense, compressed = mk(None), mk(dep)
        assert compressed._cycles_for(32) < dense._cycles_for(32)
        req = Request(uid=0, tokens=np.arange(8), deadline_s=10.0)
        q_dense = AdmissionController(dense).quote(req)
        q_comp = AdmissionController(compressed).quote(req)
        assert q_comp.service_s < q_dense.service_s
        assert q_comp.min_deadline_s < q_dense.min_deadline_s

    def test_swap_stall_burns_shared_clock(self):
        """A non-resident refill fast-forwards the shared arbiter clock by
        the swap stall (wall time, not compute), and the scheduler clock
        follows."""
        model, params, cfg = _albert_model()
        dep = _dep("mnli", occupancy=0.4, spans=None)
        res = TaskResidencyManager([dep], sram_bytes=1e9)
        arb = BatchedDVFSArbiter(_smoke_controller(cfg))
        server = ClassifierServer(
            model, params, batch_lanes=2, buckets=(32,),
            arbiter=arb, task="mnli", residency=res, deployment=dep,
        )
        server.submit(Request(uid=0, tokens=np.arange(8)))
        server.step()
        stall = dep.swap_cost()["latency_s"]
        assert res.task_swaps == 1
        assert arb.now_s >= stall
        assert server.sched.now_s >= stall

    def test_affinity_batches_tasks_and_bounds_swaps(self):
        """Acceptance criteria: under a working set smaller than the task
        count, affinity-aware stepping swaps each task in ONCE (batching
        same-task work while slack permits) while residency-blind EDF
        thrashes; no accepted-SLO misses; no extra jit traces."""
        model, params, cfg = _albert_model()
        n_req = 4

        def run(policy):
            deps = {
                t: _dep(t, occupancy=0.4, spans=None)
                for t in ("mnli", "qqp", "sst2")
            }
            foot = deps["mnli"].storage()["total_bytes"]
            res = TaskResidencyManager(deps, sram_bytes=2 * foot)
            router = ResidencyRouter(
                model, params["embed"],
                {t: params for t in deps},
                residency=res, deployments=deps, task_policy=policy,
                arbiter=BatchedDVFSArbiter(_smoke_controller(cfg)),
                buckets=(32,), batch_lanes=2,    # two refill waves per task
            )
            data = SyntheticCLS(cfg.vocab_size, 32, 16, num_classes=3, seed=0)
            b = data.batch(0)
            # round-robin storm with rotating deadline order: the globally
            # most-urgent request alternates tasks, so blind EDF thrashes
            for i in range(3 * n_req):
                t = ("mnli", "qqp", "sst2")[i % 3]
                router.submit(t, Request(
                    uid=i, tokens=b["tokens"][i][:8],
                    deadline_s=5.0 + i * 1e-4,
                ))
            out = router.run_all()
            assert set(out) == {"mnli", "qqp", "sst2"}
            for tel in out.values():
                assert tel["accepted_slo_misses"] == 0
                assert tel["step_traces"] <= 1        # one bucket, one trace
            assert all(
                len(router.tasks[t].done) == n_req for t in out
            )
            return router

        # affinity: each task swapped in exactly once, then batched through
        aff = run(TaskAffinityPolicy())
        assert aff.residency.task_swaps == 3
        # blind EDF chases the rotating deadlines across non-co-resident
        # tasks: strictly more swaps and strictly more swap stall
        blind = run(BlindEDFTaskPolicy())
        assert blind.residency.task_swaps > aff.residency.task_swaps
        assert blind.residency.swap_stall_s > aff.residency.swap_stall_s
        assert blind.task_switches > aff.task_switches
