"""Characterization test for the just-in-time deferral tail.

``benchmarks/harness/README.md`` documents a known limitation of the
affinity scheduler's just-in-time deferral under SUSTAINED bursty
multi-task load: a small explicit-completion tail (~8e-4 at 10^5 requests
of the seeded ``mmpp_multitask`` scenario) misses its admitted SLO because
the per-task quote cannot see how long the affinity policy will legally
defer a non-resident task once every wave of the burst lands at once.

This test PINS that characterization so the tail can only shrink:

* the tail EXISTS (misses > 0) — if a change eliminates it, the README's
  limitation paragraph is stale and this test should be updated along
  with it;
* the explicit-completion miss rate stays within the documented bound
  (<= 1e-3, measured 8.2e-4 at 10^5 requests, 3.5e-4 at the CI-sized
  2x10^4 replay);
* best-effort traffic never counts toward the tail (no SLO to miss);
* request conservation and the zero-new-traces invariant hold across the
  whole replay.

The always-on test replays 2x10^4 requests (~2 min on the modeled clock's
host replay).  The full 10^5-request characterization — the exact run the
README documents — is gated behind ``REPRO_TAIL_FULL=1`` since it holds a
tier-1 slot for several minutes.
"""
import os
import sys

import pytest

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks.harness.run_harness import _model_and_controller, run_once
from benchmarks.harness.scenarios import SCENARIOS

TAIL_BOUND = 1e-3          # documented: ~8e-4 at 10^5 requests
SEED = 0                   # the documented seeded replay


def _replay(n_requests):
    spec = SCENARIOS["mmpp_multitask"]
    model, params, cfg, buckets, ctrl_factory = _model_and_controller(
        spec, trained=False, target_mult=1.5
    )
    return run_once(
        spec, n_requests, SEED, model, params, cfg, buckets, ctrl_factory
    )


def _characterize(summary):
    explicit = summary["per_tier"]["explicit"]
    best_effort = summary["per_tier"]["best_effort"]
    tail = explicit["slo_misses"] / explicit["completed"]

    # the documented tail exists and stays within its bound
    assert explicit["slo_misses"] > 0, (
        "deferral tail vanished — update the README's known-limitation "
        "paragraph and this characterization together"
    )
    assert tail <= TAIL_BOUND, (
        f"explicit-completion deferral tail {tail:.2e} exceeds the "
        f"documented bound {TAIL_BOUND:.0e}"
    )
    # only explicit contracts can miss (best-effort has no SLO)
    assert best_effort["slo_misses"] == 0
    assert summary["accepted_slo_misses"] == explicit["slo_misses"]

    # replay-wide invariants the tail must not hide behind: request
    # conservation, and one compile per (bucket, replica)
    assert (
        summary["completed"] + summary["rejected"] + summary["shed"]
        == summary["submitted"]
    )
    assert summary["max_traces_per_bucket_replica"] <= 1
    return tail


class TestDeferralTailCharacterization:
    def test_seeded_mmpp_replay_tail_bounded(self):
        summary = _replay(20_000)
        tail = _characterize(summary)
        # CI-sized replay of the same seed: the burst structure that causes
        # the tail is already present at 2x10^4 requests
        assert summary["requests"] == 20_000
        assert tail <= TAIL_BOUND

    @pytest.mark.skipif(
        os.environ.get("REPRO_TAIL_FULL") != "1",
        reason="full 10^5-request characterization (set REPRO_TAIL_FULL=1)",
    )
    def test_full_100k_replay_matches_documented_tail(self):
        summary = _replay(100_000)
        tail = _characterize(summary)
        assert summary["requests"] == 100_000
        # the README's number: ~8e-4 (measured 8.2e-4) — pin the order of
        # magnitude, not the exact count, so scheduler improvements that
        # SHRINK the tail don't churn this test
        assert tail <= TAIL_BOUND
