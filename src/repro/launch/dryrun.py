import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), record memory/cost analysis and the
three-term roofline (DESIGN.md §7).

Usage:
    python -m repro.launch.dryrun --arch qwen1_5_110b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all            # orchestrates subprocesses
    python -m repro.launch.dryrun --all --mesh multi

Results append to benchmarks/results/dryrun.json (one record per cell),
which EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/bench_roofline.py
read back.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.util import human_bytes, logger
from repro.configs.base import (
    ARCH_IDS,
    SHAPES_BY_NAME,
    ModelConfig,
    ShapeConfig,
    get_config,
    shape_applicable,
)
from repro.data.synthetic import make_batch_specs
from repro.hwmodel.roofline import (
    TPUV5E,
    collective_bytes_from_hlo,
    model_flops,
    roofline_report,
)
from repro.hwmodel.hlo_analysis import analyze as hlo_analyze
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model, build_model
from repro.sharding.rules import (
    batch_shardings,
    cache_shardings,
    param_shardings,
    rules_for,
)
from repro.sharding.zero1 import zero1_opt_shardings
from repro.training.optim import AdamWConfig, adamw_init
from repro.training.train_loop import make_loss_fn
from repro.training.optim import adamw_update

RESULTS_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))),
    "benchmarks", "results", "dryrun.json",
)


def _abstract_params(model: Model):
    return jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))


def _abstract(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def _count_params(tree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(tree) if hasattr(l, "shape")))


def _active_param_count(cfg: ModelConfig, params_abs) -> int:
    """Exact param count scaled for MoE activation (top_k/n_experts on expert
    leaves) — the N in 6ND."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_abs)[0]:
        if not hasattr(leaf, "shape"):
            continue
        n = int(np.prod(leaf.shape))
        pstr = jax.tree_util.keystr(path)
        if "embed" in pstr and "proj" not in pstr:
            continue  # embeddings excluded from 6ND (lookup, not matmul)
        if cfg.family == "moe" and "/moe'" in pstr.replace('"', "'") or (
            cfg.family == "moe" and "moe" in pstr and "w_" in pstr and "shared" not in pstr
        ):
            n = int(n * cfg.top_k / max(cfg.n_experts, 1))
        if cfg.shared_layers and "'layer'" in pstr:
            n = n * cfg.n_layers
        total += n
    return total


def _useful_bytes_per_device(cfg, shape, params_abs, n_chips: int) -> float:
    """Minimum mandatory HBM traffic per device per step: every resident
    param shard read once (+written once with moments for train: x4 for
    bf16 p+g and fp32 m+v r/w approximation), plus decode KV/state I/O."""
    from repro.common.util import tree_size_bytes

    params_bytes = tree_size_bytes(params_abs) / n_chips
    if shape.kind == "train":
        # read p, write p, read+write m,v (fp32 = 2x bf16), read g
        useful = params_bytes * (1 + 1 + 1 + 4 * 2)
    elif shape.kind == "prefill":
        tokens_local = shape.global_batch * shape.seq_len / n_chips
        act = tokens_local * cfg.d_model * 2 * cfg.n_layers  # one r/w per layer
        useful = params_bytes + act
    else:  # decode: params + full KV/state read + one-column write
        kv_b = 1 if cfg.kv_cache_dtype == "af8" else 2
        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            kv = (
                2 * cfg.n_layers * shape.global_batch * shape.seq_len
                * cfg.n_kv_heads * cfg.head_dim * kv_b
            ) / n_chips
        elif cfg.family == "hybrid":
            n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
            kv = (
                2 * n_attn * shape.global_batch * shape.seq_len
                * cfg.n_kv_heads * cfg.head_dim * kv_b
            ) / n_chips
            kv += (
                cfg.n_layers * shape.global_batch
                * (2 * cfg.d_model // cfg.ssm_head_dim) * cfg.ssm_head_dim
                * cfg.ssm_state * 2 * 2
            ) / n_chips
        else:  # ssm
            kv = (
                cfg.n_layers * shape.global_batch * cfg.n_heads
                * cfg.head_dim * cfg.head_dim * 4 * 2
            ) / n_chips
        useful = params_bytes + kv
    return float(useful)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, microbatches: int = 8):
    """Returns (jitted_fn, example_args_abstract) for this cell's step.

    Training uses `microbatches`-way gradient accumulation (activation memory
    scales down by the same factor; recorded in the dry-run record)."""
    model = build_model(cfg)
    rules = rules_for(cfg, mesh, shape)
    params_abs = _abstract_params(model)
    p_shard = param_shardings(params_abs, mesh, rules)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        loss_fn = make_loss_fn(model)
        k = microbatches

        def train_step(params, opt_state, batch):
            if k > 1:
                mb = jax.tree_util.tree_map(
                    lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch
                )

                def micro(acc, b):
                    (loss, metrics), grads = jax.value_and_grad(
                        lambda p: loss_fn(p, b), has_aux=True
                    )(params)
                    acc = jax.tree_util.tree_map(
                        lambda a, g: a + g.astype(jnp.float32), acc, grads
                    )
                    return acc, loss

                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                grads, losses = jax.lax.scan(micro, zeros, mb)
                grads = jax.tree_util.tree_map(lambda g: g / k, grads)
                loss = jnp.mean(losses)
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: loss_fn(p, batch), has_aux=True
                )(params)
            params, opt_state, om = adamw_update(grads, opt_state, params, opt_cfg)
            return params, opt_state, loss

        opt_abs = _abstract(adamw_init, params_abs)
        o_shard = zero1_opt_shardings(opt_abs, p_shard, mesh)
        batch_abs = make_batch_specs(cfg, shape)
        b_shard = batch_shardings(batch_abs, mesh, rules)
        fn = jax.jit(
            train_step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, NamedSharding(mesh, P())),
            donate_argnums=(0, 1),
        )
        args = (params_abs, opt_abs, batch_abs)
        n_tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        cache_abs = _abstract(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )
        c_shard = cache_shardings(cache_abs, mesh, rules, cfg)
        batch_abs = make_batch_specs(cfg, shape)
        b_shard = batch_shardings(batch_abs, mesh, rules)

        aux_keys = [k for k in batch_abs if k not in ("tokens",)]

        def prefill_fn(params, tokens, cache, aux):
            return model.prefill(params, tokens, cache, aux=aux)

        fn = jax.jit(
            prefill_fn,
            in_shardings=(
                p_shard,
                b_shard["tokens"],
                c_shard,
                {k: b_shard[k] for k in aux_keys},
            ),
            out_shardings=(NamedSharding(mesh, P()), c_shard),
            donate_argnums=(2,),
        )
        args = (
            params_abs,
            batch_abs["tokens"],
            cache_abs,
            {k: batch_abs[k] for k in aux_keys},
        )
        n_tokens = shape.global_batch * shape.seq_len
    else:  # decode
        cache_abs = _abstract(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )
        c_shard = cache_shardings(cache_abs, mesh, rules, cfg)
        tokens_abs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
        tok_shard = batch_shardings({"tokens": tokens_abs}, mesh, rules)["tokens"]
        # batch-1 long-context: tokens replicated, KV seq sharded instead
        cb = rules.mesh_axis("cache_batch")
        if cb is None:
            tok_shard = NamedSharding(mesh, P())
        logits_shard = tok_shard

        def decode_fn(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos)

        fn = jax.jit(
            decode_fn,
            in_shardings=(p_shard, c_shard, tok_shard, NamedSharding(mesh, P())),
            out_shardings=(logits_shard, c_shard),
            donate_argnums=(1,),
        )
        args = (params_abs, cache_abs, tokens_abs, pos_abs)
        n_tokens = shape.global_batch  # one token per sequence per step
    return fn, args, params_abs, n_tokens


VARIANT_FLAGS = {
    # beyond-paper optimization stacks for §Perf hillclimbing
    "fused": dict(fused_attention=True),
    "sp": dict(sequence_parallel=True),
    "fused+sp": dict(fused_attention=True, sequence_parallel=True),
    "af8kv": dict(kv_cache_dtype="af8"),
    "fused+af8kv": dict(fused_attention=True, kv_cache_dtype="af8"),
    "moegroup": dict(moe_grouped_dispatch=True),
    "fused+moegroup": dict(fused_attention=True, moe_grouped_dispatch=True),
    "moegroup2": dict(moe_grouped_dispatch=True, moe_buffer_sharded=True),
    "fused+moegroup2": dict(
        fused_attention=True, moe_grouped_dispatch=True, moe_buffer_sharded=True
    ),
    "moeshmap": dict(moe_shardmap_dispatch=True),
    "fused+moeshmap": dict(fused_attention=True, moe_shardmap_dispatch=True),
    "fused+sp+moegroup": dict(
        fused_attention=True, sequence_parallel=True, moe_grouped_dispatch=True
    ),
    "ssmrep": dict(ssm_replicated=True),
    "fused+ssmrep": dict(fused_attention=True, ssm_replicated=True),
    "hybridgroup": dict(hybrid_grouped=True),
    "fused+hybridgroup": dict(fused_attention=True, hybrid_grouped=True),
    "opt": dict(
        fused_attention=True, sequence_parallel=True,
        moe_grouped_dispatch=True,
    ),
}


def run_cell(arch: str, shape_name: str, multi_pod: bool, microbatches: int = 8,
             variant: str = "baseline") -> Dict[str, Any]:
    import dataclasses

    cfg = get_config(arch)
    if variant != "baseline":
        over = dict(VARIANT_FLAGS[variant])
        if over.get("sequence_parallel"):
            over["sp_batch_axes"] = ("pod", "data") if multi_pod else ("data",)
        cfg = dataclasses.replace(cfg, **over)
    shape = SHAPES_BY_NAME[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "variant": variant,
        "time": time.time(),
    }
    if not shape_applicable(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = (
            "long_500k reserved for sub-quadratic families (ssm/hybrid); "
            f"{cfg.family} is full-attention — see DESIGN.md §4"
        )
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    fn, args, params_abs, n_tokens = build_cell(cfg, shape, mesh, microbatches=microbatches)
    rec["microbatches"] = microbatches if shape.kind == "train" else 1
    with jax.set_mesh(mesh):  # set_mesh (not `with mesh:`) so shard_map
        # regions (moeshmap variant) see the abstract mesh
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # ---- memory analysis ----
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k))
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
            )
            if hasattr(ma, k)
        }
        if not rec["memory_analysis"]:
            rec["memory_analysis"] = {"repr": str(ma)[:2000]}
    except Exception as e:  # CPU backend may not implement it
        rec["memory_analysis"] = {"error": str(e)[:200]}

    # ---- cost analysis (recorded for cross-check only: XLA counts scan
    # bodies ONCE, ignoring trip counts — see hwmodel/hlo_analysis.py) ----
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0))),
            "caveat": "scan bodies counted once; roofline uses hlo_analysis",
        }
    except Exception as e:
        rec["cost_analysis"] = {"error": str(e)[:200]}

    # ---- trip-count-aware HLO analysis (primary roofline source) ----
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    costs = hlo_analyze(hlo)
    flops = costs.flops
    bytes_accessed = costs.bytes_io
    coll = {
        "bytes_total": costs.coll_bytes,
        **{f"bytes_{k}": v for k, v in costs.coll_by_kind.items()},
        "n_while": costs.n_while,
        "max_trip": costs.max_trip,
    }
    rec["hlo_analysis"] = {
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": costs.coll_bytes,
    }
    rec["collectives"] = coll

    # ---- roofline ----
    n_active = _active_param_count(cfg, params_abs)
    mf = model_flops(n_active, n_tokens, shape.kind)
    rec["n_params"] = _count_params(params_abs)
    rec["n_params_active"] = n_active
    rec["roofline"] = roofline_report(
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=bytes_accessed,
        collective_bytes_per_device=coll["bytes_total"],
        n_chips=n_chips,
        model_flops_global=mf,
        useful_bytes_per_device=_useful_bytes_per_device(
            cfg, shape, params_abs, n_chips
        ),
    )
    rec["status"] = "ok"
    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    return rec


def append_result(rec: Dict[str, Any], path: str = RESULTS_PATH):
    import fcntl

    os.makedirs(os.path.dirname(path), exist_ok=True)
    lock_path = path + ".lock"
    with open(lock_path, "w") as lock:
        fcntl.flock(lock, fcntl.LOCK_EX)   # concurrent sweeps are safe
        results = []
        if os.path.exists(path):
            with open(path) as f:
                results = json.load(f)
        # replace same-key record
        key = (rec["arch"], rec["shape"], rec["mesh"], rec.get("variant", "baseline"))
        results = [
            r for r in results
            if (r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline")) != key
        ]
        results.append(rec)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(results, f, indent=1)
        os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default=None)
    ap.add_argument("--shape", choices=list(SHAPES_BY_NAME), default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline"] + list(VARIANT_FLAGS))
    args = ap.parse_args()

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)

    if args.all:
        failures = []
        for arch in ARCH_IDS:
            for shape in SHAPES_BY_NAME:
                for mesh in meshes:
                    cmd = [
                        sys.executable, "-m", "repro.launch.dryrun",
                        "--arch", arch, "--shape", shape, "--mesh", mesh,
                        "--microbatches", str(args.microbatches),
                    ]
                    print(f"=== {arch} x {shape} x {mesh} ===", flush=True)
                    r = subprocess.run(cmd, timeout=args.timeout)
                    if r.returncode != 0:
                        failures.append((arch, shape, mesh))
        print("FAILURES:", failures if failures else "none")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape
    for mesh in meshes:
        try:
            rec = run_cell(args.arch, args.shape, multi_pod=(mesh == "multi"),
                           microbatches=args.microbatches, variant=args.variant)
        except Exception as e:
            rec = {
                "arch": args.arch, "shape": args.shape, "mesh": mesh,
                "variant": args.variant,
                "status": "error", "error": str(e)[:500],
                "traceback": traceback.format_exc()[-2000:],
            }
        append_result(rec)
        status = rec["status"]
        if status == "ok":
            rl = rec["roofline"]
            print(
                f"{args.arch} {args.shape} {mesh} [{args.variant}]: OK "
                f"compile={rec['compile_s']}s dominant={rl['dominant']} "
                f"t=({rl['t_compute_s']:.3e},{rl['t_memory_s']:.3e},{rl['t_collective_s']:.3e})s "
                f"useful={rl['useful_flops_ratio']:.2f} roofline={rl['roofline_fraction']:.3f}"
            )
        else:
            print(f"{args.arch} {args.shape} {mesh}: {status} {rec.get('reason', rec.get('error',''))}")
            if status == "error":
                print(rec.get("traceback", ""))
                sys.exit(1)


if __name__ == "__main__":
    main()
