"""Sentence-level DVFS (paper Alg. 1): energy at a prescribed target latency.

Drains a request queue through the fixed-shape continuation-batching
``ClassifierServer`` with a ``LatencyAwareDVFSController`` attached, then
compares modeled accelerator energy against the paper's two reference points
at the SAME target latency (the no-early-exit baseline's full-model latency):

  * ``dvfs_no_early_exit`` — conventional inference: all layers, max V/f;
  * ``dvfs_ee_max_freq``   — latency-unbounded early exit, max V/f;
  * ``dvfs_controller``    — Alg. 1: exit-layer prediction from the first
    off-ramp entropy picks the slowest (V, f) that still meets the target.

Also regression-checks the engine's compile telemetry: the fused masked step
must trace exactly once per lane count across the full queue drain.

Usage:
  python benchmarks/bench_dvfs.py            # trained toy EdgeBERT
  python benchmarks/bench_dvfs.py --smoke    # untrained weights, CI-fast
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, trained_albert
from repro.configs.base import get_smoke_config
from repro.data.synthetic import SyntheticCLS
from repro.hwmodel.edgebert_accel import albert_layer_stats
from repro.models.model import build_model
from repro.serving.dvfs import (
    LatencyAwareDVFSController,
    calibrate_predictor,
    no_early_exit_baseline,
)
from repro.serving.engine import ClassifierServer, Request

LANES = 4


def _with_threshold(cfg, threshold: float):
    return cfg.with_edgebert(
        early_exit=dataclasses.replace(
            cfg.edgebert.early_exit, entropy_threshold=float(threshold)
        )
    )


def _setup(smoke: bool):
    if smoke:
        cfg = dataclasses.replace(
            get_smoke_config("albert_edgebert"), dtype="float32", remat_policy="none"
        )
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        data = SyntheticCLS(cfg.vocab_size, 32, 16, num_classes=3, seed=0)
    else:
        model, params, _, data, cfg = trained_albert()
    # pick a threshold that spreads exits across layers: the median entropy of
    # ALL off-ramps guarantees some sentences exit at layer 1 and some later
    out = model.apply_train(params, {"tokens": jnp.asarray(data.batch(0)["tokens"])})
    thr = float(np.quantile(np.asarray(out.all_entropies), 0.5))
    cfg = _with_threshold(cfg, thr)
    model = build_model(cfg)
    return model, params, cfg, data, thr


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--smoke", action="store_true", help="untrained weights, CI-fast")
    parser.add_argument("--queue", type=int, default=None, help="sentences to drain")
    args, _ = parser.parse_known_args()  # tolerate the suite runner's argv

    model, params, cfg, data, thr = _setup(args.smoke)
    n_queue = args.queue if args.queue is not None else (16 if args.smoke else 48)
    assert n_queue > 0, "--queue must be positive"
    seq_len = data.seq_len

    # offline Alg. 1 LUT calibration on dense profiling passes; the target
    # latency below has ZERO slack over the full-model latency, so use the
    # conservative per-bin prediction (quantile=1.0) — underprediction at a
    # slack-free target always overshoots (escalation to max V/f cannot
    # recapture time already spent at a slow operating point)
    predictor = calibrate_predictor(
        model,
        params,
        [data.batch(100 + i) for i in range(2 if args.smoke else 6)],
        quantile=1.0,
    )

    stats = albert_layer_stats(seq_len=seq_len)
    stats.n_layers = cfg.n_layers
    # EQUAL TARGET LATENCY: the controller gets exactly the latency the
    # conventional (no-early-exit, max-frequency) baseline needs
    target = no_early_exit_baseline(stats)["latency_s"]
    controller = LatencyAwareDVFSController(stats, target, predictor=predictor)

    server = ClassifierServer(model, params, batch_lanes=LANES, dvfs=controller)
    for i in range(n_queue):
        b = data.batch(200 + i // data.global_batch)
        server.submit(Request(uid=i, tokens=b["tokens"][i % data.global_batch]))
    stats_out = server.run()

    exits = [server.done[i].exit_layer for i in range(n_queue)]
    e_dvfs = stats_out["energy_j"]
    e_noee = n_queue * controller.no_early_exit_baseline()["energy_j"]
    e_eemax = controller.max_freq_early_exit_baseline(exits)["energy_j"]
    misses = stats_out["deadline_misses"]

    emit(
        "dvfs_no_early_exit", 0.0,
        f"energy_j={e_noee:.4e};latency_target_s={target:.4e}",
    )
    emit(
        "dvfs_ee_max_freq", 0.0,
        f"energy_j={e_eemax:.4e};vs_no_ee={e_noee / e_eemax:.2f}x",
    )
    emit(
        "dvfs_controller", 0.0,
        f"energy_j={e_dvfs:.4e};vs_no_ee={e_noee / e_dvfs:.2f}x;"
        f"vs_ee_max={e_eemax / e_dvfs:.2f}x;avg_exit={np.mean(exits):.2f}/"
        f"{cfg.n_layers};threshold={thr:.3f};deadline_misses={misses}",
    )
    emit(
        "dvfs_engine_compiles", 0.0,
        f"step_traces={stats_out['step_traces']};embed_traces="
        f"{stats_out['embed_traces']};lane_occupancy={stats_out['lane_occupancy']:.2f}",
    )

    ok = True
    if e_dvfs >= e_noee:
        print(f"FAIL: controller energy {e_dvfs:.3e} !< no-early-exit {e_noee:.3e}")
        ok = False
    if stats_out["step_traces"] != 1:
        print(f"FAIL: fused step traced {stats_out['step_traces']}x (want 1)")
        ok = False
    if misses:
        # only out-of-calibration-distribution sentences can still miss (the
        # LUT stores each bin's max observed exit); report the overshoot
        worst = max(server.done[i].latency_s for i in range(n_queue))
        print(
            f"WARN: {misses}/{n_queue} sentences overshot the target "
            f"(worst {worst / target:.3f}x) — entropy outside the calibration range"
        )
    if not ok:
        sys.exit(1)
    print(
        f"OK: {e_noee / e_dvfs:.2f}x lower energy than no-early-exit at equal "
        f"target latency ({target * 1e3:.2f} ms); fused step compiled once"
    )


if __name__ == "__main__":
    main()
