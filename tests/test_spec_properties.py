"""Property tests for self-speculative decode (hypothesis where available,
deterministic seeded sweeps always).

Three properties the ISSUE's accept/verify restructuring must preserve:

* **Acceptance is monotone in draft/verify agreement** — the accepted prefix
  is exactly (1 + the leading run of slots whose off-ramp draft the verifier
  let stand), so more agreement can only lengthen it; across a monotone
  threshold sweep both mean agreement and mean acceptance rise together.
* **Realized energy per accepted token never exceeds full-depth decode** —
  each accepted token is charged its realized exit depth at an operating
  point the (lower) speculative layer demand can only relax.
* **Admission quotes never under-price realized latency** — random cls+dec
  mixes on ONE shared clock, every decode contract admitted AT its quoted
  minimum feasible deadline (the tightest promise the controller makes),
  speculative execution and a warm (tightened) calibrator included: zero
  accepted-SLO misses.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.configs.base import get_smoke_config
from repro.core.early_exit import ExitThresholdSchedule
from repro.hwmodel.edgebert_accel import albert_layer_stats
from repro.models.model import build_model
from repro.serving.admission import AdmissionController
from repro.serving.dvfs import (
    BatchedDVFSArbiter,
    LatencyAwareDVFSController,
    no_early_exit_baseline,
)
from repro.serving.engine import (
    ClassifierServer,
    DecoderServer,
    Request,
    probe_exit_threshold,
)

_W = 4


@pytest.fixture(scope="module")
def decoder():
    cfg = dataclasses.replace(
        get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none",
        n_layers=4,
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    return model, params, cfg


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(4, cfg.vocab_size, size=L).astype(np.int32) for L in lengths
    ]


def _spec_block(model, params, cfg, prompt, threshold):
    cache = model.init_cache(1, 16)
    for t in range(len(prompt) - 1):
        _, cache = model.decode_step(
            params, cache, jnp.asarray([[int(prompt[t])]]), t
        )
    _, _, _, xl, _, acc = model.decode_step_spec(
        params, cache, jnp.asarray([[int(prompt[-1])]]), len(prompt) - 1,
        threshold, _W,
    )
    return np.asarray(xl)[0], np.asarray(acc)[0]


def _accept_rule_invariants(xl, acc, n_layers):
    a = int(acc.sum())
    assert 1 <= a <= _W
    assert acc[:a].all() and not acc[a:].any()       # contiguous prefix
    agree = 0
    while agree < _W and xl[agree] < n_layers:
        agree += 1
    # acceptance = 1 + leading agreement run (capped at the window): strictly
    # monotone in agreement by construction, which is the property
    assert a == min(_W, agree + 1) or (agree == _W and a == _W)
    return a, agree


class TestAcceptanceMonotoneInAgreement:
    @pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
    @given(
        thr=st.floats(min_value=-2.0, max_value=12.0,
                      allow_nan=False, allow_infinity=False),
        seed=st.integers(min_value=0, max_value=31),
    )
    @settings(max_examples=12, deadline=None)
    def test_accept_rule_invariants_hold_for_random_inputs(
        self, decoder, thr, seed
    ):
        model, params, cfg = decoder
        prompt = _prompts(cfg, (5,), seed=seed)[0]
        xl, acc = _spec_block(model, params, cfg, prompt, thr)
        _accept_rule_invariants(xl, acc, cfg.n_layers)

    def test_seeded_sweep_acceptance_rises_with_agreement(self, decoder):
        """Deterministic always-on coverage: along a loosening threshold
        sweep, mean draft/verify agreement and mean acceptance move together
        and acceptance never decreases while agreement increases."""
        model, params, cfg = decoder
        prompts = _prompts(cfg, (5, 6, 4, 7, 5, 6), seed=13)
        rows = []
        for thr in (-1.0, 5.8, 6.0, 6.2, 6.6, np.inf):
            accs, agrees = [], []
            for p in prompts:
                xl, acc = _spec_block(model, params, cfg, p, thr)
                a, agree = _accept_rule_invariants(xl, acc, cfg.n_layers)
                accs.append(a / _W)
                agrees.append(agree / _W)
            rows.append((float(np.mean(agrees)), float(np.mean(accs))))
        agrees = [r[0] for r in rows]
        accs = [r[1] for r in rows]
        assert agrees == sorted(agrees)              # sweep loosens monotone
        assert accs == sorted(accs)
        assert accs[0] == 1.0 / _W                   # -inf-ish: verify-only
        assert accs[-1] == 1.0                       # +inf: full blocks
        # sorted by agreement, acceptance is non-decreasing (the property)
        by_agree = [a for _, a in sorted(rows)]
        assert by_agree == sorted(by_agree)


class TestEnergyPerAcceptedToken:
    def _drain(self, decoder, seed, threshold, spec_window):
        model, params, cfg = decoder
        prompts = _prompts(cfg, (6, 5, 7, 4), seed=seed)
        stats = albert_layer_stats(seq_len=16)
        stats.n_layers = cfg.n_layers
        target = no_early_exit_baseline(stats)["latency_s"] * 2.0
        arb = BatchedDVFSArbiter(LatencyAwareDVFSController(stats, target))
        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
            arbiter=arb, exit_threshold=threshold, spec_window=spec_window,
        )
        for i, p in enumerate(prompts):
            srv.submit(Request(
                uid=i, tokens=p, max_new_tokens=5, deadline_s=target * 10
            ))
        stt = srv.run()
        per_req = {
            i: srv.done[i].energy_j / len(srv.done[i].generated)
            for i in range(len(prompts))
        }
        return stt, per_req

    def test_seeded_sweep_energy_per_token_below_full_depth(self, decoder):
        model, params, cfg = decoder
        prompts = _prompts(cfg, (6, 5, 7, 4), seed=0)
        thr = probe_exit_threshold(
            model, params, prompts, max_new_tokens=5, quantile=0.8
        )
        for seed in (0, 1, 2):
            spec, spec_req = self._drain(decoder, seed, thr, _W)
            full, full_req = self._drain(decoder, seed, None, 1)
            assert spec["accepted_slo_misses"] == 0
            assert full["accepted_slo_misses"] == 0
            assert spec["tokens"] == full["tokens"]
            # aggregate AND per-request: energy per accepted token never
            # exceeds the full-depth decode of the same request
            assert (
                spec["energy_j"] / spec["tokens"]
                <= full["energy_j"] / full["tokens"] * (1 + 1e-9)
            )
            for i in spec_req:
                assert spec_req[i] <= full_req[i] * (1 + 1e-9), (seed, i)

    @pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
    @given(seed=st.integers(min_value=3, max_value=9))
    @settings(max_examples=3, deadline=None)
    def test_random_traffic_energy_per_token_below_full_depth(
        self, decoder, seed
    ):
        model, params, cfg = decoder
        thr = probe_exit_threshold(
            model, params, _prompts(cfg, (6, 5, 7, 4), seed=0),
            max_new_tokens=5, quantile=0.8,
        )
        spec, _ = self._drain(decoder, seed, thr, _W)
        full, _ = self._drain(decoder, seed, None, 1)
        assert (
            spec["energy_j"] / spec["tokens"]
            <= full["energy_j"] / full["tokens"] * (1 + 1e-9)
        )


class TestAdmissionNeverUnderPrices:
    """Random cls+dec mixes on one shared clock: every decode contract is
    admitted AT its quoted minimum feasible deadline (``requote`` of an
    impossible SLO), the decoder runs speculatively off a warm calibrator's
    tightened predictions, and the admission contract must still hold —
    zero accepted-SLO misses."""

    @pytest.fixture(scope="class")
    def classifier(self):
        cfg = dataclasses.replace(
            get_smoke_config("albert_edgebert"), dtype="float32",
            remat_policy="none",
        )
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        return model, params, cfg

    def _mix(self, decoder, classifier, seed, *, spec_window, warm):
        model, params, cfg = decoder
        cmodel, cparams, ccfg = classifier
        rng = np.random.default_rng(seed)
        stats = albert_layer_stats(seq_len=32)
        stats.n_layers = cfg.n_layers
        target = no_early_exit_baseline(stats)["latency_s"] * 2.0
        arb = BatchedDVFSArbiter(LatencyAwareDVFSController(stats, target))
        thr = probe_exit_threshold(
            model, params, _prompts(cfg, (6, 5, 7, 4), seed=0),
            max_new_tokens=4, quantile=0.8,
        )
        dec = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
            arbiter=arb, exit_threshold=thr, spec_window=spec_window,
            threshold_schedule=ExitThresholdSchedule(thr),
        )
        cls = ClassifierServer(
            cmodel, cparams, batch_lanes=2, arbiter=arb, buckets=(16, 32),
        )
        if warm:
            # tighten the calibrator so quotes really use speculative-
            # informed (sub-full-depth) predictions before the storm
            for i, p in enumerate(_prompts(cfg, (5, 6), seed=99)):
                dec.submit(Request(
                    uid=900 + i, tokens=p, max_new_tokens=4,
                    deadline_s=target * 100,
                ))
            dec.run()
        n_cls = int(rng.integers(2, 6))
        n_dec = int(rng.integers(2, 6))
        for i in range(n_cls):
            L = int(rng.integers(5, 30))
            cls.submit(Request(
                uid=i, tokens=rng.integers(4, ccfg.vocab_size, size=L)
            ))
        # sibling engines' QUEUED work is invisible through the shared
        # arbiter — price the classifier backlog via the cross-server
        # demand hook (conservatively: every cls sentence serialized at
        # the per-sentence target), the same idiom the multi-task router
        # uses.  The property under test is that SPECULATION never makes
        # a demand-complete quote under-priced.
        ac = AdmissionController(
            dec, on_infeasible="requote",
            extra_wait_s=lambda: n_cls * target,
        )
        decisions = []
        for i in range(n_dec):
            L = int(rng.integers(4, 9))
            req = Request(
                uid=1000 + i,
                tokens=rng.integers(4, cfg.vocab_size, size=L).astype(np.int32),
                max_new_tokens=int(rng.integers(2, 5)),
                deadline_s=1e-9,          # impossible: forces a requote
            )
            decisions.append(ac.submit(req))
        while not (cls.sched.idle and dec.sched.idle):
            cls.step()
            dec.step()
        return dec, decisions

    def test_seeded_sweep_quoted_contracts_all_met(self, decoder, classifier):
        for seed in (0, 1, 2):
            for spec_window, warm in ((_W, False), (_W, True), (1, True)):
                dec, decisions = self._mix(
                    decoder, classifier, seed,
                    spec_window=spec_window, warm=warm,
                )
                stt = dec.telemetry()
                assert stt["accepted_slo_misses"] == 0, (seed, spec_window, warm)
                for d in decisions:
                    assert d.action == "requoted"
                # admitted at the quote: realized latency must not exceed
                # the promised (re-quoted) deadline on any completed request
                for uid, req in dec.done.items():
                    if req.deadline_s is None or req.latency_s is None:
                        continue
                    assert req.latency_s <= req.deadline_s * (1 + 1e-9), (
                        seed, uid, req.latency_s, req.deadline_s,
                    )

    @pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
    @given(seed=st.integers(min_value=10, max_value=40))
    @settings(max_examples=3, deadline=None)
    def test_random_mix_quoted_contracts_all_met(
        self, decoder, classifier, seed
    ):
        dec, decisions = self._mix(
            decoder, classifier, seed, spec_window=_W, warm=True
        )
        assert dec.telemetry()["accepted_slo_misses"] == 0
        for uid, req in dec.done.items():
            if req.deadline_s is None or req.latency_s is None:
                continue
            assert req.latency_s <= req.deadline_s * (1 + 1e-9)
