"""Serving engines on the unified lane scheduler: length-bucketed fixed
shapes, cross-bucket time slicing, per-request deadlines + shared-clock
batched DVFS.

Architecture (this module + ``serving/scheduler.py`` + ``serving/dvfs.py``):

* ``LaneScheduler`` owns the lifecycle both engines used to duplicate —
  submit -> length-bucketed queues -> refill free lanes -> fused step ->
  retire -> telemetry — and clocks it INCREMENTALLY: each ``step()`` advances
  exactly one bucket, chosen by a pluggable policy (default: EDF on
  per-request deadlines with a weighted-round-robin fallback), so a deep
  128-token drain no longer starves queued 32-token traffic.  Requests may be
  submitted between steps; ``poll()`` returns completions; ``run()`` remains
  the drain-everything back-compat wrapper.  The queue is partitioned into
  ``[lanes, S_bucket]`` buckets (e.g. 32/64/128): a request lands in the
  smallest bucket that fits and is padded up to it, so jit compiles EXACTLY
  ONE step per bucket instead of one per distinct request length; several
  buckets can be open at once, so engines key ALL their device state by
  bucket.  ``buckets=None`` keeps exact-shape buckets (one per distinct
  length).
* ``Request`` carries an optional per-request SLO: ``deadline_s`` (modeled
  seconds from submission; ``None`` falls back to the DVFS controller's
  global target).  The deadline drives both the scheduler's EDF policy and —
  threaded through ``BatchedDVFSArbiter.admit`` — the shared-clock (V, f)
  decision, which maximizes slack per lane against THAT lane's deadline.
* ``ClassifierServer`` — ALBERT-style classification with entropy early exit
  as a fixed-shape, mask-vectorized continuation-batching engine: a static
  ``[lanes, S_bucket, H]`` hidden tensor plus an active mask; one fused,
  jitted step runs encoder layer -> off-ramp logits -> entropy -> retire
  mask.  Retired lanes refill from the bucket queue between steps, so average
  depth/sentence ~ average exit layer — the batched form of the paper's
  runtime saving.
* DVFS, two modes.  Per-sentence (``dvfs=``): a ``LatencyAwareDVFSController``
  replays Alg. 1 over each sentence's entropy trace after retirement — the
  paper's single-stream analysis, which pretends every sentence owns the
  clock.  Shared-clock (``arbiter=``): the accelerator has ONE LDO/ADPLL
  pair, so a ``BatchedDVFSArbiter`` makes one (V, f) decision per fused step
  — the max over per-lane required frequencies from the entropy->exit-layer
  predictor — with misprediction escalation and the LDO/ADPLL switching
  stall charged on every operating-point change.  Each lane is budgeted at
  ITS bucket's per-layer cycle cost (``hwmodel`` stats rescaled per bucket),
  so short buckets are no longer overcharged at the largest bucket's rate.
  Retired sentences feed the controller's online per-bin quantile
  calibration when enabled.
* ``DecoderServer`` — LM decode with PER-LANE KV lengths: a vmapped decode
  step advances every lane at its OWN position (refilled lanes decode from
  their actual prompt end instead of the max active position — no pad-
  position burn), with EOS retirement + refill and a jitted fixed-shape
  masked prefill.  Cache shapes bucket by prompt + generation budget.
  With ``exit_threshold=`` the fused step additionally runs the paper's
  entropy off-ramp PER TOKEN (``Model.decode_step_ee``: layer -> LM-head ->
  entropy -> masked freeze), realized exit depths feed a position-binned
  online LUT, and with ``arbiter=`` each token is charged at its exit depth
  while the lane's required frequency budgets the predicted remaining
  layers of its remaining tokens — classifier and decoder traffic arbitrate
  on one shared timeline.
* ``MultiTaskRouter`` — the paper's multi-task scenario: one shared
  (eNVM-resident) embedding + per-task encoder/classifier weights; switching
  tasks swaps only task weights (paper §III-D).  All task servers can share
  ONE arbiter — the hardware has one clock.

Trace-count telemetry: every jitted function increments a host-side,
bucket-keyed counter *inside its traced body*, i.e. it only advances when XLA
actually retraces.  ``telemetry()`` reports totals and per-bucket counts
(``step_traces`` must equal the number of buckets used, and stay there across
repeat drains, mid-flight submits, and interleaved stepping) so recompile
regressions fail loudly in tests and CI.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.early_exit import (
    PositionBinnedExitCalibrator,
    predicted_remaining_layers,
    predicted_token_layers,
)
from repro.models.model import Model
from repro.serving import step_math
from repro.serving.scheduler import LaneScheduler, SchedulingPolicy, StepReport

if TYPE_CHECKING:  # typing-only: dvfs is not a runtime dependency of the engine
    from repro.serving.dvfs import BatchedDVFSArbiter, LatencyAwareDVFSController


@dataclass
class Request:
    uid: int
    tokens: np.ndarray                  # [S] int32
    max_new_tokens: int = 16
    deadline_s: Optional[float] = None  # per-request SLO from SUBMISSION on the
                                        # modeled clock; None = controller target
    result: Optional[np.ndarray] = None
    exit_layer: Optional[int] = None
    generated: List[int] = field(default_factory=list)
    # decoder early exit: 1-based off-ramp exit depth of each generated token
    # (full depth when per-token exit is disabled)
    token_exit_layers: List[int] = field(default_factory=list)
    submit_time: float = 0.0            # WALL clock; caller-set only — the
                                        # scheduler stamps modeled clocks and
                                        # never mixes the two
    finish_time: float = 0.0
    bucket: Optional[int] = None        # length bucket the scheduler assigned
    replica: Optional[int] = None       # device replica the request is pinned
                                        # to (admission placement routing);
                                        # None = any replica may take it
    # ---- admission / preemption lifecycle ----
    checkpoint: Optional[Any] = None    # engine-opaque lane snapshot while
                                        # the request sits preempted in queue
    ckpt_depth: int = 0                 # depth the checkpoint resumes at
    preempted: int = 0                  # times this request was evicted
    shed: bool = False                  # dropped by load shedding (never ran)
    quoted_deadline_s: Optional[float] = None  # original SLO before a re-quote
    # ---- scheduler lifecycle stamps (queue-delay telemetry) ----
    arrival_step: Optional[int] = None        # dense-step count at submit()
    first_compute_step: Optional[int] = None  # step index of its first lane step
    retire_step: Optional[int] = None         # step index it retired on
    arrival_s: float = 0.0                    # modeled clock at submit()
    admit_s: float = 0.0                      # modeled clock at lane admission
    retire_s: float = 0.0                     # modeled clock at retirement
    seq: int = 0                              # global submission order
    # per-layer off-ramp entropies observed while the sentence was in flight;
    # the DVFS controller replays this trace through Alg. 1
    entropy_trace: List[float] = field(default_factory=list)
    energy_j: Optional[float] = None    # modeled accelerator energy (DVFS)
    latency_s: Optional[float] = None   # modeled accelerator latency (DVFS)
    op_vdd: Optional[float] = None      # selected / slowest operating point
    op_freq_hz: Optional[float] = None


def _expand_arbiters(arbiter, replicas: int) -> list:
    """Normalize the ``arbiter=`` ctor argument to one arbiter PER replica.

    Replicated serving models each device as its OWN LDO/ADPLL clock domain:
    a single arbiter is kept for replica 0 and siblings sharing its
    controller (cycle model, DVFS table, online calibrator) are built for
    the rest, so every replica makes independent (V, f) decisions while
    pricing work identically.  A sequence is taken verbatim (it must have
    one arbiter per replica)."""
    if arbiter is None:
        return []
    if isinstance(arbiter, (list, tuple)):
        arbs = list(arbiter)
        assert len(arbs) == replicas, (
            f"need one arbiter per replica: got {len(arbs)} for {replicas}"
        )
        return arbs
    if replicas == 1:
        return [arbiter]
    from repro.serving.dvfs import BatchedDVFSArbiter

    return [arbiter] + [
        BatchedDVFSArbiter(arbiter.c) for _ in range(replicas - 1)
    ]


def _resolve_mesh(replicas: int, mesh):
    """Resolve the (replicas, mesh) ctor pair: ``replicas > 1`` without a
    mesh builds one over the data axis; a mesh alone sets the replica count;
    both must agree.  Returns ``(replicas, mesh)`` — mesh None means the
    engine runs the unsharded single-device path."""
    assert replicas >= 1
    if mesh is None and replicas == 1:
        return 1, None
    if mesh is None:
        from repro.common.jax_compat import make_auto_mesh

        mesh = make_auto_mesh((replicas,), ("data",))
    if replicas == 1:
        replicas = mesh.size
    assert mesh.size == replicas, (
        f"mesh has {mesh.size} devices but replicas={replicas}"
    )
    return replicas, mesh


# unique per-server prefix for arbiter lane keys: with cross-bucket time
# slicing several buckets (and, via a shared arbiter, several servers) can
# hold lanes in flight at once, so the raw lane index no longer identifies a
# request
_SERVER_IDS = itertools.count()

# admission/preemption lifecycle counters every server's telemetry() forwards
# verbatim from the scheduler — one shared tuple so the engines cannot drift
_LIFECYCLE_KEYS = (
    "accepted", "rejected", "requoted", "shed",
    "preemptions", "restored_steps_saved", "accepted_slo_misses",
)


def _fold_miss(
    acc: Dict[str, Any], req: Request, latency_s: float, target_s: float
) -> None:
    """THE per-request deadline-miss rule, shared by both engines: an
    explicit SLO is submission-anchored (modeled queue wait counts), a
    deadline-free request is judged against the admission-anchored
    controller target.  Folds into the incremental accumulators."""
    if req.deadline_s is not None:
        latency_s += req.admit_s - req.arrival_s        # queue wait
        limit = req.deadline_s
    else:
        limit = target_s
    if latency_s > limit * (1 + 1e-9):
        acc["deadline_misses"] += 1
        if req.deadline_s is not None:
            acc["accepted_slo_misses"] += 1


# ===========================================================================
# Classifier (early-exit) server — bucketed fixed-shape continuation batching
# ===========================================================================


class ClassifierServer:
    """Continuation-batching early-exit classifier with static traced shapes.

    Engine state is a dense ``[lanes, S_bucket, D]`` tensor per bucket, kept
    in a bucket-keyed dict because the scheduler time-slices across buckets;
    every step runs the full lane set under an active mask, so the fused step
    has one trace per bucket.  ``layer_calls`` telemetry counts *active*
    lane-layer executions — the quantity the accelerator actually computes.

    ``dvfs``    — per-sentence Alg. 1 replay after retirement (single-stream).
    ``arbiter`` — shared-clock batched arbitration: one (V, f) per fused step.
    The two model different hardware assumptions; pass at most one.
    ``policy``  — scheduling policy for ``step()`` (default EDF + WRR).
    ``preempt`` — allow the scheduler to evict budget-free lanes for queued
    explicit-SLO requests via ``lane_checkpoint``/``lane_restore`` (the
    checkpointed ``(h, depth, kv_len)`` round-trips through the bucket's
    existing compiled insert, so preemption adds zero traces).
    ``use_pallas`` — route the fused step's inner math (attention, layernorm,
    off-ramp entropy, activation quant, pruned MLP tiles) to the Pallas
    kernels via ``serving.step_math`` / ``kernels.dispatch``.  The flag is a
    static Python bool closed over by the jit'd closures, so it preserves
    one-compile-per-bucket and adds zero traces; on CPU the kernels run in
    interpret mode, on TPU they compile to Mosaic.
    """

    def __init__(
        self,
        model: Model,
        params: Any,
        batch_lanes: int = 8,
        dvfs: Optional["LatencyAwareDVFSController"] = None,
        arbiter: Optional["BatchedDVFSArbiter"] = None,
        buckets=None,
        policy: Optional[SchedulingPolicy] = None,
        preempt: bool = False,
        use_pallas: bool = False,
        replicas: int = 1,
        mesh=None,
        task: Optional[str] = None,
        residency: Optional["TaskResidencyManager"] = None,
        deployment: Optional["TaskDeployment"] = None,
    ):
        assert model.cfg.family == "albert", "classifier server drives the albert family"
        assert dvfs is None or arbiter is None, (
            "pass either a per-sentence controller (dvfs=) or a shared-clock "
            "arbiter (arbiter=), not both — they model different hardware"
        )
        self.model = model
        self.params = params
        # ``replicas > 1`` (or an explicit mesh) shards the fused step over a
        # device mesh: ``batch_lanes`` lanes PER replica, flat global lane
        # indices, replica of lane i = i // lanes_per_replica (contiguous
        # slabs match the leading-axis sharding), one DVFS arbiter (clock
        # domain) per replica
        self.replicas, self._mesh = _resolve_mesh(replicas, mesh)
        self.lanes_per_replica = batch_lanes
        self.lanes = batch_lanes * self.replicas
        self.cfg = model.cfg
        self.threshold = model.cfg.edgebert.early_exit.entropy_threshold
        self.dvfs = dvfs
        self.arbiters = _expand_arbiters(arbiter, self.replicas)
        self.arbiter = self.arbiters[0] if self.arbiters else None
        self.use_pallas = use_pallas
        # STATIC block-occupancy masks for the shared encoder MLP, derived
        # host-side from the concrete (post-pruning) weights; None entries /
        # None dict mean the matmul stays dense (ref path)
        self._block_masks = None
        if use_pallas and "mlp" in params.get("layer", {}):
            from repro.kernels import dispatch

            self._block_masks = dispatch.mlp_block_masks(params["layer"]["mlp"])
        self._sid = next(_SERVER_IDS)
        ctrl = self.arbiter.c if self.arbiter is not None else dvfs
        # multi-task residency: which task this server serves, the shared
        # SRAM-over-eNVM working set, and this task's compression deployment.
        # A deployment reprices the hw model: cycles/quotes route through a
        # controller over the COMPRESSED stats, and lane energy is scaled by
        # the deployment's power ratio vs the anchor stats at admit.
        self.task = task
        self.residency = residency
        self.deployment = deployment
        self._dep_ctrl = None
        self._energy_scale = 1.0
        if deployment is not None and ctrl is not None:
            from repro.serving.residency import (      # lazy: engine <-> residency
                deployment_controller,
                deployment_energy_scale,
            )

            self._dep_ctrl = deployment_controller(ctrl, deployment)
            self._energy_scale = deployment_energy_scale(ctrl, deployment)
        self.sched = LaneScheduler(
            self.lanes, self, buckets=buckets, policy=policy,
            step_time_fn=self._step_time_s,
            # with a hw model every request carries at least the controller
            # target as an implicit deadline, so EDF slack — not blind round
            # robin — decides which bucket gets each time slice
            default_deadline_s=ctrl.target_latency_s if ctrl is not None else None,
            preempt=preempt,
        )
        # per-bucket engine state: {"h": [lanes, S, D], "len": [lanes],
        # "out": last step's host copies} — several buckets open at once
        self._bstate: Dict[int, Dict[str, Any]] = {}
        # "embed"/"step"/"insert" keyed by S; "step_replica" keyed by
        # (S, replicas) — the per-(bucket, mesh) recompile telemetry the
        # sharded CI gates read (identical to (S, 1) on the unsharded path,
        # so 1-replica sharded and unsharded counters match bit-for-bit)
        self._traces = {"embed": {}, "step": {}, "insert": {}, "step_replica": {}}
        # arbiter counters attributable to THIS server's drains (the arbiter
        # itself is drain-global and may be shared across task servers)
        self._arb_acc = {
            "op_switches": 0, "switch_time_s": 0.0,
            "switch_energy_j": 0.0, "total_energy_j": 0.0,
        }
        # incremental per-retiree accounting: telemetry() must not rescan
        # ``done`` (whose payloads poll() is allowed to drop) — every sum /
        # max / miss count folds in at lane_finish instead
        self._acc = {
            "retired": 0, "exit_sum": 0.0, "energy_j": 0.0, "lat_max": 0.0,
            "deadline_misses": 0, "accepted_slo_misses": 0,
        }

        # thin wrappers around serving.step_math: the closures own ONLY the
        # host-side trace counters (bumped inside the traced body, so they
        # advance exactly when XLA retraces); the step math itself — and the
        # static use_pallas routing — lives in step_math
        def embed_fn(params, tokens):
            S = tokens.shape[1]                  # static at trace time
            self._traces["embed"][S] = self._traces["embed"].get(S, 0) + 1
            return step_math.classifier_embed(model, params, tokens)

        def step_fn(params, h, active, lengths, threshold):
            S = h.shape[1]                       # static at trace time
            self._traces["step"][S] = self._traces["step"].get(S, 0) + 1
            rk = (S, self.replicas)
            self._traces["step_replica"][rk] = (
                self._traces["step_replica"].get(rk, 0) + 1
            )
            if self._mesh is None:
                return step_math.classifier_fused_step(
                    model, params, h, active, lengths, threshold,
                    use_pallas=self.use_pallas, block_masks=self._block_masks,
                )
            return step_math.sharded_classifier_fused_step(
                model, params, h, active, lengths, threshold,
                mesh=self._mesh, use_pallas=self.use_pallas,
                block_masks=self._block_masks,
            )

        def insert_fn(h, lane, h_new):
            S = h.shape[1]
            self._traces["insert"][S] = self._traces["insert"].get(S, 0) + 1
            return step_math.lane_insert(h, lane, h_new)

        self._embed = jax.jit(embed_fn)
        self._step = jax.jit(step_fn)
        self._insert = jax.jit(insert_fn)

    # ---------------------------------------------------------- DVFS helpers
    @property
    def _ctrl(self) -> Optional["LatencyAwareDVFSController"]:
        return self.arbiter.c if self.arbiter is not None else self.dvfs

    def _cycles_for(self, bucket: int) -> Optional[float]:
        """Per-bucket layer cycles from the controller's hw stats rescaled to
        the bucket's sequence length (the controller memoizes per length).
        With a compressed ``TaskDeployment`` attached, the deployment's
        controller prices the bucket instead — span/pruning savings flow
        into step times, arbiter budgets, and admission quotes."""
        ctrl = self._dep_ctrl if self._dep_ctrl is not None else self._ctrl
        return None if ctrl is None else ctrl.cycles_for_seq_len(bucket)

    def _step_time_s(self, bucket: int) -> float:
        """NOMINAL duration of one fused step (the bucket's layer time at the
        max operating point when a hw model is attached, else 1.0 step
        units) — the EDF slack estimate.  The clock itself advances by the
        arbiter's ACTUAL step duration via ``step_dt_s`` when available."""
        ctrl = self._ctrl
        if ctrl is None:
            return 1.0
        return self._cycles_for(bucket) / ctrl.max_op.freq_hz

    def step_dt_s(self, bucket: int) -> Optional[float]:
        """Actual modeled duration of the step just run: the arbiter's chosen
        op period plus any LDO/ADPLL switching stall, so the scheduler's EDF
        clock tracks the clock deadlines are judged by."""
        if self.arbiter is None:
            return None
        st = self._bstate.get(bucket)
        return None if st is None else st.get("dt")

    def clock_s(self) -> Optional[float]:
        """Authoritative shared timeline: the arbiter's clock.  One LDO/ADPLL
        serves every server sharing the arbiter, so arrival stamps and EDF
        slack must fast-forward past time OTHER servers spent on it (the
        scheduler syncs at every submit() and step()).  With replicated
        clock domains the fleet clock is the max — ``lanes_step``'s barrier
        sync keeps the replicas within one fused step of it anyway."""
        if not self.arbiters:
            return None
        return max(a.now_s for a in self.arbiters)

    def _arb_key(self, bucket: int, lane: int):
        return (self._sid, bucket, lane)

    def lane_domain(self, lane: int) -> int:
        """Scheduler routing hook: the replica (clock domain) a lane belongs
        to.  Lane slabs are contiguous so slab r is exactly the rows device r
        computes under the leading-axis sharding."""
        return lane // self.lanes_per_replica

    def _arb_of(self, lane: int) -> "BatchedDVFSArbiter":
        return self.arbiters[self.lane_domain(lane)]

    def _explicit_budget_remaining(self, req: Request) -> Optional[float]:
        """An explicit SLO is submission-anchored (queue wait counts), but
        the DVFS layer budgets from ADMISSION — so hand it only what is LEFT
        of the request's budget after its time in queue (floored at a sliver:
        an already-late request races at max V/f and reports its miss)."""
        if req.deadline_s is None:
            return None
        spent_in_queue = self.sched.now_s - req.arrival_s
        return max(req.deadline_s - spent_in_queue, 1e-12)

    # ---------------------------------------------------------------- public
    def submit(self, req: Request):
        req.bucket = self.sched.submit(req)

    @property
    def done(self) -> Dict[int, Request]:
        return self.sched.done

    @property
    def pending(self) -> int:
        return self.sched.pending

    def step(self) -> Optional[StepReport]:
        """Advance one bucket by one fused step (see ``LaneScheduler.step``)."""
        return self.sched.step()

    def poll(self, *, pin: bool = False) -> List[Request]:
        """Requests retired since the last poll (completion order).  By
        default the polled requests' payloads are DROPPED from ``done`` —
        the caller now owns them; ``pin=True`` keeps them resident."""
        return self.sched.poll(pin=pin)

    def run(self) -> Dict[str, float]:
        """Drain every bucket with continuation batching. Returns telemetry.
        (Arbiter deltas accrue per step inside ``lanes_step``, so hand-stepped
        and run()-driven work are accounted identically.)"""
        self.sched.run()
        return self.telemetry()

    # ------------------------------------------------------- scheduler hooks
    def bucket_key(self, req: Request) -> int:
        return len(req.tokens)

    def bucket_begin(self, bucket: int) -> None:
        D = self.cfg.d_model
        dtype = jnp.asarray(self.params["embed"]["tok"]).dtype
        self._bstate[bucket] = {
            "h": jnp.zeros((self.lanes, bucket, D), dtype),
            "len": np.full(self.lanes, bucket, np.int32),
            "out": None,
        }

    def lane_load(self, bucket: int, lane: int, req: Request) -> None:
        st = self._bstate[bucket]
        toks = np.zeros(bucket, np.int32)
        toks[: len(req.tokens)] = req.tokens     # pad up to the bucket shape
        st["h"] = self._insert(
            st["h"], jnp.int32(lane), self._embed(self.params, jnp.asarray(toks)[None])
        )
        st["len"][lane] = len(req.tokens)
        if self.residency is not None:
            # task residency: refilling a lane touches this task's weights —
            # a miss swaps them in from eNVM and the stall burns wall time on
            # the shared clock BEFORE the lane's budget is computed (the
            # stall spends the request's submission-anchored SLO budget)
            stall = self.residency.acquire(self.task)
            if stall > 0.0 and self.arbiters:
                arb = self._arb_of(lane)
                arb.advance_to(arb.now_s + stall)
                self.sched.sync_clock()
        if self.arbiters:
            self._arb_of(lane).admit(
                self._arb_key(bucket, lane),
                deadline_s=self._explicit_budget_remaining(req),
                cycles_per_layer=self._cycles_for(bucket),
                energy_scale=self._energy_scale,
            )

    def lanes_step(self, bucket: int, active: np.ndarray):
        st = self._bstate[bucket]
        decision = None
        if self.arbiters:
            # ONE (V, f) PER CLOCK DOMAIN for this fused step: each replica's
            # arbiter arbitrates its own active lane slab independently, then
            # every clock fast-forwards to the fleet max — the SPMD barrier
            # (devices leave the collective step together; waiting burns wall
            # time, not operating-point state).  Telemetry deltas accrue HERE
            # (not in run()) so step()-driven serving attributes its arbiter
            # work to this server too; the actual step duration feeds the
            # scheduler clock via step_dt_s.  With one replica this is
            # exactly the single shared-clock arbitration.
            before = [a.telemetry() for a in self.arbiters]
            decisions = []
            L = self.lanes_per_replica
            slabs = [
                (arb, [
                    self._arb_key(bucket, i)
                    for i in range(r * L, (r + 1) * L) if active[i]
                ])
                for r, arb in enumerate(self.arbiters)
            ]
            # barrier-aware pacing: the fleet step lasts as long as its
            # slowest domain, so no domain may pick a point below the
            # fleet's tightest lane requirement (see BatchedDVFSArbiter.step)
            floor = max(
                (arb.required_hz(k) for arb, keys in slabs for k in keys),
                default=0.0,
            )
            for arb, keys in slabs:
                if keys:
                    decisions.append(arb.step(keys, floor_hz=floor))
            t = max(a.now_s for a in self.arbiters)
            for a in self.arbiters:
                a.advance_to(t)
            for b4, a in zip(before, self.arbiters):
                after = a.telemetry()
                for k in self._arb_acc:
                    self._arb_acc[k] += after[k] - b4[k]
            decision = decisions[0] if len(decisions) == 1 else tuple(decisions)
            # advance the scheduler clock TO the shared arbiter clock rather
            # than by an independently summed dt: combined with the
            # clock_s() sync at submit()/step(), every server sharing the
            # arbiter judges EDF slack, queue waits, and admission quotes on
            # the one hardware timeline deadlines are judged by
            st["dt"] = max(t - self.sched.now_s, 0.0)
        h, lg, ent, retire = self._step(
            self.params, st["h"], jnp.asarray(active), jnp.asarray(st["len"]),
            jnp.float32(self.threshold),
        )
        st["h"] = h
        st["out"] = (np.asarray(lg), np.asarray(ent), np.asarray(retire), decision)
        return st["out"]

    def lane_advance(
        self, bucket: int, lane: int, req: Request, out, depth: int
    ) -> bool:
        _, ent, retire, _ = out
        req.entropy_trace.append(float(ent[lane]))
        if self.arbiters and depth == 1:
            # first off-ramp evaluated: Alg. 1 line 2 prediction goes live
            self._arb_of(lane).observe_entropy(
                self._arb_key(bucket, lane), float(ent[lane])
            )
        return bool(retire[lane]) or depth >= self.cfg.n_layers

    def lane_finish(self, bucket: int, lane: int, req: Request, depth: int) -> None:
        lg, _, _, _ = self._bstate[bucket]["out"]
        req.result = lg[lane]
        req.exit_layer = depth
        req.finish_time = time.time()
        if self.arbiters:
            rep = self._arb_of(lane).retire(self._arb_key(bucket, lane), depth)
            req.energy_j = rep.energy_j
            req.latency_s = rep.latency_s
            req.op_vdd = rep.slowest_op.vdd
            req.op_freq_hz = rep.slowest_op.freq_hz
        elif self.dvfs is not None:
            # per-request deadline overrides the controller-global target —
            # minus the time the request already spent in queue (the SLO is
            # submission-anchored, Alg. 1 budgets from compute start)
            target = None
            if req.deadline_s is not None:
                target = max(req.deadline_s - (req.admit_s - req.arrival_s), 1e-12)
            rep = self.dvfs.sentence_report(
                req.entropy_trace, exit_layer=depth,
                target_latency_s=target,
            )
            req.energy_j = rep.energy_j
            req.latency_s = rep.latency_s
            req.op_vdd = rep.op.vdd
            req.op_freq_hz = rep.op.freq_hz
            # online calibration AFTER the report: a sentence's own exit must
            # not leak into its own prediction
            self.dvfs.observe_exit(req.entropy_trace[0], depth)
        self._account_retiree(req, depth)

    def _account_retiree(self, req: Request, depth: int) -> None:
        """Fold one retirement into the incremental telemetry accumulators
        (``telemetry()`` never rescans ``done`` — retired payloads may have
        been dropped by ``poll()``)."""
        acc = self._acc
        acc["retired"] += 1
        acc["exit_sum"] += depth
        ctrl = self._ctrl
        if ctrl is None:
            return
        acc["energy_j"] += req.energy_j or 0.0
        acc["lat_max"] = max(acc["lat_max"], req.latency_s or 0.0)
        _fold_miss(acc, req, req.latency_s or 0.0, ctrl.target_latency_s)

    def bucket_end(self, bucket: int) -> None:
        del self._bstate[bucket]

    def lane_checkpoint(self, bucket: int, lane: int, req: Request):
        """Snapshot ``(h, kv_len)`` at the layer boundary (the scheduler
        keeps the depth) plus the arbiter's lane clock, so an evicted
        sentence resumes without re-running completed layers.  Pure host-side
        reads — no new compiled traces."""
        st = self._bstate[bucket]
        payload = {
            "h": np.asarray(st["h"][lane]),
            "len": int(st["len"][lane]),
        }
        if self.arbiters:
            # the clock payload is RELATIVE (remaining budget + elapsed run
            # time), so it restores onto ANY replica's arbiter bit-identically
            payload["clock"] = self._arb_of(lane).checkpoint_lane(
                self._arb_key(bucket, lane)
            )
        return payload

    def lane_restore(self, bucket: int, lane: int, req: Request, payload) -> None:
        """Reload a checkpointed sentence into a (possibly different) free
        lane.  Reuses the bucket's existing ``_insert`` trace — the payload
        has the same ``[1, S_bucket, D]`` shape as an embed — so restore is
        bit-exact and adds zero traces."""
        st = self._bstate[bucket]
        st["h"] = self._insert(
            st["h"], jnp.int32(lane), jnp.asarray(payload["h"])[None]
        )
        st["len"][lane] = payload["len"]
        if self.arbiters:
            self._arb_of(lane).restore_lane(
                self._arb_key(bucket, lane), payload["clock"]
            )

    def predict_remaining_steps(
        self, bucket: int, req: Request, depth: int
    ) -> float:
        """EDF slack input: entropy-LUT predicted exit depth minus progress,
        using the SAME prediction chain the DVFS controller arbitrates with."""
        ctrl = self._ctrl
        return predicted_remaining_layers(
            req.entropy_trace, depth, self.cfg.n_layers,
            predict_fn=ctrl.predict if ctrl is not None else None,
        )

    # ------------------------------------------------------------- telemetry
    def telemetry(self) -> Dict[str, float]:
        st = self.sched.telemetry()
        acc = self._acc
        avg_exit = acc["exit_sum"] / acc["retired"] if acc["retired"] else 0.0
        out = {
            "sentences": st["sentences"],
            "layer_calls": st["lane_steps"],
            "dense_steps": st["dense_steps"],
            "avg_exit_layer": avg_exit,
            "runtime_savings": 1.0 - avg_exit / self.cfg.n_layers,
            "step_traces": sum(self._traces["step"].values()),
            "embed_traces": sum(self._traces["embed"].values()),
            "insert_traces": sum(self._traces["insert"].values()),
            "step_traces_per_bucket": dict(self._traces["step"]),
            # per-(bucket, mesh) recompile telemetry: JSON-safe "SxR" keys,
            # identical between unsharded and 1-replica sharded runs
            "step_traces_per_bucket_replica": {
                f"{s}x{r}": n
                for (s, r), n in sorted(self._traces["step_replica"].items())
            },
            "replicas": self.replicas,
            "buckets_used": st["buckets_used"],
            "bucket_steps": st["bucket_steps"],
            "lane_occupancy": st["lane_occupancy"],
            "queue_delay_steps_p50": st["queue_delay_steps_p50"],
            "queue_delay_steps_p95": st["queue_delay_steps_p95"],
            "queue_delay_steps_p99": st["queue_delay_steps_p99"],
            "queue_delay_steps_max": st["queue_delay_steps_max"],
            **{k: st[k] for k in _LIFECYCLE_KEYS},
        }
        if self._ctrl is not None:
            # incremental accumulators (folded in at lane_finish): every
            # DVFS-accounting key exists even when NOTHING has retired yet,
            # and none of them depends on ``done`` still holding payloads
            # (poll() may have dropped them)
            out["energy_j"] = float(acc["energy_j"])
            out["modeled_latency_s"] = float(acc["lat_max"])
            out["deadline_misses"] = acc["deadline_misses"]
            out["accepted_slo_misses"] = acc["accepted_slo_misses"]
        if self.arbiter is not None:
            # deltas accumulated across THIS server's drains only: a shared
            # arbiter keeps drain-global counters, and copying those verbatim
            # would multi-count other servers' work in per-task stats
            out["op_switches"] = self._arb_acc["op_switches"]
            out["switch_energy_j"] = self._arb_acc["switch_energy_j"]
            out["switch_time_s"] = self._arb_acc["switch_time_s"]
            out["arb_energy_j"] = self._arb_acc["total_energy_j"]
        return out


# ===========================================================================
# Decoder (LM) server — per-lane KV lengths on the shared scheduler
# ===========================================================================


class DecoderServer:
    """Continuation-batching LM decode with PER-LANE cache positions and
    (optionally) PER-TOKEN entropy early exit under shared-clock DVFS.

    The decode step is vmapped over lanes, so every lane attends its own
    ``[0, pos_lane]`` cache window and refilled lanes continue from their
    actual prompt end — the lock-step max-position loop (which burned pad
    positions for refilled lanes) is gone.  Cache shapes bucket by
    prompt-plus-generation budget; one decode/prefill trace per bucket.
    Caches live in a bucket-keyed dict: the scheduler time-slices across
    buckets, so several caches can be live at once.

    Per-token early exit (``exit_threshold=``): the fused decode step runs
    ``Model.decode_step_ee`` per lane — after every layer the shared LM head
    is evaluated and a token whose entropy drops below the threshold FREEZES
    (hidden-state propagation keeps the remaining layers' KV rows defined),
    so a lane that exits at layer k skips layers k+1..L for that token while
    the traced shapes stay fixed: one compile per bucket, and the per-lane
    exit-depth vector is just another masked output.  Exit depths feed a
    ``PositionBinnedExitCalibrator`` (EdgeBERT's LUT keyed by decode
    position instead of first-off-ramp entropy; cold bins predict the
    conservative full depth), and that ONE prediction chain drives all three
    consumers on the same timeline: the scheduler's EDF slack
    (``predict_remaining_steps`` in fractional full-depth steps), the
    arbiter's required frequency (``set_remaining_layers``: predicted layers
    for ALL remaining tokens over remaining time-to-deadline), and the
    admission feasibility quote (``_cycles_for`` full-depth step cycles x
    predicted fractional steps at the max operating point).

    Shared-clock DVFS (``arbiter=``): one (V, f) per fused step across every
    lane the arbiter serves — classifier and decoder traffic arbitrate on
    one hardware timeline when they share the arbiter.  Each decode token is
    charged at its realized exit depth and at this bucket's PER-TOKEN layer
    cost (the bucket layer cycles amortized per position: decode processes
    one token against <= bucket cached positions).  Prefill is not charged —
    the DVFS model budgets the decode phase, matching the paper's
    per-sentence accounting which starts at layer 1 of compute.
    """

    def __init__(
        self,
        model: Model,
        params: Any,
        batch_lanes: int = 4,
        max_seq: int = 256,
        eos_id: int = 2,
        buckets=None,
        policy: Optional[SchedulingPolicy] = None,
        preempt: bool = False,
        arbiter: Optional["BatchedDVFSArbiter"] = None,
        exit_threshold: Optional[float] = None,
        exit_calibrator: Optional[Any] = None,
        use_pallas: bool = False,
        replicas: int = 1,
        mesh=None,
        task: Optional[str] = None,
        residency: Optional["TaskResidencyManager"] = None,
        spec_window: int = 1,
        threshold_schedule: Optional[Any] = None,
    ):
        self.model = model
        self.params = params
        # multi-task residency (see ClassifierServer): decoder lanes touch
        # the task's weights at refill too, paying the eNVM swap stall on
        # the shared clock when the task is not SRAM-resident
        self.task = task
        self.residency = residency
        # replicated decode: ``batch_lanes`` lanes per replica, the KV cache
        # sharded on its lane axis, one DVFS clock domain per replica (see
        # ClassifierServer — the lane-slab layout is identical)
        self.replicas, self._mesh = _resolve_mesh(replicas, mesh)
        self.lanes_per_replica = batch_lanes
        self.lanes = batch_lanes * self.replicas
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.n_layers = model.cfg.n_layers
        self.arbiters = _expand_arbiters(arbiter, self.replicas)
        self.arbiter = self.arbiters[0] if self.arbiters else None
        self.threshold = exit_threshold
        # static routing of the fused step's eligible inner math to the
        # Pallas kernels (decode attention stays ref — it fuses the KV
        # update/codec — but norms, LM-head entropy, and act quant route);
        # closed over by the jit'd closures, so zero extra traces
        self.use_pallas = use_pallas
        # ---- self-speculative decode (exit-at-k draft / remaining-layer
        # verify): ``spec_window`` tokens per fused step per lane, gated by a
        # per-slot threshold row; an ``ExitThresholdSchedule`` generalizes
        # the scalar threshold per position / entropy band.  ``spec_window=1``
        # with no schedule keeps the existing per-token EE trace untouched.
        self.spec_window = int(spec_window)
        assert self.spec_window >= 1, "spec_window must be >= 1"
        self.schedule = threshold_schedule
        if threshold_schedule is not None and exit_threshold is None:
            exit_threshold = threshold_schedule.base
        self.threshold = exit_threshold
        assert self.spec_window == 1 or exit_threshold is not None, (
            "speculative decode drafts via the entropy off-ramp: spec_window"
            " > 1 needs exit_threshold (or a threshold_schedule)"
        )
        self._spec = exit_threshold is not None and (
            self.spec_window > 1 or threshold_schedule is not None
        )
        if (
            exit_calibrator is None
            and threshold_schedule is not None
            and threshold_schedule.calibrator is not None
        ):
            # the schedule's backing calibrator IS the prediction chain
            exit_calibrator = threshold_schedule.calibrator
        if exit_threshold is not None and exit_calibrator is None:
            exit_calibrator = PositionBinnedExitCalibrator(
                self.n_layers, max_pos=max_seq
            )
        self.calib = exit_calibrator
        self._sid = next(_SERVER_IDS)
        ctrl = self.arbiter.c if self.arbiter is not None else None
        self.sched = LaneScheduler(
            self.lanes, self, buckets=buckets, policy=policy, preempt=preempt,
            step_time_fn=self._step_time_s,
            default_deadline_s=ctrl.target_latency_s if ctrl is not None else None,
        )
        self._bucketed = buckets is not None
        # per-bucket engine state: {"cache", "pos": [lanes], "cur": [lanes, 1],
        # "reqs": per-lane Request refs, "out"} — several buckets open at once
        self._bstate: Dict[int, Dict[str, Any]] = {}
        # "decode"/"prefill" keyed by bucket; "decode_replica" keyed by
        # (bucket, replicas) — per-(bucket, mesh) recompile telemetry
        self._traces = {"decode": {}, "prefill": {}, "decode_replica": {}}
        self._arb_acc = {
            "op_switches": 0, "switch_time_s": 0.0,
            "switch_energy_j": 0.0, "total_energy_j": 0.0,
        }
        # incremental per-retiree accounting (telemetry() must not rescan
        # ``done`` — poll() may drop retired payloads)
        self._acc = {
            "retired": 0, "tokens": 0, "token_layers": 0.0,
            "energy_j": 0.0, "lat_max": 0.0,
            "deadline_misses": 0, "accepted_slo_misses": 0,
            # throughput numerator/denominator for tokens-per-fused-step:
            # one lane_step per lane per fused step (so the per-token EE
            # baseline is exactly 1.0), adv_tokens = tokens actually
            # appended (speculation appends the accepted block)
            "lane_steps": 0, "adv_tokens": 0, "accepted_blocks": 0,
        }

        # thin wrappers around serving.step_math (pure per-lane vmapped step
        # math): the closures own ONLY the host-side trace counters — decode
        # advances every lane at its own position, the EE variant adds the
        # per-token off-ramp, prefill is one fixed-shape trace per bucket
        def _bump_decode(bucket):
            self._traces["decode"][bucket] = self._traces["decode"].get(bucket, 0) + 1
            rk = (bucket, self.replicas)
            self._traces["decode_replica"][rk] = (
                self._traces["decode_replica"].get(rk, 0) + 1
            )

        def decode_fn(params, cache, tokens, pos, bucket):
            _bump_decode(bucket)
            if self._mesh is None:
                return step_math.decoder_decode(
                    model, params, cache, tokens, pos, use_pallas=self.use_pallas
                )
            return step_math.sharded_decoder_decode(
                model, params, cache, tokens, pos,
                mesh=self._mesh, use_pallas=self.use_pallas,
            )

        def decode_ee_fn(params, cache, tokens, pos, threshold, bucket):
            _bump_decode(bucket)
            if self._mesh is None:
                return step_math.decoder_decode_ee(
                    model, params, cache, tokens, pos, threshold,
                    use_pallas=self.use_pallas,
                )
            return step_math.sharded_decoder_decode_ee(
                model, params, cache, tokens, pos, threshold,
                mesh=self._mesh, use_pallas=self.use_pallas,
            )

        def decode_spec_fn(params, cache, tokens, pos, thresholds, bucket):
            # speculative fused step: spec_window and eos_id are server
            # constants closed over, thresholds is a fixed-shape [lanes, W]
            # array operand — one trace per (bucket, replica), threshold
            # VALUES never retrace
            _bump_decode(bucket)
            if self._mesh is None:
                return step_math.decoder_decode_spec(
                    model, params, cache, tokens, pos, thresholds,
                    self.spec_window, eos_id=self.eos_id,
                    use_pallas=self.use_pallas,
                )
            return step_math.sharded_decoder_decode_spec(
                model, params, cache, tokens, pos, thresholds,
                self.spec_window, eos_id=self.eos_id,
                mesh=self._mesh, use_pallas=self.use_pallas,
            )

        def prefill_fn(params, cache, tokens, lane, length):
            bucket = tokens.shape[0]             # static at trace time
            self._traces["prefill"][bucket] = self._traces["prefill"].get(bucket, 0) + 1
            return step_math.decoder_prefill(
                model, params, cache, tokens, lane, length, self.lanes,
                use_pallas=self.use_pallas,
            )

        self._decode = jax.jit(decode_fn, static_argnums=(4,))
        self._decode_ee = jax.jit(decode_ee_fn, static_argnums=(5,))
        self._decode_spec = jax.jit(decode_spec_fn, static_argnums=(5,))
        self._prefill = jax.jit(prefill_fn)

    # ---------------------------------------------------------- DVFS helpers
    @property
    def _ctrl(self) -> Optional["LatencyAwareDVFSController"]:
        return self.arbiter.c if self.arbiter is not None else None

    def _cycles_token_layer(self, bucket: int) -> Optional[float]:
        """Modeled cycles for ONE decode token through ONE layer at this
        bucket: the bucket's full-sequence layer cycles amortized per
        position (matmul work is token-linear and attention-score work
        token-quadratic, so both divide out to a per-token cost that scales
        with the cache window)."""
        ctrl = self._ctrl
        if ctrl is None:
            return None
        return ctrl.cycles_for_seq_len(bucket) / bucket

    def _cycles_for(self, bucket: int) -> Optional[float]:
        """Cycles of one FULL-DEPTH fused decode step (one token through all
        layers) — the unit ``predict_remaining_steps`` counts in, so the
        admission quote (steps x this at the max op) prices decode SLOs at
        the token-level predicted depth."""
        cyc = self._cycles_token_layer(bucket)
        return None if cyc is None else cyc * self.n_layers

    def _step_time_s(self, bucket: int) -> float:
        """NOMINAL duration of one full-depth fused decode step at the max
        operating point (1.0 step units without a hw model)."""
        ctrl = self._ctrl
        if ctrl is None:
            return 1.0
        return self._cycles_for(bucket) / ctrl.max_op.freq_hz

    def step_dt_s(self, bucket: int) -> Optional[float]:
        """Actual modeled duration of the step just run (arbiter op period
        at realized exit depths + any switching stall)."""
        if self.arbiter is None:
            return None
        st = self._bstate.get(bucket)
        return None if st is None else st.get("dt")

    def clock_s(self) -> Optional[float]:
        """Authoritative shared timeline: the arbiter's clock (classifier and
        decoder servers sharing one arbiter arbitrate on ONE timeline).
        Replicated domains report the fleet max (barrier-synced anyway)."""
        if not self.arbiters:
            return None
        return max(a.now_s for a in self.arbiters)

    def _arb_key(self, bucket: int, lane: int):
        return (self._sid, bucket, lane)

    def lane_domain(self, lane: int) -> int:
        """Scheduler routing hook: the replica (clock domain) of a lane."""
        return lane // self.lanes_per_replica

    def _arb_of(self, lane: int) -> "BatchedDVFSArbiter":
        return self.arbiters[self.lane_domain(lane)]

    def _explicit_budget_remaining(self, req: Request) -> Optional[float]:
        """Submission-anchored SLO minus time already spent in queue (the
        DVFS layer budgets from admission; floored at a sliver so an
        already-late request races at max V/f)."""
        if req.deadline_s is None:
            return None
        spent_in_queue = self.sched.now_s - req.arrival_s
        return max(req.deadline_s - spent_in_queue, 1e-12)

    def _predicted_layers_remaining(self, req: Request) -> float:
        """Predicted layers for ALL of this request's remaining tokens via
        the position-binned LUT (conservative full depth per token when the
        calibrator is cold or per-token exit is disabled)."""
        start = len(req.generated)
        end = req.max_new_tokens
        if end <= start:                 # the retiring token is still due
            end = start + 1
        if self.calib is None:
            return float(end - start) * self.n_layers
        fast = getattr(self.calib, "predict_range", None)
        if fast is not None:             # vectorized: this runs per lane per step
            return fast(start, end)
        return predicted_token_layers(
            self.calib.predict, start, end, self.n_layers
        )

    def _lane_thresholds(self, bucket: int) -> np.ndarray:
        """Per-lane, per-slot threshold rows for one speculative fused step:
        slot j gates the token at generation index ``len(generated) + j``.
        The scalar threshold broadcasts (degenerate schedule); an
        ``ExitThresholdSchedule`` prices each speculated position and the
        lane's last first-off-ramp entropy reading individually."""
        st = self._bstate[bucket]
        W = self.spec_window
        thr = np.full((self.lanes, W), self.threshold, np.float32)
        if self.schedule is not None:
            for i in range(self.lanes):
                req = st["reqs"][i]
                if req is None:
                    continue
                last_ent = (
                    req.entropy_trace[-1] if req.entropy_trace else None
                )
                thr[i] = self.schedule.thresholds(
                    len(req.generated), W, last_ent
                )
        return thr

    # ---------------------------------------------------------------- public
    def submit(self, req: Request):
        req.bucket = self.sched.submit(req)

    @property
    def done(self) -> Dict[int, Request]:
        return self.sched.done

    @property
    def pending(self) -> int:
        return self.sched.pending

    def step(self) -> Optional[StepReport]:
        return self.sched.step()

    def poll(self, *, pin: bool = False) -> List[Request]:
        return self.sched.poll(pin=pin)

    def run(self) -> Dict[str, float]:
        self.sched.run()
        return self.telemetry()

    # ------------------------------------------------------- scheduler hooks
    def bucket_key(self, req: Request) -> int:
        if not self._bucketed:
            return self.max_seq              # legacy: one cache of max_seq
        need = len(req.tokens) + req.max_new_tokens + 1
        assert need <= self.max_seq, f"request needs {need} > max_seq {self.max_seq}"
        return need

    def bucket_begin(self, bucket: int) -> None:
        self._bstate[bucket] = {
            "cache": self.model.init_cache(self.lanes, bucket),
            "pos": np.zeros(self.lanes, np.int32),
            "cur": np.zeros((self.lanes, 1), np.int32),
            "reqs": [None] * self.lanes,
            "out": None,
        }

    def lane_load(self, bucket: int, lane: int, req: Request) -> None:
        st = self._bstate[bucket]
        toks = np.zeros(bucket, np.int32)
        toks[: len(req.tokens)] = req.tokens
        st["cache"] = self._prefill(
            self.params,
            st["cache"],
            jnp.asarray(toks),
            jnp.int32(lane),
            jnp.int32(len(req.tokens)),
        )
        st["pos"][lane] = len(req.tokens) - 1
        st["cur"][lane, 0] = req.tokens[-1]
        st["reqs"][lane] = req
        if self.residency is not None:
            # eNVM task residency: a miss stalls the shared clock for the
            # swap-in before this lane's budget is computed
            stall = self.residency.acquire(self.task)
            if stall > 0.0 and self.arbiters:
                a = self._arb_of(lane)
                a.advance_to(a.now_s + stall)
                self.sched.sync_clock()
        if self.arbiters:
            key = self._arb_key(bucket, lane)
            arb = self._arb_of(lane)
            arb.admit(
                key,
                deadline_s=self._explicit_budget_remaining(req),
                cycles_per_layer=self._cycles_token_layer(bucket),
            )
            arb.set_remaining_layers(
                key, self._predicted_layers_remaining(req)
            )

    def lanes_step(self, bucket: int, active: np.ndarray):
        st = self._bstate[bucket]
        if self.arbiters:
            # refresh every active lane's predicted remaining layers BEFORE
            # the shared-clock decision: the (V, f) pick budgets the
            # position-binned token predictions against each lane's deadline
            for i in range(self.lanes):
                if active[i] and st["reqs"][i] is not None:
                    self._arb_of(i).set_remaining_layers(
                        self._arb_key(bucket, i),
                        self._predicted_layers_remaining(st["reqs"][i]),
                    )
        if self._spec:
            # self-speculative fused step: every lane drafts/verifies up to
            # spec_window tokens; the host truncates each lane's accepted
            # prefix to what the request and cache have room for BEFORE the
            # arbiter charges the block (lane_advance replays exactly this
            # truncation, keeping arbiter depth == sum(token_exit_layers))
            thr = self._lane_thresholds(bucket)
            toks_d, logits, st["cache"], xl, fe, acc_m = self._decode_spec(
                self.params,
                st["cache"],
                jnp.asarray(st["cur"]),
                jnp.asarray(st["pos"]),
                jnp.asarray(thr),
                bucket,
            )
            spec_toks = np.asarray(toks_d)          # [lanes, W]
            exit_layers = np.asarray(xl)            # [lanes, W]
            first_ent = np.asarray(fe)              # [lanes, W]
            accepted = np.asarray(acc_m)            # [lanes, W]
            keep = np.zeros(self.lanes, np.int32)
            for i in range(self.lanes):
                req = st["reqs"][i]
                if not active[i] or req is None:
                    continue
                a = int(accepted[i].sum())          # >= 1: slot 0 is alive
                room_req = req.max_new_tokens - len(req.generated)
                room_cache = (bucket - 1) - int(st["pos"][i])
                keep[i] = max(1, min(a, room_req, room_cache))
            st["keep"] = keep
        elif self.threshold is not None:
            logits, st["cache"], xl, fe = self._decode_ee(
                self.params,
                st["cache"],
                jnp.asarray(st["cur"]),
                jnp.asarray(st["pos"]),
                jnp.float32(self.threshold),
                bucket,
            )
            exit_layers = np.asarray(xl)
            first_ent = np.asarray(fe)
        else:
            logits, st["cache"] = self._decode(
                self.params,
                st["cache"],
                jnp.asarray(st["cur"]),
                jnp.asarray(st["pos"]),
                bucket,
            )
            exit_layers = np.full(self.lanes, self.n_layers, np.int32)
            first_ent = None
        if self.arbiters:
            # one (V, f) PER CLOCK DOMAIN across the stepped lanes, each
            # token charged at its REALIZED exit depth (the decision was made
            # from pre-step predictions above); after arbitration every
            # replica clock barrier-syncs to the fleet max (SPMD lockstep —
            # see ClassifierServer.lanes_step).  Deltas accrue per server
            # like the classifier, and the actual dt feeds the scheduler
            # clock.
            before = [a.telemetry() for a in self.arbiters]
            L = self.lanes_per_replica
            slabs = [
                (arb, [
                    self._arb_key(bucket, i)
                    for i in range(r * L, (r + 1) * L) if active[i]
                ])
                for r, arb in enumerate(self.arbiters)
            ]
            # barrier-aware pacing floor, as in ClassifierServer.lanes_step
            floor = max(
                (arb.required_hz(k) for arb, keys in slabs for k in keys),
                default=0.0,
            )
            for r, (arb, keys) in enumerate(slabs):
                if not keys:
                    continue
                if self._spec:
                    # an accepted BLOCK per lane: charge the summed realized
                    # exit depth of the kept slots (layer-true energy/clock)
                    # and report the accepted token count (throughput)
                    arb.step(
                        keys,
                        layers={
                            self._arb_key(bucket, i): int(
                                exit_layers[i, : st["keep"][i]].sum()
                            )
                            for i in range(r * L, (r + 1) * L)
                            if active[i]
                        },
                        floor_hz=floor,
                        tokens={
                            self._arb_key(bucket, i): int(st["keep"][i])
                            for i in range(r * L, (r + 1) * L)
                            if active[i]
                        },
                    )
                else:
                    arb.step(
                        keys,
                        layers={
                            self._arb_key(bucket, i): int(exit_layers[i])
                            for i in range(r * L, (r + 1) * L)
                            if active[i]
                        },
                        floor_hz=floor,
                        tokens={
                            self._arb_key(bucket, i): 1
                            for i in range(r * L, (r + 1) * L)
                            if active[i]
                        },
                    )
            t = max(a.now_s for a in self.arbiters)
            for a in self.arbiters:
                a.advance_to(t)
            for b4, a in zip(before, self.arbiters):
                after = a.telemetry()
                for k in self._arb_acc:
                    self._arb_acc[k] += after[k] - b4[k]
            st["dt"] = max(t - self.sched.now_s, 0.0)
        if self._spec:
            # block-shaped outputs: tokens/depths/entropies [lanes, W] on
            # host (needed to advance), full block logits ON DEVICE — only a
            # retiring lane's accepted-tail row is materialized
            st["out"] = (spec_toks, exit_layers, first_ent, logits)
        else:
            st["out"] = (
                np.asarray(jnp.argmax(logits[:, -1], axis=-1)),
                exit_layers,
                first_ent,
                # EE path: keep final-token logits ON DEVICE — only a retiring
                # lane's row is materialized (in lane_finish), so the hot loop
                # never pays a [lanes, vocab] host transfer; plain decode keeps
                # the old argmax-only transfer
                logits[:, -1] if self.threshold is not None else None,
            )
        return st["out"]

    def lane_advance(
        self, bucket: int, lane: int, req: Request, out, depth: int
    ) -> bool:
        st = self._bstate[bucket]
        toks, exit_layers, first_ent, _ = out
        acc = self._acc
        acc["lane_steps"] += 1
        if self._spec:
            # advance by the accepted prefix (host-truncated in lanes_step —
            # the same count the arbiter was charged for); every accepted
            # token's realized depth feeds the calibrator at its OWN position
            # (one observation per TOKEN, not per block: blocks would starve
            # the bins covering positions inside accepted prefixes)
            k = int(st["keep"][lane])
            acc["adv_tokens"] += k
            acc["accepted_blocks"] += 1
            for j in range(k):
                tok = int(toks[lane, j])
                req.generated.append(tok)
                xl = int(exit_layers[lane, j])
                req.token_exit_layers.append(xl)
                fe = float(first_ent[lane, j])
                req.entropy_trace.append(fe)
                if self.calib is not None:
                    self.calib.observe(len(req.generated) - 1, xl)
                if (
                    self.schedule is not None
                    and self.schedule.calibrator is not None
                    and self.schedule.calibrator is not self.calib
                ):
                    self.schedule.observe(len(req.generated) - 1, fe, xl)
            st["pos"][lane] += k
            st["cur"][lane, 0] = int(toks[lane, k - 1])
            return (
                int(toks[lane, k - 1]) == self.eos_id
                or len(req.generated) >= req.max_new_tokens
                or int(st["pos"][lane]) >= bucket - 1
            )
        tok = int(toks[lane])
        acc["adv_tokens"] += 1
        req.generated.append(tok)
        xl = int(exit_layers[lane])
        req.token_exit_layers.append(xl)
        if first_ent is not None:
            req.entropy_trace.append(float(first_ent[lane]))
        if self.calib is not None:
            # observe AFTER the step: the token's own exit fed neither this
            # step's arbitration nor its own prediction
            self.calib.observe(len(req.generated) - 1, xl)
        st["pos"][lane] += 1                 # this lane's OWN position only
        st["cur"][lane, 0] = tok
        return (
            tok == self.eos_id
            or len(req.generated) >= req.max_new_tokens
            or int(st["pos"][lane]) >= bucket - 1   # this lane's cache is full
        )

    def lane_finish(self, bucket: int, lane: int, req: Request, depth: int) -> None:
        st = self._bstate[bucket]
        _, _, _, logits = st["out"]
        if logits is not None:               # EE path: one lane row, host-side
            if self._spec:
                # last ACCEPTED slot's verified logits (block logits stay on
                # device; only the retiring row is materialized)
                req.result = np.asarray(
                    logits[lane, int(st["keep"][lane]) - 1]
                )
            else:
                req.result = np.asarray(logits[lane])
        req.finish_time = time.time()
        st["reqs"][lane] = None
        acc = self._acc
        acc["retired"] += 1
        acc["tokens"] += len(req.token_exit_layers)
        acc["token_layers"] += float(sum(req.token_exit_layers))
        if self.arbiters:
            # the lane's total arbiter depth is the summed realized exit
            # depth of every token it generated (across preemption stints)
            rep = self._arb_of(lane).retire(
                self._arb_key(bucket, lane), int(sum(req.token_exit_layers))
            )
            req.energy_j = rep.energy_j
            req.latency_s = rep.latency_s
            req.op_vdd = rep.slowest_op.vdd
            req.op_freq_hz = rep.slowest_op.freq_hz
            acc["energy_j"] += rep.energy_j
            acc["lat_max"] = max(acc["lat_max"], rep.latency_s)
            _fold_miss(acc, req, rep.latency_s, self.arbiter.c.target_latency_s)

    def bucket_end(self, bucket: int) -> None:
        del self._bstate[bucket]

    def lane_checkpoint(self, bucket: int, lane: int, req: Request):
        """Snapshot the lane's KV cache row, cache position, and pending
        token so a preempted decode resumes exactly where it stopped (the
        generated tokens and their exit depths already live on the request);
        with an arbiter, the lane clock is frozen alongside."""
        st = self._bstate[bucket]
        payload = {
            "cache": jax.tree_util.tree_map(
                lambda x: np.asarray(x[:, lane]), st["cache"]
            ),
            "pos": int(st["pos"][lane]),
            "cur": int(st["cur"][lane, 0]),
        }
        st["reqs"][lane] = None
        if self.arbiters:
            # relative clock payload: restores onto ANY replica's arbiter
            payload["clock"] = self._arb_of(lane).checkpoint_lane(
                self._arb_key(bucket, lane)
            )
        return payload

    def lane_restore(self, bucket: int, lane: int, req: Request, payload) -> None:
        """Write the checkpointed cache row back into a (possibly different)
        free lane.  Eager fixed-shape updates on the bucket's existing cache
        — the counted decode/prefill traces are untouched."""
        st = self._bstate[bucket]
        st["cache"] = jax.tree_util.tree_map(
            lambda full, row: jax.lax.dynamic_update_slice_in_dim(
                full, jnp.asarray(row)[:, None].astype(full.dtype), lane, axis=1
            ),
            st["cache"],
            payload["cache"],
        )
        st["pos"][lane] = payload["pos"]
        st["cur"][lane, 0] = payload["cur"]
        st["reqs"][lane] = req
        if self.arbiters:
            self._arb_of(lane).restore_lane(
                self._arb_key(bucket, lane), payload["clock"]
            )

    def predict_remaining_steps(
        self, bucket: int, req: Request, depth: int
    ) -> float:
        """EDF slack input in FRACTIONAL full-depth fused steps: the
        position-binned LUT's predicted layers for the remaining tokens over
        the full depth (plain remaining-token count when per-token exit is
        off — every token then costs one full-depth step)."""
        if self.calib is None:
            return float(max(req.max_new_tokens - len(req.generated), 1))
        return max(
            self._predicted_layers_remaining(req) / self.n_layers,
            1.0 / self.n_layers,             # the step that retires it
        )

    # ------------------------------------------------------------- telemetry
    def telemetry(self) -> Dict[str, float]:
        st = self.sched.telemetry()
        acc = self._acc
        avg_exit = (
            acc["token_layers"] / acc["tokens"] if acc["tokens"] else 0.0
        )
        out = {
            "decode_steps": st["dense_steps"],
            "completed": st["sentences"],
            "sentences": st["sentences"],
            "tokens": acc["tokens"],
            "token_layer_calls": acc["token_layers"],
            "avg_token_exit_layer": avg_exit,
            "decode_runtime_savings": (
                1.0 - avg_exit / self.n_layers if acc["tokens"] else 0.0
            ),
            # speculative decode throughput: tokens appended per lane per
            # fused step (exactly 1.0 for the per-token paths — the bench
            # gate's baseline denominator)
            "spec_window": self.spec_window,
            "tokens_per_fused_step": (
                acc["adv_tokens"] / acc["lane_steps"]
                if acc["lane_steps"] else 0.0
            ),
            "avg_accepted_block": (
                acc["adv_tokens"] / acc["accepted_blocks"]
                if acc["accepted_blocks"] else 0.0
            ),
            "decode_traces": sum(self._traces["decode"].values()),
            "prefill_traces": sum(self._traces["prefill"].values()),
            "decode_traces_per_bucket": dict(self._traces["decode"]),
            "step_traces": sum(self._traces["decode"].values()),
            "step_traces_per_bucket": dict(self._traces["decode"]),
            "step_traces_per_bucket_replica": {
                f"{b}x{r}": n
                for (b, r), n in sorted(self._traces["decode_replica"].items())
            },
            "replicas": self.replicas,
            "buckets_used": st["buckets_used"],
            "bucket_steps": st["bucket_steps"],
            "lane_occupancy": st["lane_occupancy"],
            "queue_delay_steps_p50": st["queue_delay_steps_p50"],
            "queue_delay_steps_p95": st["queue_delay_steps_p95"],
            "queue_delay_steps_p99": st["queue_delay_steps_p99"],
            "queue_delay_steps_max": st["queue_delay_steps_max"],
            **{k: st[k] for k in _LIFECYCLE_KEYS},
        }
        if self.arbiter is not None:
            out["energy_j"] = float(acc["energy_j"])
            out["modeled_latency_s"] = float(acc["lat_max"])
            out["deadline_misses"] = acc["deadline_misses"]
            out["accepted_slo_misses"] = acc["accepted_slo_misses"]
            out["op_switches"] = self._arb_acc["op_switches"]
            out["switch_energy_j"] = self._arb_acc["switch_energy_j"]
            out["switch_time_s"] = self._arb_acc["switch_time_s"]
            out["arb_energy_j"] = self._arb_acc["total_energy_j"]
        return out


def probe_exit_threshold(
    model: Model,
    params: Any,
    prompts,
    *,
    batch_lanes: int = 2,
    max_seq: int = 32,
    eos_id: int = -1,
    buckets=(16,),
    max_new_tokens: int = 5,
    quantile: float = 0.5,
) -> float:
    """Pick a decode off-ramp entropy threshold from observed traffic.

    Drains ``prompts`` through a throwaway ``DecoderServer`` whose threshold
    sits below any entropy (no token exits, but first-off-ramp telemetry is
    live) and cuts at the ``quantile`` of the observed readings, so the
    exit-enabled deployment genuinely spreads exits across layers instead
    of all-or-nothing — the decode analogue of the classifier demos'
    dense-profiling-pass threshold pick.  The ONE probe recipe shared by
    the benchmark, the example, and the parity tests."""
    probe = DecoderServer(
        model, params, batch_lanes=batch_lanes, max_seq=max_seq,
        eos_id=eos_id, buckets=buckets, exit_threshold=-1.0,
    )
    for i, p in enumerate(prompts):
        probe.submit(Request(
            uid=i, tokens=np.asarray(p, np.int32), max_new_tokens=max_new_tokens
        ))
    probe.run()
    ents = [e for r in probe.done.values() for e in r.entropy_trace]
    assert ents, "probe produced no off-ramp readings"
    return float(np.quantile(ents, quantile))


# ===========================================================================
# Multi-task router (shared eNVM embeddings)
# ===========================================================================


class MultiTaskRouter:
    """Holds ONE shared embedding table (the eNVM-resident, frozen, pruned
    weights) and per-task encoder/head weights; dispatches requests by task.

    Models the paper's measurement (Fig. 11): task switches swap SRAM-class
    weights only; embedding reload cost is paid once at power-on.  A single
    ``arbiter`` may be shared across all task servers — the hardware has one
    LDO/ADPLL, and drains are sequential, so the shared modeled clock simply
    keeps advancing across task switches.
    """

    def __init__(
        self,
        model: Model,
        shared_embed: Any,
        task_params: Dict[str, Any],
        dvfs: Optional["LatencyAwareDVFSController"] = None,
        arbiter: Optional["BatchedDVFSArbiter"] = None,
        buckets=None,
        policy_factory: Optional[Any] = None,
        preempt: bool = False,
        residency: Optional["TaskResidencyManager"] = None,
        deployments: Optional[Dict[str, "TaskDeployment"]] = None,
        batch_lanes: int = 8,
    ):
        self.model = model
        self.shared_embed = shared_embed
        self.tasks: Dict[str, ClassifierServer] = {}
        self.switches = 0
        self.embed_reloads = 1          # power-on load only
        for name, tp in task_params.items():
            params = dict(tp, embed=shared_embed)
            # a FACTORY, not a shared instance: policies carry per-scheduler
            # mutable state (WRR credits, quantum position) that must not
            # leak between the task servers' independent schedulers
            self.tasks[name] = ClassifierServer(
                model, params, batch_lanes=batch_lanes,
                dvfs=dvfs, arbiter=arbiter, buckets=buckets,
                policy=policy_factory() if policy_factory is not None else None,
                preempt=preempt,
                task=name, residency=residency,
                deployment=(deployments or {}).get(name),
            )

    def submit(self, task: str, req: Request):
        self.tasks[task].submit(req)

    def run_all(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for name, server in self.tasks.items():
            # queued OR mid-flight (a caller may have hand-stepped a server
            # and left lanes in flight): both need draining
            if not server.sched.idle:
                self.switches += 1
                out[name] = server.run()
        return out
