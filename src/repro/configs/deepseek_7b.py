"""deepseek-7b [dense] — 30L d_model=4096 32H (GQA kv=32 = MHA) d_ff=11008 vocab=102400.

LLaMA-style architecture. [arXiv:2401.02954; hf]
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    act="swiglu",
    norm="rms",
    pos="rope",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        name="deepseek-7b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=8,
        head_dim=8,
        d_ff=96,
        vocab_size=512,
        max_seq_len=256,
    )
