"""Trace-driven workload harness: arrival-process generators + full-path
replay through the serving stack.

Every benchmark in this repo used to be a hand-rolled single-scenario script
(a fixed queue of N requests, submitted in a loop), so the
``AdmissionController -> ResidencyRouter -> LaneScheduler ->
BatchedDVFSArbiter`` path had never been exercised against large,
statistically-shaped request streams — exactly the regime where EdgeBERT's
sentence-granularity latency/energy claims are made or broken.  This module
is the load-generation layer every perf run is measured through:

* **Arrival processes** — ``PoissonArrivals`` (memoryless open-loop load),
  ``MMPPArrivals`` (Markov-modulated Poisson: exponential dwell in each rate
  state, the classic bursty-traffic model; state switches carry the residual
  exponential across via memorylessness, so the process is exact, not
  binned), and ``DiurnalArrivals`` (sinusoid-modulated inhomogeneous Poisson
  via thinning — the day/night envelope).  All are seeded generators on the
  MODELED clock: no wall time anywhere, so a trace is a pure function of
  (config, seed).

* **Traffic shaping** — ``WorkloadConfig`` mixes explicit-SLO tiers against
  best-effort (``TierSpec``; an explicit tier's deadline is
  ``slo_mult x service_s(length)``, priced off the caller's cycle model so
  SLOs scale with the hardware), multi-task mixes with skewed popularity
  (``tasks`` weights — Zipf-style skew is just unequal weights), and
  per-bucket length distributions (sample a bucket by weight, then a length
  inside it — matching how the serving stack actually pads).

* **Traces** — ``generate_trace`` streams ``TraceEvent``s (O(1) memory);
  ``save_trace``/``load_trace`` round-trip them through JSONL so a trace can
  be generated once and replayed byte-identically elsewhere.

* **Replay** — ``TraceReplayer`` drives a trace through a live serving
  target in submission order on the modeled clock: step the system until the
  clock reaches the next arrival (fast-forwarding through idle gaps via the
  arbiter's ``advance_to`` — idle time passes, it is not compressed), submit
  through admission control, ``poll()`` every step so retired payloads are
  released immediately.  Retention is O(outstanding): the replayer folds all
  per-request accounting (queue-delay reservoirs, per-tier SLO misses,
  completion counters) incrementally at poll time and never holds the trace
  or the retirees in memory, so 10^5-10^6 request replays run in bounded
  memory with zero new jit traces beyond one compile per (bucket, replica).
  Two targets ship: ``AdmissionServerTarget`` (one engine — or a bare
  ``LaneScheduler`` in tests — behind an ``AdmissionController``) and
  ``ResidencyRouterTarget`` (the full multi-task path: per-task admission
  controllers over a ``ResidencyRouter``'s task servers).

The replay summary is a flat JSON-safe dict of MODELED quantities only
(throughput, energy/request, queue-delay p50/p95/p99, accepted-SLO miss
rate, shed/reject/requote counts, swap + trace counts), so the same seed
reproduces it bit-identically — the property the benchmark history diff and
the CI determinism gate rely on.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from typing import (
    Any,
    Callable,
    Dict,
    Iterable,
    Iterator,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
)

import numpy as np

from repro.serving.scheduler import LaneScheduler, _DelayReservoir

# ===========================================================================
# Arrival processes (seeded, modeled-clock, streaming)
# ===========================================================================


class ArrivalProcess(Protocol):
    """Yields absolute arrival instants (modeled seconds, strictly
    increasing) forever; the generator bounds how many it consumes."""

    def times(self, rng: np.random.Generator) -> Iterator[float]: ...


@dataclass(frozen=True)
class PoissonArrivals:
    """Homogeneous Poisson arrivals: i.i.d. exponential inter-arrival gaps
    at ``rate_hz`` — the memoryless open-loop baseline."""

    rate_hz: float

    def __post_init__(self):
        assert self.rate_hz > 0.0

    def times(self, rng: np.random.Generator) -> Iterator[float]:
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / self.rate_hz))
            yield t


@dataclass(frozen=True)
class MMPPArrivals:
    """Markov-modulated Poisson process: the classic bursty-traffic model.

    The process cycles through ``len(rates_hz)`` states (0 -> 1 -> ... -> 0),
    dwelling an exponential time with mean ``mean_dwell_s[i]`` in state ``i``
    and emitting Poisson arrivals at ``rates_hz[i]`` while there.  A state
    switch mid-gap is handled EXACTLY: the residual of the pending
    exponential is rescaled by the rate ratio (memorylessness makes
    ``residual * rate_old`` a unit exponential, re-priced at the new rate),
    so no arrival is binned or dropped at the boundary.  Long-run rate is
    ``sum(rate_i * dwell_i) / sum(dwell_i)`` (cyclic stationary occupancy).
    """

    rates_hz: Tuple[float, ...]
    mean_dwell_s: Tuple[float, ...]
    start_state: int = 0

    def __post_init__(self):
        assert len(self.rates_hz) >= 2, "one state is plain Poisson"
        assert len(self.rates_hz) == len(self.mean_dwell_s)
        assert all(r > 0.0 for r in self.rates_hz)
        assert all(d > 0.0 for d in self.mean_dwell_s)
        assert 0 <= self.start_state < len(self.rates_hz)

    @property
    def long_run_rate_hz(self) -> float:
        w = sum(self.mean_dwell_s)
        return sum(r * d for r, d in zip(self.rates_hz, self.mean_dwell_s)) / w

    def times(self, rng: np.random.Generator) -> Iterator[float]:
        rates, dwell = self.rates_hz, self.mean_dwell_s
        s = self.start_state
        t = 0.0
        next_switch = t + float(rng.exponential(dwell[s]))
        while True:
            gap = float(rng.exponential(1.0 / rates[s]))
            while t + gap >= next_switch:
                # carry the residual exponential across the switch exactly
                residual = (t + gap) - next_switch
                t = next_switch
                s_new = (s + 1) % len(rates)
                gap = residual * rates[s] / rates[s_new]
                s = s_new
                next_switch = t + float(rng.exponential(dwell[s]))
            t += gap
            yield t


@dataclass(frozen=True)
class DiurnalArrivals:
    """Inhomogeneous Poisson with a sinusoidal (day/night) rate envelope:
    ``rate(t) = base_rate_hz * (1 + depth * sin(2 pi t / period_s + phase))``,
    realized by thinning against the peak rate (exact for any envelope
    bounded by ``base * (1 + depth)``)."""

    base_rate_hz: float
    period_s: float
    depth: float = 0.5
    phase: float = 0.0

    def __post_init__(self):
        assert self.base_rate_hz > 0.0 and self.period_s > 0.0
        assert 0.0 <= self.depth < 1.0, "depth >= 1 would need a zero-rate trough"

    def rate_at(self, t: float) -> float:
        return self.base_rate_hz * (
            1.0 + self.depth * math.sin(2.0 * math.pi * t / self.period_s + self.phase)
        )

    def times(self, rng: np.random.Generator) -> Iterator[float]:
        peak = self.base_rate_hz * (1.0 + self.depth)
        t = 0.0
        while True:
            while True:
                t += float(rng.exponential(1.0 / peak))
                if float(rng.random()) * peak <= self.rate_at(t):
                    break
            yield t


# ===========================================================================
# Workload shaping: tiers, task mixes, length distributions
# ===========================================================================


@dataclass(frozen=True)
class TierSpec:
    """One traffic tier.  ``slo_mult=None`` is best-effort (no deadline);
    otherwise the tier's requests carry an explicit SLO of
    ``slo_mult x service_s(length)`` — a multiple of the request's own
    full-depth service time, so specs stay scale-free across hw models."""

    name: str
    weight: float
    slo_mult: Optional[float] = None

    def __post_init__(self):
        assert self.weight > 0.0
        assert self.slo_mult is None or self.slo_mult > 0.0


@dataclass(frozen=True)
class WorkloadConfig:
    """A complete, seeded workload recipe: arrivals x tiers x tasks x lengths.

    ``lengths`` is a per-bucket mixture ``((bucket_size, weight), ...)``:
    sample a bucket by weight, then a length uniform in
    ``[max(4, bucket//2 + 1), bucket]`` — every sampled length lands in its
    intended serving bucket.  ``tasks`` is a weighted popularity mix
    (``()`` = single-task traffic, events carry ``task=None``).  The config
    plus ``seed`` fully determines the trace.
    """

    arrivals: ArrivalProcess
    lengths: Tuple[Tuple[int, float], ...]
    tiers: Tuple[TierSpec, ...] = (TierSpec("best_effort", 1.0),)
    tasks: Tuple[Tuple[str, float], ...] = ()
    seed: int = 0

    def __post_init__(self):
        assert self.lengths, "need at least one (bucket, weight) pair"
        assert all(b >= 4 and w > 0.0 for b, w in self.lengths)
        assert self.tiers, "need at least one tier"
        assert all(w > 0.0 for _, w in self.tasks)


@dataclass
class TraceEvent:
    """One request of a trace, before it becomes a live ``Request``."""

    uid: int
    t_s: float                          # absolute modeled arrival instant
    length: int                         # token length (pre-padding)
    tier: str
    deadline_s: Optional[float] = None  # relative SLO; None = best-effort
    task: Optional[str] = None


def _cdf(weights: Sequence[float]) -> np.ndarray:
    w = np.asarray(weights, dtype=np.float64)
    c = np.cumsum(w / w.sum())
    c[-1] = 1.0 + 1e-12                 # guard the u ~ [0, 1) upper edge
    return c


def _pick(cdf: np.ndarray, rng: np.random.Generator) -> int:
    return int(np.searchsorted(cdf, float(rng.random()), side="right"))


def generate_trace(
    cfg: WorkloadConfig,
    n: int,
    service_s: Optional[Callable[[int], float]] = None,
) -> Iterator[TraceEvent]:
    """Stream ``n`` seeded trace events (O(1) memory — never materializes).

    ``service_s(length)`` prices one request's full-depth service time for
    the SLO tiers (pass the hw model's per-bucket cycle time; default 1.0 —
    deadlines in ``slo_mult`` step units, matching bare schedulers).  Two
    independent seeded substreams drive arrivals and shaping, so the arrival
    process's variable draw count (thinning) cannot perturb the mix."""
    assert n >= 0
    svc = service_s if service_s is not None else (lambda length: 1.0)
    rng_arr = np.random.default_rng([int(cfg.seed), 0xA1])
    rng_mix = np.random.default_rng([int(cfg.seed), 0xB2])
    arrivals = cfg.arrivals.times(rng_arr)
    tier_cdf = _cdf([t.weight for t in cfg.tiers])
    len_cdf = _cdf([w for _, w in cfg.lengths])
    task_cdf = _cdf([w for _, w in cfg.tasks]) if cfg.tasks else None

    def _events() -> Iterator[TraceEvent]:
        for uid in range(n):
            t = next(arrivals)
            tier = cfg.tiers[_pick(tier_cdf, rng_mix)]
            bucket = cfg.lengths[_pick(len_cdf, rng_mix)][0]
            length = int(rng_mix.integers(max(4, bucket // 2 + 1), bucket + 1))
            task = (
                cfg.tasks[_pick(task_cdf, rng_mix)][0]
                if task_cdf is not None
                else None
            )
            deadline = (
                None if tier.slo_mult is None
                else float(tier.slo_mult) * float(svc(length))
            )
            yield TraceEvent(
                uid=uid, t_s=float(t), length=length, tier=tier.name,
                deadline_s=deadline, task=task,
            )

    return _events()


def save_trace(path: str, events: Iterable[TraceEvent]) -> int:
    """Write events as JSONL (one event per line, streaming).  Returns the
    event count."""
    n = 0
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps({
                "uid": ev.uid, "t_s": ev.t_s, "length": ev.length,
                "tier": ev.tier, "deadline_s": ev.deadline_s, "task": ev.task,
            }, sort_keys=True))
            f.write("\n")
            n += 1
    return n


def load_trace(path: str) -> Iterator[TraceEvent]:
    """Stream events back from a ``save_trace`` JSONL file."""
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            yield TraceEvent(
                uid=int(d["uid"]), t_s=float(d["t_s"]), length=int(d["length"]),
                tier=str(d["tier"]),
                deadline_s=None if d.get("deadline_s") is None else float(d["deadline_s"]),
                task=d.get("task"),
            )


# ===========================================================================
# Replay targets: the live systems a trace drives
# ===========================================================================


class ReplayTarget(Protocol):
    """What the replayer needs from a live serving stack."""

    def now_s(self) -> float: ...
    def advance_idle_to(self, t: float) -> None: ...
    def submit(self, ev: TraceEvent, req: Any) -> Optional[Any]: ...
    def step(self) -> bool: ...
    def poll(self) -> List[Any]: ...
    def outstanding(self) -> int: ...
    def merged_telemetry(self) -> Dict[str, Any]: ...


def _max_bucket_replica_traces(tel: Dict[str, Any]) -> int:
    per = tel.get("step_traces_per_bucket_replica", {})
    return max((int(v) for v in per.values()), default=0)


def _advance_scheduler_idle(server: Any, sched: LaneScheduler, t: float) -> None:
    """Fast-forward an idle system's modeled clock to ``t``: push every
    arbiter clock (the authoritative shared timeline) and let the scheduler
    sync, or move the scheduler's own clock for arbiter-less engines.
    Monotone — a clock already past ``t`` is untouched."""
    arbs = getattr(server, "arbiters", None)
    if arbs:
        for a in arbs:
            a.advance_to(t)
        sched.sync_clock()
    else:
        sched.now_s = max(sched.now_s, float(t))


class AdmissionServerTarget:
    """One serving engine (or a bare ``LaneScheduler`` in tests) behind an
    optional ``AdmissionController``.  Without admission every request is
    submitted raw (the accept-everything baseline)."""

    def __init__(self, server: Any, admission: Optional[Any] = None):
        self.server = server
        self.sched: LaneScheduler = (
            server if isinstance(server, LaneScheduler) else server.sched
        )
        self.admission = admission

    def now_s(self) -> float:
        self.sched.sync_clock()
        return self.sched.now_s

    def advance_idle_to(self, t: float) -> None:
        _advance_scheduler_idle(self.server, self.sched, t)

    def submit(self, ev: TraceEvent, req: Any):
        if self.admission is not None:
            return self.admission.submit(req)
        if self.server is self.sched:
            self.sched.submit(req)
        else:
            self.server.submit(req)
        self.sched.admission_stats["accepted"] += 1
        return None

    def step(self) -> bool:
        return self.sched.step() is not None

    def poll(self) -> List[Any]:
        return self.sched.poll()

    def outstanding(self) -> int:
        return self.sched.pending + self.sched.in_flight + len(self.sched.done)

    def merged_telemetry(self) -> Dict[str, Any]:
        tel = dict(
            self.sched.telemetry()
            if self.server is self.sched
            else self.server.telemetry()
        )
        tel.setdefault("energy_j", tel.get("arb_energy_j", 0.0))
        tel["max_traces_per_bucket_replica"] = _max_bucket_replica_traces(tel)
        return tel


class ResidencyRouterTarget:
    """The full multi-task path: per-task ``AdmissionController``s over a
    ``ResidencyRouter``'s task servers.  Every event's ``task`` routes to
    that task's controller (quotes price the task's compressed deployment
    AND its pending eNVM swap stall), and stepping is the router's
    task-affinity arbitration."""

    def __init__(
        self,
        router: Any,
        *,
        admission: bool = True,
        admission_kwargs: Optional[Dict[str, Any]] = None,
        price_foreign_queues: bool = True,
    ):
        from functools import partial

        from repro.serving.admission import AdmissionController

        self.router = router
        self.admission: Dict[str, Any] = {}
        if admission:
            kw = dict(admission_kwargs or {})
            for name, srv in router.tasks.items():
                if price_foreign_queues and "extra_wait_s" not in kw:
                    kw_task = dict(
                        kw,
                        extra_wait_s=partial(self._foreign_queued_demand_s, name),
                    )
                else:
                    kw_task = kw
                self.admission[name] = AdmissionController(srv, **kw_task)

    def _foreign_queued_demand_s(self, task: str) -> float:
        """Upper bound on the shared-clock time SIBLING tasks' QUEUED
        explicit work steals before ``task``'s next contract can run.

        The per-task controller's cross-engine term only sees siblings'
        in-flight LANES through the arbiter; their queues are invisible to
        it, and under sustained bursts the queued demand dominates — quotes
        go optimistic and accepted contracts overrun.  The router target CAN
        see the sibling queues, so it prices each sibling bucket's queued
        contracts with the same two valid upper bounds the admission layer
        uses for cross-bucket backlog: full-remaining-depth work serialized
        at the SLOWEST shared-clock operating point (no schedule runs
        slower), capped by the bucket's deadline structure (an admitted
        contract occupies the clock at most until its own absolute
        deadline).  Over-pricing only costs rejections — the miss contract
        stays one-sided.

        Deliberately NOT priced: sibling queued best-effort work.  The
        affinity policy may batch a resident task through its best-effort
        backlog ahead of a waiting non-resident contract, but charging that
        backlog to every quote rejects ~30% of otherwise-met contracts for
        a marginal miss-rate change (measured across seeds) — the policy
        preempts residency long before a full best-effort drain.  The
        residual is the just-in-time deferral tail documented in
        ``benchmarks/harness/README.md``."""
        total = 0.0
        for name, srv in self.router.tasks.items():
            if name == task:
                continue
            sched = srv.sched
            arbs = getattr(srv, "arbiters", None)
            ctrl = arbs[0].c if arbs else None
            n_layers = ctrl.stats.n_layers if ctrl is not None else None
            for b, q in sched.queues.items():
                steps = 0.0
                latest = None
                for r in q:
                    if r.deadline_s is None:
                        continue
                    rem = (
                        float(n_layers) if n_layers is not None else 1.0
                    ) - float(r.ckpt_depth or 0)
                    steps += max(rem, 1.0)
                    d_abs = r.arrival_s + r.deadline_s
                    if latest is None or d_abs > latest:
                        latest = d_abs
                if not steps:
                    continue
                if ctrl is not None:
                    dt_slow = ctrl.cycles_for_seq_len(b) / ctrl.table[0].freq_hz
                else:
                    dt_slow = float(sched.step_time_fn(b))
                steal = math.ceil(steps / sched.lanes) * dt_slow
                if latest is not None:
                    steal = min(steal, max(0.0, latest - sched.now_s))
                total += steal
        return total

    def _servers(self) -> List[Any]:
        return list(self.router.tasks.values())

    def now_s(self) -> float:
        return max(srv.sched.now_s for srv in self._servers())

    def advance_idle_to(self, t: float) -> None:
        seen: Dict[int, Any] = {}
        for srv in self._servers():
            for a in getattr(srv, "arbiters", None) or ():
                seen[id(a)] = a
        for a in seen.values():
            a.advance_to(t)
        for srv in self._servers():
            if not seen:
                srv.sched.now_s = max(srv.sched.now_s, float(t))
            srv.sched.sync_clock()

    def _outstanding_contracts(self):
        """Every accepted-but-unretired explicit contract across the task
        servers, as ``((server_id, bucket), d_abs, remaining_steps)`` — the
        demand set the displacement guard protects."""
        out = []
        for sid, srv in enumerate(self._servers()):
            sched = srv.sched
            arbs = getattr(srv, "arbiters", None)
            n_layers = (
                arbs[0].c.stats.n_layers if arbs else 1.0
            )
            for b, q in sched.queues.items():
                for r in q:
                    if r.deadline_s is None:
                        continue
                    rem = max(float(n_layers) - float(r.ckpt_depth or 0), 1.0)
                    out.append(((sid, b), r.arrival_s + r.deadline_s, rem))
            for b, run in sched._open.items():
                for i in range(sched.lanes):
                    r = run.lane_req[i]
                    if r is None or r.deadline_s is None:
                        continue
                    rem = max(float(n_layers) - float(run.lane_depth[i]), 1.0)
                    out.append(((sid, b), r.arrival_s + r.deadline_s, rem))
        return out

    def _admitting_displaces(self, ev: TraceEvent, req: Any, ac) -> bool:
        """Online EDF demand-bound test: would admitting ``req`` push any
        ALREADY-ACCEPTED contract past its deadline?

        A per-request quote prices the arrival's own wait, but EDF lets a
        later, tighter arrival insert work ahead of standing contracts —
        the quote cannot retroactively re-check them (the documented
        second-order displacement effect headroom is asked to absorb, and
        under sustained cross-task bursts does not).  The router target has
        global visibility, so it closes the loop: for every outstanding
        contract deadline ``d`` at or beyond the new request's, the total
        remaining explicit work with deadlines <= ``d`` — including the new
        request, grouped by (server, bucket) since same-bucket lanes step
        together — must fit in ``d - now`` when serialized at the SLOWEST
        shared-clock operating point (the same "no schedule runs slower"
        bound the admission layer's backlog terms use: the arbiter may
        stretch any step down to it, and the task-affinity policy may spend
        the slack on best-effort batches before an explicit contract runs).
        Any violated window is an overcommitted one, so the request is
        rejected instead of being allowed to displace a standing contract."""
        srv = self.router.tasks[ev.task]
        sched = srv.sched
        now = max(s.sched.now_s for s in self._servers())
        sid = list(self.router.tasks).index(ev.task)
        bucket = sched.bucket_for(sched.engine.bucket_key(req))
        arbs = getattr(srv, "arbiters", None)
        if not arbs:
            return False                      # no hw model: nothing to price
        ctrl = arbs[0].c
        n_layers = float(ctrl.stats.n_layers)
        d_new = now + float(req.deadline_s)
        contracts = self._outstanding_contracts()
        contracts.append(((sid, bucket), d_new, n_layers))
        lanes = sched.lanes

        def t_step(group):
            return ctrl.cycles_for_seq_len(group[1]) / ctrl.table[0].freq_hz

        deadlines = sorted({d for _, d, _ in contracts if d >= d_new})
        contracts.sort(key=lambda c: c[1])
        steps_by_group: Dict[Any, float] = {}
        i = 0
        for d in deadlines:
            while i < len(contracts) and contracts[i][1] <= d:
                g, _, rem = contracts[i]
                steps_by_group[g] = steps_by_group.get(g, 0.0) + rem
                i += 1
            demand = sum(
                math.ceil(steps / lanes) * t_step(g)
                for g, steps in steps_by_group.items()
            )
            if demand > (d - now):
                return True
        return False

    def submit(self, ev: TraceEvent, req: Any):
        assert ev.task is not None, "multi-task replay needs per-event tasks"
        ac = self.admission.get(ev.task)
        if ac is not None:
            if req.deadline_s is not None and self._admitting_displaces(
                ev, req, ac
            ):
                srv = self.router.tasks[ev.task]
                srv.sched.admission_stats["rejected"] += 1
                from types import SimpleNamespace

                return SimpleNamespace(
                    admitted=False, action="displacement_reject", shed=[]
                )
            return ac.submit(req)
        srv = self.router.tasks[ev.task]
        srv.submit(req)
        srv.sched.admission_stats["accepted"] += 1
        return None

    def step(self) -> bool:
        return self.router.step() is not None

    def poll(self) -> List[Any]:
        out: List[Any] = []
        for srv in self._servers():
            out.extend(srv.poll())
        return out

    def outstanding(self) -> int:
        return sum(
            srv.sched.pending + srv.sched.in_flight + len(srv.sched.done)
            for srv in self._servers()
        )

    def merged_telemetry(self) -> Dict[str, Any]:
        tel = dict(self.router.telemetry())     # swaps, energy (incl. swap),
                                                # accepted_slo_misses
        per = [srv.telemetry() for srv in self._servers()]
        for k in (
            "accepted", "rejected", "requoted", "shed",
            "preemptions", "restored_steps_saved", "sentences",
        ):
            tel[k] = sum(p.get(k, 0) for p in per)
        tel["step_traces"] = sum(p.get("step_traces", 0) for p in per)
        tel["max_traces_per_bucket_replica"] = max(
            (_max_bucket_replica_traces(p) for p in per), default=0
        )
        return tel


# ===========================================================================
# The replay engine
# ===========================================================================


class TraceReplayer:
    """Streams a trace through a live target on the modeled clock, in
    bounded memory, and folds a structured summary incrementally.

    The loop per event: step the system until the modeled clock reaches the
    arrival instant (or the system idles — then fast-forward, idle time
    passes), build the live ``Request`` (tokens are a pure function of
    ``(token_seed, uid)``, so a trace file needs no token payloads), submit
    through admission, and ``poll()`` after every step so retired payloads
    are released immediately.  Nothing retained scales with the trace
    length: queue-delay percentiles ride bounded reservoirs, counters fold
    at poll time, and ``peak_outstanding``/``peak_done`` record the high-
    water marks the bounded-memory tests gate on."""

    def __init__(
        self,
        target: ReplayTarget,
        *,
        vocab_size: int,
        token_seed: int = 0,
        min_token_id: int = 4,
    ):
        assert vocab_size > min_token_id >= 0
        self.target = target
        self.vocab_size = int(vocab_size)
        self.token_seed = int(token_seed)
        self.min_token_id = int(min_token_id)

    def _make_request(self, ev: TraceEvent):
        from repro.serving.engine import Request   # lazy: engine <-> workload

        rng = np.random.default_rng([self.token_seed, ev.uid])
        tokens = rng.integers(
            self.min_token_id, self.vocab_size, size=ev.length, dtype=np.int32
        )
        return Request(uid=ev.uid, tokens=tokens, deadline_s=ev.deadline_s)

    def replay(self, events: Iterable[TraceEvent]) -> Dict[str, Any]:
        tgt = self.target
        delays_steps = _DelayReservoir(seed=1)
        delays_s = _DelayReservoir(seed=2)
        per_tier: Dict[str, Dict[str, int]] = {}
        per_task: Dict[str, int] = {}
        tier_of: Dict[int, str] = {}            # outstanding uid -> tier
        n_events = submitted = rejected = 0
        completed = completed_explicit = completed_be = misses = 0
        peak_out = peak_done = 0
        first_t = last_t = None

        def _tier_bucket(name: str) -> Dict[str, int]:
            return per_tier.setdefault(
                name, {"submitted": 0, "admitted": 0, "rejected": 0,
                       "completed": 0, "slo_misses": 0}
            )

        def _done_len() -> int:
            if isinstance(tgt, ResidencyRouterTarget):
                return sum(len(s.sched.done) for s in tgt._servers())
            return len(tgt.sched.done)

        def _fold(polled: List[Any]) -> None:
            nonlocal completed, completed_explicit, completed_be, misses
            for r in polled:
                completed += 1
                tb = _tier_bucket(tier_of.pop(r.uid, "unknown"))
                tb["completed"] += 1
                if r.first_compute_step is not None and r.arrival_step is not None:
                    delays_steps.add(r.first_compute_step - r.arrival_step)
                delays_s.add(max(0.0, r.admit_s - r.arrival_s))
                if r.deadline_s is not None:
                    completed_explicit += 1
                    if r.retire_s - r.arrival_s > r.deadline_s * (1 + 1e-9):
                        misses += 1
                        tb["slo_misses"] += 1
                else:
                    completed_be += 1

        def _track_peaks() -> None:
            nonlocal peak_out, peak_done
            peak_out = max(peak_out, tgt.outstanding())
            peak_done = max(peak_done, _done_len())

        for ev in events:
            n_events += 1
            first_t = ev.t_s if first_t is None else first_t
            last_t = ev.t_s
            while tgt.now_s() + 1e-12 < ev.t_s and tgt.step():
                _fold(tgt.poll())
                _track_peaks()
            if tgt.now_s() < ev.t_s:
                tgt.advance_idle_to(ev.t_s)     # idle gap: time passes
            req = self._make_request(ev)
            decision = tgt.submit(ev, req)
            submitted += 1
            tb = _tier_bucket(ev.tier)
            tb["submitted"] += 1
            if ev.task is not None:
                per_task[ev.task] = per_task.get(ev.task, 0) + 1
            if decision is not None and not decision.admitted:
                rejected += 1
                tb["rejected"] += 1
            else:
                tb["admitted"] += 1
                tier_of[ev.uid] = ev.tier
            _fold(tgt.poll())
            _track_peaks()
        while tgt.step():                       # drain the tail
            _fold(tgt.poll())
            _track_peaks()
        _fold(tgt.poll())

        tel = tgt.merged_telemetry()
        shed = int(tel.get("shed", 0))
        # shed requests never retire: drop their tier tracking so the
        # outstanding map stays bounded after the drain
        if shed:
            tier_of.clear()
        span = max(0.0, tgt.now_s() - (first_t or 0.0))
        energy = float(tel.get("energy_j", tel.get("arb_energy_j", 0.0)) or 0.0)
        summary: Dict[str, Any] = {
            "requests": n_events,
            "submitted": submitted,
            "accepted": int(tel.get("accepted", 0)),
            "rejected": int(tel.get("rejected", rejected)),
            "requoted": int(tel.get("requoted", 0)),
            "shed": shed,
            "completed": completed,
            "completed_explicit": completed_explicit,
            "completed_best_effort": completed_be,
            "accepted_slo_misses": misses,
            "accepted_slo_miss_rate": (
                misses / completed_explicit if completed_explicit else 0.0
            ),
            "queue_delay_steps_p50": delays_steps.percentile(50),
            "queue_delay_steps_p95": delays_steps.percentile(95),
            "queue_delay_steps_p99": delays_steps.percentile(99),
            "queue_delay_s_p50": delays_s.percentile(50),
            "queue_delay_s_p95": delays_s.percentile(95),
            "queue_delay_s_p99": delays_s.percentile(99),
            "modeled_span_s": span,
            "throughput_rps": completed / span if span > 0.0 else 0.0,
            "energy_j": energy,
            "energy_per_request_j": energy / completed if completed else 0.0,
            "preemptions": int(tel.get("preemptions", 0)),
            "step_traces": int(tel.get("step_traces", 0)),
            "max_traces_per_bucket_replica": int(
                tel.get("max_traces_per_bucket_replica", 0)
            ),
            "peak_outstanding": peak_out,
            "peak_done": peak_done,
            "per_tier": {k: dict(v) for k, v in sorted(per_tier.items())},
            "per_task": dict(sorted(per_task.items())),
        }
        for k in ("task_swaps", "swap_stall_s", "swap_energy_j"):
            if k in tel:
                summary[k] = tel[k]
        if "degraded_tasks" in tel:
            summary["degraded_tasks"] = list(tel["degraded_tasks"])
        return summary


def summaries_identical(a: Dict[str, Any], b: Dict[str, Any]) -> bool:
    """Bit-identical summary comparison (the determinism acceptance gate):
    serialized with sorted keys so nested dict ordering cannot hide a
    difference — floats must match exactly, not approximately."""
    return json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
