"""Multi-task serving with eNVM-shared embeddings (paper §III-D / Fig. 11)
and SHARED-CLOCK batched DVFS (the batched generalization of paper Alg. 1).

One frozen, pruned embedding table serves N task-specific encoder+classifier
weight sets; task switches never touch the embeddings (they live in on-chip
ReRAM in the paper; here: a single shared array).  Every server drains its
queue through the length-bucketed continuation-batching engine, and — since
the accelerator has ONE LDO/ADPLL pair — all task servers share a single
``BatchedDVFSArbiter`` that makes one (V, f) decision per fused step, charges
the switching stall on every operating-point change, and calibrates its
entropy->exit-layer LUT ONLINE as sentences retire (no offline profiling
pass).  Each task reports modeled accelerator energy at the prescribed
target latency alongside the power-on cost advantage from the hardware model.

This is a true many-task, many-tenant scenario (``serving/residency.py``):
SIX tasks contend for an SRAM working set sized to hold well under half of
them.  Each task ships a ``TaskDeployment`` — its adaptive-span budget,
movement-pruning occupancy, and AdaptivFloat format — and the engine prices
that task's cycles, per-lane energy, and admission quotes off the COMPRESSED
network (a sparser task is quoted cheaper than a dense one).  Non-resident
tasks live in eNVM: a ``TaskResidencyManager`` LRU-evicts until the task's
bitmask-encoded footprint fits and charges the modeled ReRAM read as a
STALL on the shared clock — so a non-resident task's admission quote is
strictly dearer by its pending swap stall, and the ``ResidencyRouter``'s
``TaskAffinityPolicy`` decides WHICH task steps by trading EDF urgency
against that swap cost (batch through the warm working set; preempt
residency only when a cold task's discounted slack demands it).

Also demonstrates the step()-clocked serving API: one task is driven by hand
(``step()``/``poll()``), and an URGENT request with a per-request ``deadline_s``
is submitted MID-DRAIN — the EDF policy preempts the ongoing work, the
request retires against its own SLO, and queue-delay telemetry
(arrival -> first compute, in fused steps) shows nobody starved.

Admission control (``serving/admission.py``) fronts the hand-driven task:
an impossible SLO is REJECTED at submission with the minimum feasible
deadline quoted back (priced by the per-bucket cycle model at the arbiter's
max operating point), the caller resubmits at the quote and is accepted —
and met.  The servers run ``preempt=True``, so when every lane IS busy an
urgent contract checkpoint-evicts a budget-free lane instead of waiting for
a retire (this small demo keeps a lane free; the oversubscribed case is the
``admission_storm`` scenario in ``benchmarks/bench_batched_dvfs.py``).

The closing section re-drains one task with ``use_pallas=True``: the same
fused step with its inner math (attention/layernorm/off-ramp entropy/act
quant) routed to the Pallas kernels — interpret mode on CPU, Mosaic on TPU.
The flag is static, so trace counts are identical, and logits/exit depths
match the reference drain (the CI-gated guarantee from
``tests/test_pallas_serving.py``).

    PYTHONPATH=src python examples/serve_multitask.py
"""
import dataclasses
import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core import bitmask as bm
from repro.core.early_exit import OnlineExitCalibrator
from repro.data.synthetic import SyntheticCLS
from repro.hwmodel.edgebert_accel import albert_layer_stats, poweron_embedding_cost
from repro.models.model import build_model
from repro.serving.admission import AdmissionController
from repro.serving.dvfs import (
    BatchedDVFSArbiter,
    LatencyAwareDVFSController,
    no_early_exit_baseline,
)
from repro.serving.engine import Request
from repro.serving.residency import (
    ResidencyRouter,
    TaskAffinityPolicy,
    TaskDeployment,
    TaskResidencyManager,
)

cfg = dataclasses.replace(
    get_smoke_config("albert_edgebert"), dtype="float32", remat_policy="none"
)
model = build_model(cfg)

# four "GLUE tasks": task-specific encoder/classifier, SHARED embeddings
base = model.init_params(jax.random.PRNGKey(0))

# pick an entropy threshold that actually spreads exits on these (untrained)
# weights: the median off-ramp entropy of a dense profiling pass
import jax.numpy as jnp

_probe = SyntheticCLS(cfg.vocab_size, 32, 16, num_classes=3)
_out = model.apply_train(base, {"tokens": jnp.asarray(_probe.batch(0)["tokens"])})
cfg = cfg.with_edgebert(
    early_exit=dataclasses.replace(
        cfg.edgebert.early_exit,
        entropy_threshold=float(np.quantile(np.asarray(_out.all_entropies), 0.5)),
    )
)
model = build_model(cfg)
TASKS = ("mnli", "qqp", "sst2", "qnli", "rte", "cola")
tasks = {}
for i, task in enumerate(TASKS):
    tasks[task] = model.init_params(jax.random.PRNGKey(i))

# per-task compressed deployments: span budget + pruning occupancy (+ the
# default 8-bit AdaptivFloat format).  rte ships DENSE so the pricing gap
# against its compressed neighbours is visible in the quotes below.
_n_task_params = sum(
    int(np.prod(np.shape(a)))
    for k in tasks["mnli"] if k != "embed"           # embeddings are shared
    for a in jax.tree_util.tree_leaves(tasks["mnli"][k])
)
deployments = {
    "mnli": TaskDeployment("mnli", _n_task_params, pruning_occupancy=0.4,
                           spans=(8, 8, 16, 32), n_heads=cfg.n_heads,
                           span_seq_len=32),
    "qqp":  TaskDeployment("qqp", _n_task_params, pruning_occupancy=0.5),
    "sst2": TaskDeployment("sst2", _n_task_params, pruning_occupancy=0.3,
                           spans=(8, 8, 8, 16), n_heads=cfg.n_heads,
                           span_seq_len=32),
    "qnli": TaskDeployment("qnli", _n_task_params, pruning_occupancy=0.6),
    "rte":  TaskDeployment("rte", _n_task_params, pruning_occupancy=1.0),
    "cola": TaskDeployment("cola", _n_task_params, pruning_occupancy=0.4),
}
# SRAM holds well under half the fleet: everything else pays the modeled
# eNVM read (a stall on the shared clock) to swap in
residency = TaskResidencyManager(
    deployments,
    sram_bytes=int(0.45 * sum(
        d.storage()["total_bytes"] for d in deployments.values()
    )),
)

# shared-clock latency-aware DVFS: one LDO/ADPLL for the whole chip, so ONE
# arbiter serves every task server.  The target gets deployment headroom
# (1.5x the full-model latency) — at a slack-free target the shared clock
# degenerates to race-to-idle.  The exit-layer LUT calibrates ONLINE from
# retiring sentences: no offline profiling pass.
hw = albert_layer_stats(seq_len=32)
hw.n_layers = cfg.n_layers
dvfs = LatencyAwareDVFSController(
    hw,
    no_early_exit_baseline(hw)["latency_s"] * 1.5,
    online_calibrator=OnlineExitCalibrator(cfg.n_layers, hi=float(np.log(3)) + 0.1),
)
arbiter = BatchedDVFSArbiter(dvfs)
router = ResidencyRouter(
    model, base["embed"], tasks, residency=residency,
    deployments=deployments, task_policy=TaskAffinityPolicy(),
    arbiter=arbiter, buckets=(16, 32), preempt=True,
)

data = SyntheticCLS(cfg.vocab_size, 32, 16, num_classes=3)
b = data.batch(0)
_rng = np.random.default_rng(0)
for i, task in enumerate(TASKS):
    for j in range(4):
        k = i * 4 + j
        L = int(_rng.integers(10, 33))      # mixed lengths -> both buckets
        router.submit(task, Request(uid=k, tokens=b["tokens"][k % 16][:L]))

# ---- step()-clocked serving with ADMISSION CONTROL: drive ONE task by hand
# and drop an URGENT request with its own SLO into the middle of its drain.
# An infeasible SLO is rejected at submit time with the minimum feasible
# deadline quoted back; resubmitted at the quote it is accepted, the EDF
# policy checkpoint-evicts a budget-free lane for it (preempt=True), and
# poll() hands back completions as they retire.
mnli = router.tasks["mnli"]
admit = AdmissionController(mnli, max_best_effort_queue=8)
for _ in range(2):
    mnli.step()
t_layer16 = dvfs.cycles_for_seq_len(16) / dvfs.max_op.freq_hz
impossible = admit.submit(Request(
    uid=998, tokens=b["tokens"][7][:12], deadline_s=t_layer16 * 0.5
))
assert not impossible.admitted
print(f"impossible SLO {t_layer16 * 0.5 * 1e3:.3f}ms REJECTED at admission; "
      f"min feasible quote {impossible.quote.min_deadline_s*1e3:.2f}ms "
      f"(wait {impossible.quote.wait_s*1e3:.2f}ms + service "
      f"{impossible.quote.service_s*1e3:.2f}ms, headroom included)")
urgent_deadline = max(
    impossible.quote.min_deadline_s, t_layer16 * cfg.n_layers * 2
)
accepted = admit.submit(Request(
    uid=999, tokens=b["tokens"][7][:12], deadline_s=urgent_deadline
))
assert accepted.admitted
urgent = None
while urgent is None and mnli.step() is not None:
    urgent = next((r for r in mnli.poll() if r.uid == 999), None)
assert urgent is not None
# the SLO is submission-anchored: modeled queue wait counts toward it (the
# same accounting telemetry()'s deadline_misses uses)
urgent_total = (urgent.admit_s - urgent.arrival_s) + urgent.latency_s
print(f"urgent request: exit {urgent.exit_layer}/{cfg.n_layers}, modeled "
      f"{urgent_total*1e3:.2f}ms (incl. queue wait) vs its own SLO "
      f"{urgent_deadline*1e3:.2f}ms "
      f"({'MET' if urgent_total <= urgent_deadline else 'MISSED'}); "
      f"queued {urgent.first_compute_step - urgent.arrival_step} steps")
st_mnli = mnli.telemetry()
print(f"admission: {st_mnli['accepted']} accepted, {st_mnli['rejected']} "
      f"rejected, {st_mnli['shed']} shed; {st_mnli['preemptions']} lane "
      f"preemption(s) saved {st_mnli['restored_steps_saved']} re-run layers")

# residency pricing: mnli's refills made it SRAM-resident, so its quotes
# carry no swap term — a cold task's quote for the IDENTICAL request is
# dearer by its pending eNVM swap stall (x admission headroom)
assert residency.is_resident("mnli")
probe = Request(uid=1500, tokens=b["tokens"][3][:12], deadline_s=1.0)
cold = next(t for t in TASKS if not residency.is_resident(t))
q_cold = AdmissionController(router.tasks[cold]).quote(probe)
stall = residency.pending_swap_stall_s(cold)
print(f"residency pricing: {cold} is eNVM-only, so its quote's wait "
      f"({q_cold.wait_s*1e6:.1f}us) includes the {stall*1e6:.2f}us swap "
      f"stall; resident {sorted(residency.resident_set)} quote without it")
# compressed deployment pricing: mnli (occ 0.4 + span budget) is quoted
# fewer cycles per fused step than dense rte on the same bucket
print(f"deployment pricing: bucket-16 cycles mnli(compressed) "
      f"{router.tasks['mnli']._cycles_for(16)} vs rte(dense) "
      f"{router.tasks['rte']._cycles_for(16)}")

stats = router.run_all()
e_noee_each = dvfs.no_early_exit_baseline()["energy_j"]
stats["mnli"] = mnli.telemetry()        # include the hand-stepped drain
for task, st in stats.items():
    e_noee = st["sentences"] * e_noee_each
    print(f"{task}: {st['sentences']} sentences, avg exit "
          f"{st['avg_exit_layer']:.1f}/{cfg.n_layers}, savings {st['runtime_savings']:.0%}, "
          f"energy {st['energy_j']*1e3:.2f}mJ ({e_noee / st['energy_j']:.1f}x vs no-early-exit, "
          f"{st['deadline_misses']} deadline misses, queue delay "
          f"p50/p95/p99 {st['queue_delay_steps_p50']:.0f}/{st['queue_delay_steps_p95']:.0f}"
          f"/{st['queue_delay_steps_p99']:.0f} steps)")
print(f"task switches: {router.switches}, embedding reloads: {router.embed_reloads} "
      "(embeddings are eNVM-resident); fused step traces/server: "
      f"{[st['step_traces'] for st in stats.values()]}")
rt = router.telemetry()
print(f"residency: {rt['task_swaps']} task swaps over {rt['task_steps']} "
      f"affinity-arbitrated steps ({rt['task_switches']} task switches), "
      f"{rt['swap_stall_s']*1e6:.1f}us stall + {rt['swap_energy_j']*1e6:.2f}uJ "
      f"paid to eNVM, {rt['residency_hits']} warm refills, "
      f"{rt['evictions']} evictions; resident at drain end: "
      f"{sorted(rt['resident_set'])} "
      f"({rt['resident_bytes']}/{rt['sram_bytes']} SRAM bytes)")
arb = arbiter.telemetry()
print(f"shared clock: {arb['op_switches']} (V,f) switches, "
      f"{arb['switch_energy_j']*1e6:.2f}uJ switching energy, "
      f"{dvfs.online.count} sentences folded into the online LUT")

enc = bm.encode(np.asarray(base["embed"]["tok"]))
s = bm.storage_bytes(enc, value_bits=8)
c = poweron_embedding_cost(s["value_bytes"], s["mask_bytes"])
print(f"power-on embedding load: eNVM {c['envm_latency_s']*1e6:.1f}us vs "
      f"DRAM->SRAM {c['conventional_latency_s']*1e6:.1f}us "
      f"({c['latency_advantage']:.0f}x latency, {c['energy_advantage']:.0f}x energy)")

# ---- decoder lane: per-token early exit + DVFS on the SAME shared clock ----
# The paper's entropy off-ramp generalized to autoregressive decode: after
# every layer the LM head is evaluated and a token below the threshold exits
# (hidden-state propagation keeps later layers' KV defined), its realized
# depth feeds a position-binned online LUT, and the SAME arbiter that served
# the classifier tasks budgets each decode lane's (V, f) from the predicted
# remaining layers of its remaining tokens — classifier and decoder traffic
# admitted and arbitrated on one timeline.
from repro.configs.base import get_smoke_config as _smoke
from repro.models.model import build_model as _build
from repro.serving.engine import DecoderServer, probe_exit_threshold

_dcfg = dataclasses.replace(
    _smoke("deepseek_7b"), dtype="float32", remat_policy="none", n_layers=4
)
_dmodel = _build(_dcfg)
_dparams = _dmodel.init_params(jax.random.PRNGKey(7))
_drng = np.random.default_rng(7)
_prompts = [
    _drng.integers(4, _dcfg.vocab_size, size=int(_drng.integers(4, 9))).astype(np.int32)
    for _ in range(6)
]

# probe the off-ramp threshold exactly like the classifier above: the median
# first-off-ramp entropy of a no-exit pass (the shared probe recipe)
_thr = probe_exit_threshold(_dmodel, _dparams, _prompts)

decoder = DecoderServer(
    _dmodel, _dparams, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
    arbiter=arbiter, exit_threshold=_thr,
)
# submission-anchored SLO: own full-depth work plus the serialized backlog
# ahead of it (6 requests over 2 lanes), with headroom for slack-stretching
_t_req = (decoder._cycles_for(16) / dvfs.max_op.freq_hz) * 5    # 5 tokens, full depth
_dl = _t_req * (len(_prompts) / 2) * 4
for _i, _p in enumerate(_prompts):
    decoder.submit(Request(uid=100 + _i, tokens=_p, max_new_tokens=5, deadline_s=_dl))
st_dec = decoder.run()
print(f"decoder lane (shared clock): {st_dec['tokens']} tokens, avg token exit "
      f"{st_dec['avg_token_exit_layer']:.1f}/{_dcfg.n_layers} "
      f"(decode savings {st_dec['decode_runtime_savings']:.0%}), energy "
      f"{st_dec['energy_j']*1e6:.1f}uJ, {st_dec['accepted_slo_misses']} "
      f"accepted-SLO misses, decode traces {st_dec['decode_traces_per_bucket']}")

# ---- Pallas-fused serving step (use_pallas=True) --------------------------
# Same engine, same traffic, inner math routed to the Pallas kernels via
# serving/step_math.py + kernels/dispatch.py.  The flag is a static Python
# bool closed over by the jit'd closures — zero extra traces — and the
# drain must agree with the reference path on logits AND exit depths.
from repro.serving.engine import ClassifierServer

_preqs = [Request(uid=i, tokens=b["tokens"][i % 16][: 12 + 4 * (i % 3)])
          for i in range(8)]
_pdrains = {}
for _flag in (False, True):
    _srv = ClassifierServer(model, tasks["mnli"], batch_lanes=4,
                            buckets=(16, 32), use_pallas=_flag)
    for _r in _preqs:
        _srv.submit(dataclasses.replace(_r))
    _srv.run()
    _pdrains[_flag] = _srv
_ref, _pal = _pdrains[False], _pdrains[True]
_max_diff = max(
    float(np.max(np.abs(np.asarray(_pal.done[i].result)
                        - np.asarray(_ref.done[i].result))))
    for i in range(8)
)
assert all(_pal.done[i].exit_layer == _ref.done[i].exit_layer for i in range(8))
print(f"pallas serving step ({jax.default_backend()}"
      f"{', interpret mode' if jax.default_backend() != 'tpu' else ''}): "
      f"8 sentences, max |logit diff| {_max_diff:.1e}, exit depths identical, "
      f"step traces {_pal.telemetry()['step_traces']} == "
      f"{_ref.telemetry()['step_traces']} (static flag adds none)")
