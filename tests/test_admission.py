"""Admission control subsystem: SLO feasibility quoting (reject / re-quote
instead of accept-then-miss), best-effort load shedding (bounded queue,
oldest-drop), and preemptive lane checkpointing (evict a budget-free lane for
a tighter-SLO arrival, restore it later with zero re-run layers and zero new
traces)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.data.synthetic import SyntheticCLS
from repro.hwmodel.edgebert_accel import albert_layer_stats
from repro.models.model import build_model
from repro.serving.admission import AdmissionController
from repro.serving.dvfs import (
    BatchedDVFSArbiter,
    LatencyAwareDVFSController,
    no_early_exit_baseline,
)
from repro.serving.engine import ClassifierServer, DecoderServer, Request


def _albert_model(threshold=1e-9):
    cfg = get_smoke_config("albert_edgebert")
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    cfg = cfg.with_edgebert(
        early_exit=dataclasses.replace(
            cfg.edgebert.early_exit, entropy_threshold=threshold
        )
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params, cfg


def _decoder_model():
    cfg = dataclasses.replace(
        get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none"
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    return model, params, cfg


def _batch(cfg, n=8, seed=0):
    return SyntheticCLS(cfg.vocab_size, 32, n, num_classes=3, seed=seed).batch(0)


class TestFeasibilityQuote:
    def test_infeasible_slo_rejected_with_min_feasible_quote(self):
        """An SLO below the full-depth service floor never enters a queue;
        the caller gets the minimum feasible deadline instead of a miss."""
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        srv = ClassifierServer(model, params, batch_lanes=2, buckets=(16,))
        ac = AdmissionController(srv)
        d = ac.submit(Request(uid=0, tokens=batch["tokens"][0][:12], deadline_s=1.0))
        assert not d.admitted and d.action == "rejected"
        # cold request quotes conservative full depth (steps at 1.0 s/step)
        assert d.quote.min_deadline_s >= cfg.n_layers
        assert not d.quote.feasible
        assert srv.pending == 0 and srv.sched.idle
        assert srv.telemetry()["rejected"] == 1

    def test_quote_is_honored_on_resubmission(self):
        """Resubmitting at exactly the quoted deadline must be accepted (the
        headroom lives inside the quote, not on top of it) and then met."""
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        srv = ClassifierServer(model, params, batch_lanes=2, buckets=(16,))
        ac = AdmissionController(srv)
        d = ac.submit(Request(uid=0, tokens=batch["tokens"][0][:12], deadline_s=1.0))
        d2 = ac.submit(Request(
            uid=1, tokens=batch["tokens"][0][:12], deadline_s=d.quote.min_deadline_s
        ))
        assert d2.admitted and d2.action == "accepted"
        srv.run()
        r = srv.done[1]
        # deadline math in steps: retire time minus submission, on the
        # modeled clock the quote was priced in
        assert r.retire_step - r.arrival_step <= r.deadline_s

    def test_backlog_inflates_the_quote(self):
        """Accepted explicit commitments push later quotes out: with one lane
        the accepted contract occupies it up to ITS absolute deadline (the
        DVFS layer stretches slack-rich lanes just-in-time), so the next
        identical request is quoted strictly later."""
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        srv = ClassifierServer(model, params, batch_lanes=1, buckets=(16,))
        ac = AdmissionController(srv)
        q0 = ac.quote(Request(uid=0, tokens=batch["tokens"][0][:12], deadline_s=1.0))
        ac.submit(Request(
            uid=1, tokens=batch["tokens"][1][:12], deadline_s=q0.min_deadline_s
        ))
        q1 = ac.quote(Request(uid=2, tokens=batch["tokens"][2][:12], deadline_s=1.0))
        assert q1.min_deadline_s > q0.min_deadline_s
        assert q1.wait_s > q0.wait_s
        # the wait is the accepted contract's absolute deadline, not its
        # max-op completion time
        assert q1.wait_s == pytest.approx(q0.min_deadline_s)

    def test_requote_mode_admits_at_the_quoted_deadline(self):
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        srv = ClassifierServer(model, params, batch_lanes=2, buckets=(16,))
        ac = AdmissionController(srv, on_infeasible="requote")
        d = ac.submit(Request(uid=0, tokens=batch["tokens"][0][:12], deadline_s=1.0))
        assert d.admitted and d.action == "requoted"
        req = next(iter(srv.sched.queues[16]))
        assert req.quoted_deadline_s == 1.0          # the original SLO
        assert req.deadline_s == pytest.approx(d.quote.min_deadline_s)
        srv.run()
        assert srv.telemetry()["requoted"] == 1
        r = srv.done[0]
        assert r.retire_step - r.arrival_step <= r.deadline_s

    def test_arbiter_quote_prices_bucket_cycles_at_max_op(self):
        """With a shared-clock arbiter the quote uses the per-bucket cycle
        model at the MAX operating point plus one worst-case switch stall —
        below the controller-target service time, above the raw layer time."""
        model, params, cfg = _albert_model()
        stats = albert_layer_stats(seq_len=16)
        stats.n_layers = cfg.n_layers
        ctrl = LatencyAwareDVFSController(
            stats, no_early_exit_baseline(stats)["latency_s"] * 2.0
        )
        arb = BatchedDVFSArbiter(ctrl)
        srv = ClassifierServer(
            model, params, batch_lanes=2, buckets=(16,), arbiter=arb
        )
        ac = AdmissionController(srv, headroom=1.0)
        batch = _batch(cfg)
        q = ac.quote(Request(uid=0, tokens=batch["tokens"][0][:12], deadline_s=1.0))
        floor = cfg.n_layers * ctrl.cycles_for_seq_len(16) / ctrl.max_op.freq_hz
        assert q.service_s >= floor                   # stall included
        assert q.service_s == pytest.approx(
            arb.min_latency_quote(
                cfg.n_layers, cycles_per_layer=ctrl.cycles_for_seq_len(16)
            )
        )

    def test_queued_contract_claims_the_first_freed_lane(self):
        """Without preemption, a queued accepted contract takes the first
        lane that frees (EDF pops it first) — a later arrival must be quoted
        the SECOND freed lane, not the first, or it gets accepted and then
        starved behind the earlier contract."""
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        srv = ClassifierServer(model, params, batch_lanes=1, buckets=(16,))
        ac = AdmissionController(srv)
        # occupy the single lane with best-effort work (full depth ahead)
        srv.submit(Request(uid=0, tokens=batch["tokens"][0][:12]))
        srv.step()
        q_empty = ac.quote(Request(uid=90, tokens=batch["tokens"][1][:12],
                                   deadline_s=1.0))
        # accept one contract at its quote: it now waits for the lane
        d1 = ac.submit(Request(
            uid=1, tokens=batch["tokens"][1][:12],
            deadline_s=q_empty.min_deadline_s,
        ))
        assert d1.admitted
        # the next arrival must be priced BEHIND uid 1's whole occupancy
        # (its absolute deadline), not just the best-effort retire
        q2 = ac.quote(Request(uid=2, tokens=batch["tokens"][2][:12],
                              deadline_s=1.0))
        assert q2.wait_s > q_empty.wait_s
        assert q2.wait_s >= q_empty.min_deadline_s - srv.sched.now_s - 1e-9
        # both accepted contracts must then actually be met
        d2 = ac.submit(Request(
            uid=2, tokens=batch["tokens"][2][:12],
            deadline_s=q2.min_deadline_s,
        ))
        assert d2.admitted
        srv.run()
        for uid in (1, 2):
            r = srv.done[uid]
            assert r.retire_step - r.arrival_step <= r.deadline_s, uid

    def test_shared_arbiter_syncs_interleaved_scheduler_clocks(self):
        """Two servers on ONE arbiter, hand-interleaved: each scheduler's
        modeled clock must track the SHARED hardware timeline (the arbiter
        clock), not just its own steps — otherwise EDF slack and admission
        quotes judge deadlines on a stale 'now'."""
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        stats = albert_layer_stats(seq_len=16)
        stats.n_layers = cfg.n_layers
        ctrl = LatencyAwareDVFSController(
            stats, no_early_exit_baseline(stats)["latency_s"] * 1.5
        )
        arb = BatchedDVFSArbiter(ctrl)
        s1 = ClassifierServer(model, params, batch_lanes=2, buckets=(16,),
                              arbiter=arb)
        s2 = ClassifierServer(model, params, batch_lanes=2, buckets=(16,),
                              arbiter=arb)
        for i in range(2):
            s1.submit(Request(uid=i, tokens=batch["tokens"][i][:12]))
            s2.submit(Request(uid=10 + i, tokens=batch["tokens"][2 + i][:12]))
        s1.step()
        s2.step()
        s1.step()
        # after each server's step its clock equals the shared arbiter clock
        assert s1.sched.now_s == pytest.approx(arb.now_s)
        s2.step()
        assert s2.sched.now_s == pytest.approx(arb.now_s)
        # a submit() to the OTHER server stamps arrival on the shared
        # timeline too — an explicit SLO's queue wait starts at the true
        # hardware now, not at a clock frozen while this server was idle
        s1.step()
        late = Request(uid=50, tokens=batch["tokens"][5][:12],
                       deadline_s=ctrl.target_latency_s)
        s2.submit(late)
        assert late.arrival_s == pytest.approx(arb.now_s)

    def test_best_effort_always_admitted(self):
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        srv = ClassifierServer(model, params, batch_lanes=2, buckets=(16,))
        ac = AdmissionController(srv)
        d = ac.submit(Request(uid=0, tokens=batch["tokens"][0][:12]))
        assert d.admitted and d.quote is None and d.shed == []


class TestLoadShedding:
    def test_bounded_queue_drops_oldest_best_effort(self):
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        srv = ClassifierServer(model, params, batch_lanes=2, buckets=(16,))
        ac = AdmissionController(srv, max_best_effort_queue=2)
        shed = []
        for i in range(6):
            d = ac.submit(Request(uid=i, tokens=batch["tokens"][i][:12]))
            shed += d.shed
        # queue bound 2: four oldest dropped, in arrival order
        assert [r.uid for r in shed] == [0, 1, 2, 3]
        assert all(r.shed for r in shed)
        srv.run()
        assert sorted(srv.done) == [4, 5]             # shed requests never ran
        st = srv.telemetry()
        assert st["shed"] == 4 and st["sentences"] == 2

    def test_explicit_slo_never_shed(self):
        """A storm of best-effort submissions must drop best-effort work, not
        the accepted contract sitting in the same queue."""
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        srv = ClassifierServer(model, params, batch_lanes=2, buckets=(16,))
        ac = AdmissionController(srv, max_best_effort_queue=1)
        ac.submit(Request(
            uid=100, tokens=batch["tokens"][0][:12],
            deadline_s=float(cfg.n_layers * 4),
        ))
        for i in range(4):
            ac.submit(Request(uid=i, tokens=batch["tokens"][i][:12]))
        srv.run()
        assert 100 in srv.done
        assert srv.telemetry()["shed"] == 3

    def test_checkpointed_request_never_shed(self):
        """A preempted request waiting with its checkpoint holds completed
        layers — the oldest-drop policy must skip it."""
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        srv = ClassifierServer(
            model, params, batch_lanes=1, buckets=(16,), preempt=True
        )
        ac = AdmissionController(srv, max_best_effort_queue=1)
        ac.submit(Request(uid=0, tokens=batch["tokens"][0][:12]))
        srv.step()                                    # uid 0 in flight
        # explicit arrival preempts uid 0 back into the queue, checkpointed
        ac.submit(Request(
            uid=99, tokens=batch["tokens"][1][:12],
            deadline_s=float(cfg.n_layers * 6),
        ))
        srv.step()
        assert srv.telemetry()["preemptions"] == 1
        # queue bound 1 with uid 0 (checkpointed) waiting: new best-effort
        # submissions shed EACH OTHER, never uid 0
        d = ac.submit(Request(uid=1, tokens=batch["tokens"][2][:12]))
        d2 = ac.submit(Request(uid=2, tokens=batch["tokens"][3][:12]))
        assert d.shed == [] and [r.uid for r in d2.shed] == [1]
        srv.run()
        assert 0 in srv.done and 99 in srv.done


class TestPreemption:
    def test_classifier_checkpoint_restore_parity(self):
        """Acceptance criterion: a preempted-then-restored sentence produces
        BIT-IDENTICAL logits and the same exit depth as an uninterrupted run,
        with zero additional jit traces."""
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        srv = ClassifierServer(
            model, params, batch_lanes=2, buckets=(16,), preempt=True
        )
        ref = ClassifierServer(model, params, batch_lanes=2, buckets=(16,))
        for s in (srv, ref):
            for i in range(3):
                s.submit(Request(uid=i, tokens=batch["tokens"][i][:12]))
        srv.step()
        srv.step()
        # tight-SLO arrival with all lanes busy on budget-free work
        srv.submit(Request(
            uid=99, tokens=batch["tokens"][4][:12],
            deadline_s=float(cfg.n_layers + 3),
        ))
        while srv.step() is not None:
            pass
        while ref.step() is not None:
            pass
        st, st_ref = srv.telemetry(), ref.telemetry()
        assert st["preemptions"] >= 1
        assert st["restored_steps_saved"] >= 1
        preempted = [i for i in range(3) if srv.done[i].preempted]
        assert preempted, "scenario must actually preempt a lane"
        for i in range(3):
            assert srv.done[i].exit_layer == ref.done[i].exit_layer, i
            assert np.array_equal(srv.done[i].result, ref.done[i].result), i
        # zero ADDITIONAL traces: same per-bucket compile counts as the
        # uninterrupted run (restore reuses the bucket's insert trace)
        assert st["step_traces"] == st_ref["step_traces"] == 1
        assert st["insert_traces"] == st_ref["insert_traces"] == 1

    def test_preemption_bounds_explicit_wait_by_one_step(self):
        """With every lane busy on budget-free work, an explicit arrival is
        admitted at the NEXT fused step under preemption; without it, only
        after a retire (full depth away)."""
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        outcomes = {}
        for preempt in (True, False):
            srv = ClassifierServer(
                model, params, batch_lanes=2, buckets=(16,), preempt=preempt
            )
            for i in range(4):
                srv.submit(Request(uid=i, tokens=batch["tokens"][i][:12]))
            srv.step()
            srv.submit(Request(
                uid=99, tokens=batch["tokens"][5][:12],
                deadline_s=float(cfg.n_layers + 2),
            ))
            while srv.step() is not None:
                pass
            r = srv.done[99]
            outcomes[preempt] = r.first_compute_step - r.arrival_step
        assert outcomes[True] == 0                    # evicted at next refill
        assert outcomes[False] >= cfg.n_layers - 1    # waited for a retire

    def test_preempted_lane_resumes_at_saved_depth(self):
        """The restored request's total layer count equals its exit layer —
        completed layers are not re-run (the depth carries over)."""
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        srv = ClassifierServer(
            model, params, batch_lanes=1, buckets=(16,), preempt=True
        )
        srv.submit(Request(uid=0, tokens=batch["tokens"][0][:12]))
        srv.step()
        srv.step()                                    # uid 0 at depth 2
        srv.submit(Request(
            uid=99, tokens=batch["tokens"][1][:12],
            deadline_s=float(cfg.n_layers * 4),
        ))
        while srv.step() is not None:
            pass
        st = srv.telemetry()
        assert st["restored_steps_saved"] == 2
        r = srv.done[0]
        assert r.exit_layer == cfg.n_layers           # threshold ~0
        # entropy trace has exactly one entry per executed layer: no layer
        # ran twice across the preemption boundary
        assert len(r.entropy_trace) == cfg.n_layers

    def test_arbiter_clock_survives_checkpoint(self):
        """Under a shared-clock arbiter, a preempted lane's DVFS clock is
        frozen while parked (no budget burn, no energy) and resumes with its
        depth/energy intact — retire reconciles without assertion."""
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        stats = albert_layer_stats(seq_len=16)
        stats.n_layers = cfg.n_layers
        ctrl = LatencyAwareDVFSController(
            stats, no_early_exit_baseline(stats)["latency_s"] * 2.0
        )
        arb = BatchedDVFSArbiter(ctrl)
        srv = ClassifierServer(
            model, params, batch_lanes=2, buckets=(16,), arbiter=arb,
            preempt=True,
        )
        for i in range(3):
            srv.submit(Request(uid=i, tokens=batch["tokens"][i][:12]))
        srv.step()
        srv.step()
        t_layer = ctrl.cycles_for_seq_len(16) / ctrl.max_op.freq_hz
        srv.submit(Request(
            uid=99, tokens=batch["tokens"][4][:12],
            deadline_s=t_layer * cfg.n_layers * 8,
        ))
        while srv.step() is not None:
            pass
        st = srv.telemetry()
        assert st["preemptions"] >= 1
        assert st["accepted_slo_misses"] == 0
        for i in range(3):
            r = srv.done[i]
            assert r.exit_layer == cfg.n_layers
            assert r.energy_j is not None and r.energy_j > 0
            # latency excludes the parked interval: it can never exceed the
            # arbiter's whole modeled drain time
            assert r.latency_s <= arb.now_s

    def test_decoder_checkpoint_restore_parity(self):
        """Decoder acceptance: a preempted-then-restored decode generates the
        same tokens as an isolated single-request decode, with one decode
        and one prefill trace total."""
        model, params, cfg = _decoder_model()
        rng = np.random.default_rng(0)
        prompts = [
            rng.integers(4, cfg.vocab_size, size=L).astype(np.int32)
            for L in (6, 5, 7)
        ]

        def reference(p, max_new, max_seq):
            cache = model.init_cache(1, max_seq)
            for t in range(len(p) - 1):
                _, cache = model.decode_step(
                    params, cache, jnp.asarray([[int(p[t])]]), t
                )
            pos, cur, outs = len(p) - 1, int(p[-1]), []
            for _ in range(max_new):
                lg, cache = model.decode_step(params, cache, jnp.asarray([[cur]]), pos)
                cur = int(jnp.argmax(lg[0, -1]))
                outs.append(cur)
                pos += 1
            return outs

        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, preempt=True
        )
        for i, p in enumerate(prompts):
            srv.submit(Request(uid=i, tokens=p, max_new_tokens=6))
        srv.step()
        srv.step()
        srv.submit(Request(
            uid=99, tokens=prompts[0][:4], max_new_tokens=2, deadline_s=30.0
        ))
        stats = srv.run()
        assert stats["preemptions"] >= 1
        assert stats["restored_steps_saved"] >= 1
        for i, p in enumerate(prompts):
            assert srv.done[i].generated == reference(p, 6, 32), i
        assert stats["decode_traces"] == 1 and stats["prefill_traces"] == 1

    def test_preempt_flag_off_is_inert(self):
        """preempt=False (the default): no eviction ever happens, matching
        the pre-admission scheduler exactly."""
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        srv = ClassifierServer(model, params, batch_lanes=2, buckets=(16,))
        for i in range(3):
            srv.submit(Request(uid=i, tokens=batch["tokens"][i][:12]))
        srv.step()
        srv.submit(Request(
            uid=99, tokens=batch["tokens"][4][:12],
            deadline_s=float(cfg.n_layers + 2),
        ))
        st = srv.run()
        assert st["preemptions"] == 0 and st["restored_steps_saved"] == 0


class TestOversubscriptionStorm:
    def test_zero_accepted_slo_misses_under_storm(self):
        """The benchmark property at test scale: an oversubscribed tight-SLO
        storm through admission control rejects the infeasible tail and
        misses ZERO accepted SLOs, while the same storm without admission
        misses some; best-effort completes in both."""
        model, params, cfg = _albert_model()
        stats = albert_layer_stats(seq_len=16)
        stats.n_layers = cfg.n_layers
        batch = _batch(cfg, n=16)
        t_layer_max = None
        results = {}
        for admission in (True, False):
            ctrl = LatencyAwareDVFSController(
                stats, no_early_exit_baseline(stats)["latency_s"] * 1.5
            )
            arb = BatchedDVFSArbiter(ctrl)
            srv = ClassifierServer(
                model, params, batch_lanes=2, buckets=(16,), arbiter=arb,
                preempt=admission,
            )
            ac = AdmissionController(srv, max_best_effort_queue=4)
            t_layer = ctrl.cycles_for_seq_len(16) / ctrl.max_op.freq_hz
            deadline = cfg.n_layers * t_layer * 4.0
            for i in range(4):                       # best-effort floor
                (ac.submit if admission else srv.submit)(
                    Request(uid=i, tokens=batch["tokens"][i][:12])
                )
            for j in range(10):                      # tight-SLO storm
                (ac.submit if admission else srv.submit)(Request(
                    uid=100 + j, tokens=batch["tokens"][(j + 4) % 16][:12],
                    deadline_s=deadline,
                ))
            st = srv.run()
            results[admission] = st
        with_ac, without = results[True], results[False]
        assert with_ac["rejected"] > 0
        assert with_ac["accepted_slo_misses"] == 0
        assert without["accepted_slo_misses"] > 0
        # best-effort completed under the storm in the admission run
        assert with_ac["sentences"] >= 4


class TestTelemetryGuards:
    def test_zero_retirees_all_keys_present(self):
        """telemetry() on a fresh server (ctrl attached, nothing retired):
        every percentile / miss / energy key exists and is zero."""
        model, params, cfg = _albert_model()
        stats = albert_layer_stats(seq_len=16)
        stats.n_layers = cfg.n_layers
        ctrl = LatencyAwareDVFSController(
            stats, no_early_exit_baseline(stats)["latency_s"] * 1.5
        )
        srv = ClassifierServer(
            model, params, batch_lanes=2, buckets=(16,),
            arbiter=BatchedDVFSArbiter(ctrl),
        )
        st = srv.telemetry()
        for key in (
            "queue_delay_steps_p50", "queue_delay_steps_p95",
            "queue_delay_steps_p99",
            "queue_delay_steps_max", "deadline_misses", "accepted_slo_misses",
            "energy_j", "modeled_latency_s", "rejected", "requoted", "shed",
            "preemptions", "restored_steps_saved",
        ):
            assert st[key] == 0, key

    def test_no_explicit_slo_retirees(self):
        """deadline-miss accounting with ONLY best-effort retirees: the
        explicit-SLO miss counter exists and is zero, not absent/crashing."""
        model, params, cfg = _albert_model(threshold=0.5)
        stats = albert_layer_stats(seq_len=16)
        stats.n_layers = cfg.n_layers
        ctrl = LatencyAwareDVFSController(
            stats, no_early_exit_baseline(stats)["latency_s"] * 1.5
        )
        srv = ClassifierServer(
            model, params, batch_lanes=2, buckets=(16,),
            arbiter=BatchedDVFSArbiter(ctrl),
        )
        batch = _batch(cfg)
        for i in range(3):
            srv.submit(Request(uid=i, tokens=batch["tokens"][i][:12]))
        st = srv.run()
        assert st["accepted_slo_misses"] == 0
        assert st["deadline_misses"] >= 0


class TestModeledClockOnly:
    def test_submit_never_stamps_wall_clock(self):
        """The scheduler's modeled-time path must not mix in wall-clock reads:
        submit() stamps arrival_s/arrival_step only, and submit_time stays at
        its caller-owned default."""
        model, params, cfg = _albert_model()
        batch = _batch(cfg)
        srv = ClassifierServer(model, params, batch_lanes=2, buckets=(16,))
        req = Request(uid=0, tokens=batch["tokens"][0][:12])
        srv.submit(req)
        assert req.submit_time == 0.0
        assert req.arrival_s == srv.sched.now_s
        assert req.arrival_step == 0
