#!/usr/bin/env bash
# Tier-1 CI: unit-test suite + a DVFS-benchmark smoke pass.
#
#   bash scratch/run_ci.sh
#
# The suite must COLLECT cleanly with or without `hypothesis` installed
# (property tests skip when it's absent — see tests/hypothesis_compat.py),
# and the DVFS smoke pass asserts the paper's headline result end-to-end:
# lower energy than the no-early-exit baseline at equal target latency, with
# the fused engine step compiling exactly once for the whole queue drain.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -q
tier1=$?

echo "== bench_dvfs --smoke =="
python benchmarks/bench_dvfs.py --smoke
smoke=$?

echo "== summary: tier1=$tier1 smoke=$smoke =="
exit $(( tier1 || smoke ))
