"""TPU v5e three-term roofline from compiled dry-run artifacts.

    compute term    = FLOPs_per_device / peak_FLOP/s
    memory term     = HBM_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

The post-SPMD HLO module IS the per-device program, so ``cost_analysis()``
FLOPs/bytes and the collective operand sizes parsed from ``as_text()`` are
per-device quantities; dividing by per-chip peaks gives seconds directly
(algebraically identical to the global-quantities/(chips x peak) form).

all-reduce traffic is weighted 2x (ring reduce-scatter + all-gather phases);
all-gather / reduce-scatter / all-to-all 1x of the LARGER (unsharded) side;
collective-permute 1x.  (n-1)/n ring factors are folded to 1.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float
    hbm_bw: float
    link_bw: float
    hbm_bytes: float


TPUV5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    link_bw=50e9,
    hbm_bytes=16 * 1024**3,
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?P<type>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\("
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic by op kind (weighted: see module doc)."""
    out = {
        "all-reduce": 0.0,
        "all-gather": 0.0,
        "reduce-scatter": 0.0,
        "all-to-all": 0.0,
        "collective-permute": 0.0,
    }
    counts = {k: 0 for k in out}
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        # skip -done ops (the -start carries the shape; avoid double count)
        if m.group("suffix") == "-done":
            continue
        size = _shape_bytes(m.group("type"))
        weight = 2.0 if op == "all-reduce" else 1.0
        out[op] += weight * size
        counts[op] += 1
    total = sum(out.values())
    res = {f"bytes_{k}": v for k, v in out.items()}
    res.update({f"count_{k}": float(v) for k, v in counts.items()})
    res["bytes_total"] = total
    return res


def model_flops(n_params_active: int, n_tokens: int, kind: str) -> float:
    """Useful-model FLOPs: 6ND train, 2ND forward/prefill/decode-token."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * n_tokens


def roofline_report(
    *,
    hlo_flops_per_device: float,
    hlo_bytes_per_device: float,
    collective_bytes_per_device: float,
    n_chips: int,
    model_flops_global: float,
    useful_bytes_per_device: float = 0.0,
    chip: ChipSpec = TPUV5E,
) -> Dict[str, float]:
    """Three roofline terms + efficiency of the DOMINANT term.

    roofline_fraction = (time the dominant resource would need for the
    *useful* work) / (time it needs for the work the compiled program actually
    does).  For compute-bound cells that is model_FLOPs/HLO_FLOPs; for
    memory-bound cells it is useful_bytes/HLO_bytes (useful bytes = params
    read once + mandatory state I/O, supplied by the caller); for collective-
    bound cells we report useful-flops-time/bound (no collective is "useful"
    in the 6ND sense).
    """
    t_compute = hlo_flops_per_device / chip.peak_flops_bf16
    t_memory = hlo_bytes_per_device / chip.hbm_bw
    t_coll = collective_bytes_per_device / chip.link_bw
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    bound = max(t_compute, t_memory, t_coll)
    hlo_flops_global = hlo_flops_per_device * n_chips
    useful_flops_ratio = (
        model_flops_global / hlo_flops_global if hlo_flops_global else 0.0
    )
    memory_efficiency = (
        useful_bytes_per_device / hlo_bytes_per_device if hlo_bytes_per_device else 0.0
    )
    if dominant == "compute":
        frac = useful_flops_ratio
    elif dominant == "memory":
        frac = memory_efficiency
    else:
        frac = (
            (model_flops_global / (n_chips * chip.peak_flops_bf16)) / bound
            if bound > 0 else 0.0
        )
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops": model_flops_global,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": useful_flops_ratio,
        "memory_efficiency": memory_efficiency,
        "roofline_fraction": frac,
        "n_chips": n_chips,
    }
