"""Multi-task residency: compression-aware deployments, eNVM task-swap
costs, and task-affinity-aware scheduling (paper §III-D + Table I stacked
onto the serving stack).

The paper's headline energy numbers come from the compression triad —
adaptive attention span, movement pruning, AdaptivFloat — applied PER TASK,
with every task's sparse weight set resident in eNVM and a bounded SRAM
working set serving the hot tasks.  This module turns those ``core/``
primitives into serving features:

* ``TaskDeployment`` — one task's compression configuration (span budget,
  pruning occupancy, AdaptivFloat format).  Its sparsity/span factors flow
  into the hwmodel via ``deployment_stats`` (a ``WorkloadStats`` of the
  COMPRESSED network), so ``cycles_for_seq_len``, DVFS arbitration, and
  admission quotes price the savings instead of dense full-precision work,
  and its bitmask-encoded footprint (``bitmask.storage_bytes`` accounting)
  prices the eNVM->SRAM swap.
* ``TaskResidencyManager`` — a bounded SRAM working set over an eNVM
  backing store.  Resident tasks serve immediately; a non-resident task
  pays a modeled power-on read of its sparse-encoded footprint
  (``hwmodel.task_swap_cost`` — the Fig. 11 machinery applied to task
  weights) charged as a stall on the shared DVFS clock, with LRU eviction
  (free: task weights are read-only) and swap telemetry (``task_swaps``,
  ``swap_stall_s``, ``resident_set``).  ``load_from_envm`` runs the actual
  fault-injected readback (``core.envm.store_and_readback``): a degraded
  readback raises the ``degraded_tasks`` telemetry flag instead of serving
  corrupted weights silently.
* ``TaskAffinityPolicy`` / ``ResidencyRouter`` — cross-server arbitration
  that trades EDF urgency against swap cost.  Each task is one
  ``ClassifierServer`` (the ``MultiTaskRouter`` layout), so affinity is a
  TASK-level decision: the router snapshots every server's candidate
  buckets, discounts a non-resident task's slack by its swap stall, and
  keeps serving resident tasks while deadlines permit — same-task requests
  batch through the warm working set, and residency is preempted only when
  a non-resident task's discounted slack demands it.  ``BlindEDFTaskPolicy``
  is the residency-oblivious baseline (global min slack, swap-thrashing)
  the CI benchmark gate beats.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from repro.core import bitmask as bm
from repro.core.adaptive_span import active_head_indices, span_flop_factor
from repro.core.adaptivfloat import AFFormat
from repro.core.envm import store_and_readback
from repro.hwmodel.edgebert_accel import (
    WorkloadStats,
    accel_power_mw,
    task_swap_cost,
)
from repro.serving.dvfs import LatencyAwareDVFSController
from repro.serving.engine import ClassifierServer, MultiTaskRouter
from repro.serving.scheduler import BucketView


# ===========================================================================
# Compression-aware task deployments
# ===========================================================================


@dataclass(frozen=True)
class TaskDeployment:
    """One task's deployed compression configuration (paper Table I row).

    ``pruning_occupancy`` is the fraction of weights the movement-pruned
    network KEEPS (occupancy 0.4 = 60% sparse); ``spans`` are the task's
    per-head hard attention spans (``core.adaptive_span.hard_spans``), from
    which the retained-FLOP factor and active-head fraction derive exactly
    as the standalone span benchmark computes them; ``fmt`` is the
    AdaptivFloat storage format of the eNVM-resident non-zero values.

    The deployment prices two different things from ONE config:
    * compute: ``deployment_stats`` folds span/sparsity into the hwmodel's
      ``WorkloadStats``, so cycles AND power reflect the compressed network;
    * storage: the bitmask-encoded footprint (``storage()``, mirroring
      ``bitmask.storage_bytes``) prices SRAM residency and the eNVM swap.
    """

    task: str
    n_params: float                          # dense encoder+head param count
    pruning_occupancy: float = 1.0           # fraction of weights kept
    spans: Optional[Tuple[int, ...]] = None  # per-head hard spans (None=dense)
    n_heads: int = 12
    span_seq_len: int = 128                  # seq len the spans were budgeted at
    fmt: AFFormat = field(default_factory=AFFormat)

    def __post_init__(self):
        assert 0.0 < self.pruning_occupancy <= 1.0
        assert self.n_params > 0
        assert self.spans is None or len(self.spans) == self.n_heads

    @property
    def weight_sparsity(self) -> float:
        return 1.0 - self.pruning_occupancy

    @property
    def span_factor(self) -> float:
        if self.spans is None:
            return 1.0
        return span_flop_factor(self.spans, self.n_heads, self.span_seq_len)

    @property
    def heads_active_frac(self) -> float:
        if self.spans is None:
            return 1.0
        idx, _ = active_head_indices(self.spans)
        return len(idx) / self.n_heads

    def storage(self) -> Dict[str, float]:
        """Sparse-encoded footprint: the analytic mirror of
        ``bitmask.storage_bytes`` (1 mask bit per dense param, ``fmt.n_bits``
        per surviving value) — what the SRAM working set and the eNVM swap
        actually move."""
        mask_bytes = math.ceil(self.n_params / 8.0)
        value_bytes = self.n_params * self.pruning_occupancy * self.fmt.n_bits / 8.0
        return {
            "mask_bytes": float(mask_bytes),
            "value_bytes": float(value_bytes),
            "total_bytes": float(mask_bytes) + float(value_bytes),
        }

    def swap_cost(self) -> Dict[str, float]:
        """Modeled eNVM->SRAM switch-in cost of this task's weight set."""
        s = self.storage()
        return task_swap_cost(s["value_bytes"], s["mask_bytes"])


def measured_footprint(task_params: Any, fmt: AFFormat = AFFormat()) -> Dict[str, float]:
    """Bitmask-encode a task's ACTUAL weight arrays and sum the storage
    accounting — the measured counterpart of ``TaskDeployment.storage()``
    for deployments built from concrete (pruned) parameter trees."""
    totals = {"mask_bytes": 0.0, "value_bytes": 0.0, "total_bytes": 0.0}

    def _walk(node):
        if isinstance(node, dict):
            for v in node.values():
                _walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                _walk(v)
        else:
            s = bm.storage_bytes(bm.encode(np.asarray(node)), value_bits=fmt.n_bits)
            totals["mask_bytes"] += s["mask_bytes"]
            totals["value_bytes"] += s["value_bytes"]
            totals["total_bytes"] += s["total_bytes"]

    _walk(task_params)
    return totals


def deployment_stats(base: WorkloadStats, dep: TaskDeployment) -> WorkloadStats:
    """The COMPRESSED network's workload statistics: the anchor stats with
    the deployment's span/sparsity factors and sparse footprint folded in.
    Everything downstream of ``WorkloadStats`` — ``layer_cycles``,
    ``layer_energy_j``, ``cycles_for_seq_len``, admission quotes — then
    prices the compressed network instead of dense full-precision work."""
    return replace(
        base,
        span_factor=dep.span_factor,
        heads_active_frac=dep.heads_active_frac,
        weight_sparsity=dep.weight_sparsity,
        model_bytes=dep.storage()["total_bytes"],
    )


def deployment_controller(
    ctrl: LatencyAwareDVFSController, dep: TaskDeployment
) -> LatencyAwareDVFSController:
    """A pricing controller over the deployment's compressed stats, sharing
    the anchor controller's target, table, and MAC width.  Used by the
    engine for per-bucket CYCLE pricing only (prediction LUTs stay on the
    shared anchor controller), so a compressed task's quotes, step times,
    and arbiter budgets all see the span/pruning savings."""
    return LatencyAwareDVFSController(
        deployment_stats(ctrl.stats, dep),
        ctrl.target_latency_s,
        table=ctrl.table,
        n=ctrl.n,
        use_span=ctrl._use_span,
    )


def deployment_energy_scale(
    ctrl: LatencyAwareDVFSController, dep: TaskDeployment
) -> float:
    """Per-layer POWER ratio of the compressed network vs the anchor stats.

    The arbiter scales lane energy by the lane's cycles ratio; sparsity
    additionally gates PU/SRAM power (``accel_power_mw``) without changing
    cycles, so the engine passes this ratio as ``admit(energy_scale=...)``
    — lane energy then equals the compressed task's actual layer energy."""
    p_dep = accel_power_mw(deployment_stats(ctrl.stats, dep), ctrl.n)["total"]
    p_base = accel_power_mw(ctrl.stats, ctrl.n)["total"]
    return p_dep / p_base


# ===========================================================================
# Bounded SRAM working set over the eNVM backing store
# ===========================================================================


class TaskResidencyManager:
    """Models which tasks' weight sets are SRAM-resident.

    All tasks live sparse-encoded in eNVM (the paper's multi-task ReRAM
    deployment); ``sram_bytes`` bounds the working set of switch-ready
    tasks.  ``acquire`` is the single serving-path entry point: a resident
    task is free (LRU-touched), a non-resident task evicts LRU victims
    until its footprint fits and pays its modeled eNVM read as a stall the
    ENGINE charges on the shared DVFS clock (the manager owns no clock —
    it returns the stall and accounts the energy).  Evictions are free:
    task weights are read-only, so there is no write-back.

    ``load_from_envm`` additionally runs the REAL fault-injected readback
    (``core.envm.store_and_readback``) over a task's arrays: any injected
    mask/code fault raises the ``degraded_tasks`` telemetry flag, so a
    risky cell configuration (MLC3) degrades detectably instead of serving
    corrupted weights silently, while the paper's SLC-mask/MLC2-data
    deployment round-trips clean.
    """

    def __init__(
        self,
        deployments: Any,
        sram_bytes: float,
    ):
        if not isinstance(deployments, dict):
            deployments = {d.task: d for d in deployments}
        self.deployments: Dict[str, TaskDeployment] = dict(deployments)
        self.sram_bytes = float(sram_bytes)
        for t, d in self.deployments.items():
            need = d.storage()["total_bytes"]
            assert need <= self.sram_bytes, (
                f"task {t!r} footprint {need:.0f}B exceeds the SRAM working "
                f"set {self.sram_bytes:.0f}B — it could never become resident"
            )
        self._resident: "OrderedDict[str, float]" = OrderedDict()
        self.degraded_tasks: set = set()
        # ---- swap telemetry ----
        self.task_swaps = 0
        self.swap_stall_s = 0.0
        self.swap_energy_j = 0.0
        self.swap_bytes = 0.0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # ------------------------------------------------------------- queries
    def footprint_bytes(self, task: str) -> float:
        return self.deployments[task].storage()["total_bytes"]

    def is_resident(self, task: Optional[str]) -> bool:
        return task in self._resident

    def swap_cost(self, task: str) -> Dict[str, float]:
        return self.deployments[task].swap_cost()

    def pending_swap_stall_s(self, task: Optional[str]) -> float:
        """The stall the NEXT request of ``task`` would pay before compute:
        zero when resident (or unmanaged), else its modeled eNVM read
        latency.  This is the term admission quotes add to the wait — a
        resident task quotes the identical request strictly cheaper."""
        if task is None or task not in self.deployments:
            return 0.0
        if task in self._resident:
            return 0.0
        return self.swap_cost(task)["latency_s"]

    @property
    def resident_set(self) -> Tuple[str, ...]:
        return tuple(self._resident)

    @property
    def resident_bytes(self) -> float:
        return sum(self._resident.values())

    # ------------------------------------------------------------- serving
    def acquire(self, task: Optional[str]) -> float:
        """Serve-path touch: make ``task`` resident, returning the swap
        stall (modeled seconds) this acquisition cost — zero on a hit.
        The caller charges the stall on its clock; the manager accounts
        swap energy and working-set churn here."""
        if task is None or task not in self.deployments:
            return 0.0
        if task in self._resident:
            self._resident.move_to_end(task)
            self.hits += 1
            return 0.0
        self.misses += 1
        need = self.footprint_bytes(task)
        while self._resident and self.resident_bytes + need > self.sram_bytes:
            self._resident.popitem(last=False)      # LRU, write-back-free
            self.evictions += 1
        cost = self.swap_cost(task)
        self._resident[task] = need
        self.task_swaps += 1
        self.swap_stall_s += cost["latency_s"]
        self.swap_energy_j += cost["energy_j"]
        self.swap_bytes += cost["bytes"]
        return cost["latency_s"]

    def load_from_envm(
        self,
        task: str,
        weights: Dict[str, np.ndarray],
        *,
        data_cell: str = "MLC2",
        mask_cell: str = "SLC",
        seed: int = 0,
    ) -> Tuple[Dict[str, np.ndarray], Dict[str, float]]:
        """Fault-injected eNVM readback of a task's weight arrays.

        Each array round-trips ``core.envm.store_and_readback`` (bitmask +
        AdaptivFloat codes, faults injected per cell config).  Any injected
        mask-bit flip or code fault marks the task DEGRADED — the flag the
        serving telemetry surfaces instead of silently computing on
        corrupted weights.  Returns the (possibly faulted) readback arrays
        and summed fault statistics."""
        fmt = self.deployments[task].fmt if task in self.deployments else AFFormat()
        out: Dict[str, np.ndarray] = {}
        stats = {"n_mask_bit_flips": 0, "n_code_faults": 0}
        for i, (name, arr) in enumerate(sorted(weights.items())):
            decoded, st = store_and_readback(
                np.asarray(arr), data_cell=data_cell, mask_cell=mask_cell,
                fmt=fmt, seed=seed + i,
            )
            out[name] = decoded
            stats["n_mask_bit_flips"] += st["n_mask_bit_flips"]
            stats["n_code_faults"] += st["n_code_faults"]
        if stats["n_mask_bit_flips"] or stats["n_code_faults"]:
            self.degraded_tasks.add(task)
        return out, stats

    # ----------------------------------------------------------- telemetry
    def telemetry(self) -> Dict[str, Any]:
        return {
            "task_swaps": self.task_swaps,
            "swap_stall_s": self.swap_stall_s,
            "swap_energy_j": self.swap_energy_j,
            "swap_bytes": self.swap_bytes,
            "residency_hits": self.hits,
            "residency_misses": self.misses,
            "evictions": self.evictions,
            "resident_set": self.resident_set,
            "resident_bytes": self.resident_bytes,
            "sram_bytes": self.sram_bytes,
            "degraded_tasks": tuple(sorted(self.degraded_tasks)),
        }


# ===========================================================================
# Task-affinity-aware cross-server scheduling
# ===========================================================================


@dataclass
class TaskView:
    """One task server's scheduling snapshot for cross-server arbitration."""

    task: str
    resident: bool
    swap_stall_s: float             # stall the task's next refill would pay
    views: List[BucketView]         # the server's candidate buckets


def _task_slack_s(tv: TaskView) -> float:
    """A task's raw urgency: the least slack across its candidate buckets
    (explicit SLOs and implicit budgets alike — the same quantity EDF ranks
    buckets by, minimized over the task's buckets)."""
    return min(
        (min(v.explicit_slack_s, v.min_slack_s) for v in tv.views),
        default=float("inf"),
    )


class TaskSchedulingPolicy(Protocol):
    """Picks which TASK server the router steps next."""

    def choose_task(self, task_views: Sequence[TaskView], now_s: float) -> str:
        ...


class BlindEDFTaskPolicy:
    """Residency-oblivious EDF across tasks: always step the task holding
    the globally least slack.  Correct on deadlines, catastrophic on swaps
    — interleaving tasks whose working sets do not co-fit thrashes the
    eNVM (every alternation is a swap stall + swap energy).  The baseline
    the ``multitask_residency`` CI gate requires affinity to beat."""

    def choose_task(self, task_views: Sequence[TaskView], now_s: float) -> str:
        return min(task_views, key=lambda tv: (_task_slack_s(tv), tv.task)).task


class TaskAffinityPolicy:
    """EDF urgency traded against eNVM swap cost.

    A non-resident task's slack is discounted by its swap stall (the stall
    runs on the shared clock BEFORE any of its compute, so that is its real
    slack).  While any resident task has work, the most urgent RESIDENT
    task keeps the working set warm — same-task requests batch through it —
    UNLESS a non-resident task's discounted slack has dropped below
    ``preempt_slack_s``: then deadlines demand the swap now and residency
    is preempted.  With no resident work the least-discounted-slack task
    swaps in (ties by task name, so drains are deterministic).
    """

    def __init__(self, *, preempt_slack_s: float = 0.0):
        self.preempt_slack_s = float(preempt_slack_s)

    def _discounted(self, tv: TaskView) -> float:
        s = _task_slack_s(tv)
        return s if tv.resident else s - tv.swap_stall_s

    def choose_task(self, task_views: Sequence[TaskView], now_s: float) -> str:
        resident = [tv for tv in task_views if tv.resident]
        urgent = min(task_views, key=lambda tv: (self._discounted(tv), tv.task))
        if not resident:
            return urgent.task
        if not urgent.resident and self._discounted(urgent) < self.preempt_slack_s:
            return urgent.task          # slack demands the swap NOW
        return min(resident, key=lambda tv: (self._discounted(tv), tv.task)).task


class ResidencyRouter(MultiTaskRouter):
    """``MultiTaskRouter`` + bounded SRAM residency + task-affinity stepping.

    Each task server carries ``task=``/``residency=``/``deployment=`` (so
    its refills pay swap stalls on the shared clock, its admission quotes
    include the pending swap, and its cycle/energy pricing reflects its
    compressed deployment).  ``step()`` arbitrates ACROSS tasks: every
    non-idle server's candidate buckets are snapshotted (clocks synced to
    the shared arbiter), the task policy picks which task steps, and that
    server advances one fused step — the cross-server generalization of the
    scheduler's per-bucket policy step.  ``run_all`` drains everything
    under that arbitration instead of task-sequentially.
    """

    def __init__(
        self,
        model,
        shared_embed,
        task_params,
        *,
        residency: TaskResidencyManager,
        deployments: Optional[Dict[str, TaskDeployment]] = None,
        task_policy: Optional[TaskSchedulingPolicy] = None,
        dvfs=None,
        arbiter=None,
        buckets=None,
        policy_factory=None,
        preempt: bool = False,
        batch_lanes: int = 8,
    ):
        super().__init__(
            model, shared_embed, task_params, dvfs=dvfs, arbiter=arbiter,
            buckets=buckets, policy_factory=policy_factory, preempt=preempt,
            residency=residency, deployments=deployments,
            batch_lanes=batch_lanes,
        )
        self.residency = residency
        self.task_policy = (
            task_policy if task_policy is not None else TaskAffinityPolicy()
        )
        self.task_steps = 0
        self.task_switches = 0          # consecutive-step task changes
        self._last_task: Optional[str] = None

    def _task_views(self) -> List[TaskView]:
        out = []
        for name, srv in self.tasks.items():
            views = srv.sched.candidate_views()
            if not views:
                continue
            out.append(TaskView(
                task=name,
                resident=self.residency.is_resident(name),
                swap_stall_s=self.residency.pending_swap_stall_s(name),
                views=views,
            ))
        return out

    def step(self):
        """Step ONE task server one fused step, chosen by the task policy.
        Returns ``(task, StepReport)`` or ``None`` when everything is idle."""
        tvs = self._task_views()
        if not tvs:
            return None
        now = max(srv.sched.now_s for srv in self.tasks.values())
        choice = self.task_policy.choose_task(tvs, now)
        if self._last_task is not None and choice != self._last_task:
            self.task_switches += 1
        self._last_task = choice
        self.task_steps += 1
        return choice, self.tasks[choice].step()

    def run_all(self) -> Dict[str, Dict[str, float]]:
        served = set()
        while True:
            out = self.step()
            if out is None:
                break
            served.add(out[0])
        self.switches += len(served)
        return {name: self.tasks[name].telemetry() for name in sorted(served)}

    def telemetry(self) -> Dict[str, Any]:
        out = dict(self.residency.telemetry())
        out["task_steps"] = self.task_steps
        out["task_switches"] = self.task_switches
        out["energy_j"] = sum(
            srv.telemetry().get("energy_j", 0.0) for srv in self.tasks.values()
        ) + self.residency.swap_energy_j
        out["accepted_slo_misses"] = sum(
            srv.telemetry().get("accepted_slo_misses", 0)
            for srv in self.tasks.values()
        )
        return out
