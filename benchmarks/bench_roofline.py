"""Roofline table from the dry-run results (benchmarks/results/dryrun.json):
per (arch x shape x mesh): three terms, dominant bottleneck, useful-FLOPs
ratio, roofline fraction. This is the §Roofline source of record."""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR, emit

DRYRUN_JSON = os.path.join(RESULTS_DIR, "dryrun.json")


def main() -> None:
    if not os.path.exists(DRYRUN_JSON):
        emit("roofline_missing", 0.0, "run: python -m repro.launch.dryrun --all --mesh both")
        return
    with open(DRYRUN_JSON) as f:
        recs = json.load(f)
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    n_ok = n_skip = 0
    for r in recs:
        key = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r["status"] == "skipped":
            n_skip += 1
            emit(key, 0.0, "skipped:" + r["reason"][:60])
            continue
        if r["status"] != "ok":
            emit(key, 0.0, f"ERROR:{r.get('error','')[:80]}")
            continue
        n_ok += 1
        rl = r["roofline"]
        ma = r.get("memory_analysis", {})
        hbm = (ma.get("argument_size_in_bytes", 0) + ma.get("temp_size_in_bytes", 0)) / 2**30
        emit(
            key,
            rl["bound_s"] * 1e6,
            f"dom={rl['dominant']};tc={rl['t_compute_s']:.2e};tm={rl['t_memory_s']:.2e};"
            f"tx={rl['t_collective_s']:.2e};useful={rl['useful_flops_ratio']:.2f};"
            f"frac={rl['roofline_fraction']:.3f};hbm_GiB={hbm:.1f}",
        )
    emit("roofline_summary", 0.0, f"ok={n_ok};skipped={n_skip};total={len(recs)}")

    # --- multi-pod scaling: 512 vs 256 chips at fixed global work ---
    base = {
        (r["arch"], r["shape"], r["mesh"]): r
        for r in recs
        if r.get("variant", "baseline") == "baseline" and r["status"] == "ok"
    }
    for (arch, shape, mesh), r in sorted(base.items()):
        if mesh != "single":
            continue
        multi = base.get((arch, shape, "multi"))
        if multi is None:
            continue
        b1 = r["roofline"]["bound_s"]
        b2 = multi["roofline"]["bound_s"]
        if b2 <= 0:
            continue
        # ideal: 2x chips halve the bound at fixed global batch
        eff = b1 / (2.0 * b2)
        emit(
            f"scaling_{arch}_{shape}", 0.0,
            f"bound_256={b1:.2e}s;bound_512={b2:.2e}s;pod_scaling_eff={eff:.2f}",
        )


if __name__ == "__main__":
    main()
