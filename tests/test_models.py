"""Per-arch smoke tests (reduced configs, one forward + decode step on CPU,
shape + finiteness asserts) and sequence-mixer equivalence properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models import mamba2, rwkv6
from repro.models.model import build_model, count_params


def _cpu_cfg(arch):
    return dataclasses.replace(
        get_smoke_config(arch), dtype="float32", remat_policy="none"
    )


def _batch(cfg, B=2, S=32, seed=0):
    rng = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["enc_input"] = (
            jax.random.normal(rng, (B, cfg.enc_seq_len, cfg.d_model)) * 0.1
        )
    if cfg.family == "vlm":
        batch["image_embeds"] = (
            jax.random.normal(rng, (B, cfg.n_image_tokens, cfg.d_model)) * 0.1
        )
    if cfg.num_classes:
        batch["labels"] = jax.random.randint(rng, (B,), 0, cfg.num_classes)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS + ("albert_base", "albert_edgebert"))
def test_arch_smoke(arch):
    cfg = _cpu_cfg(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    assert count_params(params) > 0
    out = jax.jit(model.apply_train)(params, _batch(cfg))
    lg = out.logits if out.logits is not None else out.cls_logits
    assert lg is not None
    assert np.isfinite(np.asarray(lg, np.float32)).all(), f"{arch}: non-finite"
    if out.logits is not None:
        assert out.logits.shape[-1] == cfg.vocab_size


@pytest.mark.parametrize("arch", ["qwen1_5_110b", "zamba2_1p2b", "rwkv6_7b", "whisper_medium"])
def test_decode_consistency(arch):
    """prefill(prompt) + decode_step(token) logits == full forward logits at
    the same position (cache path correctness)."""
    cfg = _cpu_cfg(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    B, S = 2, 24
    batch = _batch(cfg, B, S, seed=2)
    tokens = batch["tokens"]
    out = model.apply_train(params, batch)

    cache = model.init_cache(B, 64)
    aux = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    lg_prefill, cache = model.prefill(params, tokens[:, : S - 1], cache, aux=aux or None)
    # prefill's last-token logits must match forward logits at S-2
    np.testing.assert_allclose(
        np.asarray(lg_prefill[:, 0]), np.asarray(out.logits[:, S - 2]),
        atol=2e-2, rtol=2e-2,
    )
    lg_dec, cache = model.decode_step(params, cache, tokens[:, S - 1 :], S - 1)
    np.testing.assert_allclose(
        np.asarray(lg_dec[:, 0]), np.asarray(out.logits[:, S - 1]),
        atol=2e-2, rtol=2e-2,
    )


class TestWKV6:
    def test_chunked_equals_recurrent(self):
        B, S, H, K = 2, 50, 3, 8
        ks = jax.random.split(jax.random.PRNGKey(3), 5)
        r, k, v = (jax.random.normal(ks[i], (B, S, H, K)) for i in range(3))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, K)) + 2.0)
        u = jax.random.normal(ks[4], (H, K)) * 0.1
        y1, s1 = rwkv6._wkv_recurrent(r, k, v, w, u)
        y2, s2 = rwkv6._wkv_chunked(r, k, v, w, u, chunk=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)

    def test_state_carry(self):
        """Splitting a sequence across two chunked calls == one call."""
        B, S, H, K = 1, 32, 2, 8
        ks = jax.random.split(jax.random.PRNGKey(4), 5)
        r, k, v = (jax.random.normal(ks[i], (B, S, H, K)) for i in range(3))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, K)) + 2.0)
        u = jax.random.normal(ks[4], (H, K)) * 0.1
        y_full, s_full = rwkv6._wkv_chunked(r, k, v, w, u, chunk=8)
        y1, s1 = rwkv6._wkv_chunked(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u, 8)
        y2, s2 = rwkv6._wkv_chunked(
            r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u, 8, init_state=s1
        )
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), atol=1e-4
        )
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


class TestSSD:
    def test_chunked_equals_stepwise(self):
        B, S, H, P, N = 2, 29, 3, 8, 6
        ks = jax.random.split(jax.random.PRNGKey(5), 5)
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, S, N))
        Cm = jax.random.normal(ks[4], (B, S, N))
        y1, f1 = mamba2._ssd_chunked(x, dt, a, Bm, Cm, chunk=8)
        st = jnp.zeros((B, H, P, N))
        ys = []
        for t in range(S):
            st, y = mamba2._ssd_step(st, x[:, t], dt[:, t], a, Bm[:, t], Cm[:, t])
            ys.append(y)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(jnp.stack(ys, 1)), atol=1e-4)
        np.testing.assert_allclose(np.asarray(f1), np.asarray(st), atol=1e-4)


def test_albert_weight_sharing():
    """ALBERT: one shared layer — param count independent of depth."""
    cfg4 = _cpu_cfg("albert_base")
    cfg8 = dataclasses.replace(cfg4, n_layers=8)
    p4 = build_model(cfg4).init_params(jax.random.PRNGKey(0))
    p8 = build_model(cfg8).init_params(jax.random.PRNGKey(0))
    assert count_params(p4) == count_params(p8)


def test_span_changes_attention():
    """Enabling small spans changes ALBERT outputs (mask actually applies)."""
    cfg = _cpu_cfg("albert_edgebert")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 32, seed=6)
    out1 = model.apply_train(params, batch)
    p2 = dict(params, span_z=jnp.full_like(params["span_z"], 1.0))
    out2 = model.apply_train(p2, batch)
    a = np.asarray(out1.all_cls_logits if out1.all_cls_logits is not None else out1.cls_logits)
    b = np.asarray(out2.all_cls_logits if out2.all_cls_logits is not None else out2.cls_logits)
    assert not np.allclose(a, b)
