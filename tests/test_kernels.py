"""Per-kernel allclose sweeps vs the ref.py oracles (interpret mode on CPU).

Every Pallas kernel is swept over shapes (incl. non-multiples forcing padding)
and dtypes; hypothesis drives the AdaptivFloat property sweep.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, st

from repro.core.adaptivfloat import AFFormat, af_encode
from repro.kernels import ref
from repro.kernels.adaptivfloat_k import af_matmul, quantize
from repro.kernels.block_sparse import block_sparse_matmul, build_block_index
from repro.kernels.layernorm import layernorm
from repro.kernels.softmax_entropy import softmax_entropy
from repro.kernels.span_attention import span_attention
from repro.kernels import ops


def _r(shape, seed=0, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape) * scale).astype(dtype)


class TestLayerNorm:
    @pytest.mark.parametrize("rows,d", [(4, 8), (100, 128), (257, 96), (1, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, rows, d, dtype):
        x = _r((rows, d), 1, dtype, 3.0)
        g, b = _r((d,), 2), _r((d,), 3)
        got = layernorm(x, g, b, block_rows=64)
        want = ref.layernorm(x, g, b)
        atol = 1e-5 if dtype == jnp.float32 else 0.05
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), atol=atol
        )


class TestSoftmaxEntropy:
    @pytest.mark.parametrize("rows,n", [(3, 4), (100, 64), (130, 3)])
    def test_matches_ref(self, rows, n):
        x = _r((rows, n), 4, scale=5.0)
        mask = (jax.random.uniform(jax.random.PRNGKey(5), (rows, n)) > 0.3).astype(
            jnp.float32
        )
        p1, h1 = softmax_entropy(x, mask, block_rows=32)
        p2, h2 = ref.softmax_entropy(x, mask)
        np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-6)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-6)

    def test_entropy_matches_core(self):
        from repro.core.entropy import entropy_from_logits

        x = _r((64, 16), 6, scale=8.0)
        _, h = softmax_entropy(x, jnp.ones_like(x))
        np.testing.assert_allclose(
            np.asarray(h), np.asarray(entropy_from_logits(x)), atol=1e-5
        )


class TestAFQuantKernel:
    @given(st.integers(5, 8), st.sampled_from([0.01, 1.0, 50.0]))
    def test_matches_ref(self, n_bits, scale):
        fmt = AFFormat(n_bits, 3)
        x = _r((100, 32), n_bits, scale=scale)
        got = quantize(x, fmt=fmt, block_rows=32)
        want = ref.adaptivfloat_quantize(x, fmt)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


class TestAFMatmul:
    @pytest.mark.parametrize("m,k,n", [(16, 32, 16), (70, 96, 50), (128, 128, 128)])
    def test_matches_ref(self, m, k, n):
        w = _r((k, n), 7, scale=2.0)
        codes, e_min = af_encode(w)
        x = _r((m, k), 8)
        got = af_matmul(x, codes, e_min, bm=32, bk=32, bn=32)
        want = ref.af_matmul(x, codes, e_min)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-4)


class TestBlockSparse:
    @pytest.mark.parametrize("density", [0.0, 0.3, 1.0])
    def test_matches_ref(self, density):
        rng = np.random.default_rng(9)
        K, N, bk, bn = 128, 128, 32, 32
        bmask = rng.random((K // bk, N // bn)) < density
        full = np.repeat(np.repeat(bmask, bk, 0), bn, 1)
        w = jnp.asarray(rng.normal(size=(K, N)) * full, jnp.float32)
        x = _r((48, K), 10)
        got = block_sparse_matmul(x, w, bmask, bm=16, bk=bk, bn=bn)
        want = ref.block_sparse_matmul(x, w, jnp.asarray(bmask), bk, bn)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)

    def test_index_list(self):
        bmask = np.array([[1, 0], [0, 0], [1, 1]], bool)
        idx, counts, mx = build_block_index(bmask)
        assert list(counts) == [2, 1] and mx == 2
        assert list(idx[0]) == [0, 2] and idx[1][0] == 2


class TestSpanAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize(
        "B,H,KV,S,dh,window", [(1, 2, 1, 64, 8, 16), (2, 4, 2, 100, 16, 37)]
    )
    def test_matches_ref(self, causal, B, H, KV, S, dh, window):
        q = _r((B, H, S, dh), 11)
        k = _r((B, KV, S, dh), 12)
        v = _r((B, KV, S, dh), 13)
        spans = jnp.asarray(
            np.random.default_rng(14).integers(1, window + 1, H), jnp.int32
        )
        want = ref.span_attention(q, k, v, spans, causal=causal)
        G = H // KV
        ke = jnp.repeat(k, G, axis=1).reshape(B * H, S, dh)
        ve = jnp.repeat(v, G, axis=1).reshape(B * H, S, dh)
        got = span_attention(
            q.reshape(B * H, S, dh), ke, ve, jnp.tile(spans, B), window,
            causal=causal, bq=32, bk=32,
        ).reshape(B, H, S, dh)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_ops_gathers_dead_heads(self):
        """Full deploy path with paper Table I QQP spans (8/12 heads off)."""
        B, S, H, dh = 2, 128, 12, 16
        q = _r((B, S, H, dh), 15)
        k = _r((B, S, H, dh), 16)
        v = _r((B, S, H, dh), 17)
        spans = [16, 0, 0, 0, 0, 0, 40, 75, 0, 0, 0, 2]
        got = ops.span_attention_op(q, k, v, spans, causal=False, bq=32, bk=32)
        want = ref.span_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            jnp.asarray(spans), causal=False,
        ).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
        dead = [i for i, s in enumerate(spans) if s == 0]
        assert (np.asarray(got)[:, :, dead] == 0).all()

    def test_all_heads_off(self):
        B, S, H, dh = 1, 32, 4, 8
        q, k, v = _r((B, S, H, dh)), _r((B, S, H, dh)), _r((B, S, H, dh))
        out = ops.span_attention_op(q, k, v, [0, 0, 0, 0], causal=True)
        assert (np.asarray(out) == 0).all()

    def test_ops_traced_spans_under_jit(self):
        """Regression: ``span_attention_op`` used host-side numpy indexing on
        the span vector, so TRACED spans (e.g. learned spans flowing through
        a jit'd serving step) crashed at trace time.  Traced spans must now
        route through the kernel's scalar-prefetch operand and match the
        static-span result."""
        B, S, H, KV, dh = 2, 64, 4, 2, 8
        q, k, v = _r((B, S, H, dh), 20), _r((B, S, KV, dh), 21), _r((B, S, KV, dh), 22)
        spans = [9, 0, 33, 17]

        @jax.jit
        def f(q, k, v, sp):
            return ops.span_attention_op(q, k, v, sp, causal=True, bq=32, bk=32)

        got = f(q, k, v, jnp.asarray(spans, jnp.int32))   # spans TRACED
        want = ops.span_attention_op(q, k, v, spans, causal=True, bq=32, bk=32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    @pytest.mark.parametrize("causal", [True, False])
    def test_kv_lens_masks_padded_keys(self, causal):
        """Per-row kv_len (bucket padding) must compute the SAME function as
        physically truncating the key/value rows — incl. under jit(vmap) with
        a traced per-row length, the shape the serving lane vmap produces."""
        BH, S, dh, window = 4, 64, 8, 64
        q, k, v = _r((BH, S, dh), 23), _r((BH, S, dh), 24), _r((BH, S, dh), 25)
        spans = jnp.full((BH,), window, jnp.int32)
        kvl = 23
        got = span_attention(q, k, v, spans, window, causal=causal, bq=32,
                             bk=32, kv_lens=jnp.full((BH,), kvl, jnp.int32))
        # oracle: the first kvl query rows of the padded run must equal a run
        # on the physically truncated arrays (rows past kvl are padding)
        want = ref.span_attention(
            q[:, None, :kvl], k[:, None, :kvl], v[:, None, :kvl],
            jnp.asarray([window]), causal=causal,
        )[:, 0]
        np.testing.assert_allclose(
            np.asarray(got)[:, :kvl], np.asarray(want), atol=2e-5
        )

        @jax.jit
        def lane_step(q, k, v, lens):
            def one(ql, kl, vl, n):
                return span_attention(
                    ql[None], kl[None], vl[None],
                    jnp.full((1,), window, jnp.int32), window,
                    causal=causal, bq=32, bk=32, kv_lens=n[None],
                )[0]
            return jax.vmap(one)(q, k, v, lens)

        lens = jnp.asarray([23, 64, 1, 40], jnp.int32)   # per-lane, TRACED
        got_v = lane_step(q, k, v, lens)
        for i, n in enumerate([23, 64, 1, 40]):
            want = ref.span_attention(
                q[i : i + 1, None, :n], k[i : i + 1, None, :n],
                v[i : i + 1, None, :n], jnp.asarray([window]), causal=causal,
            )[0, 0]
            np.testing.assert_allclose(
                np.asarray(got_v)[i, :n], np.asarray(want), atol=2e-5,
                err_msg=str(i),
            )
