"""Serving-layer lifecycle: ``submit() -> step() -> poll() -> telemetry()``.

``LaneScheduler`` is the single continuously-clocked loop every serving engine
rides.  A caller may submit a request AT ANY TIME — before a drain, or between
two ``step()`` calls while other buckets are mid-flight — and the request
lands in a later refill of its length bucket with no new compiled traces (the
fused step's shapes are fixed per bucket, so interleaving and mid-flight
admission never retrace).  Each ``step()`` advances EXACTLY ONE bucket by one
fused step, chosen by a pluggable ``SchedulingPolicy``; ``poll()`` drains the
requests that retired since the last poll; ``run()`` is a thin back-compat
wrapper (``while work remains: step()``) for callers that still want the
drain-the-world API.  ``telemetry()`` reports lifetime counters, including
per-request queue delay (``arrival_step -> first_compute_step``) percentiles.

Retention: ``poll()`` RELEASES the polled requests' payloads from ``done``
(the caller owns them now; ``pin=True`` keeps them resident), and every
retirement-derived telemetry figure — queue-delay percentiles (bounded
reservoir), SLO-miss counters — folds in incrementally at retirement, so a
long-running submit/step/poll server stays bounded-memory while the
batch-drain idiom (``run()`` then index ``done``) is unchanged.

Engine hooks
------------
``ClassifierServer`` and ``DecoderServer`` used to each own a private copy of
the same loop — submit -> queue -> refill free lanes -> fused step -> retire.
``EngineHooks`` is that lifecycle's explicit contract: the engine owns all
device state (hidden tensors, KV caches, jitted functions) and supplies the
compute; the scheduler owns queues, lane bookkeeping, the modeled clock, and
telemetry.  Because ``step()`` time-slices across buckets, MULTIPLE buckets
may be open at once: an engine must keep its per-bucket state keyed by bucket
(``bucket_begin``/``bucket_end`` bracket a bucket's lifetime, not the drain's).

Length buckets
--------------
The queue is partitioned by *bucket*: a request is assigned the smallest
configured bucket that fits its shape key (sequence length for the
classifier, prompt + generation budget for the decoder), and its tokens are
padded up to the bucket size by the engine.  Each bucket drains as its own
fixed-shape ``[lanes, S_bucket]`` engine state, so jit compiles EXACTLY ONE
step per bucket instead of one per distinct request length.  ``buckets=None``
keeps the legacy behavior: every distinct shape key is its own bucket.

Deadlines and the modeled clock
-------------------------------
``Request.deadline_s`` is a per-request SLO measured from SUBMISSION on the
scheduler's modeled clock, which advances by ``step_time_fn(bucket)`` per
fused step (default 1.0 — deadlines in "steps"; engines with a hardware model
pass the per-bucket layer time so deadlines are in modeled seconds).  The
default ``EDFPolicy`` ranks buckets by the least slack among their work:
absolute deadline minus the modeled now minus the predicted remaining work,
where remaining work comes from the engine's entropy-LUT exit prediction
(``predict_remaining_steps`` hook -> ``core.early_exit``).  Buckets whose
work carries no deadline fall back to weighted-round-robin time slicing, so a
deep 128-token drain can no longer starve queued 32-token traffic.

Preemption and lane checkpointing
---------------------------------
With ``preempt=True`` (and an engine implementing the optional
``lane_checkpoint``/``lane_restore`` hooks) a queued EXPLICIT-SLO request no
longer waits for a lane to drain when every lane is busy: the scheduler
evicts a budget-free (deadline-less) lane — checkpointing its hidden state
``(h, depth, kv_len)`` at the layer boundary — and re-queues the evicted
request at the FRONT of its bucket's FIFO with the checkpoint attached.  A
later refill restores the checkpoint into a free lane and the request resumes
at its saved depth WITHOUT re-running completed layers; because the
checkpoint round-trips through the same fixed ``[lanes, S_bucket]`` shapes
the engine already traced, eviction and restore add ZERO new compiled traces.
Preemption bounds an explicit request's lane wait by one fused step instead
of one retire (or, FIFO-worst-case, one whole drain round).

Admission control (``serving/admission.py``) sits in FRONT of ``submit()``:
it quotes feasibility for explicit SLOs (reject / re-quote instead of
accept-then-miss) and bounds the best-effort queue (``shed_oldest``) under
sustained oversubscription.  The scheduler carries the shared telemetry
counters — ``rejected`` / ``requoted`` / ``shed`` / ``preemptions`` /
``restored_steps_saved`` — so one ``telemetry()`` call reports the whole
admit -> [preempt/checkpoint] -> retire lifecycle.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import (
    Any,
    Callable,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    TYPE_CHECKING,
)

import numpy as np

if TYPE_CHECKING:  # circular: engine imports scheduler
    from repro.serving.engine import Request


class EngineHooks(Protocol):
    """Compute hooks a serving engine implements to ride the scheduler.

    The engine owns all device state (hidden tensors, KV caches, jitted
    functions); the scheduler owns queues, lane bookkeeping, the modeled
    clock, and telemetry.  Cross-bucket time slicing means several buckets
    can be open simultaneously — implementations must key their state by
    bucket.
    """

    def bucket_key(self, req: "Request") -> int:
        """Shape key of a request (e.g. sequence length) used for bucketing."""
        ...

    def bucket_begin(self, bucket: int) -> None:
        """Allocate the fixed-shape ``[lanes, bucket]`` state for this bucket."""
        ...

    def lane_load(self, bucket: int, lane: int, req: "Request") -> None:
        """Insert a request into a free lane (embed / prefill)."""
        ...

    def lanes_step(self, bucket: int, active: np.ndarray) -> Any:
        """Run ONE fused step over all lanes; returns host-side step outputs."""
        ...

    # -- optional (resolved via getattr; engines may omit it) ---------------
    def step_dt_s(self, bucket: int) -> Optional[float]:
        """ACTUAL modeled duration of the step just run (e.g. the DVFS
        arbiter's chosen-op period plus any switching stall).  When provided,
        the scheduler's clock advances by this instead of the nominal
        ``step_time_fn`` estimate, keeping the EDF clock and the DVFS clock
        from drifting apart.  ``None``/absent = use ``step_time_fn``."""
        ...

    # -- optional (resolved via getattr; engines may omit it) ---------------
    def clock_s(self) -> Optional[float]:
        """Authoritative modeled time when the engine shares a hardware
        timeline with others (e.g. several servers on ONE DVFS arbiter —
        one LDO/ADPLL is one clock).  The scheduler fast-forwards its own
        ``now_s`` to this at every ``submit()`` and ``step()``, so arrival
        stamps, EDF slack, and admission quotes are judged on the same clock
        deadlines are — even when OTHER servers advanced it in between.
        ``None``/absent = the scheduler's own clock is authoritative."""
        ...

    def lane_advance(
        self, bucket: int, lane: int, req: "Request", out: Any, depth: int
    ) -> bool:
        """Per-lane host postprocess after a step; True retires the lane."""
        ...

    def lane_finish(self, bucket: int, lane: int, req: "Request", depth: int) -> None:
        """Retirement bookkeeping (final logits, DVFS report, ...)."""
        ...

    def bucket_end(self, bucket: int) -> None:
        """Release / park the bucket state once its queue + lanes drained."""
        ...

    # -- optional (resolved via getattr; engines may omit it) ---------------
    def predict_remaining_steps(
        self, bucket: int, req: "Request", depth: int
    ) -> Optional[float]:
        """Predicted fused steps this request still needs (entropy-LUT exit
        prediction for the classifier, generation budget for the decoder).
        ``None``/absent = unknown; the EDF policy then uses the bare deadline."""
        ...

    # -- optional (both required for preempt=True; resolved via getattr) ----
    def lane_checkpoint(self, bucket: int, lane: int, req: "Request") -> Any:
        """Snapshot a lane's engine state (hidden tensor row / KV cache row,
        valid length, DVFS lane clock) at a layer boundary so the lane can be
        freed for a tighter-SLO arrival.  Returns an opaque payload handed
        back verbatim to ``lane_restore``; the scheduler separately remembers
        the lane's depth.  Must not mutate the lane — the request may be
        restored into a DIFFERENT lane index later."""
        ...

    def lane_restore(self, bucket: int, lane: int, req: "Request", payload: Any) -> None:
        """Reload a checkpointed request into a free lane.  Must reuse the
        bucket's existing fixed-shape compiled paths (zero new traces) and
        reproduce the checkpointed state bit-identically, so a preempted-
        then-restored request computes the same function as an uninterrupted
        run."""
        ...


# Back-compat alias: PR 2 exported the protocol under this name.
LaneEngine = EngineHooks


@dataclass
class BucketView:
    """Per-bucket snapshot handed to a ``SchedulingPolicy``."""

    bucket: int
    queued: int                     # requests waiting in this bucket's queue
    active: int                     # lanes currently in flight
    step_time_s: float              # modeled duration of one fused step
    earliest_deadline_s: float      # min absolute deadline (inf if none),
                                    # explicit SLOs and implicit budgets alike
    min_slack_s: float              # min(deadline - now - predicted remaining)
    earliest_seq: int               # submission order of the oldest work item
    # explicit per-request SLOs only (requests with their own deadline_s):
    # EDF ranks these STRICTLY above implicit controller-target budgets — a
    # per-request SLO is a contract, the global target is best-effort shaping
    explicit_deadline_s: float = float("inf")
    explicit_slack_s: float = float("inf")


class SchedulingPolicy(Protocol):
    """Picks which candidate bucket the next ``step()`` advances."""

    def choose(self, views: Sequence[BucketView], now_s: float) -> int:
        ...


class WeightedRoundRobinPolicy:
    """Deficit-style weighted round robin over the candidate buckets.

    Each bucket accrues ``weights[bucket]`` credits (default 1.0) whenever
    every candidate is out of credit; the richest candidate runs ``quantum``
    consecutive steps before the next arbitration.  With default weights this
    is fair time slicing — a deep drain and a short queue alternate instead
    of the deep drain running to completion first.
    """

    def __init__(
        self, weights: Optional[Dict[int, float]] = None, quantum: int = 1
    ):
        assert quantum >= 1
        self.weights = dict(weights or {})
        self.quantum = int(quantum)
        self._credit: Dict[int, float] = {}
        self._last: Optional[int] = None
        self._ran = 0

    def choose(self, views: Sequence[BucketView], now_s: float) -> int:
        byb = {v.bucket: v for v in views}
        if self._last in byb and self._ran < self.quantum:
            self._ran += 1
            return self._last
        for b in byb:
            self._credit.setdefault(b, 0.0)
        if all(self._credit[b] <= 0 for b in byb):
            for b in byb:
                self._credit[b] += self.weights.get(b, 1.0)
        choice = max(byb, key=lambda b: (self._credit[b], -b))
        self._credit[choice] -= 1.0
        self._last, self._ran = choice, 1
        return choice


class EDFPolicy:
    """Earliest-deadline-first across buckets, slack-ranked by the predicted
    exit depth; deadline-free work falls back to ``fallback`` (WRR).

    A bucket's urgency is the least slack among its queued + in-flight
    requests: absolute deadline minus the modeled now minus the predicted
    remaining work (the engine's entropy-LUT exit prediction times the
    bucket's step time).  Deadlines come in two strengths and EDF ranks them
    in strict tiers: buckets holding EXPLICIT per-request SLOs (contracts,
    queue-wait-inclusive) preempt buckets whose urgency is only the implicit
    controller-target budget (best-effort energy shaping), which in turn
    preempt deadline-free work — the property that lets a tight-SLO 32-token
    request retire in the middle of a deep 128-token drain.
    """

    def __init__(self, fallback: Optional[SchedulingPolicy] = None):
        self.fallback = fallback if fallback is not None else WeightedRoundRobinPolicy()

    def choose(self, views: Sequence[BucketView], now_s: float) -> int:
        contracted = [v for v in views if np.isfinite(v.explicit_deadline_s)]
        if contracted:
            return min(
                contracted,
                key=lambda v: (v.explicit_slack_s, v.explicit_deadline_s, v.bucket),
            ).bucket
        dated = [v for v in views if np.isfinite(v.earliest_deadline_s)]
        if not dated:
            return self.fallback.choose(views, now_s)
        return min(
            dated,
            key=lambda v: (v.min_slack_s, v.earliest_deadline_s, v.bucket),
        ).bucket


class FIFOPolicy:
    """Strict arrival order: always advance the bucket holding the oldest
    unfinished request — the sequential drain-the-world behavior, kept as the
    baseline the EDF tests beat."""

    def choose(self, views: Sequence[BucketView], now_s: float) -> int:
        return min(views, key=lambda v: (v.earliest_seq, v.bucket)).bucket


class _DelayReservoir:
    """Bounded-memory percentile sample for the queue-delay telemetry.

    Classic reservoir sampling (deterministic seed, so telemetry is
    reproducible): the first ``cap`` observations are kept exactly — small
    drains report EXACT percentiles, unchanged from the rescan-the-retirees
    implementation — and a long-running server degrades gracefully to a
    uniform sample instead of growing without bound.  The max is tracked
    exactly (it is O(1) state)."""

    def __init__(self, cap: int = 4096, seed: int = 0):
        assert cap >= 1
        self.cap = cap
        self.n = 0
        self.buf: List[float] = []
        self.max = 0.0
        self._rng = np.random.default_rng(seed)

    def add(self, x: float) -> None:
        self.n += 1
        self.max = max(self.max, float(x))
        if len(self.buf) < self.cap:
            self.buf.append(float(x))
        else:
            j = int(self._rng.integers(0, self.n))
            if j < self.cap:
                self.buf[j] = float(x)

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.buf, q)) if self.buf else 0.0


def _pop_at(q: deque, idx: int) -> "Request":
    """Remove and return the element at ``idx`` from a deque in O(idx):
    rotate it to the front, pop, rotate back (popping at the front is what
    makes rotating by the PRE-pop index correct afterwards)."""
    q.rotate(-idx)
    item = q.popleft()
    q.rotate(idx)
    return item


@dataclass
class _BucketRun:
    """Scheduler-side lane bookkeeping of one OPEN bucket."""

    lane_req: List[Optional["Request"]]
    lane_depth: np.ndarray
    active: np.ndarray


@dataclass
class StepReport:
    """What one ``step()`` did (host-side, for callers driving the loop)."""

    bucket: int
    n_active: int
    retired: List["Request"] = field(default_factory=list)


class LaneScheduler:
    """Length-bucketed, continuously-clocked continuation-batching scheduler.

    Parameters
    ----------
    lanes:        number of hardware lanes (the fixed batch dimension).
    engine:       the ``EngineHooks`` implementation supplying compute.
    buckets:      ascending bucket sizes (e.g. ``(32, 64, 128)``); a request
                  lands in the smallest bucket >= its shape key.  ``None`` =
                  exact-shape buckets (one per distinct key).
    policy:       ``SchedulingPolicy`` picking the bucket each ``step()``
                  advances.  Default: ``EDFPolicy`` (WRR fallback when no
                  deadlines are in play).
    step_time_fn: modeled seconds one fused step of a bucket takes (drives
                  the modeled clock the EDF slack computation runs on).
                  Default: 1.0 per step — deadlines measured in steps.
    default_deadline_s: implicit latency budget for IN-FLIGHT requests that
                  carry no ``deadline_s`` (engines pass the DVFS controller's
                  global target).  Anchored at lane ADMISSION — the clock the
                  DVFS layer judges — so once a lane is loaded, EDF slack
                  (not blind round robin) decides which bucket gets each time
                  slice and the lane closest to its budget runs next.
                  QUEUED deadline-free requests stay undated: their budget
                  has not started, so an explicit (submission-anchored,
                  queue-wait-inclusive) per-request SLO always outranks a
                  backlog of budget-free work.  ``None`` keeps deadline-free
                  requests out of the EDF ranking entirely (WRR fallback
                  when nothing carries a deadline).
    preempt:      enable lane eviction for explicit SLOs: when a bucket's
                  queue holds an explicit-deadline request and every lane is
                  busy, a budget-free lane is checkpointed
                  (``engine.lane_checkpoint``) and re-queued at the FIFO
                  front, to be restored later without re-running completed
                  layers.  Requires the engine to implement the
                  ``lane_checkpoint``/``lane_restore`` hooks; silently
                  disabled otherwise.
    """

    def __init__(
        self,
        lanes: int,
        engine: EngineHooks,
        buckets=None,
        *,
        policy: Optional[SchedulingPolicy] = None,
        step_time_fn: Optional[Callable[[int], float]] = None,
        default_deadline_s: Optional[float] = None,
        preempt: bool = False,
    ):
        assert lanes >= 1
        self.lanes = lanes
        self.engine = engine
        self.buckets = tuple(sorted(int(b) for b in buckets)) if buckets else None
        assert self.buckets is None or len(set(self.buckets)) == len(self.buckets)
        self.policy: SchedulingPolicy = policy if policy is not None else EDFPolicy()
        self.step_time_fn = step_time_fn if step_time_fn is not None else (lambda b: 1.0)
        self.default_deadline_s = default_deadline_s
        self.preempt = bool(preempt) and (
            getattr(engine, "lane_checkpoint", None) is not None
            and getattr(engine, "lane_restore", None) is not None
        )
        self.queues: Dict[int, deque] = {}
        self.done: Dict[int, "Request"] = {}
        self.now_s = 0.0                # modeled clock (sum of step times)
        self._open: Dict[int, _BucketRun] = {}
        self._completed: deque = deque()  # retired since the last poll()
        self._seq = 0                   # global submission order
        # min absolute EXPLICIT deadline among each bucket's QUEUED requests,
        # maintained incrementally so _view() stays O(lanes) per step instead
        # of rescanning the whole queue (recomputed only when the minimum
        # element itself is admitted)
        self._qmin_deadline: Dict[int, float] = {}
        # ---- lifetime telemetry (persists across run()/step() calls) ----
        self._sentences = 0
        self._dense_steps = 0
        self._lane_steps = 0            # ACTIVE lane x step executions
        self._refills = 0
        self._bucket_steps: Dict[int, int] = {}
        self._preemptions = 0
        self._restored_steps_saved = 0  # checkpointed layers NOT re-run
        self._shed = 0                  # best-effort requests dropped
        # incremental retirement accounting: telemetry() must not rescan
        # ``done`` (poll() drops retired payloads unless pinned, so a
        # long-running submit/step/poll server stays bounded-memory)
        self._delays = _DelayReservoir()
        self._slo_misses = 0            # explicit SLOs missed (modeled clock)
        # admission-layer verdict counters (``serving/admission.py`` updates
        # these so one telemetry() call covers the whole request lifecycle)
        self.admission_stats: Dict[str, int] = {
            "accepted": 0, "rejected": 0, "requoted": 0,
        }

    # ------------------------------------------------------------- queueing
    def bucket_for(self, key: int) -> int:
        if self.buckets is None:
            return int(key)
        for b in self.buckets:
            if key <= b:
                return b
        raise ValueError(
            f"shape key {key} exceeds the largest bucket {self.buckets[-1]}"
        )

    def submit(self, req: "Request") -> int:
        """Queue a request — at any time, including between steps of an
        in-flight drain; it lands in a later refill of its bucket.  Returns
        the bucket it landed in.

        Stamps MODELED clocks only (``arrival_s`` / ``arrival_step``).  The
        wall-clock ``req.submit_time`` is deliberately NOT written here:
        deadline math runs entirely on the modeled clock, and a wall-clock
        stamp on the same object invited silently mixing the two (callers
        that want wall time set it themselves)."""
        self.sync_clock()
        req.arrival_step = self._dense_steps
        req.arrival_s = self.now_s
        req.seq = self._seq
        self._seq += 1
        b = self.bucket_for(self.engine.bucket_key(req))
        self.queues.setdefault(b, deque()).append(req)
        if req.deadline_s is not None:
            d_abs = req.arrival_s + req.deadline_s
            if d_abs < self._qmin_deadline.get(b, float("inf")):
                self._qmin_deadline[b] = d_abs
        return b

    def queued_best_effort(self, bucket: int) -> int:
        """Budget-free (no explicit SLO) requests waiting in a bucket's queue,
        excluding preempted requests carrying a checkpoint (those hold
        partially computed state and are not shed)."""
        return sum(
            1
            for r in self.queues.get(bucket, ())
            if r.deadline_s is None and r.checkpoint is None
        )

    def shed_oldest(self, bucket: int, n: int = 1) -> List["Request"]:
        """Load shedding: drop up to ``n`` of the OLDEST queued budget-free
        requests from a bucket (oldest-drop keeps the freshest traffic, the
        usual bounded-queue policy).  Explicit-SLO requests are never shed —
        they were admission-quoted — and neither are preempted requests
        carrying a checkpoint (their completed layers would be wasted).
        Dropped requests are marked ``shed`` and returned; they never retire
        and never appear in ``done``."""
        out: List["Request"] = []
        q = self.queues.get(bucket)
        if not q:
            return out
        for _ in range(n):
            idx = next(
                (
                    i
                    for i, r in enumerate(q)
                    if r.deadline_s is None and r.checkpoint is None
                ),
                None,
            )
            if idx is None:
                break
            victim = _pop_at(q, idx)
            victim.shed = True
            out.append(victim)
            self._shed += 1
        return out

    @property
    def pending(self) -> int:
        """Queued requests not yet loaded into a lane."""
        return sum(len(q) for q in self.queues.values())

    @property
    def in_flight(self) -> int:
        """Requests currently occupying a lane."""
        return sum(int(run.active.sum()) for run in self._open.values())

    @property
    def idle(self) -> bool:
        return self.pending == 0 and self.in_flight == 0

    # ---------------------------------------------------------- the clock
    def sync_clock(self) -> None:
        """Fast-forward ``now_s`` to the engine's authoritative shared clock
        (``clock_s`` hook), if it has one and it ran ahead — e.g. another
        server stepped the shared DVFS arbiter since we last ran.  No-op for
        engines that own their timeline (monotone: never rewinds)."""
        hook = getattr(self.engine, "clock_s", None)
        if hook is None:
            return
        t = hook()
        if t is not None and t > self.now_s:
            self.now_s = float(t)

    def _predict_remaining(self, bucket: int, req: "Request", depth: int):
        hook = getattr(self.engine, "predict_remaining_steps", None)
        if hook is None:
            return None
        return hook(bucket, req, depth)

    def _recompute_qmin(self, bucket: int) -> None:
        m = float("inf")
        for r in self.queues.get(bucket, ()):
            if r.deadline_s is not None:
                m = min(m, r.arrival_s + r.deadline_s)
        if np.isfinite(m):
            self._qmin_deadline[bucket] = m
        else:
            self._qmin_deadline.pop(bucket, None)

    def _pop_next(self, bucket: int, domain: Optional[int] = None) -> Optional["Request"]:
        """Next request to admit from a bucket's queue: the earliest-deadline
        EXPLICIT-SLO request if any (so a contract jumps the queue inside its
        own bucket, not just across buckets), else plain FIFO.  The O(queue)
        scan runs once per lane admission, not per step.

        ``domain`` restricts the pop to requests compatible with the lane's
        replica (admission placement pins ``req.replica``; unpinned requests
        run anywhere).  Returns ``None`` when nothing queued may take this
        lane — the refill leaves it free for a compatible arrival."""
        q = self.queues[bucket]
        best, best_d = None, float("inf")
        first_ok = None
        for idx, r in enumerate(q):
            pin = getattr(r, "replica", None)
            if domain is not None and pin is not None and pin != domain:
                continue
            if first_ok is None:
                first_ok = idx
            if r.deadline_s is not None:
                d = r.arrival_s + r.deadline_s
                if d < best_d:
                    best, best_d = idx, d
        if best is None:
            return _pop_at(q, first_ok) if first_ok is not None else None
        req = _pop_at(q, best)
        self._recompute_qmin(bucket)       # the minimum just left the queue
        return req

    def _view(self, bucket: int) -> BucketView:
        """Per-bucket urgency snapshot — O(lanes), not O(queue): in-flight
        lanes are enumerated, while the queue contributes its (incrementally
        maintained) min explicit deadline and its FIFO head's cold-start
        remaining-work estimate (queued requests have no entropy trace yet,
        so the head's prediction stands in for all of them)."""
        run = self._open.get(bucket)
        q = self.queues.get(bucket)
        dt = float(self.step_time_fn(bucket))
        queued = len(q) if q else 0
        active = int(run.active.sum()) if run is not None else 0
        earliest_deadline = float("inf")
        min_slack = float("inf")
        explicit_deadline = float("inf")
        explicit_slack = float("inf")
        earliest_seq = np.iinfo(np.int64).max
        if run is not None:
            for i in range(self.lanes):
                if not run.active[i]:
                    continue
                req, depth = run.lane_req[i], int(run.lane_depth[i])
                earliest_seq = min(earliest_seq, req.seq)
                explicit = req.deadline_s is not None
                if explicit:
                    # explicit SLO: submission-anchored — queue wait counts
                    d_abs = req.arrival_s + req.deadline_s
                elif self.default_deadline_s is not None:
                    # implicit budget: admission-anchored — the DVFS clock
                    d_abs = req.admit_s + self.default_deadline_s
                else:
                    continue
                rem = self._predict_remaining(bucket, req, depth)
                slack = d_abs - self.now_s - (rem or 0.0) * dt
                earliest_deadline = min(earliest_deadline, d_abs)
                min_slack = min(min_slack, slack)
                if explicit:
                    explicit_deadline = min(explicit_deadline, d_abs)
                    explicit_slack = min(explicit_slack, slack)
        if q:
            # queued budget-free work stays undated (its implicit budget has
            # not started); queued explicit SLOs enter via the running min
            earliest_seq = min(earliest_seq, q[0].seq)
            d_abs = self._qmin_deadline.get(bucket, float("inf"))
            if np.isfinite(d_abs):
                rem = self._predict_remaining(bucket, q[0], 0)
                slack = d_abs - self.now_s - (rem or 0.0) * dt
                earliest_deadline = min(earliest_deadline, d_abs)
                min_slack = min(min_slack, slack)
                explicit_deadline = min(explicit_deadline, d_abs)
                explicit_slack = min(explicit_slack, slack)
        return BucketView(
            bucket=bucket,
            queued=queued,
            active=active,
            step_time_s=dt,
            earliest_deadline_s=earliest_deadline,
            min_slack_s=min_slack,
            earliest_seq=int(earliest_seq),
            explicit_deadline_s=explicit_deadline,
            explicit_slack_s=explicit_slack,
        )

    def _candidates(self) -> List[BucketView]:
        out = []
        seen = set()
        for b, q in self.queues.items():
            if q:
                seen.add(b)
        for b, run in self._open.items():
            if run.active.any():
                seen.add(b)
        for b in sorted(seen):
            out.append(self._view(b))
        return out

    def candidate_views(self) -> List[BucketView]:
        """Public snapshot of this scheduler's candidate buckets, with the
        clock synced to the engine's shared timeline first.  Cross-server
        arbitration (e.g. task-affinity routing across per-task servers)
        ranks these the same way ``step()``'s own policy does, without
        stepping anything."""
        self.sync_clock()
        return self._candidates()

    # --------------------------------------------------------- preemption
    def _maybe_preempt(self, bucket: int, run: _BucketRun) -> None:
        """Evict budget-free lanes for queued EXPLICIT-SLO requests.

        Runs just before refill on the bucket ``step()`` chose: if the queue
        holds more explicit requests than there are free lanes, budget-free
        in-flight lanes are checkpointed (most predicted remaining work
        first — the longest work is the cheapest to defer) and re-queued at
        the FIFO front so the freed lanes take the contracts THIS step.  The
        explicit request's lane wait is thereby bounded by one fused step
        instead of one retire."""
        q = self.queues.get(bucket)
        if not q:
            return
        explicit = [r for r in q if r.deadline_s is not None]
        if not explicit:
            return

        def _victims(lane_idxs) -> List:
            out = []
            for i in lane_idxs:
                req = run.lane_req[i]
                if req is None or req.deadline_s is not None:
                    continue
                rem = self._predict_remaining(bucket, req, int(run.lane_depth[i]))
                out.append((-(rem if rem is not None else float(np.inf)), i))
            out.sort()
            return out

        def _evict(victims, need: int) -> None:
            for _, i in victims[: max(need, 0)]:
                req = run.lane_req[i]
                req.checkpoint = self.engine.lane_checkpoint(bucket, i, req)
                req.ckpt_depth = int(run.lane_depth[i])
                req.preempted += 1
                q.appendleft(req)
                run.lane_req[i] = None
                run.active[i] = False
                self._preemptions += 1

        dom_hook = getattr(self.engine, "lane_domain", None)
        pinned = [r for r in explicit if getattr(r, "replica", None) is not None]
        if dom_hook is None or not pinned:
            # single-domain (or wholly unpinned) case: evict globally
            free = sum(1 for r in run.lane_req if r is None)
            _evict(_victims(range(self.lanes)), len(explicit) - free)
            return
        # replica-pinned contracts can only take lanes of THEIR domain, so
        # eviction runs per domain for them; unpinned contracts then evict
        # globally for whatever free capacity remains
        domains: Dict[int, List[int]] = {}
        for i in range(self.lanes):
            domains.setdefault(dom_hook(i), []).append(i)
        for d, lane_idxs in domains.items():
            n_d = sum(1 for r in pinned if r.replica == d)
            if not n_d:
                continue
            free_d = sum(1 for i in lane_idxs if run.lane_req[i] is None)
            _evict(_victims(lane_idxs), n_d - free_d)
        n_wild = len(explicit) - len(pinned)
        if n_wild:
            free = sum(1 for r in run.lane_req if r is None)
            _evict(_victims(range(self.lanes)), n_wild - free)

    # ----------------------------------------------------------- stepping
    def step(self) -> Optional[StepReport]:
        """Advance ONE bucket by one fused step; returns what happened, or
        ``None`` when no work remains anywhere."""
        self.sync_clock()       # another server may have advanced the shared
                                # timeline: EDF slack and admit_s need it
        views = self._candidates()
        if not views:
            return None
        bucket = self.policy.choose(views, self.now_s)
        assert any(v.bucket == bucket for v in views), (
            f"policy chose bucket {bucket} which has no queued or active work"
        )
        eng = self.engine
        run = self._open.get(bucket)
        if run is None:
            eng.bucket_begin(bucket)
            run = _BucketRun(
                lane_req=[None] * self.lanes,
                lane_depth=np.zeros(self.lanes, np.int32),
                active=np.zeros(self.lanes, bool),
            )
            self._open[bucket] = run

        # evict budget-free lanes for queued explicit SLOs BEFORE refill, so
        # the freed lanes take the contracts in this very step
        if self.preempt:
            self._maybe_preempt(bucket, run)

        # refill every free lane from this bucket's queue (continuation
        # batching: retired lanes never idle while work is queued)
        q = self.queues.get(bucket)
        step_idx = self._dense_steps
        # replica-aware refill: a lane only takes work compatible with its
        # clock domain (engines without replicas report domain 0 for every
        # lane, and unpinned requests run anywhere — the common path is
        # unchanged)
        dom_hook = getattr(eng, "lane_domain", None)
        for i in range(self.lanes):
            if run.lane_req[i] is None and q:
                req = self._pop_next(
                    bucket, dom_hook(i) if dom_hook is not None else None
                )
                if req is None:
                    continue    # everything queued is pinned elsewhere
                if req.checkpoint is not None:
                    # preempted earlier: restore the checkpointed state and
                    # resume at its saved depth — completed layers are NOT
                    # re-run, and the original admission stamps survive (the
                    # queue-delay telemetry measures the FIRST admission)
                    eng.lane_restore(bucket, i, req, req.checkpoint)
                    run.lane_depth[i] = req.ckpt_depth
                    self._restored_steps_saved += req.ckpt_depth
                    req.checkpoint = None
                else:
                    eng.lane_load(bucket, i, req)
                    run.lane_depth[i] = 0
                    req.admit_s = self.now_s
                if req.first_compute_step is None:
                    req.first_compute_step = step_idx
                run.lane_req[i] = req
                run.active[i] = True
                self._refills += 1
        assert run.active.any(), "candidate bucket must have work after refill"

        out = eng.lanes_step(bucket, run.active.copy())
        n_active = int(run.active.sum())
        self._dense_steps += 1
        self._lane_steps += n_active
        self._bucket_steps[bucket] = self._bucket_steps.get(bucket, 0) + 1
        # the engine may report the step's ACTUAL modeled duration (DVFS op
        # period + switching stalls); fall back to the nominal estimate so
        # the EDF clock cannot drift from the clock deadlines are judged by
        dt_hook = getattr(eng, "step_dt_s", None)
        dt = dt_hook(bucket) if dt_hook is not None else None
        self.now_s += float(dt) if dt is not None else float(self.step_time_fn(bucket))
        run.lane_depth[run.active] += 1

        report = StepReport(bucket=bucket, n_active=n_active)
        for i in range(self.lanes):
            if not run.active[i]:
                continue
            req = run.lane_req[i]
            if eng.lane_advance(bucket, i, req, out, int(run.lane_depth[i])):
                eng.lane_finish(bucket, i, req, int(run.lane_depth[i]))
                req.retire_step = step_idx
                req.retire_s = self.now_s
                self.done[req.uid] = req
                self._completed.append(req)
                self._sentences += 1
                # fold retirement telemetry in NOW — once poll() hands the
                # request to the caller its payload may be gone
                if (
                    req.first_compute_step is not None
                    and req.arrival_step is not None
                ):
                    self._delays.add(req.first_compute_step - req.arrival_step)
                if (
                    req.deadline_s is not None
                    and req.retire_s - req.arrival_s > req.deadline_s * (1 + 1e-9)
                ):
                    self._slo_misses += 1
                report.retired.append(req)
                run.lane_req[i] = None
                run.active[i] = False

        if not run.active.any() and not self.queues.get(bucket):
            eng.bucket_end(bucket)
            del self._open[bucket]
        return report

    def poll(self, *, pin: bool = False) -> List["Request"]:
        """Requests retired since the last ``poll()`` (completion order).

        By default the polled requests are DROPPED from ``done`` — the
        caller now owns the payloads (tokens, logits, entropy traces), and a
        long-running submit/step/poll server keeps ``done`` at
        O(retired-but-unpolled) instead of growing forever (telemetry is
        folded incrementally at retirement, so nothing is lost).
        ``pin=True`` keeps the polled requests resident in ``done`` — the
        batch-drain idiom (``run()`` then index ``done`` by uid) is
        unaffected either way, since it never polls."""
        out = list(self._completed)
        self._completed.clear()
        if not pin:
            for r in out:
                self.done.pop(r.uid, None)
        return out

    def run(self) -> Dict[str, float]:
        """Back-compat drain-the-world wrapper: step until idle.

        The bucket ORDER now follows the configured policy (EDF/WRR time
        slicing instead of ascending sequential drains).  Per-request COMPUTE
        results (logits, exit layers, generated tokens) are identical — lanes
        are independent and each bucket's shapes are fixed, so no new traces
        either — but shared-clock DVFS accounting (energy_j / latency_s /
        operating points) legitimately differs from the sequential order: the
        arbiter sees a different lane mix and admission timeline.
        """
        while not self.idle:
            self.step()
        return self.telemetry()

    # ------------------------------------------------------------ telemetry
    def telemetry(self) -> Dict[str, float]:
        # all retirement-derived keys come from INCREMENTAL accumulators
        # (delay reservoir, miss counters) folded in at retirement: they are
        # exact for small drains, bounded-memory for long-running servers,
        # and independent of whether poll() already dropped the payloads;
        # every key exists, as 0, even when nothing has retired yet
        return {
            "sentences": self._sentences,
            "dense_steps": self._dense_steps,
            "lane_steps": self._lane_steps,
            "refills": self._refills,
            "buckets_used": len(self._bucket_steps),
            "bucket_steps": dict(self._bucket_steps),
            "lane_occupancy": (
                self._lane_steps / (self._dense_steps * self.lanes)
                if self._dense_steps
                else 0.0
            ),
            "modeled_now_s": self.now_s,
            "queue_delay_steps_p50": self._delays.percentile(50),
            "queue_delay_steps_p95": self._delays.percentile(95),
            "queue_delay_steps_p99": self._delays.percentile(99),
            "queue_delay_steps_max": self._delays.max if self._delays.n else 0.0,
            # ---- admission / preemption lifecycle counters ----
            "accepted": self.admission_stats["accepted"],
            "rejected": self.admission_stats["rejected"],
            "requoted": self.admission_stats["requoted"],
            "shed": self._shed,
            "preemptions": self._preemptions,
            "restored_steps_saved": self._restored_steps_saved,
            # explicit SLOs judged on the MODELED engine clock (submission ->
            # retirement), so the contract metric exists for every engine and
            # DVFS configuration; servers with a DVFS controller overwrite it
            # with the equivalent arbiter-latency accounting
            "accepted_slo_misses": self._slo_misses,
        }
