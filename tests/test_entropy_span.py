"""Entropy (Eq. 1/4) and adaptive attention span (§III-B) properties."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, st

from repro.core.adaptive_span import (
    active_head_indices,
    clamp_spans,
    hard_spans,
    span_flop_factor,
    span_loss,
    span_soft_mask,
)
from repro.core.entropy import entropy_from_logits


class TestEntropy:
    @given(st.integers(2, 64), st.floats(0.1, 50.0))
    def test_bounds(self, n, scale):
        x = jax.random.normal(jax.random.PRNGKey(n), (8, n)) * scale
        h = np.asarray(entropy_from_logits(x))
        assert (h >= 0).all()
        assert (h <= np.log(n) + 1e-5).all()

    def test_uniform_is_log_n(self):
        h = entropy_from_logits(jnp.zeros((3, 7)))
        np.testing.assert_allclose(np.asarray(h), np.log(7), rtol=1e-6)

    def test_confident_is_zero(self):
        x = jnp.array([[100.0, 0.0, 0.0]])
        assert float(entropy_from_logits(x)[0]) < 1e-4

    def test_matches_definition(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 10)) * 3
        p = jax.nn.softmax(x, axis=-1)
        ref = -jnp.sum(p * jnp.log(p + 1e-30), axis=-1)
        np.testing.assert_allclose(
            np.asarray(entropy_from_logits(x)), np.asarray(ref), atol=1e-5
        )

    def test_shift_invariant(self):
        """The max-trick form must be invariant to logit shifts (incl. huge).

        Tolerance note: ``x + 1e4`` in float32 rounds each logit to the
        ~1.2e-3 ULP grid at 1e4 (eps * shift), so the SHIFTED input itself
        differs from ``x`` by O(1e-3) before entropy is even computed; the
        old atol=1e-4 asserted more precision than float32 carries and
        flaked.  The max-trick invariance property itself is checked tightly
        with a moderate shift whose rounding perturbation (~3e-6) stays far
        inside the tolerance.
        """
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 5))
        h1 = entropy_from_logits(x)
        h2 = entropy_from_logits(x + 1e4)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=5e-3)
        h3 = entropy_from_logits(x + 256.0)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h3), atol=1e-5)


class TestSpan:
    def test_soft_mask_range_and_shape(self):
        z = jnp.array([0.0, 16.0, 128.0])
        m = span_soft_mask(z, 32, 32, ramp=8, causal=False)
        assert m.shape == (3, 32, 32)
        assert float(m.min()) >= 0 and float(m.max()) <= 1

    def test_mask_monotone_in_distance(self):
        z = jnp.array([10.0])
        m = np.asarray(span_soft_mask(z, 1, 64, ramp=8, causal=False))[0, 0]
        assert (np.diff(m) <= 1e-7).all()  # decays away from the query

    def test_causal_future_zero(self):
        z = jnp.array([100.0])
        m = np.asarray(span_soft_mask(z, 8, 8, ramp=4, causal=True))[0]
        assert (m[np.triu_indices(8, 1)] == 0).all()

    def test_hard_spans_paper_table1(self):
        """MNLI learned spans from paper Table I — 8/12 heads off."""
        z = jnp.array([20, 0.1, 0.2, 0, 0, 0.3, 36, 81, 0, 0.4, 0, 10.0])
        s = hard_spans(z)
        assert (s == np.array([20, 0, 0, 0, 0, 0, 36, 81, 0, 0, 0, 10])).all()
        idx, window = active_head_indices(s)
        assert list(idx) == [0, 6, 7, 11] and window == 81

    def test_flop_factor_matches_paper(self):
        """Paper: MNLI spans give ~1.22x FLOP reduction on attention-score
        work at S=128... the factor here is score-FLOPs retained."""
        spans = [20, 0, 0, 0, 0, 0, 36, 81, 0, 0, 0, 10]
        f = span_flop_factor(spans, 12, 128)
        assert 0.05 < f < 0.15  # 147/1536 ~= 0.096 of dense score FLOPs

    def test_span_loss_and_clamp(self):
        z = jnp.array([-5.0, 300.0])
        zc = clamp_spans(z, 128)
        assert float(zc[0]) == 0.0 and float(zc[1]) == 128.0
        assert float(span_loss(jnp.array([64.0]), 128, 1.0)) == 0.5
