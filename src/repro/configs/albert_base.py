"""ALBERT-base-v2 — the paper's baseline model (Fig. 2b).

12 encoder layers sharing ONE set of parameters (cross-layer sharing), embedding
factorized to 128, d_model=768, 12 heads, d_ff=3072, vocab=30000, max seq 128
(GLUE fine-tuning length used throughout the paper).
"""
from dataclasses import replace

from repro.configs.base import (
    EarlyExitConfig,
    EdgeBertConfig,
    ModelConfig,
    PruneConfig,
    QuantConfig,
    SpanConfig,
)

CONFIG = ModelConfig(
    name="albert-base-v2",
    family="albert",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=30000,
    embed_dim=128,            # factorized embedding (ALBERT)
    shared_layers=True,       # cross-layer parameter sharing
    act="gelu",
    norm="layernorm",
    pos="learned",
    max_seq_len=512,
    num_classes=3,            # MNLI-style
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        name="albert-smoke",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        embed_dim=32,
        max_seq_len=128,
    )
