"""Unified lane scheduler: length-bucketed fixed shapes (one compile per
bucket), bucket padding parity, per-lane KV-length decode parity against
isolated single-request decoding, and the step()-clocked API: mid-flight
submit parity, EDF-beats-FIFO cross-bucket preemption, poll(), and run()
back-compat."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.early_exit import offramp_logits
from repro.core.entropy import entropy_from_logits
from repro.data.synthetic import SyntheticCLS
from repro.models.model import build_model
from repro.serving.engine import ClassifierServer, DecoderServer, Request
from repro.serving.scheduler import (
    EDFPolicy,
    FIFOPolicy,
    LaneScheduler,
    WeightedRoundRobinPolicy,
)


def _albert_model(threshold=0.6):
    cfg = get_smoke_config("albert_edgebert")
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    cfg = cfg.with_edgebert(
        early_exit=dataclasses.replace(
            cfg.edgebert.early_exit, entropy_threshold=threshold
        )
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params, cfg


def _decoder_model():
    cfg = dataclasses.replace(
        get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none"
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    return model, params, cfg


class TestBucketAssignment:
    def test_smallest_fitting_bucket(self):
        class _E:  # minimal engine: bucket key = token length
            def bucket_key(self, req):
                return len(req.tokens)

        sched = LaneScheduler(2, _E(), buckets=(32, 64, 128))
        assert sched.bucket_for(10) == 32
        assert sched.bucket_for(32) == 32
        assert sched.bucket_for(33) == 64
        assert sched.bucket_for(128) == 128
        with pytest.raises(ValueError):
            sched.bucket_for(129)

    def test_exact_shape_buckets_when_unconfigured(self):
        class _E:
            def bucket_key(self, req):
                return len(req.tokens)

        sched = LaneScheduler(2, _E())          # buckets=None
        assert sched.bucket_for(17) == 17       # every length its own bucket


class TestBucketedCompileCount:
    def test_one_step_trace_per_bucket_not_per_length(self):
        """Five distinct request lengths over two buckets must compile the
        fused step exactly twice — the bucketed-engine regression."""
        model, params, cfg = _albert_model(threshold=0.5)
        data = SyntheticCLS(cfg.vocab_size, 32, 10, num_classes=3, seed=0)
        batch = data.batch(0)
        server = ClassifierServer(model, params, batch_lanes=3, buckets=(16, 32))
        lengths = [10, 13, 16, 24, 32]          # 3 -> bucket 16, 2 -> bucket 32
        for i, L in enumerate(lengths * 2):
            server.submit(Request(uid=i, tokens=batch["tokens"][i % 10][:L]))
        stats = server.run()
        assert stats["sentences"] == 10
        assert stats["step_traces"] == 2
        assert stats["step_traces_per_bucket"] == {16: 1, 32: 1}
        assert stats["embed_traces"] == 2       # one embed shape per bucket
        assert stats["buckets_used"] == 2

    def test_second_drain_same_buckets_no_retrace(self):
        model, params, cfg = _albert_model(threshold=0.6)
        data = SyntheticCLS(cfg.vocab_size, 32, 4, num_classes=3, seed=1)
        batch = data.batch(0)
        server = ClassifierServer(model, params, batch_lanes=2, buckets=(16, 32))
        for i, L in enumerate((12, 30, 16, 32)):
            server.submit(Request(uid=i, tokens=batch["tokens"][i][:L]))
        server.run()
        for i, L in enumerate((11, 29, 15, 31)):
            server.submit(Request(uid=4 + i, tokens=batch["tokens"][i][:L]))
        stats = server.run()
        assert stats["sentences"] == 8
        assert stats["step_traces"] == 2        # still one per bucket

    def test_padded_result_matches_native_length_reference(self):
        """Bucket padding must NOT change the computed function: a short
        sentence padded up to its bucket produces the same logits and exit
        layer as the straight-line reference at its NATIVE length (pad
        positions are masked out of attention via per-lane kv_len)."""
        thr = 0.5
        model, params, cfg = _albert_model(threshold=thr)
        data = SyntheticCLS(cfg.vocab_size, 32, 4, num_classes=3, seed=2)
        batch = data.batch(0)
        server = ClassifierServer(model, params, batch_lanes=2, buckets=(16,))
        for i in range(4):
            server.submit(Request(uid=i, tokens=batch["tokens"][i][:11]))
        server.run()
        for i in range(4):
            # reference: UNPADDED, exact 11-token shapes, no bucket, no mask
            h = model.embed(params, jnp.asarray(batch["tokens"][i][:11])[None])
            want_exit, want_lg = None, None
            for li in range(cfg.n_layers):
                span_z = model._span_for_layer(params, 0)
                h, _, _ = model._dense_layer_step(
                    params["layer"], h, causal=False, span_z=span_z
                )
                lg = offramp_logits(h, model._offramp(params))
                ent = float(entropy_from_logits(lg)[0])
                if ent < thr or li == cfg.n_layers - 1:
                    want_exit, want_lg = li + 1, np.asarray(lg[0])
                    break
            req = server.done[i]
            assert req.exit_layer == want_exit
            np.testing.assert_allclose(req.result, want_lg, atol=5e-2)
            assert np.argmax(req.result) == np.argmax(want_lg)


class TestSteppedAPI:
    def test_mid_drain_submit_parity_and_no_new_traces(self):
        """Submitting BETWEEN steps must produce the same per-request outputs
        as submitting everything up front, and must not add compiled traces
        (the step shapes are fixed per bucket)."""
        thr = 0.5
        model, params, cfg = _albert_model(threshold=thr)
        data = SyntheticCLS(cfg.vocab_size, 32, 8, num_classes=3, seed=4)
        batch = data.batch(0)
        lengths = [10, 30, 14, 28, 12, 26, 16, 32]

        # reference: everything submitted up front, drained with run()
        ref = ClassifierServer(model, params, batch_lanes=2, buckets=(16, 32))
        for i, L in enumerate(lengths):
            ref.submit(Request(uid=i, tokens=batch["tokens"][i][:L]))
        ref_stats = ref.run()

        # stepped: half up front, the rest injected mid-drain
        srv = ClassifierServer(model, params, batch_lanes=2, buckets=(16, 32))
        for i, L in enumerate(lengths[:4]):
            srv.submit(Request(uid=i, tokens=batch["tokens"][i][:L]))
        steps = 0
        while True:
            rep = srv.step()
            if rep is None:
                break
            steps += 1
            if steps == 2:
                for i, L in enumerate(lengths[4:], start=4):
                    srv.submit(Request(uid=i, tokens=batch["tokens"][i][:L]))
        stats = srv.telemetry()
        assert len(srv.done) == 8
        for i in range(8):
            assert srv.done[i].exit_layer == ref.done[i].exit_layer, i
            np.testing.assert_allclose(
                srv.done[i].result, ref.done[i].result, atol=1e-5
            )
        # no extra compiles vs the up-front drain: one step trace per bucket
        assert stats["step_traces_per_bucket"] == ref_stats["step_traces_per_bucket"]
        assert stats["step_traces"] == 2

    def test_poll_returns_each_completion_exactly_once(self):
        model, params, cfg = _albert_model(threshold=0.5)
        data = SyntheticCLS(cfg.vocab_size, 32, 6, num_classes=3, seed=5)
        batch = data.batch(0)
        srv = ClassifierServer(model, params, batch_lanes=2, buckets=(32,))
        for i in range(6):
            srv.submit(Request(uid=i, tokens=batch["tokens"][i]))
        polled = []
        while srv.step() is not None:
            polled.extend(r.uid for r in srv.poll())
        polled.extend(r.uid for r in srv.poll())
        assert sorted(polled) == list(range(6))   # each exactly once
        assert srv.poll() == []                    # drained

    def test_run_is_equivalent_to_step_loop(self):
        """run() is a thin `while work: step()` wrapper — same completions,
        same telemetry counters as driving step() by hand."""
        model, params, cfg = _albert_model(threshold=0.5)
        data = SyntheticCLS(cfg.vocab_size, 32, 6, num_classes=3, seed=6)
        batch = data.batch(0)
        a = ClassifierServer(model, params, batch_lanes=2, buckets=(16, 32))
        b = ClassifierServer(model, params, batch_lanes=2, buckets=(16, 32))
        for i in range(6):
            L = 12 if i % 2 else 30
            a.submit(Request(uid=i, tokens=batch["tokens"][i][:L]))
            b.submit(Request(uid=i, tokens=batch["tokens"][i][:L]))
        st_a = a.run()
        while b.step() is not None:
            pass
        st_b = b.telemetry()
        assert len(a.done) == len(b.done) == 6
        for i in range(6):
            assert a.done[i].exit_layer == b.done[i].exit_layer
        for k in ("sentences", "dense_steps", "layer_calls", "step_traces",
                  "bucket_steps", "lane_occupancy"):
            assert st_a[k] == st_b[k], k

    def test_queue_delay_telemetry(self):
        """arrival_step -> first_compute_step -> retire_step stamps and the
        p50/p95 queue-delay telemetry: more requests than lanes means later
        requests provably wait in queue."""
        model, params, cfg = _albert_model(threshold=0.5)
        data = SyntheticCLS(cfg.vocab_size, 32, 8, num_classes=3, seed=7)
        batch = data.batch(0)
        srv = ClassifierServer(model, params, batch_lanes=2, buckets=(32,))
        for i in range(8):
            srv.submit(Request(uid=i, tokens=batch["tokens"][i]))
        st = srv.run()
        for r in srv.done.values():
            assert r.arrival_step == 0
            assert r.first_compute_step is not None and r.retire_step is not None
            assert r.first_compute_step >= r.arrival_step
            assert r.retire_step >= r.first_compute_step
        delays = [r.first_compute_step - r.arrival_step for r in srv.done.values()]
        assert max(delays) > 0                 # someone actually queued
        assert (
            st["queue_delay_steps_p99"]
            >= st["queue_delay_steps_p95"]
            >= st["queue_delay_steps_p50"]
            >= 0.0
        )
        assert st["queue_delay_steps_max"] == max(delays)
        assert st["queue_delay_steps_p99"] <= st["queue_delay_steps_max"]


class TestCrossBucketPolicies:
    def _mk(self, policy):
        model, params, cfg = _albert_model(threshold=1e-9)  # never early-exit
        data = SyntheticCLS(cfg.vocab_size, 32, 8, num_classes=3, seed=8)
        batch = data.batch(0)
        srv = ClassifierServer(
            model, params, batch_lanes=2, buckets=(16, 32), policy=policy
        )
        return srv, batch, cfg

    def test_edf_short_deadline_preempts_deep_drain(self):
        """The acceptance property: a short-deadline 16-token request
        submitted DURING a deep 32-token drain retires before the drain
        completes under EDF, and the drain's results are unaffected."""
        srv, batch, cfg = self._mk(EDFPolicy())
        for i in range(4):                      # deep drain: full-depth, no SLO
            srv.submit(Request(uid=i, tokens=batch["tokens"][i][:32]))
        srv.step()
        srv.step()
        # tight-but-feasible SLO: needs n_layers steps, deadline has headroom
        srv.submit(Request(
            uid=99, tokens=batch["tokens"][4][:12],
            deadline_s=float(cfg.n_layers + 2),
        ))
        while srv.step() is not None:
            pass
        short = srv.done[99]
        drain_last = max(srv.done[i].retire_step for i in range(4))
        assert short.retire_step < drain_last, (
            "EDF must retire the short-deadline request before the deep "
            "drain finishes"
        )
        assert short.exit_layer == cfg.n_layers       # threshold ~0: full depth
        st = srv.telemetry()
        assert st["step_traces"] == 2                 # interleaving: no retrace

    def test_fifo_finishes_deep_drain_first(self):
        """The FIFO baseline the EDF property beats: same workload, but the
        late short request waits until the earlier-submitted drain is done."""
        srv, batch, cfg = self._mk(FIFOPolicy())
        for i in range(4):
            srv.submit(Request(uid=i, tokens=batch["tokens"][i][:32]))
        srv.step()
        srv.step()
        srv.submit(Request(
            uid=99, tokens=batch["tokens"][4][:12],
            deadline_s=float(cfg.n_layers + 2),
        ))
        while srv.step() is not None:
            pass
        drain_last = max(srv.done[i].retire_step for i in range(4))
        assert srv.done[99].retire_step > drain_last

    def test_explicit_slo_jumps_queue_inside_its_own_bucket(self):
        """An explicit-SLO request queued BEHIND deadline-free work in the
        SAME bucket must be admitted at the next free lane, not after the
        whole FIFO backlog (intra-bucket priority, not just cross-bucket)."""
        srv, batch, cfg = self._mk(EDFPolicy())
        for i in range(6):                      # backlog: one bucket, no SLOs
            srv.submit(Request(uid=i, tokens=batch["tokens"][i][:12]))
        srv.step()                              # lanes now hold uid 0 and 1
        srv.submit(Request(
            uid=77, tokens=batch["tokens"][6][:12],
            deadline_s=float(cfg.n_layers + 2),
        ))
        while srv.step() is not None:
            pass
        # admitted at the FIRST refill after submission: only the two
        # in-flight requests may retire before it
        assert srv.done[77].first_compute_step <= srv.done[77].arrival_step + cfg.n_layers
        before = [u for u in range(6) if srv.done[u].retire_step < srv.done[77].retire_step]
        assert len(before) <= 2, before

    def test_wrr_time_slices_both_buckets(self):
        """Weighted round robin: with no deadlines anywhere, both buckets
        advance in alternation instead of one draining to completion first."""
        srv, batch, cfg = self._mk(WeightedRoundRobinPolicy())
        for i in range(2):
            srv.submit(Request(uid=i, tokens=batch["tokens"][i][:32]))
        for i in range(2, 4):
            srv.submit(Request(uid=i, tokens=batch["tokens"][i][:12]))
        buckets_seen = []
        for _ in range(4):
            buckets_seen.append(srv.step().bucket)
        assert set(buckets_seen) == {16, 32}, buckets_seen
        while srv.step() is not None:
            pass
        assert len(srv.done) == 4


class TestPerLaneKVDecode:
    def _reference_decode(self, model, params, prompt, max_new, max_seq):
        """Isolated single-request greedy decode — the ground truth a lane
        must reproduce regardless of what its neighbours are doing."""
        cache = model.init_cache(1, max_seq)
        for t in range(len(prompt) - 1):
            _, cache = model.decode_step(
                params, cache, jnp.asarray([[int(prompt[t])]]), t
            )
        pos = len(prompt) - 1
        cur = int(prompt[-1])
        outs = []
        for _ in range(max_new):
            lg, cache = model.decode_step(params, cache, jnp.asarray([[cur]]), pos)
            cur = int(jnp.argmax(lg[0, -1]))
            outs.append(cur)
            pos += 1
        return outs

    def test_staggered_lengths_with_refill_match_isolated(self):
        """Prompts of different lengths + a mid-drain refill: every lane must
        decode from its OWN position.  The old lock-step loop stepped refilled
        lanes at the max active position (burning pad positions and attending
        a zero gap) and cannot pass this."""
        model, params, cfg = _decoder_model()
        rng = np.random.default_rng(0)
        prompts = [
            rng.integers(4, cfg.vocab_size, size=L).astype(np.int32)
            for L in (6, 9, 4, 7, 5)
        ]
        server = DecoderServer(model, params, batch_lanes=2, max_seq=32, eos_id=-1)
        for i, p in enumerate(prompts):
            server.submit(Request(uid=i, tokens=p, max_new_tokens=4))
        stats = server.run()
        assert stats["completed"] == 5
        assert stats["decode_traces"] == 1 and stats["prefill_traces"] == 1
        for i, p in enumerate(prompts):
            want = self._reference_decode(model, params, p, 4, 32)
            assert server.done[i].generated == want, i

    def test_bucketed_caches_one_trace_per_bucket(self):
        model, params, cfg = _decoder_model()
        rng = np.random.default_rng(1)
        # needs (len + max_new + 1): 4+3+1=8 -> bucket 8; 10+3+1=14 -> bucket 16
        prompts = [rng.integers(4, cfg.vocab_size, size=L).astype(np.int32)
                   for L in (4, 10, 4, 10)]
        server = DecoderServer(
            model, params, batch_lanes=2, max_seq=64, eos_id=-1, buckets=(8, 16)
        )
        for i, p in enumerate(prompts):
            server.submit(Request(uid=i, tokens=p, max_new_tokens=3))
        stats = server.run()
        assert stats["completed"] == 4
        assert stats["buckets_used"] == 2
        assert stats["decode_traces"] == 2      # one per cache bucket
        assert stats["decode_traces_per_bucket"] == {8: 1, 16: 1}
        for i, p in enumerate(prompts):
            bucket = 8 if len(p) == 4 else 16
            want = self._reference_decode(model, params, p, 3, bucket)
            assert server.done[i].generated == want, i

    def test_lane_occupancy_beats_lockstep_accounting(self):
        """Per-lane positions mean decode steps track the LONGEST remaining
        lane, not a global max position; total steps equal the work of the
        slowest chain under continuation batching."""
        model, params, cfg = _decoder_model()
        rng = np.random.default_rng(2)
        prompts = [rng.integers(4, cfg.vocab_size, size=L).astype(np.int32)
                   for L in (5, 5, 5, 5)]
        server = DecoderServer(model, params, batch_lanes=2, max_seq=32, eos_id=-1)
        for i, p in enumerate(prompts):
            server.submit(Request(uid=i, tokens=p, max_new_tokens=3))
        stats = server.run()
        # 4 requests x 3 tokens over 2 lanes = 12 lane-steps in 6 fused steps
        assert stats["decode_steps"] == 6
        assert stats["lane_occupancy"] == 1.0


class _NullEngine:
    """Minimal host-only engine: every request retires after ``steps_per_req``
    fused steps — lets the scheduler churn 10k requests in milliseconds."""

    def __init__(self, steps_per_req=1):
        self.steps_per_req = steps_per_req

    def bucket_key(self, req):
        return len(req.tokens)

    def bucket_begin(self, bucket):
        pass

    def lane_load(self, bucket, lane, req):
        pass

    def lanes_step(self, bucket, active):
        return None

    def lane_advance(self, bucket, lane, req, out, depth):
        return depth >= self.steps_per_req

    def lane_finish(self, bucket, lane, req, depth):
        pass

    def bucket_end(self, bucket):
        pass


class TestRetiredRequestRetention:
    """ROADMAP retention item: a long-running submit/step/poll server must
    not accumulate every retired Request forever — poll() releases payloads
    (unless pinned) and telemetry folds incrementally."""

    def test_poll_drops_payloads_unless_pinned(self):
        sched = LaneScheduler(2, _NullEngine(), buckets=(8,))
        for i in range(4):
            sched.submit(Request(uid=i, tokens=np.zeros(4, np.int32)))
        while sched.step() is not None:
            pass
        assert len(sched.done) == 4          # nothing polled yet: all resident
        got = sched.poll(pin=True)
        assert len(got) == 4 and len(sched.done) == 4   # pinned: kept
        for i in range(4, 8):
            sched.submit(Request(uid=i, tokens=np.zeros(4, np.int32)))
        while sched.step() is not None:
            pass
        got = sched.poll()                   # default: payloads released
        assert sorted(r.uid for r in got) == [4, 5, 6, 7]
        assert sorted(sched.done) == [0, 1, 2, 3]

    def test_ten_thousand_request_drain_stays_bounded(self):
        """The acceptance drain: 10k requests through submit/step/poll keep
        ``done`` at O(outstanding) and the queue-delay reservoir at O(cap) —
        while the lifetime telemetry still counts every retiree."""
        lanes, wave = 4, 100
        sched = LaneScheduler(lanes, _NullEngine(), buckets=(8,))
        total, max_done = 10_000, 0
        uid = 0
        for _ in range(total // wave):
            for _ in range(wave):
                sched.submit(Request(uid=uid, tokens=np.zeros(4, np.int32)))
                uid += 1
            while sched.step() is not None:
                sched.poll()
                max_done = max(max_done, len(sched.done))
            sched.poll()
        # retired-but-unpolled work is bounded by one wave, nowhere near 10k
        assert max_done <= wave
        assert len(sched.done) == 0
        st = sched.telemetry()
        assert st["sentences"] == total      # accounting survived every drop
        assert len(sched._delays.buf) <= sched._delays.cap
        assert (
            st["queue_delay_steps_p99"]
            >= st["queue_delay_steps_p95"]
            >= st["queue_delay_steps_p50"]
            >= 0.0
        )

    def test_incremental_delay_stats_match_rescan_semantics(self):
        """Below the reservoir cap the incremental percentiles are EXACT —
        identical to rescanning the retirees like the old telemetry did."""
        sched = LaneScheduler(2, _NullEngine(), buckets=(8,))
        for i in range(12):
            sched.submit(Request(uid=i, tokens=np.zeros(4, np.int32)))
        delays = []
        while sched.step() is not None:
            for r in sched.poll():
                delays.append(r.first_compute_step - r.arrival_step)
        for r in sched.poll():
            delays.append(r.first_compute_step - r.arrival_step)
        st = sched.telemetry()
        assert st["queue_delay_steps_p50"] == float(np.percentile(delays, 50))
        assert st["queue_delay_steps_p95"] == float(np.percentile(delays, 95))
        assert st["queue_delay_steps_p99"] == float(np.percentile(delays, 99))
        assert st["queue_delay_steps_max"] == float(max(delays))

    def test_slo_miss_counter_survives_poll_drop(self):
        """accepted_slo_misses is folded at retirement: dropping payloads
        via poll() must not erase recorded misses."""
        sched = LaneScheduler(1, _NullEngine(steps_per_req=3), buckets=(8,))
        sched.submit(Request(
            uid=0, tokens=np.zeros(4, np.int32), deadline_s=0.5
        ))                                   # 3 steps at 1.0s/step: missed
        sched.submit(Request(
            uid=1, tokens=np.zeros(4, np.int32), deadline_s=100.0
        ))                                   # met
        while sched.step() is not None:
            pass
        assert sched.telemetry()["accepted_slo_misses"] == 1
        sched.poll()
        assert len(sched.done) == 0
        assert sched.telemetry()["accepted_slo_misses"] == 1
