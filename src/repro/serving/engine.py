"""Serving engine: the system layer that converts EdgeBERT's per-sentence
early exit into real throughput on batched hardware.

* ``ClassifierServer`` — ALBERT-style classification with entropy early exit,
  run as a FIXED-SHAPE, mask-vectorized continuation-batching engine.  The
  server owns a static ``[lanes, S, H]`` hidden-state tensor plus an active
  mask; one fused, jitted step runs encoder layer -> off-ramp logits ->
  entropy -> retire-mask.  Traced shapes never change, so jit compiles the
  step EXACTLY ONCE per lane count (the previous engine concatenated a
  variable-size active-lane set every layer, recompiling for every distinct
  active count).  Retired lanes are refilled from the queue between steps
  (continuation batching), so lanes never idle: average depth/sentence ~
  average exit layer — the multi-batch generalization of the paper's
  single-stream latency saving.  An optional ``LatencyAwareDVFSController``
  (serving/dvfs.py, paper Alg. 1) converts each sentence's entropy trace into
  a per-sentence (voltage, frequency) schedule and energy/latency report.
* ``DecoderServer`` — LM decode with KV cache, EOS retirement + refill, and a
  jitted fixed-shape prefill (masked single-lane cache merge) replacing the
  old per-token Python prefill loop.
* ``MultiTaskRouter`` — the paper's multi-task scenario: one shared (eNVM-
  resident) embedding + per-task encoder/classifier weights; switching tasks
  swaps only task weights, never embeddings (paper §III-D).

Trace-count telemetry: every jitted function increments a host-side counter
*inside its traced body*, i.e. the counter only advances when XLA actually
retraces.  ``run()`` reports these counts (``step_traces`` must stay 1 across
a full queue drain) so recompile regressions fail loudly in tests.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.util import logger
from repro.core.early_exit import offramp_logits
from repro.core.entropy import entropy_from_logits
from repro.models.model import Model

if TYPE_CHECKING:  # typing-only: dvfs is not a runtime dependency of the engine
    from repro.serving.dvfs import LatencyAwareDVFSController


@dataclass
class Request:
    uid: int
    tokens: np.ndarray                  # [S] int32
    max_new_tokens: int = 16
    result: Optional[np.ndarray] = None
    exit_layer: Optional[int] = None
    generated: List[int] = field(default_factory=list)
    submit_time: float = 0.0
    finish_time: float = 0.0
    # per-layer off-ramp entropies observed while the sentence was in flight;
    # the DVFS controller replays this trace through Alg. 1
    entropy_trace: List[float] = field(default_factory=list)
    energy_j: Optional[float] = None    # modeled accelerator energy (DVFS)
    latency_s: Optional[float] = None   # modeled accelerator latency (DVFS)
    op_vdd: Optional[float] = None      # selected operating point
    op_freq_hz: Optional[float] = None


# ===========================================================================
# Classifier (early-exit) server — fixed-shape masked continuation batching
# ===========================================================================


class ClassifierServer:
    """Continuation-batching early-exit classifier with static traced shapes.

    The engine state is a dense ``[lanes, S, D]`` tensor; per-step work is
    always the full lane set with an active mask, so the fused step function
    has one trace per (lanes, S) shape.  ``layer_calls`` telemetry still
    counts *active* lane-layer executions — the quantity the accelerator
    would actually compute — so throughput accounting matches the paper's
    runtime-savings form.
    """

    def __init__(
        self,
        model: Model,
        params: Any,
        batch_lanes: int = 8,
        dvfs: Optional["LatencyAwareDVFSController"] = None,
    ):
        assert model.cfg.family == "albert", "classifier server drives the albert family"
        self.model = model
        self.params = params
        self.lanes = batch_lanes
        self.cfg = model.cfg
        self.threshold = model.cfg.edgebert.early_exit.entropy_threshold
        self.dvfs = dvfs
        self.queue: deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self._layer_calls = 0       # telemetry: total ACTIVE layer x lane executions
        self._dense_steps = 0       # telemetry: fused steps (dense over lanes)
        self._sentences = 0
        self._traces = {"embed": 0, "step": 0, "insert": 0}

        def embed_fn(params, tokens):
            self._traces["embed"] += 1          # advances only on retrace
            return model.embed(params, tokens)

        def step_fn(params, h, active, threshold):
            """Fused: encoder layer -> off-ramp -> entropy -> retire mask.

            h:      [lanes, S, D] static-shape hidden states
            active: [lanes] bool — inactive lanes are frozen by the mask
            """
            self._traces["step"] += 1           # advances only on retrace
            span_z = model._span_for_layer(params, 0)
            h_new, _, _ = model._dense_layer_step(
                params["layer"], h, causal=False, span_z=span_z
            )
            h = jnp.where(active[:, None, None], h_new, h)
            lg = offramp_logits(h, model._offramp(params))
            ent = entropy_from_logits(lg)
            retire = jnp.logical_and(active, ent < threshold)
            return h, lg, ent, retire

        def insert_fn(h, lane, h_new):
            self._traces["insert"] += 1         # advances only on retrace
            return jax.lax.dynamic_update_slice_in_dim(h, h_new, lane, axis=0)

        self._embed = jax.jit(embed_fn)
        self._step = jax.jit(step_fn)
        self._insert = jax.jit(insert_fn)

    def submit(self, req: Request):
        req.submit_time = time.time()
        self.queue.append(req)

    # ------------------------------------------------------------- internals
    def _refill(self, h, lane_req, lane_depth, active):
        """Fill every free lane from the queue; returns the updated h."""
        for i in range(self.lanes):
            if lane_req[i] is None and self.queue:
                req = self.queue.popleft()
                toks = jnp.asarray(req.tokens)[None]
                h = self._insert(h, jnp.int32(i), self._embed(self.params, toks))
                lane_req[i] = req
                lane_depth[i] = 0
                active[i] = True
        return h

    def _finish(self, req: Request, logits: np.ndarray, depth: int):
        req.result = logits
        req.exit_layer = depth
        req.finish_time = time.time()
        if self.dvfs is not None:
            rep = self.dvfs.sentence_report(req.entropy_trace, exit_layer=depth)
            req.energy_j = rep.energy_j
            req.latency_s = rep.latency_s
            req.op_vdd = rep.op.vdd
            req.op_freq_hz = rep.op.freq_hz
        self.done[req.uid] = req
        self._sentences += 1

    # ---------------------------------------------------------------- public
    def run(self) -> Dict[str, float]:
        """Drain the queue with continuation batching. Returns telemetry."""
        if not self.queue:
            return self.telemetry()
        S = len(self.queue[0].tokens)
        assert all(
            len(r.tokens) == S for r in self.queue
        ), "fixed-shape engine drains one sequence length per run()"
        D = self.cfg.d_model
        h = jnp.zeros((self.lanes, S, D), jnp.asarray(self.params["embed"]["tok"]).dtype)

        lane_req: List[Optional[Request]] = [None] * self.lanes
        lane_depth = np.zeros(self.lanes, np.int32)
        active = np.zeros(self.lanes, bool)
        thr = jnp.float32(self.threshold)

        while self.queue or active.any():
            h = self._refill(h, lane_req, lane_depth, active)
            if not active.any():
                break
            h, lg, ent, retire = self._step(self.params, h, jnp.asarray(active), thr)
            n_active = int(active.sum())
            self._layer_calls += n_active
            self._dense_steps += 1
            lane_depth[active] += 1
            ent_np = np.asarray(ent)
            lg_np = np.asarray(lg)
            retire_np = np.asarray(retire)
            for i in range(self.lanes):
                if not active[i]:
                    continue
                req = lane_req[i]
                req.entropy_trace.append(float(ent_np[i]))
                if retire_np[i] or lane_depth[i] >= self.cfg.n_layers:
                    self._finish(req, lg_np[i], int(lane_depth[i]))
                    lane_req[i] = None
                    active[i] = False
        return self.telemetry()

    def telemetry(self) -> Dict[str, float]:
        avg_exit = (
            float(np.mean([r.exit_layer for r in self.done.values()]))
            if self.done
            else 0.0
        )
        out = {
            "sentences": self._sentences,
            "layer_calls": self._layer_calls,
            "dense_steps": self._dense_steps,
            "avg_exit_layer": avg_exit,
            "runtime_savings": 1.0 - avg_exit / self.cfg.n_layers,
            "step_traces": self._traces["step"],
            "embed_traces": self._traces["embed"],
            "insert_traces": self._traces["insert"],
            "lane_occupancy": (
                self._layer_calls / (self._dense_steps * self.lanes)
                if self._dense_steps
                else 0.0
            ),
        }
        if self.dvfs is not None and self.done:
            done = self.done.values()
            out["energy_j"] = float(sum(r.energy_j or 0.0 for r in done))
            out["modeled_latency_s"] = float(
                max((r.latency_s or 0.0) for r in done)
            )
            out["deadline_misses"] = sum(
                1 for r in done if (r.latency_s or 0.0) > self.dvfs.target_latency_s * (1 + 1e-9)
            )
        return out


# ===========================================================================
# Decoder (LM) server
# ===========================================================================


class DecoderServer:
    def __init__(
        self,
        model: Model,
        params: Any,
        batch_lanes: int = 4,
        max_seq: int = 256,
        eos_id: int = 2,
    ):
        self.model = model
        self.params = params
        self.lanes = batch_lanes
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.done: Dict[int, Request] = {}
        self._traces = {"decode": 0, "prefill": 0}

        def decode_fn(params, cache, tokens, pos):
            self._traces["decode"] += 1         # advances only on retrace
            return model.decode_step(params, cache, tokens, pos)

        def prefill_fn(params, cache, tokens, lane, length):
            """Write one lane's prompt[:length-1] into the KV cache.

            tokens: [max_seq] zero-padded prompt; lane/length: scalars.  The
            prompt is decoded step-by-step in a fori_loop on a scratch cache,
            then merged back under a lane one-hot so other lanes' cache rows
            are untouched — the whole prefill is ONE fixed-shape trace instead
            of a Python loop of per-token dispatches.
            """
            self._traces["prefill"] += 1        # advances only on retrace
            lane_ids = jnp.arange(self.lanes)

            def body(t, c):
                tok = jnp.where(lane_ids == lane, tokens[t], 0)[:, None]
                _, c = model.decode_step(params, c, tok, t)
                return c

            scratch = jax.lax.fori_loop(0, length - 1, body, cache)

            def merge(new, old):
                mask = (lane_ids == lane).reshape((1, self.lanes) + (1,) * (new.ndim - 2))
                return jnp.where(mask, new, old)

            return jax.tree_util.tree_map(merge, scratch, cache)

        self._decode = jax.jit(decode_fn)
        self._prefill = jax.jit(prefill_fn)

    def submit(self, req: Request):
        req.submit_time = time.time()
        self.queue.append(req)

    def run(self) -> Dict[str, float]:
        """Static-lane continuation batching decode loop."""
        model, params = self.model, self.params
        cache = model.init_cache(self.lanes, self.max_seq)
        lane_req: List[Optional[Request]] = [None] * self.lanes
        lane_pos = np.zeros(self.lanes, np.int32)
        cur_tok = np.zeros((self.lanes, 1), np.int32)
        steps = 0

        # NOTE: per-lane positions differ; for simplicity this server steps all
        # lanes in lock-step using the max position.  Per-lane KV length is not
        # tracked — acceptable for the CPU demo; the multi-pod serving path
        # uses uniform-length batches from the shape sheet (see ROADMAP).
        while self.queue or any(r is not None for r in lane_req):
            for i in range(self.lanes):
                if lane_req[i] is None and self.queue:
                    req = self.queue.popleft()
                    lane_req[i] = req
                    toks = np.zeros(self.max_seq, np.int32)
                    toks[: len(req.tokens)] = req.tokens
                    cache = self._prefill(
                        params,
                        cache,
                        jnp.asarray(toks),
                        jnp.int32(i),
                        jnp.int32(len(req.tokens)),
                    )
                    lane_pos[i] = len(req.tokens) - 1
                    cur_tok[i, 0] = req.tokens[-1]
            active = [i for i in range(self.lanes) if lane_req[i] is not None]
            if not active:
                break
            pos = int(max(lane_pos[i] for i in active))
            logits, cache = self._decode(params, cache, jnp.asarray(cur_tok), pos)
            steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for i in active:
                req = lane_req[i]
                tok = int(nxt[i])
                req.generated.append(tok)
                lane_pos[i] = pos + 1
                cur_tok[i, 0] = tok
                if tok == self.eos_id or len(req.generated) >= req.max_new_tokens:
                    req.finish_time = time.time()
                    self.done[req.uid] = req
                    lane_req[i] = None
            if lane_pos.max() >= self.max_seq - 1:
                for i in active:
                    if lane_req[i] is not None:
                        self.done[lane_req[i].uid] = lane_req[i]
                        lane_req[i] = None
        return {
            "decode_steps": steps,
            "completed": len(self.done),
            "decode_traces": self._traces["decode"],
            "prefill_traces": self._traces["prefill"],
        }


# ===========================================================================
# Multi-task router (shared eNVM embeddings)
# ===========================================================================


class MultiTaskRouter:
    """Holds ONE shared embedding table (the eNVM-resident, frozen, pruned
    weights) and per-task encoder/head weights; dispatches requests by task.

    Models the paper's measurement (Fig. 11): task switches swap SRAM-class
    weights only; embedding reload cost is paid once at power-on.
    """

    def __init__(
        self,
        model: Model,
        shared_embed: Any,
        task_params: Dict[str, Any],
        dvfs: Optional["LatencyAwareDVFSController"] = None,
    ):
        self.model = model
        self.shared_embed = shared_embed
        self.tasks: Dict[str, ClassifierServer] = {}
        self.switches = 0
        self.embed_reloads = 1          # power-on load only
        for name, tp in task_params.items():
            params = dict(tp, embed=shared_embed)
            self.tasks[name] = ClassifierServer(model, params, dvfs=dvfs)

    def submit(self, task: str, req: Request):
        self.tasks[task].submit(req)

    def run_all(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for name, server in self.tasks.items():
            if server.queue:
                self.switches += 1
                out[name] = server.run()
        return out
