"""Sentence-level latency-aware DVFS (paper Alg. 1, §IV; system Fig. 9).

EdgeBERT's headline mechanism: entropy-based early-exit *prediction* drives
dynamic voltage-frequency scaling per sentence, so each inference finishes
"just in time" at the lowest energy instead of racing to idle at max clock.

Mapping to the paper:

  * **Alg. 1 line 1** (run the first encoder layer at nominal VDD/freq):
    ``sentence_report`` always charges layer 1 at the table's top operating
    point — the LDO/ADPLL switch only after the first off-ramp is evaluated.
  * **Alg. 1 line 2** (predict the exit layer from the first off-ramp's
    entropy): ``core.early_exit.ExitPredictor``, a binned LUT calibrated
    offline (``calibrate_predictor``) — the ASIC's small SRAM table.
  * **Alg. 1 lines 3-4** (pick the minimum (V, f) that finishes the predicted
    remaining layers within the latency target): ``select_op`` scans the
    ``DVFS table`` (fast-switching LDO + ADPLL operating points, Fig. 9's
    clock/power management blocks) for the slowest point whose frequency
    still meets ``remaining_cycles / remaining_time``.
  * **Misprediction guard**: if the sentence has not exited by its predicted
    layer, remaining layers escalate to the maximum operating point so the
    latency target stays bounded (the paper's latency-aware guarantee).
  * **Energy accounting**: per-layer energy comes from the calibrated
    accelerator model (``hwmodel.edgebert_accel``); dynamic energy scales as
    (VDD/VDD_NOM)^2 and latency as cycles/f, so the DVFS win is quadratic in
    the voltage headroom the early-exit prediction uncovers.

The controller is deliberately analytic + host-side: the serving engine
(`serving/engine.py`) records each sentence's off-ramp entropy trace while
the fixed-shape batched step runs, and the controller replays Alg. 1 over
that trace to produce the per-sentence (V, f) schedule and energy/latency
report.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.early_exit import (
    ExitPredictor,
    fit_exit_predictor,
    predict_exit_layer,
)
from repro.hwmodel.edgebert_accel import (
    CLOCK_HZ,
    VDD_NOM,
    WorkloadStats,
    albert_layer_stats,
    layer_cycles,
    layer_energy_j,
)


@dataclass(frozen=True)
class OperatingPoint:
    """One LDO/ADPLL setting: supply voltage (V) and clock frequency (Hz)."""

    vdd: float
    freq_hz: float


# Fast-switching LDO (25mV steps) + ADPLL operating points for the 12nm
# design; the top entry is the nominal point the TableV anchors are fitted
# at.  Voltage ascends with frequency, so per-cycle energy is monotone in
# the table index — the property the controller's energy guarantees rest on.
DEFAULT_DVFS_TABLE: Tuple[OperatingPoint, ...] = (
    OperatingPoint(0.50, 100e6),
    OperatingPoint(0.55, 166e6),
    OperatingPoint(0.60, 250e6),
    OperatingPoint(0.65, 333e6),
    OperatingPoint(0.70, 400e6),
    OperatingPoint(VDD_NOM, CLOCK_HZ),
)


@dataclass
class DVFSReport:
    """Per-sentence outcome of Alg. 1."""

    exit_layer: int
    predicted_exit: float
    op: OperatingPoint              # point selected after the first off-ramp
    latency_s: float
    energy_j: float
    deadline_met: bool
    energy_max_freq_j: float        # same exit schedule, always at max V/f
    escalated_layers: int           # layers run at max point after a mispredict


def no_early_exit_baseline(
    stats: WorkloadStats,
    *,
    n: int = 16,
    op: OperatingPoint = DEFAULT_DVFS_TABLE[-1],
    use_span: bool = True,
    use_sparsity: bool = True,
) -> Dict[str, float]:
    """Conventional inference: all ``stats.n_layers`` layers at ``op``.

    Standalone so callers can derive a latency target BEFORE constructing the
    controller (the usual idiom: target = the full-model latency).
    """
    cyc = layer_cycles(stats, n, use_span=use_span)
    e = layer_energy_j(stats, n, vdd=op.vdd, use_span=use_span, use_sparsity=use_sparsity)
    L = stats.n_layers
    return {"latency_s": L * cyc / op.freq_hz, "energy_j": L * e}


class LatencyAwareDVFSController:
    """Replays paper Alg. 1 over a sentence's off-ramp entropy trace.

    Parameters
    ----------
    stats:            workload statistics of ONE encoder layer pass (from the
                      JAX model or ``albert_layer_stats``).
    target_latency_s: the prescribed per-sentence latency target T.
    predictor:        entropy -> exit-layer LUT; ``None`` predicts the full
                      ``stats.n_layers`` (conservative: never misses deadline,
                      saves least energy).
    """

    def __init__(
        self,
        stats: WorkloadStats,
        target_latency_s: float,
        *,
        table: Sequence[OperatingPoint] = DEFAULT_DVFS_TABLE,
        n: int = 16,
        predictor: Optional[ExitPredictor] = None,
        use_span: bool = True,
        use_sparsity: bool = True,
    ):
        assert target_latency_s > 0
        table = tuple(sorted(table, key=lambda p: p.freq_hz))
        assert all(
            a.vdd <= b.vdd for a, b in zip(table, table[1:])
        ), "DVFS table voltage must ascend with frequency"
        self.stats = stats
        self.target_latency_s = float(target_latency_s)
        self.table = table
        self.n = n
        self.predictor = predictor
        self.cycles_per_layer = layer_cycles(stats, n, use_span=use_span)
        # per-layer energy at each table point: E ~ (V/V_nom)^2, f-independent
        self._e_layer = {
            op: layer_energy_j(
                stats, n, vdd=op.vdd, use_span=use_span, use_sparsity=use_sparsity
            )
            for op in table
        }

    # ----------------------------------------------------------- primitives
    @property
    def max_op(self) -> OperatingPoint:
        return self.table[-1]

    def layer_time_s(self, op: OperatingPoint) -> float:
        return self.cycles_per_layer / op.freq_hz

    def layer_energy(self, op: OperatingPoint) -> float:
        return self._e_layer[op]

    def select_op(self, predicted_remaining: float, remaining_time_s: float) -> OperatingPoint:
        """Alg. 1 lines 3-4: slowest point meeting the remaining budget."""
        if remaining_time_s <= 0:
            return self.max_op
        need_hz = max(predicted_remaining, 0.0) * self.cycles_per_layer / remaining_time_s
        for op in self.table:
            if op.freq_hz >= need_hz:
                return op
        return self.max_op

    def predict(self, first_entropy: float) -> float:
        if self.predictor is None:
            return float(self.stats.n_layers)
        p = predict_exit_layer(self.predictor, first_entropy)
        return float(np.clip(p, 1.0, self.stats.n_layers))

    # -------------------------------------------------------------- Alg. 1
    def sentence_report(
        self, entropy_trace: Sequence[float], exit_layer: Optional[int] = None
    ) -> DVFSReport:
        """Run Alg. 1 for one sentence given its per-layer off-ramp entropies.

        ``entropy_trace[i]`` is the entropy after layer i+1; the trace ends at
        the layer the sentence exited (``exit_layer`` defaults to its length).
        """
        if exit_layer is None:
            exit_layer = len(entropy_trace)
        assert exit_layer >= 1 and len(entropy_trace) >= 1
        t_max = self.layer_time_s(self.max_op)
        e_max = self.layer_energy(self.max_op)

        # line 1: the first layer always runs at the nominal/maximum point
        latency = t_max
        energy = e_max
        if exit_layer == 1:
            return DVFSReport(
                exit_layer=1,
                predicted_exit=1.0,
                op=self.max_op,
                latency_s=latency,
                energy_j=energy,
                deadline_met=latency <= self.target_latency_s * (1 + 1e-9),
                energy_max_freq_j=e_max,
                escalated_layers=0,
            )

        # line 2: predict the total exit layer from the first off-ramp entropy
        predicted = max(self.predict(entropy_trace[0]), 2.0)
        # lines 3-4: slowest (V, f) finishing the predicted remainder in time
        op = self.select_op(predicted - 1.0, self.target_latency_s - latency)

        escalated = 0
        for li in range(2, exit_layer + 1):
            # misprediction guard: past the predicted exit, bound the latency
            # by escalating to the maximum operating point
            cur = op if li <= predicted + 1e-9 else self.max_op
            if cur is self.max_op and li > predicted:
                escalated += 1
            latency += self.layer_time_s(cur)
            energy += self.layer_energy(cur)
        return DVFSReport(
            exit_layer=int(exit_layer),
            predicted_exit=predicted,
            op=op,
            latency_s=latency,
            energy_j=energy,
            deadline_met=latency <= self.target_latency_s * (1 + 1e-9),
            energy_max_freq_j=exit_layer * e_max,
            escalated_layers=escalated,
        )

    # ----------------------------------------------------------- baselines
    def no_early_exit_baseline(self) -> Dict[str, float]:
        """Conventional inference: all n_layers, always at the max point."""
        L = self.stats.n_layers
        return {
            "latency_s": L * self.layer_time_s(self.max_op),
            "energy_j": L * self.layer_energy(self.max_op),
        }  # == module-level no_early_exit_baseline(self.stats) at defaults

    def max_freq_early_exit_baseline(self, exit_layers: Sequence[int]) -> Dict[str, float]:
        """Latency-unbounded early exit: race to the exit at max V/f."""
        t = self.layer_time_s(self.max_op)
        e = self.layer_energy(self.max_op)
        exits = np.asarray(list(exit_layers), np.float64)
        return {
            "latency_s": float(exits.max() * t) if exits.size else 0.0,
            "energy_j": float(exits.sum() * e),
        }


def calibrate_predictor(
    model, params, batches, n_bins: int = 16, quantile: Optional[float] = None
) -> ExitPredictor:
    """Fit the Alg. 1 LUT from dense profiling passes (offline calibration).

    ``batches`` is an iterable of ``{"tokens": [B, S]}``-style dicts; the
    model's dense all-layers forward provides (first-off-ramp entropy, exit
    layer) pairs at the configured entropy threshold.  ``quantile`` picks the
    conservative per-bin prediction (see ``fit_exit_predictor``).
    """
    import jax.numpy as jnp

    ents: List[np.ndarray] = []
    exits: List[np.ndarray] = []
    for b in batches:
        out = model.apply_train(params, {"tokens": jnp.asarray(b["tokens"])})
        assert out.all_entropies is not None and out.exit_layer is not None
        ents.append(np.asarray(out.all_entropies[0]))
        exits.append(np.asarray(out.exit_layer))
    return fit_exit_predictor(
        np.concatenate(ents), np.concatenate(exits), n_bins=n_bins, quantile=quantile
    )


def default_albert_controller(
    target_latency_s: float,
    *,
    seq_len: int = 128,
    n: int = 16,
    n_layers: int = 12,
    avg_exit_layer: Optional[float] = None,
    predictor: Optional[ExitPredictor] = None,
) -> LatencyAwareDVFSController:
    """Controller over the analytic ALBERT-base layer workload (Fig. 8)."""
    stats = albert_layer_stats(seq_len=seq_len)
    stats.n_layers = n_layers
    if avg_exit_layer is not None:
        stats.avg_exit_layer = avg_exit_layer
    return LatencyAwareDVFSController(
        stats, target_latency_s, n=n, predictor=predictor
    )
