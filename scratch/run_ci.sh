#!/usr/bin/env bash
# Tier-1 CI: unit-test suite + DVFS-benchmark smoke passes.
#
#   bash scratch/run_ci.sh
#
# The suite must COLLECT cleanly with or without `hypothesis` installed
# (property tests skip when it's absent — see tests/hypothesis_compat.py).
# Two benchmark smoke passes assert the paper's headline results end-to-end:
#   * bench_dvfs:          lower energy than the no-early-exit baseline at
#                          equal target latency (per-sentence Alg. 1);
#   * bench_batched_dvfs:  shared-clock arbitration (one LDO/ADPLL) below
#                          per-sentence max-V/f replay at equal target
#                          latency, with exactly one compile per length
#                          bucket.
# A grep-gate re-checks the bucketed engine's compile telemetry from the
# emitted `step_traces=N;bucket_count=M` pair: N > M means the fused step
# recompiled inside a bucket — fail even if the benchmark's own asserts
# were loosened.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -q
tier1=$?

echo "== bench_dvfs --smoke =="
python benchmarks/bench_dvfs.py --smoke
smoke=$?

echo "== bench_batched_dvfs --smoke =="
batched_log=$(mktemp)
python benchmarks/bench_batched_dvfs.py --smoke | tee "$batched_log"
batched=$?

echo "== grep-gate: step_traces <= bucket_count =="
gate=0
pair=$(grep -o 'step_traces=[0-9]*;bucket_count=[0-9]*' "$batched_log" | head -1)
if [ -z "$pair" ]; then
    echo "GATE FAIL: no step_traces/bucket_count telemetry emitted"
    gate=1
else
    traces=${pair#step_traces=}; traces=${traces%%;*}
    count=${pair##*bucket_count=}
    if [ "$traces" -gt "$count" ]; then
        echo "GATE FAIL: fused step traced ${traces}x for ${count} buckets"
        gate=1
    else
        echo "gate ok: ${traces} traces / ${count} buckets"
    fi
fi
rm -f "$batched_log"

echo "== summary: tier1=$tier1 smoke=$smoke batched=$batched gate=$gate =="
exit $(( tier1 || smoke || batched || gate ))
