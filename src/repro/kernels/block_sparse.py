"""Block-sparse matmul Pallas kernel (paper §V-C bitmask + zero-skip, TPU-adapted).

The paper's PU skips VMAC products when an operand vector is all-zero, with
bitmask-encoded storage.  The MXU has no element-granular skip, so the TPU
adaptation prunes at (bk x bn) tile granularity (PruneConfig.block_size) and
skips *whole tiles*: a CSR-of-blocks index list (one list of occupied k-blocks
per n-block, built host-side from the static pruning mask) drives the kernel's
k-loop via scalar-prefetch indirection, so pruned tiles are never DMA'd from
HBM and never touch the MXU — compute AND memory traffic scale with density.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def build_block_index(block_mask: np.ndarray) -> Tuple[np.ndarray, np.ndarray, int]:
    """CSR-of-blocks: for each n-block, the occupied k-block indices.

    Returns (indices [Nb, max_nnz] int32, counts [Nb] int32, max_nnz).
    Padded entries repeat the last valid index (clamped DMA, masked compute).
    """
    block_mask = np.asarray(block_mask, bool)
    Kb, Nb = block_mask.shape
    counts = block_mask.sum(axis=0).astype(np.int32)
    max_nnz = max(int(counts.max()) if counts.size else 0, 1)
    indices = np.zeros((Nb, max_nnz), np.int32)
    for j in range(Nb):
        ks = np.nonzero(block_mask[:, j])[0]
        if len(ks):
            indices[j, : len(ks)] = ks
            indices[j, len(ks) :] = ks[-1]
    return indices, counts, max_nnz


def _bs_kernel(idx_ref, cnt_ref, x_ref, w_ref, o_ref, acc_ref, *, n_s: int):
    j = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(s < cnt_ref[j])
    def _accum():
        x = x_ref[...].astype(jnp.float32)
        w = w_ref[...].astype(jnp.float32)
        acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(s == n_s - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def block_sparse_matmul(
    x: jnp.ndarray,              # [M, K]
    w: jnp.ndarray,              # [K, N] (zeros outside occupied blocks)
    block_mask: np.ndarray,      # STATIC [K//bk, N//bn] occupancy
    *,
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and K % bk == 0 and N % bn == 0, (K, N, bk, bn)
    bm_ = min(bm, M)
    pm = (-M) % bm_
    if pm:
        x = jnp.pad(x, ((0, pm), (0, 0)))
    Mp = x.shape[0]

    indices, counts, max_nnz = build_block_index(block_mask)

    grid = (Mp // bm_, N // bn, max_nnz)
    kernel = functools.partial(_bs_kernel, n_s=max_nnz)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm_, bk), lambda i, j, s, idx, cnt: (i, idx[j, s])),
                pl.BlockSpec((bk, bn), lambda i, j, s, idx, cnt: (idx[j, s], j)),
            ],
            out_specs=pl.BlockSpec((bm_, bn), lambda i, j, s, idx, cnt: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm_, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, N), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(indices), jnp.asarray(counts), x, w)
    return out[:M]
