"""Paper Fig. 7 + §IV-B: the synergistic stack — memory footprint and latency
proxy of the fully optimized model vs the ALBERT baseline.

Memory: bitmask-encoded AF8 weights (+12% mask overhead), 0.59MB off-ramp,
1.53KB span mask — the paper's accounting, on our toy model's actual tensors.
Latency proxy: layer-FLOPs x avg-exit-layer x span factor (the accelerator's
latency drivers), normalized to the unoptimized baseline.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, eval_accuracy, trained_albert
from repro.core import bitmask as bm
from repro.core import early_exit as ee
from repro.core.adaptivfloat import AFFormat, quantize_pytree
from repro.core.adaptive_span import hard_spans, span_flop_factor
from repro.core.pruning import apply_masks, measured_sparsity


def _footprint_bytes(params, value_bits=8) -> dict:
    import jax

    total_dense = total_sparse = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if not hasattr(leaf, "shape") or leaf.ndim < 1:
            continue
        arr = np.asarray(leaf)
        enc = bm.encode(arr)
        s = bm.storage_bytes(enc, value_bits=value_bits)
        total_dense += s["dense_bytes"]
        total_sparse += s["total_bytes"]
    return {"dense": total_dense, "sparse_encoded": total_sparse}


def main() -> None:
    # baseline: dense fp32-behaviour model, no optimizations
    model, params_base, _, data, cfg_base = trained_albert(
        phase1_steps=60, phase2_steps=40, sparsity=0.0, span_coef=0.0
    )
    base_acc = eval_accuracy(model, params_base, data)
    base_mem = _footprint_bytes(params_base, value_bits=32)["dense"]
    trained_albert.cache_clear()

    # optimized: pruned + span + early exit + AF8 + bitmask encoding
    model, params, st, data, cfg = trained_albert(
        phase1_steps=60, phase2_steps=40, sparsity=0.5, span_coef=0.02
    )
    params_q = quantize_pytree(
        params, AFFormat(8, 3),
        predicate=lambda path, leaf: "norm" not in str(path).lower(),
    )
    opt_acc = eval_accuracy(model, params_q, data)
    mem = _footprint_bytes(params_q, value_bits=8)
    sparsity = measured_sparsity(params, st)["sparsity"]

    # latency proxy on the accelerator's drivers
    b = data.batch(7000)
    out = model.apply_train(params_q, {"tokens": jnp.asarray(b["tokens"])})
    avg_exit = float(jnp.mean(out.exit_layer.astype(jnp.float32)))
    spans = hard_spans(np.asarray(params["span_z"])[0])
    span_f = span_flop_factor(spans, cfg.n_heads, 128)
    # attention score work is ~15% of layer FLOPs at S=128 on albert-base dims
    layer_factor = 0.85 + 0.15 * span_f
    latency_ratio = (avg_exit / cfg.n_layers) * layer_factor
    mem_ratio = base_mem / mem["sparse_encoded"]

    emit(
        "fig7_combined", 0.0,
        f"mem_reduction={mem_ratio:.1f}x;latency_reduction={1/latency_ratio:.2f}x;"
        f"acc_base={base_acc:.3f};acc_opt={opt_acc:.3f};sparsity={sparsity:.2f};"
        f"avg_exit={avg_exit:.2f}",
    )


if __name__ == "__main__":
    main()
