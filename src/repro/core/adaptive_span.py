"""Adaptive attention span (paper §III-B; Sukhbaatar et al. [50]).

Each head h owns a learnable scalar z_h in [0, max_span].  During fine-tuning a
soft ramp mask

    m_z(d) = clamp((ramp + z - d) / ramp, 0, 1)        d = token distance

re-modulates attention weights (d = |i-j| for bidirectional ALBERT, i-j for
causal LMs), and the mean normalized span is added to the loss.  At deployment
the spans are frozen to integers (paper Table I): a head with span 0 is skipped
entirely (the accelerator writes zeros for its context vector; we gather it out
of the computation graph), and surviving heads attend over a window of
``span`` tokens — which the Pallas kernel exploits by bounding its kv-block
loop (block-level predication, DESIGN.md §2).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def distance_matrix(q_len: int, k_len: int, causal: bool, q_offset=0) -> jnp.ndarray:
    """d[i, j] = distance from query i to key j (>= 0); causal masks j > i."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(k_len)[None, :]
    d = qi - kj
    if not causal:
        d = jnp.abs(d)
    return d  # causal: negative d means "future" -> masked by attention anyway


def span_soft_mask(
    z: jnp.ndarray,            # [n_heads] learnable spans
    q_len: int,
    k_len: int,
    ramp: int,
    causal: bool,
    q_offset=0,
) -> jnp.ndarray:
    """[n_heads, q_len, k_len] soft mask in [0, 1]."""
    d = distance_matrix(q_len, k_len, causal, q_offset).astype(jnp.float32)
    m = (ramp + z[:, None, None] - d[None]) / float(ramp)
    m = jnp.clip(m, 0.0, 1.0)
    if causal:
        m = jnp.where(d[None] < 0, 0.0, m)
    return m


def span_loss(z: jnp.ndarray, max_span: int, coef: float) -> jnp.ndarray:
    """Regularizer pushing spans down (added to the task loss during phase 1)."""
    return coef * jnp.mean(z) / float(max_span)


def clamp_spans(z: jnp.ndarray, max_span: int) -> jnp.ndarray:
    """Projection applied after each optimizer step (z stays in [0, S])."""
    return jnp.clip(z, 0.0, float(max_span))


def hard_spans(z: jnp.ndarray, threshold: float = 0.5) -> np.ndarray:
    """Deployment-time integer spans (paper Table I). z < threshold -> head off."""
    z = np.asarray(z)
    s = np.ceil(z).astype(np.int32)
    s[z < threshold] = 0
    return s


def active_head_indices(spans: Sequence[int]) -> Tuple[np.ndarray, int]:
    """Indices of heads with span > 0 and the max surviving span (window)."""
    spans = np.asarray(spans)
    idx = np.nonzero(spans > 0)[0]
    window = int(spans[idx].max()) if idx.size else 0
    return idx, window


def span_flop_factor(spans: Sequence[int], n_heads: int, seq_len: int) -> float:
    """Fraction of attention-score FLOPs retained vs full dense attention.

    Reproduces the paper's Table I claim (e.g. MNLI: 1.22x fewer total FLOPs
    for single-batch inference once 8/12 heads are off).
    """
    spans = np.asarray(spans, dtype=np.float64)
    kept = np.minimum(spans, seq_len).sum() * seq_len
    total = float(n_heads) * seq_len * seq_len
    return float(kept / total) if total else 0.0
