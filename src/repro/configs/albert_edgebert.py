"""ALBERT + full EdgeBERT optimization stack (the paper's deployed configuration).

Matches Table IV's MNLI row by default: 50% encoder MaP, 60% embedding MaP,
adaptive span (max 128), early exit T_E=0.4, AdaptivFloat 8-bit (3-bit exp),
embeddings resident in MLC2 eNVM.
"""
from dataclasses import replace

from repro.configs.albert_base import CONFIG as ALBERT
from repro.configs.base import (
    EarlyExitConfig,
    EdgeBertConfig,
    PruneConfig,
    QuantConfig,
    SpanConfig,
)

CONFIG = replace(
    ALBERT,
    name="albert-edgebert",
    edgebert=EdgeBertConfig(
        quant=QuantConfig(enabled=True, n_bits=8, n_exp=3),
        span=SpanConfig(enabled=True, max_span=128, ramp=32, loss_coef=2e-3),
        early_exit=EarlyExitConfig(enabled=True, entropy_threshold=0.4, num_classes=3),
        prune=PruneConfig(
            enabled=True,
            method="magnitude",
            encoder_sparsity=0.5,
            embedding_sparsity=0.6,
        ),
        distill_alpha=0.5,
        envm_embeddings=True,
    ),
)


def smoke_config():
    from repro.configs.albert_base import smoke_config as albert_smoke

    return replace(albert_smoke(), name="albert-edgebert-smoke", edgebert=CONFIG.edgebert)
