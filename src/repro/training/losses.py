"""Task losses: next-token LM CE, classification CE, EdgeBERT composite
(task CE + distillation + span regularizer + router aux + multi-off-ramp)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.adaptive_span import span_loss
from repro.core.distill import cross_entropy, distill_objective


def lm_loss(logits: jnp.ndarray, tokens: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """Next-token CE: logits [B, S, V] predict tokens shifted left."""
    tgt = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    lp = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(lg, -1) == tgt).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}


def cls_loss(cls_logits: jnp.ndarray, labels: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    loss = cross_entropy(cls_logits, labels)
    acc = jnp.mean((jnp.argmax(cls_logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}


def offramp_loss(all_cls_logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Phase-2 (DeeBERT): sum of CE over every off-ramp layer [L, B, C]."""
    L = all_cls_logits.shape[0]
    losses = jax.vmap(lambda lg: cross_entropy(lg, labels))(all_cls_logits)
    return jnp.sum(losses)


def edgebert_phase1_loss(
    cls_logits: jnp.ndarray,
    labels: jnp.ndarray,
    *,
    teacher_logits: Optional[jnp.ndarray] = None,
    distill_alpha: float = 0.0,
    span_z: Optional[jnp.ndarray] = None,
    max_span: int = 128,
    span_coef: float = 0.0,
    aux: jnp.ndarray = 0.0,
) -> Tuple[jnp.ndarray, Dict]:
    """Paper Fig. 6 phase 1: task CE (+KD) while pruning + span learning."""
    if teacher_logits is not None and distill_alpha > 0:
        task = distill_objective(cls_logits, teacher_logits, labels, distill_alpha)
    else:
        task = cross_entropy(cls_logits, labels)
    total = task + aux
    metrics = {"task_loss": task}
    if span_z is not None and span_coef > 0:
        sl = span_loss(span_z, max_span, span_coef)
        total = total + sl
        metrics["span_loss"] = sl
        metrics["mean_span"] = jnp.mean(span_z)
    acc = jnp.mean((jnp.argmax(cls_logits.astype(jnp.float32), -1) == labels).astype(jnp.float32))
    metrics.update({"loss": total, "acc": acc})
    return total, metrics
