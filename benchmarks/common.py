"""Shared benchmark utilities: timing, CSV emission, a trained toy EdgeBERT,
and the versioned bounded-history benchmark artifact."""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import subprocess
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")
os.makedirs(RESULTS_DIR, exist_ok=True)

_rows: List[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.2f},{derived}"
    _rows.append(row)
    print(row, flush=True)


def all_rows() -> List[str]:
    return list(_rows)


def git_tag() -> str:
    """``git describe --always --dirty`` of the repo, or "unknown" outside
    git — stamps benchmark-history entries so regressions bisect to a ref."""
    try:
        out = subprocess.run(
            ["git", "describe", "--always", "--dirty"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
        tag = out.stdout.strip()
        return tag if out.returncode == 0 and tag else "unknown"
    except Exception:
        return "unknown"


BENCH_HISTORY_LIMIT = 20

# every history entry must carry these so CI can diff like with like;
# missing keys fail the append LOUDLY instead of silently polluting history
BENCH_ENTRY_REQUIRED_KEYS = ("scenario", "backend", "device_count", "tag")


def validate_bench_entry(entry: Dict) -> Dict:
    """Schema-check one benchmark-history entry; raises ``ValueError`` on a
    malformed entry (wrong type, missing identity keys, or non-JSON-safe
    payload) so a bad run fails the append instead of corrupting the
    trajectory."""
    if not isinstance(entry, dict):
        raise ValueError(f"bench entry must be a dict, got {type(entry).__name__}")
    missing = [k for k in BENCH_ENTRY_REQUIRED_KEYS if k not in entry]
    if missing:
        raise ValueError(f"bench entry missing required keys: {missing}")
    if not isinstance(entry["scenario"], str) or not entry["scenario"]:
        raise ValueError("bench entry 'scenario' must be a non-empty string")
    if not isinstance(entry["tag"], str) or not entry["tag"]:
        raise ValueError("bench entry 'tag' must be a non-empty string")
    if not isinstance(entry["device_count"], int) or entry["device_count"] < 1:
        raise ValueError("bench entry 'device_count' must be a positive int")
    try:
        json.dumps(entry, sort_keys=True)
    except (TypeError, ValueError) as e:
        raise ValueError(f"bench entry is not JSON-serializable: {e}") from e
    return entry


def diff_bench_entries(prev: Dict, new: Dict) -> List[str]:
    """Human-readable newest-vs-previous diff lines over shared numeric
    scalar keys (identity keys skipped); booleans are compared as flips."""
    lines: List[str] = []
    skip = set(BENCH_ENTRY_REQUIRED_KEYS)
    for k in sorted(set(prev) & set(new)):
        if k in skip:
            continue
        a, b = prev[k], new[k]
        if isinstance(a, bool) or isinstance(b, bool):
            if a != b:
                lines.append(f"  {k}: {a} -> {b}")
            continue
        if not (isinstance(a, (int, float)) and isinstance(b, (int, float))):
            continue
        if a == b:
            continue
        rel = f" ({(b - a) / a:+.1%})" if a else ""
        lines.append(f"  {k}: {a:g} -> {b:g}{rel}")
    return lines


def append_bench_history(path: str, entry: Dict, *, limit: int = BENCH_HISTORY_LIMIT) -> Dict:
    """Append one run to a versioned benchmark artifact instead of
    overwriting it.

    The artifact is ``{"version": 2, "history": [entry, ...]}`` with the
    NEWEST entry last and the list bounded to ``limit`` (oldest dropped), so
    CI can diff the newest entry against the previous comparable one rather
    than only shape-checking a single overwritten snapshot.  A legacy flat
    v1 payload found at ``path`` is migrated in place as the history's first
    entry (tagged ``pre-history``).  Every entry must pass
    ``validate_bench_entry`` (carry ``scenario``, ``backend``,
    ``device_count``, ``tag``) so diffs compare like with like — a malformed
    entry raises instead of silently dropping into history.  After the
    append, the newest entry is diffed against the previous entry of the
    SAME scenario (if any) and the numeric deltas are printed.  Returns the
    payload written."""
    validate_bench_entry(entry)
    history: List[Dict] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
        except Exception:
            old = None
        if isinstance(old, dict):
            if isinstance(old.get("history"), list) and old.get("version", 0) >= 2:
                history = [e for e in old["history"] if isinstance(e, dict)]
            elif old.get("version") == 1:
                old = dict(old)
                old.pop("version", None)
                old.setdefault("scenario", "pallas_serving")
                old.setdefault("device_count", 1)
                old.setdefault("tag", "pre-history")
                history = [old]
    prev = next(
        (e for e in reversed(history) if e.get("scenario") == entry.get("scenario")),
        None,
    )
    history.append(entry)
    history = history[-max(int(limit), 1):]
    payload = {"version": 2, "history": history}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    name = os.path.basename(path)
    if prev is not None:
        lines = diff_bench_entries(prev, entry)
        print(
            f"[bench-history] {name}: {entry['scenario']} "
            f"{prev.get('tag', '?')} -> {entry['tag']} "
            f"({len(lines)} metric(s) changed)",
            flush=True,
        )
        for ln in lines:
            print(ln, flush=True)
    else:
        print(
            f"[bench-history] {name}: first '{entry['scenario']}' entry "
            f"@ {entry['tag']} ({len(history)} total)",
            flush=True,
        )
    return payload


def time_us(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


@functools.lru_cache(maxsize=4)
def trained_albert(phase1_steps: int = 60, phase2_steps: int = 40, seed: int = 0,
                   sparsity: float = 0.5, method: str = "magnitude",
                   span_coef: float = 0.02):
    """A phase-1+2 trained smoke-size ALBERT-EdgeBERT (cached per-process)."""
    from repro.configs.base import PruneConfig, SpanConfig, get_smoke_config
    from repro.data.synthetic import SyntheticCLS
    from repro.models.model import build_model
    from repro.training.optim import AdamWConfig
    from repro.training.train_loop import EdgeBertTrainer, TrainerConfig

    cfg = get_smoke_config("albert_edgebert")
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    cfg = cfg.with_edgebert(
        prune=PruneConfig(enabled=sparsity > 0, method=method,
                          encoder_sparsity=sparsity, embedding_sparsity=0.6,
                          end_step=max(phase1_steps - 10, 1), update_every=5),
        span=SpanConfig(enabled=True, max_span=128, ramp=16,
                        loss_coef=span_coef, init_span=96.0),
    )
    model = build_model(cfg)
    data = SyntheticCLS(cfg.vocab_size, 32, 16, num_classes=3, seed=seed)
    trainer = EdgeBertTrainer(
        model,
        TrainerConfig(phase1_steps=phase1_steps, phase2_steps=phase2_steps,
                      opt=AdamWConfig(lr=2e-3, warmup_steps=5,
                                      total_steps=phase1_steps + phase2_steps,
                                      span_lr_mult=300.0)),
    )
    params = model.init_params(jax.random.PRNGKey(seed))
    params, prune_state, _ = trainer.phase1(params, data, log_every=10_000)
    if phase2_steps:
        params, _ = trainer.phase2(params, data)
    return model, params, prune_state, data, cfg


def eval_accuracy(model, params, data, n_batches: int = 4, start: int = 5000) -> float:
    accs = []
    for i in range(n_batches):
        b = data.batch(start + i)
        batch = {"tokens": jnp.asarray(b["tokens"])}
        out = model.apply_train(params, batch)
        logits = (
            out.all_cls_logits[-1] if out.all_cls_logits is not None else out.cls_logits
        )
        accs.append(float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(b["labels"]))))
    return float(np.mean(accs))
