"""Mixture-of-Experts layer with sort-based static-capacity dispatch.

Tokens are flattened, their top-k expert assignments sorted by expert id, and
gathered into a dense [E, C, d] buffer that is batch-matmul'd against stacked
expert weights — the TPU-native formulation: the [tokens] -> [E, C, d]
resharding is where XLA inserts the all-to-all when experts are sharded over
the `model` mesh axis (EP).  Overflowing tokens beyond capacity C are dropped
(their residual passes through), standard GShard/Switch semantics.

qwen2-moe additionally has a dense shared expert applied to every token.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.jax_compat import shard_map
from repro.models.layers import dense_init

Params = Dict[str, Any]


def init_moe(rng, cfg, dtype) -> Params:
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),  # router kept fp32+dense
        "w_gate": dense_init(ks[1], (E, d, ff), dtype),
        "w_up": dense_init(ks[2], (E, d, ff), dtype),
        "w_down": dense_init(ks[3], (E, ff, d), dtype),
    }
    if cfg.shared_expert_d_ff:
        sks = jax.random.split(ks[4], 4)
        sff = cfg.shared_expert_d_ff
        p["shared"] = {
            "w_gate": dense_init(sks[0], (d, sff), dtype),
            "w_up": dense_init(sks[1], (d, sff), dtype),
            "w_down": dense_init(sks[2], (sff, d), dtype),
            "gate_proj": dense_init(sks[3], (d, 1), dtype),  # qwen2-moe shared gate
        }
    return p


def _expert_ffn(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """x: [E, C, d] -> [E, C, d] via per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", x, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def apply_moe(
    p: Params,
    x: jnp.ndarray,               # [B, S, d]
    cfg,
    capacity_factor: float = 1.25,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output [B, S, d], router aux loss)."""
    if getattr(cfg, "moe_shardmap_dispatch", False):
        return apply_moe_shardmap(p, x, cfg, capacity_factor)
    if getattr(cfg, "moe_grouped_dispatch", False):
        # group by batch row: sorts/cumsums stay local to the data shard
        # (vmapped over B, which is batch-sharded) -> no global-argsort
        # all-gathers; only the [E, C, d] expert reshard moves data (§Perf)
        vmap_kw = {}
        if getattr(cfg, "moe_buffer_sharded", False):
            # spmd_axis_name keeps the vmapped group dim sharded through the
            # in-body sharding constraint: buffer [G, E, C, d] pinned to
            # P(batch, model, None, None) (§Perf qwen3 iteration 3)
            ba = getattr(cfg, "sp_batch_axes", ("data",))
            vmap_kw["spmd_axis_name"] = ba if len(ba) > 1 else ba[0]
        y, aux = jax.vmap(
            lambda xb: _moe_tokens(p, xb, cfg, capacity_factor), **vmap_kw
        )(x)
        return y, jnp.mean(aux)
    y, aux = _moe_tokens(p, x.reshape(-1, x.shape[-1]), cfg, capacity_factor)
    return y.reshape(x.shape), aux


def apply_moe_shardmap(
    p: Params,
    x: jnp.ndarray,               # [B, S, d]
    cfg,
    capacity_factor: float = 1.25,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Explicit-collective EP dispatch (§Perf qwen3 iteration 5).

    Under this framework's layout, activations are REPLICATED along the model
    axis (TP shards weights, not the residual stream), so EP dispatch needs no
    all-to-all at all: every model shard routes its (identical) data-shard
    tokens against the full router, slices out the assignments that hit ITS
    experts, runs them, and a single psum over `model` merges the per-expert
    partial outputs. Collective cost per layer = ONE all-reduce of [n, d]
    activations — vs the SPMD partitioner's gathered-dispatch trainwreck.

    Requires a mesh context (jax.sharding.use_mesh / `with mesh:`); experts
    must divide the model axis; no shared expert inside the region (qwen2's
    shared expert runs densely outside).
    """
    from jax.sharding import PartitionSpec as P

    mesh = jax.sharding.get_abstract_mesh()
    axis_sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    m_size = axis_sizes.get("model", 1)
    E = cfg.n_experts
    assert E % m_size == 0, "shard_map EP needs experts % model == 0"
    ba = tuple(a for a in getattr(cfg, "sp_batch_axes", ("data",)) if a in axis_sizes)
    batch_spec = ba if len(ba) > 1 else (ba[0] if ba else None)
    all_axes = tuple(ba) + (("model",) if "model" in axis_sizes else ())

    routed = {k: p[k] for k in ("router", "w_gate", "w_up", "w_down")}

    def local(p_loc, x_loc):
        B_l, S, d = x_loc.shape
        xt = x_loc.reshape(-1, d)
        e_loc = E // m_size
        e_off = jax.lax.axis_index("model") * e_loc if m_size > 1 else 0
        y, aux = _moe_tokens(
            dict(p_loc, router=p_loc["router"]), xt, cfg, capacity_factor,
            local_expert_range=(e_off, e_loc),
        )
        if m_size > 1:
            y = jax.lax.psum(y, "model")
        if ba:
            aux = jax.lax.pmean(aux, ba if len(ba) > 1 else ba[0])
        return y.reshape(B_l, S, d), aux

    y, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            {
                "router": P(),
                "w_gate": P("model", None, None),
                "w_up": P("model", None, None),
                "w_down": P("model", None, None),
            },
            P(batch_spec, None, None),
        ),
        out_specs=(P(batch_spec, None, None), P()),
    )(routed, x)

    if "shared" in p:
        sp = p["shared"]
        xt = x.reshape(-1, x.shape[-1])
        g = xt @ sp["w_gate"]
        u = xt @ sp["w_up"]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
        shared_out = h @ sp["w_down"]
        sgate = jax.nn.sigmoid((xt @ sp["gate_proj"]).astype(jnp.float32)).astype(xt.dtype)
        y = y + (sgate * shared_out).reshape(x.shape)
    return y, aux


def _moe_tokens(
    p: Params,
    xt: jnp.ndarray,              # [N, d] flat tokens
    cfg,
    capacity_factor: float = 1.25,
    local_expert_range: Optional[Tuple[Any, int]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    d = xt.shape[-1]
    E, k = cfg.n_experts, cfg.top_k
    N = xt.shape[0]

    router_logits = (xt.astype(jnp.float32)) @ p["router"]          # [N, E]
    probs = jax.nn.softmax(router_logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, k)                      # [N, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- load-balancing aux loss (Switch) ---
    me = jnp.mean(probs, axis=0)                                     # [E]
    onehot_top1 = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=0)
    aux = E * jnp.sum(me * ce)

    # --- sort assignments by expert ---
    C = max(int(N * k * capacity_factor / E), 4)
    flat_expert = expert_idx.reshape(-1)                             # [N*k]
    flat_token = jnp.repeat(jnp.arange(N), k)
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se = flat_expert[order]
    st = flat_token[order]
    sg = flat_gate[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_expert].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(N * k) - starts[se]
    valid = pos_in_expert < C
    if local_expert_range is not None:
        # shard_map EP: this shard owns experts [e_off, e_off + e_loc)
        e_off, e_loc = local_expert_range
        se_local = se - e_off
        valid = valid & (se_local >= 0) & (se_local < e_loc)
        dest = jnp.where(valid, se_local * C + pos_in_expert, e_loc * C)
        n_buf = e_loc * C
        buf_experts = e_loc
    else:
        dest = jnp.where(valid, se * C + pos_in_expert, E * C)      # last = drop
        n_buf = E * C
        buf_experts = E

    # --- gather to [buf_experts, C, d] ---
    buf = jnp.zeros((n_buf + 1, d), xt.dtype).at[dest].set(xt[st])
    expert_in = buf[:n_buf].reshape(buf_experts, C, d)
    if getattr(cfg, "moe_buffer_sharded", False) and local_expert_range is None:
        # pin the dispatch buffer to expert-sharding (model axis); without
        # this the vmapped-group buffer replicates across the data axis and
        # the EP all-to-all balloons ~dp-fold (§Perf qwen3 iteration 2)
        from jax.sharding import PartitionSpec as P

        expert_in = jax.lax.with_sharding_constraint(expert_in, P("model", None, None))
    expert_out = _expert_ffn(p, expert_in).reshape(n_buf, d)
    expert_out = jnp.concatenate([expert_out, jnp.zeros((1, d), xt.dtype)], axis=0)

    # --- combine back ---
    contrib = expert_out[dest] * sg[:, None].astype(xt.dtype)
    y = jnp.zeros((N, d), xt.dtype).at[st].add(jnp.where(valid[:, None], contrib, 0))

    if "shared" in p:
        sp = p["shared"]
        g = xt @ sp["w_gate"]
        u = xt @ sp["w_up"]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
        shared_out = h @ sp["w_down"]
        sgate = jax.nn.sigmoid((xt @ sp["gate_proj"]).astype(jnp.float32)).astype(xt.dtype)
        y = y + sgate * shared_out

    return y, aux
