"""Analytical model of the EdgeBERT accelerator (paper §V-VI).

First-order energy/latency model of the 12nm/500MHz design, calibrated to the
paper's measured anchors (Table V breakdown at MAC vector size n=16; Fig. 10
energy-optimal n=16; Fig. 11 eNVM power-on advantage) and driven by *measured*
workload statistics from the JAX model (FLOPs, sparsity, spans, exit layers).

The model reproduces the paper's hardware evaluation methodology:
  * PU: n^2 8-bit FP MACs -> matmul cycles = MACs / n^2 at 500 MHz; datapath
    power grows ~n^2 with a wiring/accumulator overhead term alpha*n that
    makes n=32 subdue its latency gains (paper Fig. 10);
  * zero-skip: sparsity leaves the cycle count unchanged (fixed scheduling)
    but gates VMAC energy — up to the paper's 2.6x energy saving;
  * adaptive span: heads with span 0 are skipped outright (predication);
    surviving heads' score/context MACs scale with span/S;
  * early exit: everything scales with avg_exit_layer / n_layers; the entropy
    unit adds its (measured-negligible, 0.02-0.78%) latency;
  * GB peripherals (softmax/LN/entropy): vector ops at `vpu_lanes`/cycle;
  * memories: per-access energies for SRAM / ReRAM(MLC2) / LPDDR4 DRAM.

All constants are module-level and documented; anchors marked [TableV]/[Fig10]
/[Fig11] are fitted to the paper's reported numbers.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional

CLOCK_HZ = 500e6
VDD_NOM = 0.80               # 12nm nominal supply; the DVFS table (serving/
                             # dvfs.py) scales 0.50-0.80V via the on-die LDO

# ---- power (mW) anchors at n=16 [TableV] ----
PU_DATAPATH_MW_N16 = 40.26
GB_PERIPH_MW = 6.13
SRAM_MW = 60.67
RERAM_MW = 3.48
ALPHA_WIRE = 0.06            # datapath wiring/accumulator overhead growth:
                             # calibrated so the energy optimum lands at n=16
                             # (paper Fig. 10: n=32's power subdues its gains)

# ---- area (mm^2) anchors at n=16 [TableV] ----
PU_AREA_N16 = 0.45
GB_AREA = 0.41
SRAM_AREA = 4.10
RERAM_AREA = 0.15

# ---- memory access energies (pJ/byte), 12nm-class estimates ----
E_SRAM_PJ_B = 0.8            # large SRAM banks
E_RERAM_READ_PJ_B = 2.0      # MLC2 ReRAM read
E_DRAM_PJ_B = 160.0          # LPDDR4 access incl. PHY/controller
DRAM_LATENCY_S_PER_MB = 3.2e-4   # effective streaming incl. wakeup [Fig11 ~50x]
RERAM_LATENCY_S_PER_MB = 6.5e-6  # dense parallel read arrays
# LPDDR4 power-cycle overhead: self-refresh exit + controller/PHY init +
# activate energy after SoC power-on (DRAMsim3 thermally-aware run in the
# paper) — the term that makes Fig. 11's energy gap ~4 orders of magnitude
DRAM_POWERON_ENERGY_J = 0.25     # [Fig11 anchor ~66,000x at 1.94MB]

# ---- mGPU (Jetson TX2) anchors [Fig10: ~163x energy vs n=16 optimized] ----
MGPU_POWER_W = 7.5
MGPU_EFF_GFLOPS = 120.0      # effective (not peak) FP16 throughput on BERT-ish
MGPU_LATENCY_OVERHEAD_S = 2.0e-3  # kernel-launch/serial logic per sentence

# ---- DVFS operating-point switching (paper §IV: the single on-die fast-
# switching LDO + ADPLL pair; transitions are sub-us, but a SHARED clock means
# every (V, f) change stalls all in-flight lanes, so batched arbitration must
# charge it per change, not per sentence) ----
LDO_STEP_V = 0.025               # LDO programmable voltage step granularity
LDO_SETTLE_S_PER_STEP = 25e-9    # per-25mV settle (full 0.5->0.8V swing ~300ns)
ADPLL_RELOCK_S = 0.5e-6          # ADPLL frequency retarget lock time
SWITCH_IDLE_POWER_FRAC = 0.30    # fraction of nominal power burned while the
                                 # datapath stalls during a transition

VPU_LANES = 8                # GB vector unit effective width
GB_CONTROL_CYCLES = 30000    # per layer-pass: bitmask encode/decode streaming,
                             # AXI handshakes, span-register checks — n-independent
                             # (gives the paper's ~3.5x latency per n-doubling
                             # instead of an idealized 4x)


@dataclass
class WorkloadStats:
    """Measured statistics for ONE task inference (from the JAX model)."""
    matmul_flops: float               # dense encoder matmul FLOPs per layer-pass
    attention_score_flops: float      # span-affected score+context FLOPs/layer
    vector_elems: float               # softmax/LN/add elems per layer-pass
    n_layers: int = 12
    seq_len: int = 128
    avg_exit_layer: float = 12.0
    span_factor: float = 1.0          # fraction of score FLOPs retained (Table I)
    heads_active_frac: float = 1.0    # fraction of heads with span > 0
    weight_sparsity: float = 0.0
    act_sparsity: float = 0.0
    model_bytes: float = 11e6         # encoder weights resident in SRAM
    embedding_bytes: float = 1.73e6   # paper's compact multi-task baseline


@dataclass
class AccelReport:
    latency_s: float
    energy_j: float
    breakdown_mw: Dict[str, float]
    area_mm2: Dict[str, float]
    entropy_overhead_frac: float


def pu_power_mw(n: int) -> float:
    """Datapath power ~ n^2 * (1 + alpha*n), anchored at n=16 [TableV]."""
    base = PU_DATAPATH_MW_N16 / (16 ** 2 * (1 + ALPHA_WIRE * 16))
    return base * n ** 2 * (1 + ALPHA_WIRE * n)


def pu_area_mm2(n: int) -> float:
    return PU_AREA_N16 * (n / 16) ** 2


def layer_cycles(stats: WorkloadStats, n: int = 16, *, use_span: bool = True) -> float:
    """Accelerator cycles for ONE encoder layer pass (frequency-independent).

    This is the quantity the DVFS controller needs: at operating frequency f
    the per-layer latency is ``layer_cycles / f`` regardless of voltage.
    """
    mm_flops = stats.matmul_flops
    score_flops = stats.attention_score_flops
    if use_span:
        score_flops = score_flops * stats.span_factor
        # QKV/output projections of fully-off heads are skipped too
        mm_flops = mm_flops * (
            0.5 + 0.5 * stats.heads_active_frac  # ~half of encoder matmul FLOPs
        )                                         # are attention projections
    macs_per_layer = (mm_flops + score_flops) / 2.0
    matmul_cycles = macs_per_layer / (n ** 2)
    vector_cycles = stats.vector_elems / VPU_LANES
    layer = matmul_cycles + vector_cycles + entropy_cycles(stats) + GB_CONTROL_CYCLES
    return layer


def scale_stats_to_seq_len(stats: WorkloadStats, seq_len: int) -> WorkloadStats:
    """Rescale one layer's workload statistics to a different sequence length.

    Per-token intensities are preserved: encoder matmul FLOPs and vector
    elements scale linearly with tokens, attention score/context FLOPs
    quadratically.  This is how the DVFS layer derives PER-BUCKET cycle
    models from a single measured/analytic ``WorkloadStats`` — a 32-token
    bucket's lanes get budgeted (deadline AND energy) at 32-token cost
    instead of the largest bucket's.
    """
    assert seq_len >= 1 and stats.seq_len >= 1
    r = seq_len / stats.seq_len
    return replace(
        stats,
        matmul_flops=stats.matmul_flops * r,
        attention_score_flops=stats.attention_score_flops * r * r,
        vector_elems=stats.vector_elems * r,
        seq_len=int(seq_len),
    )


def entropy_cycles(stats: WorkloadStats) -> float:
    """GB-unit cycles for one off-ramp softmax+entropy evaluation (Eq. 4)."""
    return (3 * 32 + stats.seq_len) / VPU_LANES


def accel_power_mw(stats: WorkloadStats, n: int = 16, *, use_sparsity: bool = True) -> Dict[str, float]:
    """Total + per-block power at the NOMINAL operating point (VDD_NOM, CLOCK_HZ)."""
    pu_mw = pu_power_mw(n)
    # SRAM power scales with the streaming duty cycle (reads per cycle ~ n)
    sram_mw = SRAM_MW * (0.5 + 0.5 * n / 16)
    if use_sparsity:
        # zero-skip gates VMAC energy [§V-C]; bitmask-compressed weights also
        # skip the SRAM reads of zero entries — scheduling (latency) unchanged
        nz = (1.0 - stats.weight_sparsity) * (1.0 - 0.3 * stats.act_sparsity)
        pu_mw_eff = pu_mw * max(nz, 1.0 / 2.6)
        sram_mw = sram_mw * max(0.4 + 0.6 * (1.0 - stats.weight_sparsity), 1.0 / 2.6)
    else:
        pu_mw_eff = pu_mw
    total_mw = pu_mw_eff + GB_PERIPH_MW + sram_mw + RERAM_MW
    return {
        "pu_datapath": pu_mw_eff,
        "gb_periph": GB_PERIPH_MW,
        "sram": sram_mw,
        "reram": RERAM_MW,
        "total": total_mw,
    }


def layer_energy_j(
    stats: WorkloadStats,
    n: int = 16,
    *,
    vdd: float = VDD_NOM,
    use_span: bool = True,
    use_sparsity: bool = True,
) -> float:
    """Energy of ONE layer pass at supply ``vdd``.

    Dynamic CMOS energy per cycle scales ~VDD^2 and is frequency-independent
    (E = P*t = [P0 * (V/V0)^2 * f/f0] * [cycles/f] = E0 * (V/V0)^2), which is
    exactly the knob the paper's sentence-level DVFS exploits: finishing *just
    in time* at a lower voltage is quadratically cheaper than racing to idle.
    """
    cyc = layer_cycles(stats, n, use_span=use_span)
    p_nom_mw = accel_power_mw(stats, n, use_sparsity=use_sparsity)["total"]
    return p_nom_mw * 1e-3 * (cyc / CLOCK_HZ) * (vdd / VDD_NOM) ** 2


def simulate(
    stats: WorkloadStats,
    n: int = 16,
    *,
    use_early_exit: bool = True,
    use_span: bool = True,
    use_sparsity: bool = True,
    freq_hz: float = CLOCK_HZ,
    vdd: float = VDD_NOM,
) -> AccelReport:
    """Latency + energy for one sentence inference at an operating point.

    ``freq_hz``/``vdd`` default to the nominal design point [TableV]; passing
    a DVFS table entry scales latency as cycles/f and power as (V/V0)^2 * f/f0
    (so energy scales purely as (V/V0)^2).
    """
    layers = stats.avg_exit_layer if use_early_exit else stats.n_layers

    per_layer = layer_cycles(stats, n, use_span=use_span)
    total_cycles = layers * per_layer
    latency = total_cycles / freq_hz

    # --- power/energy ---
    op_scale = (vdd / VDD_NOM) ** 2 * (freq_hz / CLOCK_HZ)
    power = accel_power_mw(stats, n, use_sparsity=use_sparsity)
    pu_mw_eff = power["pu_datapath"] * op_scale
    sram_mw = power["sram"] * op_scale
    gb_mw = GB_PERIPH_MW * op_scale
    reram_mw = RERAM_MW * op_scale
    total_mw = power["total"] * op_scale
    energy = total_mw * 1e-3 * latency

    return AccelReport(
        latency_s=latency,
        energy_j=energy,
        breakdown_mw={
            "pu_datapath": pu_mw_eff,
            "gb_periph": gb_mw,
            "sram": sram_mw,
            "reram": reram_mw,
            "total": total_mw,
        },
        area_mm2={
            "pu_datapath": pu_area_mm2(n),
            "gb_periph": GB_AREA,
            "sram": SRAM_AREA,
            "reram": RERAM_AREA,
            "total": pu_area_mm2(n) + GB_AREA + SRAM_AREA + RERAM_AREA,
        },
        entropy_overhead_frac=(layers * entropy_cycles(stats)) / total_cycles,
    )


def simulate_mgpu(stats: WorkloadStats, *, use_early_exit=True, use_span=True) -> Dict[str, float]:
    """Jetson TX2 baseline: same workload, GPU constants; conditional/serial
    logic (span predication, exit checks) runs on the embedded CPU — modeled
    as per-layer overhead the accelerator does not pay [§VI-B]."""
    layers = stats.avg_exit_layer if use_early_exit else stats.n_layers
    score = stats.attention_score_flops * (stats.span_factor if use_span else 1.0)
    flops = layers * (stats.matmul_flops + score)
    latency = flops / (MGPU_EFF_GFLOPS * 1e9) + layers * MGPU_LATENCY_OVERHEAD_S / 12.0
    energy = MGPU_POWER_W * latency
    return {"latency_s": latency, "energy_j": energy}


def op_switch_overhead(
    vdd_from: float,
    freq_from_hz: float,
    vdd_to: float,
    freq_to_hz: float,
    *,
    power_mw_nom: float,
) -> Dict[str, float]:
    """Latency + energy of one LDO/ADPLL operating-point transition.

    The LDO walks ``|dV| / LDO_STEP_V`` 25mV steps; a frequency retarget adds
    one ADPLL relock.  During the transition the accelerator stalls at an idle
    power fraction of ``power_mw_nom`` (the workload's nominal total power).
    Identical points cost zero — callers charge this ONLY on a change.
    """
    steps = round(abs(vdd_to - vdd_from) / LDO_STEP_V)
    t = steps * LDO_SETTLE_S_PER_STEP
    if freq_to_hz != freq_from_hz:
        t += ADPLL_RELOCK_S
    return {
        "time_s": t,
        "energy_j": power_mw_nom * 1e-3 * SWITCH_IDLE_POWER_FRAC * t,
    }


def poweron_embedding_cost(embedding_bytes: float, bitmask_bytes: float) -> Dict[str, float]:
    """Fig. 11: read all embeddings after power-on.

    EdgeBERT: embeddings pre-loaded in integrated ReRAM -> a single ReRAM read.
    Conventional: DRAM read, SRAM write, then SRAM read (for first use).
    """
    total = embedding_bytes + bitmask_bytes
    envm_latency = total / 1e6 * RERAM_LATENCY_S_PER_MB
    envm_energy = total * E_RERAM_READ_PJ_B * 1e-12
    conv_latency = total / 1e6 * DRAM_LATENCY_S_PER_MB
    # DRAM read + SRAM write + SRAM read + power-cycle overhead
    conv_energy = (
        total * (E_DRAM_PJ_B + 2 * E_SRAM_PJ_B) * 1e-12 + DRAM_POWERON_ENERGY_J
    )
    return {
        "envm_latency_s": envm_latency,
        "envm_energy_j": envm_energy,
        "conventional_latency_s": conv_latency,
        "conventional_energy_j": conv_energy,
        "latency_advantage": conv_latency / envm_latency,
        "energy_advantage": conv_energy / envm_energy,
    }


def task_swap_cost(weight_bytes: float, bitmask_bytes: float) -> Dict[str, float]:
    """Switch-in cost of one non-resident task's weight set (§III-D applied
    to TASK weights instead of embeddings).

    The multi-task deployment keeps every task's bitmask-compressed
    encoder/classifier weights in eNVM; a bounded SRAM working set holds the
    resident tasks.  Serving a non-resident task streams its sparse-encoded
    footprint (values + bitmask) out of ReRAM into SRAM — a dense parallel
    read plus an SRAM write, charged on the shared modeled clock as a swap
    stall.  Evictions are free: task weights are read-only, so there is no
    write-back.
    """
    total = weight_bytes + bitmask_bytes
    return {
        "latency_s": total / 1e6 * RERAM_LATENCY_S_PER_MB,
        "energy_j": total * (E_RERAM_READ_PJ_B + E_SRAM_PJ_B) * 1e-12,
        "bytes": total,
    }


def albert_layer_stats(seq_len: int = 128, d: int = 768, ff: int = 3072, heads: int = 12) -> WorkloadStats:
    """Analytic ALBERT-base encoder layer workload (paper Fig. 8: ~1.9 GFLOP
    for the 12-layer pass at S=128 => ~158 MFLOP/layer)."""
    mm = 2 * seq_len * d * (3 * d) + 2 * seq_len * d * d + 2 * seq_len * d * ff * 2
    score = 2 * 2 * seq_len * seq_len * d
    vec = seq_len * (2 * d + heads * seq_len + 4 * d)
    return WorkloadStats(
        matmul_flops=float(mm),
        attention_score_flops=float(score),
        vector_elems=float(vec),
        seq_len=seq_len,
    )
