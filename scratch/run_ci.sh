#!/usr/bin/env bash
# Tier-1 CI: unit-test suite + DVFS-benchmark smoke passes.
#
#   bash scratch/run_ci.sh
#
# The suite must COLLECT cleanly with or without `hypothesis` installed
# (property tests skip when it's absent — see tests/hypothesis_compat.py).
# Two benchmark smoke passes assert the paper's headline results end-to-end:
#   * bench_dvfs:          lower energy than the no-early-exit baseline at
#                          equal target latency (per-sentence Alg. 1);
#   * bench_batched_dvfs:  shared-clock arbitration (one LDO/ADPLL) below
#                          per-sentence max-V/f replay at equal target
#                          latency, with exactly one compile per length
#                          bucket — including the INTERLEAVED EDF scenario
#                          (late tight-SLO shorts preempting a deep drain).
# Grep-gates re-check the emitted telemetry even if the benchmark's own
# asserts were loosened:
#   * EVERY `step_traces=N;bucket_count=M` pair (sequential drain,
#     interleaved stepping AND the preemption-enabled admission storm) must
#     satisfy N <= M — N > M means the fused step recompiled inside a
#     bucket;
#   * `edf_deadline_misses=K` from the interleaved scenario must be 0 —
#     a tight per-request SLO admitted mid-drain may not be missed;
#   * admission storm: `accepted_slo_misses` must be 0 (an admitted SLO is a
#     contract), `rejected` must be > 0 (the storm IS oversubscribed — the
#     infeasible tail must be refused at submit time, not accepted and
#     missed), and `best_effort_completed` must be > 0 (the bounded queue
#     sheds instead of letting contracts starve best-effort forever);
#   * decode early exit: under the mixed classifier+decoder storm,
#     `exit_beats_full` must be 1 (per-token exit strictly cheaper than
#     full-depth decode) at 0 accepted-SLO misses on BOTH decode runs;
#   * pallas serving step: `parity=1` and `exit_parity=1` (use_pallas=True
#     numerically interchangeable with the ref path over a full drain) at
#     `pallas_slo_misses=0`, and the run must write a well-formed versioned
#     BENCH_serving.json (step wall-clock p50/p95, energy/request,
#     accepted-SLO miss rate, trace counts, ref-vs-pallas speedup).  No
#     speedup gate: on CPU the kernels run in interpret mode.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -q
tier1=$?

echo "== bench_dvfs --smoke =="
python benchmarks/bench_dvfs.py --smoke
smoke=$?

echo "== bench_batched_dvfs --smoke =="
batched_log=$(mktemp)
python benchmarks/bench_batched_dvfs.py --smoke | tee "$batched_log"
batched=$?

echo "== grep-gate: step_traces <= bucket_count (all scenarios) =="
gate=0
pairs=$(grep -o 'step_traces=[0-9]*;bucket_count=[0-9]*' "$batched_log")
if [ -z "$pairs" ]; then
    echo "GATE FAIL: no step_traces/bucket_count telemetry emitted"
    gate=1
else
    npairs=0
    while IFS= read -r pair; do
        npairs=$((npairs + 1))
        traces=${pair#step_traces=}; traces=${traces%%;*}
        count=${pair##*bucket_count=}
        if [ "$traces" -gt "$count" ]; then
            echo "GATE FAIL: fused step traced ${traces}x for ${count} buckets"
            gate=1
        else
            echo "gate ok: ${traces} traces / ${count} buckets"
        fi
    done <<< "$pairs"
    if [ "$npairs" -lt 4 ]; then
        echo "GATE FAIL: expected trace telemetry from the sequential, the"
        echo "           interleaved, the admission-storm AND the"
        echo "           decode-early-exit scenario, got ${npairs} pair(s)"
        gate=1
    fi
fi

echo "== grep-gate: edf_deadline_misses == 0 =="
edf=$(grep -o 'edf_deadline_misses=[0-9]*' "$batched_log" | head -1)
if [ -z "$edf" ]; then
    echo "GATE FAIL: no edf_deadline_misses telemetry emitted (interleaved"
    echo "           EDF scenario missing from bench_batched_dvfs)"
    gate=1
else
    misses=${edf#edf_deadline_misses=}
    if [ "$misses" -gt 0 ]; then
        echo "GATE FAIL: ${misses} tight-SLO requests missed their deadline"
        echo "           under interleaved EDF stepping"
        gate=1
    else
        echo "gate ok: 0 EDF deadline misses"
    fi
fi
echo "== grep-gate: admission storm (accepted_slo_misses=0, rejected>0, best-effort alive) =="
storm=$(grep -o 'accepted_slo_misses=[0-9]*' "$batched_log" | head -1)
if [ -z "$storm" ]; then
    echo "GATE FAIL: no accepted_slo_misses telemetry emitted (admission"
    echo "           storm scenario missing from bench_batched_dvfs)"
    gate=1
else
    misses=${storm#accepted_slo_misses=}
    if [ "$misses" -gt 0 ]; then
        echo "GATE FAIL: ${misses} ADMITTED SLOs were missed — the feasibility"
        echo "           quote accepted contracts it could not honor"
        gate=1
    else
        echo "gate ok: 0 accepted-SLO misses"
    fi
fi
# anchor to the admission_storm line: the baseline line hardcodes rejected=0
rejected=$(grep '^admission_storm,' "$batched_log" | grep -o 'rejected=[0-9]*' | head -1)
rejected=${rejected#rejected=}
if [ -z "$rejected" ] || [ "$rejected" -eq 0 ]; then
    echo "GATE FAIL: the oversubscribed storm rejected nothing — infeasible"
    echo "           SLOs must be refused at submit time"
    gate=1
else
    echo "gate ok: ${rejected} infeasible SLOs rejected at admission"
fi
be=$(grep -o 'best_effort_completed=[0-9]*' "$batched_log" | head -1)
be=${be#best_effort_completed=}
if [ -z "$be" ] || [ "$be" -eq 0 ]; then
    echo "GATE FAIL: best-effort traffic starved to zero under the storm"
    gate=1
else
    echo "gate ok: ${be} best-effort completions under the storm"
fi
echo "== grep-gate: decode_early_exit (exit beats full depth, 0 accepted misses) =="
dee=$(grep '^decode_early_exit,' "$batched_log" | head -1)
if [ -z "$dee" ]; then
    echo "GATE FAIL: no decode_early_exit telemetry emitted (mixed"
    echo "           classifier+decoder storm missing from bench_batched_dvfs)"
    gate=1
else
    beats=$(echo "$dee" | grep -o 'exit_beats_full=[0-9]*'); beats=${beats#*=}
    if [ "$beats" != "1" ]; then
        echo "GATE FAIL: exit-enabled decode did not beat full-depth decode"
        echo "           on modeled energy under the mixed storm"
        gate=1
    else
        echo "gate ok: exit-enabled decode below full-depth energy"
    fi
    # key anchored on the leading ';' so it cannot match inside
    # 'full_accepted_slo_misses=' regardless of emit order
    dmiss=$(echo "$dee" | grep -o ';accepted_slo_misses=[0-9]*' | head -1)
    dmiss=${dmiss#*=}
    fmiss=$(echo "$dee" | grep -o 'full_accepted_slo_misses=[0-9]*')
    fmiss=${fmiss#*=}
    if [ -z "$dmiss" ] || [ "$dmiss" -gt 0 ] || [ -z "$fmiss" ] || [ "$fmiss" -gt 0 ]; then
        echo "GATE FAIL: decode storm missed accepted SLOs (exit=${dmiss:-?},"
        echo "           full=${fmiss:-?}) — the energy win must hold at equal"
        echo "           (zero) deadline-miss count"
        gate=1
    else
        echo "gate ok: 0 accepted-SLO misses on both decode runs"
    fi
fi
echo "== grep-gate: pallas_serving_step (parity, 0 accepted misses) + BENCH_serving.json =="
psl=$(grep '^pallas_serving_step,' "$batched_log" | head -1)
if [ -z "$psl" ]; then
    echo "GATE FAIL: no pallas_serving_step telemetry emitted (ref-vs-pallas"
    echo "           serving scenario missing from bench_batched_dvfs)"
    gate=1
else
    for key in parity exit_parity; do
        val=$(echo "$psl" | grep -o ";${key}=[0-9]*" | head -1); val=${val#*=}
        if [ "$val" != "1" ]; then
            echo "GATE FAIL: pallas serving ${key}=${val:-?} — use_pallas=True"
            echo "           must be numerically interchangeable with ref"
            gate=1
        else
            echo "gate ok: pallas serving ${key}=1"
        fi
    done
    pmiss=$(echo "$psl" | grep -o 'pallas_slo_misses=[0-9]*'); pmiss=${pmiss#*=}
    if [ -z "$pmiss" ] || [ "$pmiss" -gt 0 ]; then
        echo "GATE FAIL: pallas serving drain missed ${pmiss:-?} accepted SLOs"
        gate=1
    else
        echo "gate ok: 0 accepted-SLO misses under use_pallas=True"
    fi
fi
if python - <<'EOF'
import json, sys
try:
    with open("BENCH_serving.json") as f:
        b = json.load(f)
except Exception as e:
    print(f"GATE FAIL: BENCH_serving.json unreadable: {e}")
    sys.exit(1)
need = {"version", "backend", "ref", "pallas", "speedup_ref_over_pallas_p50",
        "logit_parity", "exit_depth_parity"}
missing = need - b.keys()
if missing or b["version"] < 1:
    print(f"GATE FAIL: BENCH_serving.json malformed (missing {sorted(missing)})")
    sys.exit(1)
sk = {"step_wall_p50_ms", "step_wall_p95_ms", "energy_per_request_j",
      "accepted_slo_miss_rate", "step_traces"}
for side in ("ref", "pallas"):
    if sk - b[side].keys():
        print(f"GATE FAIL: BENCH_serving.json {side} missing {sorted(sk - b[side].keys())}")
        sys.exit(1)
print(f"gate ok: BENCH_serving.json v{b['version']} ({b['backend']}, "
      f"speedup {b['speedup_ref_over_pallas_p50']:.2f}x)")
EOF
then :; else gate=1; fi
rm -f "$batched_log"

echo "== summary: tier1=$tier1 smoke=$smoke batched=$batched gate=$gate =="
exit $(( tier1 || smoke || batched || gate ))
