"""Gradient compression for the data-parallel all-reduce (distributed-
optimization trick for 1000+ node scale).

int8 uniform quantization with ERROR FEEDBACK: each worker quantizes
(grad + residual) to int8 against a globally-agreed scale (psum-max of
|g|), all-reduces the int8 payload (as int32 accumulate — the 4x wire
saving is the int8 payload; XLA all-reduces the widened type, a real
deployment uses the ICI int8 reduction path), dequantizes, and carries the
quantization error into the next step.  Error feedback keeps SGD/Adam
convergence unbiased (Karimireddy et al. 2019).

Used inside a shard_map'd train-step variant (``dp_axis`` is a mesh axis
name); validated for convergence parity in tests/test_compress.py.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any    # same structure as grads, fp32


def ef_init(grads_shape: Any) -> EFState:
    return EFState(
        residual=jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape
        )
    )


def compressed_psum(
    grads: Any,
    ef: EFState,
    axis_name: str,
    n_devices: int,
) -> Tuple[Any, EFState]:
    """All-reduce mean of grads over `axis_name` with int8 + error feedback.

    Must be called inside shard_map/pmap with `axis_name` bound.
    """

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        # globally-agreed scale so dequantization is consistent
        amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
        scale = jnp.maximum(amax, 1e-20) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_r = g32 - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = total.astype(jnp.float32) * scale / n_devices
        return mean.astype(g.dtype), new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_r = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return new_g, EFState(residual=new_r)
