"""End-to-end EdgeBERT deployment pipeline (paper Fig. 6):

  phase 1  fine-tune with magnitude/movement pruning + adaptive-span learning
  phase 2  freeze backbone, train the early-exit off-ramp
  deploy   AdaptivFloat-8 quantization + bitmask encoding + eNVM (MLC2)
           embedding storage + early-exit serving, with the paper's
           memory/latency accounting printed at the end.

Smoke-size by default (CPU); pass --full for published ALBERT dims.

    PYTHONPATH=src python examples/finetune_edgebert.py --steps 80
"""
import argparse
import dataclasses
import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PruneConfig, SpanConfig, get_config, get_smoke_config
from repro.core import bitmask as bm
from repro.core import envm
from repro.core.adaptivfloat import AFFormat, quantize_pytree
from repro.core.adaptive_span import hard_spans, span_flop_factor
from repro.core.pruning import measured_sparsity
from repro.data.synthetic import SyntheticCLS
from repro.models.model import build_model
from repro.serving.engine import ClassifierServer, Request
from repro.training.optim import AdamWConfig
from repro.training.train_loop import EdgeBertTrainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--method", choices=("magnitude", "movement"), default="magnitude")
    ap.add_argument("--sparsity", type=float, default=0.5)
    args = ap.parse_args()

    cfg = get_config("albert_edgebert") if args.full else get_smoke_config("albert_edgebert")
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    cfg = cfg.with_edgebert(
        prune=PruneConfig(enabled=True, method=args.method,
                          encoder_sparsity=args.sparsity, embedding_sparsity=0.6,
                          end_step=args.steps - 10, update_every=5),
        span=SpanConfig(enabled=True, max_span=128, ramp=16, loss_coef=0.02,
                        init_span=96.0),
    )
    model = build_model(cfg)
    data = SyntheticCLS(cfg.vocab_size, 32, 16, num_classes=3)

    trainer = EdgeBertTrainer(
        model,
        TrainerConfig(phase1_steps=args.steps, phase2_steps=args.steps // 2,
                      opt=AdamWConfig(lr=2e-3, warmup_steps=5,
                                      total_steps=args.steps * 2,
                                      span_lr_mult=300.0)),
    )
    params = model.init_params(jax.random.PRNGKey(0))

    print("== phase 1: prune + span learning ==")
    params, prune_state, h1 = trainer.phase1(params, data)
    print(f"   sparsity: {measured_sparsity(params, prune_state)['sparsity']:.2f}")
    spans = hard_spans(np.asarray(params["span_z"])[0])
    print(f"   learned spans: {list(spans)}  "
          f"(score FLOPs kept: {span_flop_factor(spans, cfg.n_heads, 128):.3f})")

    print("== phase 2: off-ramp highway training ==")
    params, h2 = trainer.phase2(params, data)

    print("== deploy: AF8 quantization + eNVM embeddings ==")
    params_q = quantize_pytree(params, AFFormat(8, 3),
                               predicate=lambda p, l: "norm" not in str(p).lower())
    emb = np.asarray(params_q["embed"]["tok"])
    emb_rb, stats = envm.store_and_readback(emb, data_cell="MLC2")
    params_q = dict(params_q, embed=dict(params_q["embed"], tok=jnp.asarray(emb_rb)))
    enc = bm.encode(emb)
    s = bm.storage_bytes(enc, value_bits=8)
    print(f"   embedding: {s['total_bytes']/1e3:.1f} KB bitmask-encoded "
          f"({s['compression']:.2f}x vs dense-8b); "
          f"{stats['n_code_faults']} MLC2 code faults injected")

    print("== serve with early exit ==")
    server = ClassifierServer(model, params_q, batch_lanes=4)
    b = data.batch(9999)
    for i in range(16):
        server.submit(Request(uid=i, tokens=b["tokens"][i]))
    st = server.run()
    print(f"   avg exit layer {st['avg_exit_layer']:.2f}/{cfg.n_layers} "
          f"-> runtime savings {st['runtime_savings']:.1%} "
          f"(layer_calls={st['layer_calls']})")


if __name__ == "__main__":
    main()
