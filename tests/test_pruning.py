"""Movement + magnitude pruning (§III-C)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pruning


class TestMasks:
    def test_magnitude_mask_sparsity(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
        m = np.asarray(pruning.magnitude_mask(w, 0.75))
        assert abs(m.mean() - 0.25) < 0.02
        # surviving weights are the largest
        kept = np.abs(np.asarray(w))[m == 1]
        dropped = np.abs(np.asarray(w))[m == 0]
        assert kept.min() >= dropped.max() - 1e-6

    def test_zero_sparsity_keeps_all(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 32))
        m = np.asarray(pruning.magnitude_mask(w, 0.0))
        assert m.mean() == 1.0

    def test_block_mask_structure(self):
        """block_size>1 prunes whole (b,b) tiles — TPU-structured mode."""
        w = jax.random.normal(jax.random.PRNGKey(2), (64, 64))
        m = np.asarray(pruning.magnitude_mask(w, 0.5, block_size=16))
        blocks = m.reshape(4, 16, 4, 16).transpose(0, 2, 1, 3).reshape(16, 256)
        assert set(np.unique(blocks.mean(axis=1))) <= {0.0, 1.0}

    def test_schedule_cubic(self):
        s0 = float(pruning.sparsity_schedule(0, 0.8, 0, 100))
        s50 = float(pruning.sparsity_schedule(50, 0.8, 0, 100))
        s100 = float(pruning.sparsity_schedule(100, 0.8, 0, 100))
        s200 = float(pruning.sparsity_schedule(200, 0.8, 0, 100))
        assert s0 == 0.0 and abs(s100 - 0.8) < 1e-6 and s200 == s100
        assert s50 > 0.8 / 2  # cubic front-loads sparsification


class TestMovement:
    def test_ste_gradients(self):
        """dL/dscores = dL/d(masked_w) * w  (straight-through)."""
        w = jnp.array([[1.0, -2.0], [0.5, 3.0]])
        s = jnp.array([[1.0, 4.0], [2.0, 3.0]])

        def loss(w, s):
            return jnp.sum(pruning.movement_masked_weight(w, s, 0.5) * 2.0)

        gw, gs = jax.grad(loss, argnums=(0, 1))(w, s)
        mask = np.asarray(pruning.topv_mask(s, 0.5))
        np.testing.assert_allclose(np.asarray(gw), 2.0 * mask)
        np.testing.assert_allclose(np.asarray(gs), 2.0 * np.asarray(w))

    def test_movement_differs_from_magnitude(self):
        """Movement keeps weights moving AWAY from zero even if small now."""
        w = jnp.array([0.01, 1.0, -0.02, 0.5])
        scores = jnp.array([10.0, -5.0, 8.0, -2.0])  # movement favors 0 and 2
        mv = np.asarray(pruning.topv_mask(scores, 0.5))
        mag = np.asarray(pruning.magnitude_mask(w, 0.5))
        assert (mv != mag).any()
        assert mv[0] == 1 and mv[2] == 1  # small-but-moving kept


class TestTreePlumbing:
    def _params(self):
        k = jax.random.PRNGKey(3)
        return {
            "layers": {"attn": {"wq": jax.random.normal(k, (16, 16))}},
            "norm1": {"scale": jnp.ones((16,))},
            "offramp_cls_w": jax.random.normal(k, (16, 4)),
        }

    def test_excludes_norm_and_offramp(self):
        """Paper §IV-B2: LN / off-ramp / classifier stay dense."""
        p = self._params()
        st = pruning.init_prune_state(p, "magnitude")
        st = pruning.update_masks(p, st, 1000, "magnitude", 0.9, 0, 10)
        masked = pruning.apply_masks(p, st)
        assert np.asarray(masked["norm1"]["scale"]).all()  # untouched
        assert (np.asarray(masked["offramp_cls_w"]) != 0).all()
        assert (np.asarray(masked["layers"]["attn"]["wq"]) == 0).mean() > 0.8

    def test_measured_sparsity(self):
        p = self._params()
        st = pruning.init_prune_state(p, "magnitude")
        st = pruning.update_masks(p, st, 1000, "magnitude", 0.5, 0, 10)
        m = pruning.measured_sparsity(p, st)
        assert 0.4 < m["sparsity"] < 0.6
