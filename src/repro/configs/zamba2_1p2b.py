"""zamba2-1.2b [hybrid] — 38 Mamba2 blocks d_model=2048 + one shared attention
block (32H on concat([h, x0]) of width 2*d_model) invoked every 6 Mamba blocks,
d_ff=8192 (shared block MLP), vocab=32000, ssm_state=64. [arXiv:2411.15242; hf]

Runs long_500k (sub-quadratic: Mamba2 state recurrence; shared attention during
decode is O(window) against the KV cache).
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,             # mamba2 blocks
    d_model=2048,
    n_heads=32,              # shared attention block heads (on 2*d_model)
    n_kv_heads=32,
    head_dim=128,            # 2*2048/32 = 128
    d_ff=8192,
    vocab_size=32000,
    act="gelu",
    norm="rms",
    pos="rope",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_chunk=128,
    attn_every=6,
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        name="zamba2-1.2b-smoke",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,          # 2*64/4
        d_ff=128,
        vocab_size=512,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=32,
        attn_every=2,
        max_seq_len=256,
    )
