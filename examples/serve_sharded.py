"""Multi-device sharded serving: one scheduler, two replicated clock domains.

The fused per-bucket serving step is ``shard_map``-ed over a ``("data",)``
mesh, so ONE ``LaneScheduler`` drives ``replicas x batch_lanes`` concurrent
requests: lane slab r is exactly the rows device r computes, and each device
is its own DVFS clock domain — one ``BatchedDVFSArbiter`` per replica making
its own (V, f) decisions (barrier-aware: never below the fleet's tightest
lane requirement, since the SPMD step leaves the collective together).

Admission control quotes feasibility PER REPLICA and routes each accepted
contract to a replica with a pluggable ``PlacementPolicy`` — the request is
pinned and only refills lanes of that clock domain.  This demo:

  * forces 2 host devices (the ``XLA_FLAGS`` recipe below — the flag must be
    set BEFORE jax initializes, which is why it is exported at the very top
    of this file, before any jax import);
  * drains best-effort traffic over both replicas plus explicit contracts
    admitted at their own feasibility quote, under least-loaded placement;
  * shows the per-(bucket, replica) compile telemetry — exactly one fused
    trace per pair — and each clock domain's independent energy/switch
    accounting.

Recipe for any multi-device-on-CPU run (benchmarks, tests, this demo)::

    XLA_FLAGS=--xla_force_host_platform_device_count=N python ...

    PYTHONPATH=src python examples/serve_sharded.py
"""
import os
import sys

# must happen before jax (or anything importing jax) loads: XLA reads the
# flag once at backend initialization
_FORCE = "--xla_force_host_platform_device_count=2"
_flags = [t for t in os.environ.get("XLA_FLAGS", "").split()
          if not t.startswith("--xla_force_host_platform_device_count")]
os.environ["XLA_FLAGS"] = " ".join(_flags + [_FORCE])

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.data.synthetic import SyntheticCLS
from repro.hwmodel.edgebert_accel import albert_layer_stats
from repro.models.model import build_model
from repro.serving.admission import AdmissionController, LeastLoadedPlacement
from repro.serving.dvfs import (
    BatchedDVFSArbiter,
    LatencyAwareDVFSController,
    no_early_exit_baseline,
)
from repro.serving.engine import ClassifierServer, Request

REPLICAS, LANES, BUCKETS = 2, 2, (16, 32)


def main() -> None:
    assert jax.device_count() >= REPLICAS, (
        f"forced host device count did not take: {jax.device_count()} device(s)"
    )
    import dataclasses

    cfg = dataclasses.replace(
        get_smoke_config("albert_edgebert"), dtype="float32", remat_policy="none"
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    data = SyntheticCLS(cfg.vocab_size, 32, 16, num_classes=3, seed=0)

    stats = albert_layer_stats(seq_len=max(BUCKETS))
    stats.n_layers = cfg.n_layers
    target = no_early_exit_baseline(stats)["latency_s"] * 1.5
    ctrl = LatencyAwareDVFSController(stats, target)

    srv = ClassifierServer(
        model, params, batch_lanes=LANES, arbiter=BatchedDVFSArbiter(ctrl),
        buckets=BUCKETS, replicas=REPLICAS,
    )
    ac = AdmissionController(srv, placement=LeastLoadedPlacement())
    print(f"devices={jax.device_count()} replicas={srv.replicas} "
          f"lanes={srv.lanes} ({srv.lanes_per_replica}/replica)")

    # best-effort floor across both buckets, then explicit contracts admitted
    # at their own per-replica feasibility quote (and pinned by placement)
    rng = np.random.default_rng(0)
    uid = 0
    for i in range(4 * REPLICAS * LANES):
        b = data.batch(100 + i)
        n = int(rng.integers(6, 32))
        srv.submit(Request(uid=uid, tokens=np.asarray(b["tokens"][0][:n], np.int32)))
        uid += 1
    pins = []
    for i in range(2 * REPLICAS):
        b = data.batch(300 + i)
        toks = np.asarray(b["tokens"][0][:12], np.int32)
        q = ac.quote(Request(uid=uid, tokens=toks, deadline_s=1e9))
        d = ac.submit(Request(uid=uid, tokens=toks, deadline_s=q.min_deadline_s))
        assert d.admitted, "own-quote contract rejected"
        pins.append((uid, q.replica))
        uid += 1
    srv.run()

    st = srv.telemetry()
    print(f"\nretired {st['sentences']} requests in {st['dense_steps']} fused "
          f"steps (avg exit {st['avg_exit_layer']:.2f}/{cfg.n_layers})")
    print("placement (uid -> replica):", pins)
    print("fused traces per (bucket x replicas):",
          st["step_traces_per_bucket_replica"])
    print(f"accepted={st['accepted']} accepted_slo_misses="
          f"{st['accepted_slo_misses']}")
    for r, arb in enumerate(srv.arbiters):
        print(f"replica {r}: clock={arb.now_s * 1e3:.2f}ms "
              f"energy={arb.compute_energy_j:.3e}J "
              f"op_switches={arb.op_switches} "
              f"stall={arb.switch_time_s * 1e6:.1f}us")
    assert st["accepted_slo_misses"] == 0
    assert max(st["step_traces_per_bucket_replica"].values()) == 1
    print("\nok: one compile per (bucket, replica), zero accepted-SLO misses")


if __name__ == "__main__":
    main()
