# NOTE: no XLA_FLAGS here by design — unit tests see the 1 real CPU device.
# Sharding/dry-run tests that need multiple devices spawn subprocesses with
# --xla_force_host_platform_device_count set (see test_dryrun_small.py).
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# `hypothesis` is optional: property tests skip (not error) when it's absent.
from hypothesis_compat import HAS_HYPOTHESIS, settings

if HAS_HYPOTHESIS:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


@pytest.fixture(autouse=True, scope="module")
def _release_xla_executables():
    """Clear jax's global jit caches at every module boundary.

    The suite compiles hundreds of fused-step/decode executables (every
    server instance re-jits its closures), and XLA:CPU's accumulated live
    executables can segfault a LATE module's compile in a full `-x -q` run
    even though the same module passes standalone.  Compiled objects are
    per-instance closures anyway, so cross-module cache hits are not a
    thing worth keeping; bounding peak compiler memory is."""
    import jax

    jax.clear_caches()
    yield


@pytest.fixture(scope="session")
def rng():
    import jax

    return jax.random.PRNGKey(0)
