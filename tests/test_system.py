"""End-to-end system test: the full EdgeBERT pipeline (paper Fig. 6) on CPU —
phase-1 fine-tune with pruning+span, phase-2 off-ramp training, AdaptivFloat
post-quantization, eNVM embedding storage, then early-exit serving — and the
accuracy/latency bookkeeping the paper reports.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PruneConfig, SpanConfig, get_smoke_config
from repro.core import envm, pruning
from repro.core.adaptivfloat import AFFormat, quantize_pytree
from repro.data.synthetic import SyntheticCLS
from repro.models.model import build_model
from repro.serving.engine import ClassifierServer, Request
from repro.training.optim import AdamWConfig
from repro.training.train_loop import EdgeBertTrainer, TrainerConfig


def test_full_edgebert_pipeline():
    cfg = get_smoke_config("albert_edgebert")
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    cfg = cfg.with_edgebert(
        prune=PruneConfig(enabled=True, method="magnitude", encoder_sparsity=0.4,
                          embedding_sparsity=0.5, end_step=25, update_every=5),
        span=SpanConfig(enabled=True, max_span=128, ramp=16, loss_coef=0.02,
                        init_span=96.0),
    )
    model = build_model(cfg)
    data = SyntheticCLS(cfg.vocab_size, 32, 8, num_classes=3, seed=0)
    trainer = EdgeBertTrainer(
        model,
        TrainerConfig(phase1_steps=35, phase2_steps=25,
                      opt=AdamWConfig(lr=2e-3, warmup_steps=3, total_steps=60)),
    )

    # phase 1: prune + learn spans
    params = model.init_params(jax.random.PRNGKey(0))
    params, prune_state, hist1 = trainer.phase1(params, data, log_every=1000)
    assert pruning.measured_sparsity(params, prune_state)["sparsity"] > 0.3

    # phase 2: off-ramp
    params, hist2 = trainer.phase2(params, data)
    assert np.isfinite(hist2[-1]["loss"])

    # post-finetuning AdaptivFloat quantization (weights)
    params_q = quantize_pytree(
        params, AFFormat(8, 3),
        predicate=lambda path, leaf: "norm" not in str(path).lower(),
    )

    # embeddings -> eNVM MLC2 round-trip (faults injected on stored codes)
    emb = np.asarray(params_q["embed"]["tok"])
    emb_readback, stats = envm.store_and_readback(emb, data_cell="MLC2", seed=1)
    params_q = dict(params_q)
    params_q["embed"] = dict(params_q["embed"], tok=jnp.asarray(emb_readback))

    # early-exit serving on the deployed model
    server = ClassifierServer(model, params_q, batch_lanes=4)
    batch = data.batch(777)
    for i in range(8):
        server.submit(Request(uid=i, tokens=batch["tokens"][i]))
    served = server.run()
    assert served["sentences"] == 8
    assert 1.0 <= served["avg_exit_layer"] <= cfg.n_layers

    # deployed accuracy sanity: quantized+faulted model close to trained model
    test_batch = {k: jnp.asarray(v) for k, v in data.batch(999).items()
                  if k != "signal_ratio"}
    out_f = model.apply_train(params, test_batch)
    out_q = model.apply_train(params_q, test_batch)
    acc = lambda o: float(jnp.mean((jnp.argmax(o.cls_logits, -1) == test_batch["labels"])))
    assert acc(out_q) >= acc(out_f) - 0.25  # <1%-pt in the paper; slack on toy
