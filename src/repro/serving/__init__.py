from repro.serving.admission import AdmissionController, AdmissionDecision, Quote
from repro.serving.engine import ClassifierServer, DecoderServer, Request, MultiTaskRouter
from repro.serving.scheduler import (
    BucketView,
    EDFPolicy,
    EngineHooks,
    FIFOPolicy,
    LaneEngine,
    LaneScheduler,
    SchedulingPolicy,
    StepReport,
    WeightedRoundRobinPolicy,
)
from repro.serving.dvfs import (
    DEFAULT_DVFS_TABLE,
    ArbiterStepDecision,
    BatchedDVFSArbiter,
    DVFSReport,
    LaneDVFSReport,
    LatencyAwareDVFSController,
    OperatingPoint,
    calibrate_predictor,
    default_albert_controller,
    no_early_exit_baseline,
)
from repro.serving.workload import (
    AdmissionServerTarget,
    DiurnalArrivals,
    MMPPArrivals,
    PoissonArrivals,
    ResidencyRouterTarget,
    TierSpec,
    TraceEvent,
    TraceReplayer,
    WorkloadConfig,
    generate_trace,
    load_trace,
    save_trace,
    summaries_identical,
)
from repro.serving.residency import (
    BlindEDFTaskPolicy,
    ResidencyRouter,
    TaskAffinityPolicy,
    TaskDeployment,
    TaskResidencyManager,
    TaskView,
    deployment_controller,
    deployment_energy_scale,
    deployment_stats,
    measured_footprint,
)
