"""Distributed training driver.

    PYTHONPATH=src python -m repro.launch.train --arch albert_edgebert \
        --steps 200 --batch 8 --seq 128 --smoke

Production semantics built in:
  * deterministic, seekable data (restart-exact after failure),
  * CheckpointManager: atomic saves, auto-resume from LATEST, SIGTERM
    preemption checkpoints,
  * mesh-elastic restore: --data-par/--model-par may differ from the run that
    wrote the checkpoint (ZeRO/param shards are re-laid-out on load),
  * straggler/failure policy (multi-host): the launcher re-execs this driver
    after any worker failure; because data order is a pure function of
    (seed, step) and checkpoints are atomic, recovery is exact.  A heartbeat
    thread logs step latency so a fleet scheduler can flag stragglers.
"""
from __future__ import annotations

import argparse
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.util import logger
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_config, get_smoke_config
from repro.data.synthetic import SyntheticCLS, SyntheticLM
from repro.models.model import build_model
from repro.training.optim import AdamWConfig, adamw_init
from repro.training.train_loop import EdgeBertTrainer, TrainerConfig, make_train_step


class Heartbeat:
    """Step-latency telemetry; a scheduler watching the log can evict
    stragglers (paper-scale fleets) — here it logs p50/p95."""

    def __init__(self, window: int = 50):
        self.times = []
        self.window = window

    def beat(self, dt: float, step: int):
        self.times.append(dt)
        if len(self.times) >= self.window:
            arr = np.array(self.times)
            logger.info(
                "heartbeat step=%d p50=%.3fs p95=%.3fs", step,
                float(np.percentile(arr, 50)), float(np.percentile(arr, 95)),
            )
            self.times = []


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="albert_edgebert")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--smoke", action="store_true", help="use reduced config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--phase2", action="store_true", help="run off-ramp phase too")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    import dataclasses

    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(args.seed)

    if cfg.num_classes:
        data = SyntheticCLS(cfg.vocab_size, args.seq, args.batch,
                            num_classes=cfg.num_classes, seed=args.seed)
    else:
        data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 10, 1))

    ckpt = CheckpointManager(args.ckpt_dir, save_every=args.save_every)
    ckpt.install_preemption_handler()

    if cfg.edgebert.prune.enabled or cfg.edgebert.span.enabled or cfg.edgebert.early_exit.enabled:
        # the paper's two-phase procedure
        tcfg = TrainerConfig(
            phase1_steps=args.steps, phase2_steps=args.steps // 2 if args.phase2 else 0,
            opt=opt_cfg,
        )
        trainer = EdgeBertTrainer(model, tcfg)
        params = model.init_params(rng)
        params, prune_state, hist = trainer.phase1(params, data)
        ckpt.maybe_save(args.steps, {"params": params}, force=True)
        if args.phase2:
            params, hist2 = trainer.phase2(params, data)
            ckpt.maybe_save(args.steps * 2, {"params": params}, force=True)
        logger.info("final loss=%.4f acc=%.3f", hist[-1]["loss"], hist[-1].get("acc", 0.0))
        return

    # generic LM training path with resume
    params = model.init_params(rng)
    opt_state = adamw_init(params)
    start_step = 0
    if ckpt.latest_step() is not None:
        (state, manifest) = ckpt.restore_latest({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start_step = manifest["step"]
        logger.info("resumed from step %d", start_step)

    step_fn = jax.jit(make_train_step(model, opt_cfg, microbatches=args.microbatches))
    hb = Heartbeat()
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items() if k != "signal_ratio"}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        hb.beat(time.time() - t0, step)
        if step % 20 == 0:
            logger.info("step=%d loss=%.4f", step, float(metrics["loss"]))
        ckpt.maybe_save(step, {"params": params, "opt": opt_state})
        if ckpt.preempted:
            logger.warning("preempted: exiting after checkpoint")
            return
    ckpt.maybe_save(args.steps, {"params": params, "opt": opt_state}, force=True)
    logger.info("done: final loss=%.4f", float(metrics["loss"]))


if __name__ == "__main__":
    main()
