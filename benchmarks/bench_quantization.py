"""Paper Table II: AdaptivFloat bit-width sweep (3-bit exponent) — accuracy of
the post-finetuning-quantized model per bit width, plus weight RMSE."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, eval_accuracy, time_us, trained_albert
from repro.core.adaptivfloat import AFFormat, quantize_pytree
from repro.kernels.ops import af_quantize_op


def main() -> None:
    model, params, _, data, cfg = trained_albert()
    base_acc = eval_accuracy(model, params, data)
    emit("table2_fp32", 0.0, f"acc={base_acc:.3f}")
    pred = lambda path, leaf: "norm" not in str(path).lower()
    for bits in (8, 7, 6, 5, 4):
        fmt = AFFormat(bits, 3)
        pq = quantize_pytree(params, fmt, predicate=pred)
        acc = eval_accuracy(model, pq, data)
        w = params["layer"]["attn"]["wq"]
        rmse = float(jnp.sqrt(jnp.mean((pq["layer"]["attn"]["wq"] - w) ** 2)))
        emit(f"table2_af{bits}", 0.0, f"acc={acc:.3f};d_acc={acc-base_acc:+.3f};wq_rmse={rmse:.2e}")
    # kernel timing (interpret-mode executes the kernel body)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(256, 256)), jnp.float32)
    us = time_us(lambda: af_quantize_op(x))
    emit("table2_quant_kernel_256x256", us, "interpret-mode")


if __name__ == "__main__":
    main()
