from repro.serving.engine import ClassifierServer, DecoderServer, Request, MultiTaskRouter
