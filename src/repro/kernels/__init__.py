"""Pallas TPU kernels for EdgeBERT hot paths + jnp oracles.

Kernels (each <name>.py with pl.pallas_call + BlockSpec, validated in
interpret mode against ref.py):
  span_attention   — windowed flash attention with per-head span predication
                     AND per-row kv_len masking (bucket padding); spans and
                     lengths ride in one scalar-prefetch operand, so both
                     may be TRACED values (vmap/jit-safe per-lane lengths)
  adaptivfloat_k   — AF quantize + AF8-weight matmul (8b mult / 32b acc)
  block_sparse     — CSR-of-blocks sparse matmul (pruning tile skip)
  softmax_entropy  — fused Algorithm-1 softmax + Eq.-4 entropy
  layernorm        — fused two-moment LayerNorm (Eq. 5)

Serving integration (``dispatch.py``): the fused classifier/decoder steps
route their eligible inner ops here when a server is built with
``use_pallas=True`` — a static Python bool closed over by the jit'd step
closures, so the routing adds zero traces and preserves
one-compile-per-bucket. On CPU the kernels run in INTERPRET mode (bodies
execute as Python at reference numerics — this is how CI exercises the
Pallas path without a TPU); on TPU they compile to Mosaic.  Eligibility is
decided per op: soft ramped span masks and KV-cache decode attention stay
on the ref path (no kernel equivalent), everything else — dense/windowed
attention with per-lane kv_len, layernorm, off-ramp entropy, activation
quant, block-sparse MLP tiles — dispatches.  Parity vs the ref path over
full serving drains is CI-gated in ``tests/test_pallas_serving.py`` and
the ``pallas_serving_step`` benchmark scenario.
"""
from repro.kernels import ref
