"""Logical-axis sharding rules (MaxText-style) for every parameter family.

Each parameter leaf is matched by path substring to a tuple of LOGICAL axis
names per dimension; a per-arch ``logical_to_mesh`` table maps logical axes to
mesh axes.  Divisibility is enforced at assignment time: a logical axis whose
dimension does not divide the mesh axis size silently degrades to replicated
(this is what handles kv_heads=4/8 on a 16-way model axis, and 60 experts on
qwen2-moe via its expert-TP override).

Stacked-layer leaves (paths containing layers/cross_layers/enc_layers/
dec_cross) get a leading replicated 'layers' dim prepended automatically.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.util import logger
from repro.configs.base import ModelConfig, ShapeConfig

# ---------------------------------------------------------------------------
# path-pattern -> logical axes (per trailing dim)
# ---------------------------------------------------------------------------

# order matters: first match wins
PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    ("embed/tok", ("vocab", "embed_small")),
    ("embed/proj", ("embed_small", None)),
    ("embed/pos", (None, None)),
    ("enc_pos", (None, None)),
    ("lm_head", (None, "vocab")),
    # attention
    ("attn/wq", (None, "heads_out")),
    ("attn/wk", (None, "kv_out")),
    ("attn/wv", (None, "kv_out")),
    ("attn/wo", ("heads_out", None)),
    ("attn/bq", ("heads_out",)),
    ("attn/bk", ("kv_out",)),
    ("attn/bv", ("kv_out",)),
    ("xattn/wq", (None, "heads_out")),
    ("xattn/wk", (None, "kv_out")),
    ("xattn/wv", (None, "kv_out")),
    ("xattn/wo", ("heads_out", None)),
    ("xattn/bq", ("heads_out",)),
    ("xattn/bk", ("kv_out",)),
    ("xattn/bv", ("kv_out",)),
    # MoE (3D expert-stacked)
    ("moe/router", (None, None)),
    ("moe/w_gate", ("experts", None, "moe_ffn")),
    ("moe/w_up", ("experts", None, "moe_ffn")),
    ("moe/w_down", ("experts", "moe_ffn", None)),
    ("shared/w_gate", (None, "ffn")),
    ("shared/w_up", (None, "ffn")),
    ("shared/w_down", ("ffn", None)),
    ("shared/gate_proj", (None, None)),
    # dense MLP
    ("mlp/w_gate", (None, "ffn")),
    ("mlp/w_up", (None, "ffn")),
    ("mlp/w_down", ("ffn", None)),
    # rwkv6
    ("tmix/w_r", (None, "heads_out")),
    ("tmix/w_k", (None, "heads_out")),
    ("tmix/w_v", (None, "heads_out")),
    ("tmix/w_g", (None, "heads_out")),
    ("tmix/w_o", ("heads_out", None)),
    ("cmix/w_k", (None, "ffn")),
    ("cmix/w_v", ("ffn", None)),
    ("cmix/w_r", (None, None)),
    # mamba2
    ("mixer/w_in", (None, "ssm_inner")),
    ("mixer/w_out", ("ssm_inner_in", None)),
    ("mixer/conv_w", (None, None)),
    # zamba shared attn out projection
    ("shared_attn/out_proj", ("heads_out", None)),
    # classifiers / off-ramps / norms / scalars: replicated
)

STACK_MARKERS = ("layers", "cross_layers", "enc_layers", "dec_cross")


@dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axis (or None). Per-arch overridable."""

    table: Dict[str, Optional[str]] = field(
        default_factory=lambda: {
            "vocab": "model",
            "heads_out": "model",
            "kv_out": "model",
            "ffn": "model",
            "moe_ffn": None,          # MoE default: experts sharded instead
            "experts": "model",
            "ssm_inner": "model",
            "ssm_inner_in": "model",
            "embed_small": None,
            "batch": ("pod", "data"),
            "cache_batch": "data",
            "cache_seq": None,
            "cache_kv": "model",
        }
    )

    def mesh_axis(self, logical: Optional[str]):
        if logical is None:
            return None
        return self.table.get(logical)


def rules_for(cfg: ModelConfig, mesh: Mesh, shape: Optional[ShapeConfig] = None) -> ShardingRules:
    """Arch- and shape-specific rule table."""
    table = dict(ShardingRules().table)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = axis_sizes.get("model", 1)
    # batch axes present in this mesh
    dp_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    table["batch"] = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)

    if cfg.family == "moe":
        if cfg.n_experts % model_size == 0:
            table["experts"] = "model"
            table["moe_ffn"] = None
        else:
            # qwen2-moe: 60 experts don't divide 16 -> expert-TP on ffn dim
            table["experts"] = None
            table["moe_ffn"] = "model"

    if getattr(cfg, "ssm_replicated", False):
        table["ssm_inner"] = None
        table["ssm_inner_in"] = None

    if shape is not None:
        dp_total = int(np.prod([axis_sizes[a] for a in dp_axes])) if dp_axes else 1
        if shape.kind in ("decode", "prefill"):
            if shape.global_batch % dp_total == 0 and shape.global_batch >= dp_total:
                table["cache_batch"] = table["batch"]
                table["cache_seq"] = None
            else:
                # batch-1 long-context decode: shard the KV sequence instead
                # (flash-decode style; XLA partitions the softmax reduction)
                table["cache_batch"] = None
                table["cache_seq"] = table["batch"]
    return ShardingRules(table=table)


# ---------------------------------------------------------------------------
# Param tree -> NamedSharding tree
# ---------------------------------------------------------------------------


def _spec_for_leaf(path: str, shape: Tuple[int, ...], rules: ShardingRules, mesh: Mesh):
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    stacked = any(m in path for m in STACK_MARKERS)
    for pat, logical_axes in PARAM_RULES:
        if pat in path:
            n_stack_dims = len(shape) - len(logical_axes)
            spec: list = [None] * n_stack_dims
            if stacked and n_stack_dims == 0:
                # rule length == ndim but leaf is stacked: shouldn't happen
                pass
            for dim, logical in zip(shape[n_stack_dims:], logical_axes):
                ax = rules.mesh_axis(logical)
                if ax is None:
                    spec.append(None)
                    continue
                size = (
                    int(np.prod([axis_sizes[a] for a in ax]))
                    if isinstance(ax, tuple)
                    else axis_sizes.get(ax, 1)
                )
                if dim % size == 0:
                    spec.append(ax)
                else:
                    spec.append(None)
            return P(*spec)
    return P()  # replicated (norms, scalars, classifiers, off-ramps)


def path_to_str(path) -> str:
    """('layers','mlp','w_up') key path -> 'layers/mlp/w_up' (rules match on
    slash-joined names; jax.tree_util.keystr's bracket form does not)."""
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_shardings(params: Any, mesh: Mesh, rules: ShardingRules):
    """Pytree of NamedSharding matching `params` (works on ShapeDtypeStructs)."""

    def assign(path, leaf):
        pstr = path_to_str(path)
        if not hasattr(leaf, "shape"):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, _spec_for_leaf(pstr, tuple(leaf.shape), rules, mesh))

    return jax.tree_util.tree_map_with_path(assign, params)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------


def batch_shardings(batch: Any, mesh: Mesh, rules: ShardingRules):
    """tokens/labels [B, S] or [B] -> batch over dp axes; aux embeds too."""
    b_ax = rules.mesh_axis("batch")

    def assign(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        size = (
            int(np.prod([axis_sizes[a] for a in b_ax]))
            if isinstance(b_ax, tuple)
            else axis_sizes.get(b_ax, 1) if b_ax else 1
        )
        if leaf.shape[0] % size == 0 and b_ax is not None:
            return NamedSharding(mesh, P(*((b_ax,) + (None,) * (nd - 1))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(assign, batch)


def cache_shardings(cache: Any, mesh: Mesh, rules: ShardingRules, cfg: ModelConfig):
    """Decode caches: [L, B, S, KV, hd] (k/v), mamba/rwkv states, etc."""
    cb = rules.mesh_axis("cache_batch")
    cs = rules.mesh_axis("cache_seq")
    kv_ax = rules.mesh_axis("cache_kv")
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def sz(ax):
        if ax is None:
            return 1
        if isinstance(ax, tuple):
            return int(np.prod([axis_sizes[a] for a in ax]))
        return axis_sizes.get(ax, 1)

    def assign(path, leaf):
        parts = path_to_str(path).split("/")
        pstr = "/".join(parts)
        shape = leaf.shape
        spec: list = [None] * len(shape)
        if any(key in parts for key in ("k", "v", "img_k", "img_v", "enc_k", "enc_v")):
            # [L, B, S, KV, hd]
            if cb is not None and shape[1] % sz(cb) == 0 and shape[1] >= sz(cb):
                spec[1] = cb
            if cs is not None and shape[2] % sz(cs) == 0:
                spec[2] = cs
            if kv_ax is not None and shape[3] % sz(kv_ax) == 0:
                spec[3] = kv_ax
            elif kv_ax is not None and spec[2] is None and shape[2] % sz(kv_ax) == 0:
                # kv_heads don't divide the model axis (GQA kv=4/8 on 16-way):
                # replicating the cache over model would blow HBM (146 GiB/chip
                # for internlm2 decode_32k) — shard the SEQUENCE dim over model
                # instead (flash-decode: XLA partitions the softmax reduction)
                spec[2] = kv_ax
        elif any(key in parts for key in ("conv", "ssm", "last_tm", "last_cm", "wkv")):
            # [L, B, ...] state tensors: shard batch; wkv heads over model
            if cb is not None and shape[1] % sz(cb) == 0 and shape[1] >= sz(cb):
                spec[1] = cb
            if "wkv" in pstr or "ssm" in pstr:
                if kv_ax is not None and len(shape) > 2 and shape[2] % sz(kv_ax) == 0:
                    spec[2] = kv_ax
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(assign, cache)


def logical_to_mesh(rules: ShardingRules, *logical: Optional[str]) -> P:
    return P(*(rules.mesh_axis(l) for l in logical))
