"""Bitmask sparse storage (§V-C) + eNVM MLC ReRAM fault injection (Table III)."""
import numpy as np
import pytest

from repro.core import bitmask as bm
from repro.core import envm


class TestBitmask:
    def test_roundtrip(self):
        rng = np.random.default_rng(0)
        arr = rng.normal(size=(37, 53)).astype(np.float32)
        arr[rng.random(arr.shape) < 0.6] = 0.0
        enc = bm.encode(arr)
        np.testing.assert_array_equal(bm.decode(enc), arr)

    def test_storage_accounting_matches_paper(self):
        """Paper: bitmask adds ~12% overhead on the dense-8bit footprint at
        60% sparsity; compression vs dense ~1.9x."""
        rng = np.random.default_rng(1)
        arr = rng.normal(size=(1024, 128)).astype(np.float32)
        arr[rng.random(arr.shape) < 0.6] = 0.0
        s = bm.storage_bytes(bm.encode(arr), value_bits=8)
        assert abs(s["mask_overhead_vs_dense"] - 0.125) < 0.001
        assert 1.7 < s["compression"] < 2.1


class TestENVM:
    def test_slc_is_safe(self):
        rng = np.random.default_rng(2)
        emb = rng.normal(size=(512, 64)).astype(np.float32)
        emb[rng.random(emb.shape) < 0.6] = 0.0
        out, stats = envm.store_and_readback(emb, data_cell="SLC", seed=3)
        # SLC ber=1e-8: essentially no faults on ~13k codes
        assert stats["n_code_faults"] == 0

    def test_mlc2_low_fault_mlc3_high_fault(self):
        """Table III: MLC2 safe, MLC3 risky — fault counts must reflect the
        cell BERs."""
        rng = np.random.default_rng(4)
        emb = rng.normal(size=(512, 64)).astype(np.float32)
        emb[rng.random(emb.shape) < 0.6] = 0.0
        _, s2 = envm.store_and_readback(emb, data_cell="MLC2", seed=5)
        _, s3 = envm.store_and_readback(emb, data_cell="MLC3", seed=5)
        assert s3["n_code_faults"] > 10 * max(s2["n_code_faults"], 1)

    def test_readback_error_ordering(self):
        rng = np.random.default_rng(6)
        emb = rng.normal(size=(256, 64)).astype(np.float32)
        emb[rng.random(emb.shape) < 0.6] = 0.0
        errs = {}
        for cell in ("SLC", "MLC2", "MLC3"):
            out, _ = envm.store_and_readback(emb, data_cell=cell, seed=7)
            errs[cell] = float(np.abs(out - emb).mean())
        assert errs["SLC"] <= errs["MLC2"] <= errs["MLC3"]
        # quantization-only error (SLC, no faults) stays small
        assert errs["SLC"] < 0.05

    def test_area_density_table3(self):
        """Area density per Table III: SLC 0.28, MLC2 0.08, MLC3 0.04 mm2/MB."""
        one_mb = 1024 * 1024
        assert abs(envm.area_mm2(one_mb, "SLC") - 0.28) < 1e-9
        assert abs(envm.area_mm2(one_mb, "MLC2") - 0.08) < 1e-9
        assert envm.read_latency_ns("MLC3") > envm.read_latency_ns("SLC")

    def test_level_shift_bounded(self):
        """A faulty MLC cell moves +/-1 level only (adjacent disturb)."""
        codes = np.full((10000,), 0b10101010, np.uint8)
        cell = envm.CellConfig("T", 2, 0.1, 1.0, 0.5)
        rng = np.random.default_rng(8)
        out = envm.inject_cell_faults(codes, cell, rng)
        for shift in (0, 2, 4, 6):
            lv = (codes >> shift) & 3
            lo = (out >> shift) & 3
            assert np.abs(lv.astype(int) - lo.astype(int)).max() <= 1
