"""Regenerate the ROOFLINE_TABLE and the variant-comparison table for
EXPERIMENTS.md from benchmarks/results/dryrun.json."""
import json
import sys

recs = json.load(open('benchmarks/results/dryrun.json'))


def roofline_table():
    base = [r for r in recs if r.get('variant', 'baseline') == 'baseline']
    base.sort(key=lambda r: (r['arch'], r['shape'], r['mesh']))
    out = ['| arch | shape | mesh | t_compute (s) | t_memory (s) | t_coll (s) | dominant | useful | frac | HBM/chip (GiB) | compile (s) |',
           '|---|---|---|---|---|---|---|---|---|---|---|']
    for r in base:
        if r['status'] == 'skipped':
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | *skipped: full-attention* | — | — | — | — |")
            continue
        rl = r['roofline']
        ma = r.get('memory_analysis', {})
        hbm = (ma.get('argument_size_in_bytes', 0) + ma.get('temp_size_in_bytes', 0)) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {rl['t_compute_s']:.2e} | "
            f"{rl['t_memory_s']:.2e} | {rl['t_collective_s']:.2e} | **{rl['dominant']}** | "
            f"{rl['useful_flops_ratio']:.2f} | {rl['roofline_fraction']:.3f} | {hbm:.1f} | {r.get('compile_s','')} |")
    return '\n'.join(out)


def variant_table():
    var = [r for r in recs if r.get('variant', 'baseline') != 'baseline' and r['status'] == 'ok']
    keys = sorted({(r['arch'], r['shape'], r['mesh']) for r in var})
    out = ['| cell | variant | t_compute | t_memory | t_coll | dominant | frac |',
           '|---|---|---|---|---|---|---|']
    for key in keys:
        cell = [r for r in recs if (r['arch'], r['shape'], r['mesh']) == key and r['status'] == 'ok']
        cell.sort(key=lambda r: (r.get('variant', 'baseline') != 'baseline', r.get('variant', '')))
        for r in cell:
            rl = r['roofline']
            out.append(
                f"| {key[0]} {key[1]} {key[2]} | {r.get('variant','baseline')} | "
                f"{rl['t_compute_s']:.2e} | {rl['t_memory_s']:.2e} | {rl['t_collective_s']:.2e} | "
                f"{rl['dominant']} | {rl['roofline_fraction']:.3f} |")
    return '\n'.join(out)


if __name__ == '__main__':
    which = sys.argv[1] if len(sys.argv) > 1 else 'both'
    if which in ('roofline', 'both'):
        print(roofline_table())
    if which in ('variants', 'both'):
        print()
        print(variant_table())
