"""Entropy-based early exit (§III-A): mode equivalence + threshold semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import early_exit as ee


def _setup(d=16, C=3, L=6, B=4, S=8, seed=0):
    rng = jax.random.PRNGKey(seed)
    offramp = ee.init_offramp(rng, d, C)
    ws = jax.random.normal(jax.random.PRNGKey(seed + 1), (L, d, d)) * (1.0 / np.sqrt(d))

    def layer_fn(i, h):
        w = ws[i]
        return jnp.tanh(h @ w)

    h0 = jax.random.normal(jax.random.PRNGKey(seed + 2), (B, S, d))
    return layer_fn, offramp, h0, L


class TestModes:
    def test_all_layers_shapes(self):
        layer_fn, offramp, h0, L = _setup()
        logits, ent = ee.exit_all_layers(layer_fn, L, h0, offramp)
        assert logits.shape == (L, 4, 3) and ent.shape == (L, 4)
        assert np.isfinite(np.asarray(ent)).all()

    def test_threshold_semantics(self):
        layer_fn, offramp, h0, L = _setup()
        _, ent = ee.exit_all_layers(layer_fn, L, h0, offramp)
        # infinite threshold -> exit at layer 1; zero threshold -> last layer
        exit_inf, _ = ee.exit_decisions(ent, np.inf)
        exit_zero, _ = ee.exit_decisions(ent, 0.0)
        assert (np.asarray(exit_inf) == 1).all()
        assert (np.asarray(exit_zero) == L).all()

    def test_monotone_in_threshold(self):
        layer_fn, offramp, h0, L = _setup()
        _, ent = ee.exit_all_layers(layer_fn, L, h0, offramp)
        prev = None
        for t in (0.01, 0.3, 0.6, 1.0, np.inf):
            el = np.asarray(ee.exit_decisions(ent, t)[0])
            if prev is not None:
                assert (el <= prev).all()
            prev = el

    def test_while_loop_matches_all_layers(self):
        layer_fn, offramp, h0, L = _setup()
        logits_all, ent = ee.exit_all_layers(layer_fn, L, h0, offramp)
        threshold = float(np.median(np.asarray(ent)))
        exit_layer, _ = ee.exit_decisions(ent, threshold)
        sel = ee.select_exit_logits(logits_all, exit_layer)
        for b in range(h0.shape[0]):
            lg, el, e = ee.exit_while_loop(
                lambda i, h: layer_fn(i, h[None])[0], L, h0[b], offramp, threshold
            )
            assert int(el) == int(exit_layer[b])
            np.testing.assert_allclose(np.asarray(lg), np.asarray(sel[b]), atol=1e-5)

    def test_batched_masked_matches_all_layers(self):
        layer_fn, offramp, h0, L = _setup()
        logits_all, ent = ee.exit_all_layers(layer_fn, L, h0, offramp)
        threshold = float(np.median(np.asarray(ent)))
        exit_layer, _ = ee.exit_decisions(ent, threshold)
        lg, el = ee.exit_batched_masked(layer_fn, L, h0, offramp, threshold)
        np.testing.assert_array_equal(np.asarray(el), np.asarray(exit_layer))
        sel = ee.select_exit_logits(logits_all, exit_layer)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(sel), atol=1e-5)

    def test_runtime_savings_eq2(self):
        el = jnp.array([6, 6, 6, 6])
        assert abs(float(ee.runtime_savings(el, 12)) - 0.5) < 1e-6
        assert abs(ee.ee_perf(0.9, 0.5) - 1.8) < 1e-9


class TestTokenLevelExit:
    """Beyond-paper CALM-style per-token exit for decoder LMs."""

    def _model(self):
        import dataclasses
        from repro.configs.base import get_smoke_config
        from repro.models.model import build_model

        cfg = dataclasses.replace(
            get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none"
        )
        m = build_model(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
        return m, params, toks, cfg

    def test_zero_threshold_equals_full_forward(self):
        m, params, toks, cfg = self._model()
        logits, exit_layer = m.forward_token_exit(params, toks, threshold=0.0)
        full = m.apply_train(params, {"tokens": toks}).logits
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full), atol=1e-5)
        assert (np.asarray(exit_layer) == cfg.n_layers).all()

    def test_inf_threshold_exits_first_layer(self):
        m, params, toks, cfg = self._model()
        logits, exit_layer = m.forward_token_exit(params, toks, threshold=np.inf)
        assert (np.asarray(exit_layer) == 1).all()
        assert np.isfinite(np.asarray(logits)).all()
