"""Checkpointing: atomic commit, integrity, resume, GC, preemption, elastic."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "opt": {"m": jnp.ones((8, 4)), "count": jnp.array(7, jnp.int32)},
    }


class TestSaveRestore:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        save_checkpoint(str(tmp_path), 10, t)
        restored, manifest = restore_checkpoint(str(tmp_path), t)
        assert manifest["step"] == 10
        for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_pointer(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, _tree())
        save_checkpoint(str(tmp_path), 5, _tree(1))
        assert latest_step(str(tmp_path)) == 5

    def test_integrity_check(self, tmp_path):
        save_checkpoint(str(tmp_path), 3, _tree())
        npz = os.path.join(str(tmp_path), "step_00000003", "arrays.npz")
        with open(npz, "r+b") as f:
            f.seek(100)
            f.write(b"\xde\xad")
        with pytest.raises(IOError):
            restore_checkpoint(str(tmp_path), _tree())

    def test_missing_key_detected(self, tmp_path):
        save_checkpoint(str(tmp_path), 3, {"a": jnp.zeros(3)})
        with pytest.raises(KeyError):
            restore_checkpoint(str(tmp_path), {"a": jnp.zeros(3), "b": jnp.zeros(2)})

    def test_elastic_dtype_cast(self, tmp_path):
        """Mesh-elastic restore recasts to the target tree's dtype (e.g. a
        bf16 run restoring an fp32-written checkpoint)."""
        t = {"w": jnp.ones((4, 4), jnp.float32)}
        save_checkpoint(str(tmp_path), 1, t)
        target = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
        restored, _ = restore_checkpoint(str(tmp_path), target)
        assert restored["w"].dtype == jnp.bfloat16

    def test_no_partial_checkpoint_visible(self, tmp_path):
        """A .tmp dir must never be considered a checkpoint."""
        os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
        assert latest_step(str(tmp_path)) is None


class TestManager:
    def test_cadence_and_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), save_every=2, keep=2)
        for step in range(1, 8):
            mgr.maybe_save(step, _tree(step))
        dirs = sorted(d for d in os.listdir(str(tmp_path)) if d.startswith("step_"))
        assert len(dirs) == 2  # GC keeps 2
        assert mgr.latest_step() == 6

    def test_preemption_forces_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), save_every=1000)
        mgr.simulate_preemption()
        assert mgr.preempted
        path = mgr.maybe_save(3, _tree())
        assert path is not None and mgr.latest_step() == 3
        assert not mgr.preempted  # cleared after save

    def test_resume_matches(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), save_every=1)
        t = _tree(9)
        mgr.maybe_save(4, t)
        restored, manifest = mgr.restore_latest(t)
        assert manifest["step"] == 4
        np.testing.assert_array_equal(
            np.asarray(t["params"]["w"]), np.asarray(restored["params"]["w"])
        )
