"""Self-speculative decode via the entropy off-ramps: the parity suite.

The accept rule's contract is that speculation is an OPTIMIZATION, not a
model change: (a) ``spec_window=1`` is bit-identical to ``decode_step_ee``;
(b) a spec-enabled server's accepted tokens, exit depths, and final logits
are bit-identical to the non-speculative EE server on the same traffic;
(c) rejected suffixes roll back losslessly (continuing from a partially-
accepted block reproduces the pure-sequential stream); (d) checkpoint/
restore round-trips bit-identically mid-speculation; (e) trace counts are
unchanged — one compile per (bucket, replica); and (f) the position-binned
calibrator is fed EVERY accepted token's realized depth (one observation
per token, not per block — the bin-starvation regression).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.early_exit import (
    ExitThresholdSchedule,
    PositionBinnedExitCalibrator,
)
from repro.hwmodel.edgebert_accel import albert_layer_stats
from repro.models.model import build_model
from repro.serving.dvfs import (
    BatchedDVFSArbiter,
    LatencyAwareDVFSController,
    no_early_exit_baseline,
)
from repro.serving.engine import DecoderServer, Request, probe_exit_threshold


def _decoder_model(n_layers=4, seed=1):
    cfg = dataclasses.replace(
        get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none",
        n_layers=n_layers,
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    return model, params, cfg


def _prompts(cfg, lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(4, cfg.vocab_size, size=L).astype(np.int32) for L in lengths
    ]


def _prefilled_cache(model, params, prompt, bucket):
    cache = model.init_cache(1, bucket)
    for t in range(len(prompt) - 1):
        _, cache = model.decode_step(
            params, cache, jnp.asarray([[int(prompt[t])]]), t
        )
    return cache, len(prompt) - 1, int(prompt[-1])


def _sequential_ee(model, params, cache, pos, cur, threshold, n):
    """Ground truth: n tokens through per-token EE decode, one at a time."""
    toks, exits = [], []
    for _ in range(n):
        lg, cache, xl, _ = model.decode_step_ee(
            params, cache, jnp.asarray([[cur]]), pos, threshold
        )
        cur = int(jnp.argmax(lg[0, -1]))
        toks.append(cur)
        exits.append(int(xl[0]))
        pos += 1
    return toks, exits, cache, pos, cur


class TestModelDecodeStepSpec:
    def test_spec_window_one_degenerates_bitwise(self):
        """W=1 must be EXACTLY one decode_step_ee call: logits, exit depth,
        first entropy, and every cache leaf bit-identical, slot accepted."""
        model, params, cfg = _decoder_model()
        prompt = _prompts(cfg, (5,))[0]
        cache, pos, cur = _prefilled_cache(model, params, prompt, 16)
        tk, lg, c_sp, xl, fe, acc = model.decode_step_spec(
            params, cache, jnp.asarray([[cur]]), pos, 6.2, 1
        )
        lg_e, c_ee, xl_e, fe_e = model.decode_step_ee(
            params, cache, jnp.asarray([[cur]]), pos, 6.2
        )
        assert np.asarray(acc)[0].tolist() == [True]
        assert int(tk[0, 0]) == int(jnp.argmax(lg_e[0, -1]))
        np.testing.assert_array_equal(np.asarray(lg[:, 0]), np.asarray(lg_e[:, -1]))
        np.testing.assert_array_equal(np.asarray(xl[:, 0]), np.asarray(xl_e))
        np.testing.assert_array_equal(np.asarray(fe[:, 0]), np.asarray(fe_e))
        for a, b in zip(
            jax.tree_util.tree_leaves(c_sp), jax.tree_util.tree_leaves(c_ee)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_accepted_prefix_matches_sequential_ee(self):
        """Every ACCEPTED slot's token and exit depth must be bit-identical
        to the sequential per-token EE stream from the same state."""
        model, params, cfg = _decoder_model()
        for thr, seed in ((6.2, 0), (np.inf, 3), (5.9, 5)):
            prompt = _prompts(cfg, (6,), seed=seed)[0]
            cache, pos, cur = _prefilled_cache(model, params, prompt, 16)
            want_t, want_x, _, _, _ = _sequential_ee(
                model, params, cache, pos, cur, thr, 4
            )
            tk, _, _, xl, _, acc = model.decode_step_spec(
                params, cache, jnp.asarray([[cur]]), pos, thr, 4
            )
            a = int(np.asarray(acc)[0].sum())
            assert a >= 1
            assert np.asarray(tk)[0, :a].tolist() == want_t[:a]
            assert np.asarray(xl)[0, :a].tolist() == want_x[:a]
            if thr is np.inf:        # every token exits layer 1: full accept
                assert a == 4
                assert (np.asarray(xl)[0] == 1).all()

    def test_accept_rule_prefix_structure(self):
        """``accepted`` is a PREFIX mask: 1 + the leading run of slots whose
        token took an off-ramp (and wasn't EOS) — the batched accept rule."""
        model, params, cfg = _decoder_model()
        prompt = _prompts(cfg, (5,), seed=2)[0]
        cache, pos, cur = _prefilled_cache(model, params, prompt, 16)
        for thr in (-1.0, 5.8, 6.0, 6.2, np.inf):
            tk, _, _, xl, _, acc = model.decode_step_spec(
                params, cache, jnp.asarray([[cur]]), pos, thr, 4
            )
            acc = np.asarray(acc)[0]
            xl = np.asarray(xl)[0]
            a = int(acc.sum())
            assert acc[:a].all() and not acc[a:].any()      # contiguous prefix
            # the prefix extends exactly while drafted slots exited early
            agree = 0
            while agree < 4 and xl[agree] < cfg.n_layers:
                agree += 1
            assert a == min(4, agree + 1) or (agree == 4 and a == 4)
        # threshold below every entropy: nothing drafts, one verified token
        _, _, _, xl, _, acc = model.decode_step_spec(
            params, cache, jnp.asarray([[cur]]), pos, -1.0, 4
        )
        assert int(np.asarray(acc)[0].sum()) == 1

    def test_rejected_suffix_rolls_back_bitwise(self):
        """Continuing (sequentially) from a partially-accepted block must
        reproduce the pure-sequential token stream bit-for-bit: rejected
        slots leave no trace the accepted positions can observe."""
        model, params, cfg = _decoder_model()
        prompt = _prompts(cfg, (6,), seed=7)[0]
        thr = 6.2
        cache, pos, cur = _prefilled_cache(model, params, prompt, 16)
        want_t, want_x, _, _, _ = _sequential_ee(
            model, params, cache, pos, cur, thr, 6
        )
        tk, _, c_sp, xl, _, acc = model.decode_step_spec(
            params, cache, jnp.asarray([[cur]]), pos, thr, 4
        )
        a = int(np.asarray(acc)[0].sum())
        assert a < 4, "want a genuinely rejected suffix for this seed"
        # resume from the speculation's cache at the accepted prefix
        got_t, got_x, _, _, _ = _sequential_ee(
            model, params, c_sp, pos + a, int(np.asarray(tk)[0, a - 1]),
            thr, 6 - a,
        )
        assert np.asarray(tk)[0, :a].tolist() + got_t == want_t
        assert np.asarray(xl)[0, :a].tolist() + got_x == want_x

    def test_per_slot_thresholds_gate_each_position(self):
        """A [W] threshold row prices slots individually: an -inf slot-0
        threshold forces full depth there while +inf later slots draft."""
        model, params, cfg = _decoder_model()
        prompt = _prompts(cfg, (5,), seed=9)[0]
        cache, pos, cur = _prefilled_cache(model, params, prompt, 16)
        thr = jnp.asarray([-1.0, np.inf, np.inf, np.inf], jnp.float32)
        _, _, _, xl, _, acc = model.decode_step_spec(
            params, cache, jnp.asarray([[cur]]), pos, thr, 4
        )
        xl, acc = np.asarray(xl)[0], np.asarray(acc)[0]
        assert xl[0] == cfg.n_layers          # slot 0: no off-ramp taken
        assert int(acc.sum()) == 1            # full depth terminates the block


class TestEngineSpecParity:
    def _run(self, model, params, prompts, thr, **kw):
        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
            exit_threshold=thr, **kw,
        )
        for i, p in enumerate(prompts):
            srv.submit(Request(uid=i, tokens=p, max_new_tokens=4))
        st = srv.run()
        return srv, st

    def test_spec_server_matches_ee_server_bitwise(self):
        """Same traffic through spec_window=4 and the per-token EE baseline:
        generated tokens, exit depths, and final logits bit-identical; one
        compile per (bucket, replica) on BOTH; throughput >= baseline."""
        model, params, cfg = _decoder_model()
        prompts = _prompts(cfg, (6, 5, 7, 4, 6))
        thr = probe_exit_threshold(
            model, params, prompts, max_new_tokens=5, quantile=0.8
        )
        s1, t1 = self._run(model, params, prompts, thr)
        s4, t4 = self._run(model, params, prompts, thr, spec_window=4)
        for st in (t1, t4):
            assert st["completed"] == 5
            assert st["decode_traces_per_bucket"] == {16: 1}
            assert st["step_traces_per_bucket_replica"] == {"16x1": 1}
        for i in range(5):
            assert s4.done[i].generated == s1.done[i].generated, i
            assert s4.done[i].token_exit_layers == s1.done[i].token_exit_layers, i
            np.testing.assert_array_equal(s4.done[i].result, s1.done[i].result)
        assert t1["tokens_per_fused_step"] == pytest.approx(1.0)
        assert t4["tokens_per_fused_step"] >= t1["tokens_per_fused_step"]
        assert t4["avg_accepted_block"] >= 1.0

    def test_degenerate_schedule_spec_path_is_bitwise_identical(self):
        """A constant ExitThresholdSchedule activates the speculative trace
        even at W=1 — and must still produce bit-identical output (the
        degenerate schedule IS the scalar threshold)."""
        model, params, cfg = _decoder_model()
        prompts = _prompts(cfg, (6, 5, 7), seed=4)
        thr = probe_exit_threshold(
            model, params, prompts, max_new_tokens=4, quantile=0.7
        )
        s_ee, _ = self._run(model, params, prompts, thr)
        sched = ExitThresholdSchedule(thr)
        s_sp, t_sp = self._run(
            model, params, prompts, None, threshold_schedule=sched,
            spec_window=1,
        )
        assert s_sp._spec                     # the spec path actually ran
        assert t_sp["decode_traces_per_bucket"] == {16: 1}
        for i in range(3):
            assert s_sp.done[i].generated == s_ee.done[i].generated, i
            assert (
                s_sp.done[i].token_exit_layers == s_ee.done[i].token_exit_layers
            ), i
            np.testing.assert_array_equal(s_sp.done[i].result, s_ee.done[i].result)

    def test_eos_truncates_the_accepted_block(self):
        """A server with a real eos_id must stop a lane at the EOS token even
        when later draft slots accepted — no post-EOS tokens are appended."""
        model, params, cfg = _decoder_model()
        prompts = _prompts(cfg, (6, 5), seed=11)
        thr = probe_exit_threshold(
            model, params, prompts, max_new_tokens=6, quantile=0.9
        )
        # find the EOS id that actually occurs: run the baseline first and
        # pick a generated token, then re-run with that id as EOS
        s_ref, _ = self._run(model, params, prompts, thr)
        eos = s_ref.done[0].generated[1]      # second generated token
        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=int(eos),
            buckets=(16,), exit_threshold=thr, spec_window=4,
        )
        for i, p in enumerate(prompts):
            srv.submit(Request(uid=i, tokens=p, max_new_tokens=4))
        srv.run()
        g = srv.done[0].generated
        assert int(eos) in g
        assert g.index(int(eos)) == len(g) - 1    # EOS ends the stream


class TestSpecCheckpointRestore:
    def test_preempted_spec_decode_matches_uninterrupted(self):
        """A mid-generation preempt/checkpoint/restore cycle on a spec-
        enabled server (lane parked between partially-accepted blocks) must
        reproduce the uninterrupted spec run bit-for-bit with zero extra
        compiled traces."""
        model, params, cfg = _decoder_model()
        prompts = _prompts(cfg, (6, 5, 7), seed=5)
        thr = probe_exit_threshold(
            model, params, prompts, max_new_tokens=6, quantile=0.6
        )

        def build():
            return DecoderServer(
                model, params, batch_lanes=2, max_seq=32, eos_id=-1,
                buckets=(16,), exit_threshold=thr, preempt=True, spec_window=3,
            )

        ref = build()
        for i, p in enumerate(prompts):
            ref.submit(Request(uid=i, tokens=p, max_new_tokens=6))
        ref.run()

        srv = build()
        for i, p in enumerate(prompts):
            srv.submit(Request(uid=i, tokens=p, max_new_tokens=6))
        srv.step()
        srv.submit(Request(
            uid=99, tokens=prompts[0][:4], max_new_tokens=2, deadline_s=30.0
        ))
        st = srv.run()
        assert st["preemptions"] >= 1
        for i in range(3):
            assert srv.done[i].generated == ref.done[i].generated, i
            assert srv.done[i].token_exit_layers == ref.done[i].token_exit_layers, i
            np.testing.assert_array_equal(srv.done[i].result, ref.done[i].result)
        assert st["decode_traces"] == 1 and st["prefill_traces"] == 1

    def test_arbiter_depth_reconciles_across_spec_checkpoint(self):
        """With the shared-clock arbiter live, block-depth charging plus a
        checkpoint/restore cycle must still reconcile at retire (the
        ``depth == sum(token_exit_layers)`` assert) and report energy."""
        model, params, cfg = _decoder_model()
        prompts = _prompts(cfg, (6, 5, 7), seed=6)
        thr = probe_exit_threshold(
            model, params, prompts, max_new_tokens=6, quantile=0.6
        )
        stats = albert_layer_stats(seq_len=16)
        stats.n_layers = cfg.n_layers
        target = no_early_exit_baseline(stats)["latency_s"] * 2.0
        arb = BatchedDVFSArbiter(LatencyAwareDVFSController(stats, target))
        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
            exit_threshold=thr, preempt=True, arbiter=arb, spec_window=3,
        )
        for i, p in enumerate(prompts):
            srv.submit(Request(uid=i, tokens=p, max_new_tokens=6))
        srv.step()
        srv.submit(Request(
            uid=99, tokens=prompts[0][:4], max_new_tokens=2,
            deadline_s=target * 50,
        ))
        st = srv.run()
        assert st["preemptions"] >= 1
        assert st["accepted_slo_misses"] == 0
        for i in range(3):
            r = srv.done[i]
            assert r.energy_j is not None and r.energy_j > 0
            assert len(r.token_exit_layers) == len(r.generated)
        # the arbiter's token accounting saw every accepted token
        assert arb.tokens_accepted == sum(
            len(srv.done[i].generated) for i in (0, 1, 2)
        ) + len(srv.done[99].generated)


class TestCalibratorPerTokenObservation:
    def test_every_accepted_token_feeds_its_position_bin(self):
        """The bin-starvation regression: under speculation the calibrator
        must receive one observation PER ACCEPTED TOKEN at that token's own
        position — a block-granular observer would leave the bins covering
        positions inside accepted prefixes empty."""
        model, params, cfg = _decoder_model()
        prompts = _prompts(cfg, (6, 5), seed=8)
        max_new = 8
        calib = PositionBinnedExitCalibrator(
            cfg.n_layers, max_pos=max_new, n_bins=max_new
        )
        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
            exit_threshold=np.inf,       # everything drafts: full W-blocks
            exit_calibrator=calib, spec_window=4,
        )
        for i, p in enumerate(prompts):
            srv.submit(Request(uid=i, tokens=p, max_new_tokens=max_new))
        st = srv.run()
        total = sum(len(srv.done[i].generated) for i in range(2))
        assert total == 2 * max_new
        assert st["avg_accepted_block"] > 1.0          # blocks really formed
        assert calib.count == total                    # one obs per TOKEN
        # every per-position bin a generated token landed in is warm — with
        # one bin per position, interior-of-block positions included
        fill = calib.bin_fill_counts()
        assert (fill[:max_new] > 0).all(), fill

    def test_calibrator_predictions_tighten_under_spec(self):
        """The one prediction chain: after a spec run whose tokens exited at
        layer 1, predict_range must drop to ~1 layer per token (block-depth
        realized exits thread into EDF slack / set_remaining_layers /
        admission quotes through this same LUT)."""
        model, params, cfg = _decoder_model()
        prompts = _prompts(cfg, (6,), seed=8)
        srv = DecoderServer(
            model, params, batch_lanes=2, max_seq=32, eos_id=-1, buckets=(16,),
            exit_threshold=np.inf, spec_window=4,
        )
        srv.submit(Request(uid=0, tokens=prompts[0], max_new_tokens=8))
        srv.run()
        assert srv.calib.predict_range(0, 8) == pytest.approx(8.0)
        req = Request(uid=1, tokens=prompts[0], max_new_tokens=8)
        assert srv.predict_remaining_steps(16, req, 0) == pytest.approx(
            8.0 / cfg.n_layers
        )


class TestExitThresholdSchedule:
    def test_degenerate_schedule_equals_base_everywhere(self):
        s = ExitThresholdSchedule(0.73)
        got = s.thresholds(0, 16)
        np.testing.assert_array_equal(got, np.full(16, np.float32(0.73)))
        assert s.threshold_at(123) == np.float32(0.73)

    def test_position_scales_digitize(self):
        s = ExitThresholdSchedule(
            1.0, position_edges=(4, 8), position_scales=(1.0, 2.0, 0.5)
        )
        got = s.thresholds(2, 8)             # positions 2..9
        want = np.array([1, 1, 2, 2, 2, 2, 0.5, 0.5], np.float32)
        np.testing.assert_allclose(got, want)

    def test_entropy_band_scales(self):
        s = ExitThresholdSchedule(
            1.0, band_edges=(0.5,), band_scales=(2.0, 1.0)
        )
        np.testing.assert_allclose(s.thresholds(0, 3, last_entropy=0.1),
                                   np.full(3, 2.0, np.float32))
        np.testing.assert_allclose(s.thresholds(0, 3, last_entropy=0.9),
                                   np.ones(3, np.float32))
        # no reading yet: base only
        np.testing.assert_allclose(s.thresholds(0, 3), np.ones(3, np.float32))

    def test_from_cold_calibrator_is_constant(self):
        calib = PositionBinnedExitCalibrator(12, max_pos=64)
        s = ExitThresholdSchedule.from_calibrator(0.9, calib)
        np.testing.assert_array_equal(
            s.thresholds(0, 64), np.full(64, np.float32(0.9))
        )

    def test_from_warm_calibrator_loosens_confident_bins(self):
        calib = PositionBinnedExitCalibrator(12, max_pos=64, n_bins=8)
        for _ in range(32):
            calib.observe(2, 2)              # early positions exit shallow
            calib.observe(60, 11)            # late positions run deep
        s = ExitThresholdSchedule.from_calibrator(
            1.0, calib, loosen=1.5, tighten=0.5
        )
        assert s.threshold_at(2) == pytest.approx(1.5)
        assert s.threshold_at(60) == pytest.approx(0.5)
        # untouched (cold) bins keep the base
        assert s.threshold_at(33) == pytest.approx(1.0)

    def test_observe_forwards_to_calibrator(self):
        calib = PositionBinnedExitCalibrator(12, max_pos=64)
        s = ExitThresholdSchedule(1.0, calibrator=calib)
        s.observe(3, 0.4, 5)
        assert calib.count == 1

    def test_clipping(self):
        s = ExitThresholdSchedule(
            1.0, position_edges=(4,), position_scales=(1.0, 10.0),
            max_threshold=2.0, min_threshold=0.0,
        )
        assert s.threshold_at(10) == pytest.approx(2.0)
