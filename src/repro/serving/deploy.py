"""Deployed EdgeBERT: the accelerator's dataflow, composed from the Pallas
kernels (paper Fig. 9).

`deploy_albert` bakes a trained ALBERT-EdgeBERT into its on-chip form:
  * matmul weights -> AF8 codes (uint8 + per-tensor bias) — §V-C's 8-bit PU,
    executed by the `af_matmul` kernel (decode at the VMEM edge, f32 acc);
  * learned spans -> integer registers; attention runs the `span_attention`
    kernel (dead heads gathered out, survivors windowed) — §V-D1;
  * LayerNorm -> the fused two-moment kernel — §V-D3;
  * off-ramp evaluation -> the fused softmax+entropy kernel — Alg. 1 + Eq. 4;
  * embeddings come back from the eNVM round-trip (bitmask in SLC, AF8 codes
    in MLC2) — §III-D.

`DeployedAlbert.classify` then runs sentences layer-by-layer with entropy
early exit — the complete EdgeBERT inference pass, every hot op on a kernel.
CPU here = interpret mode (correctness); on TPU the same calls emit Mosaic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import envm
from repro.core.adaptivfloat import AFFormat, af_encode
from repro.core.adaptive_span import hard_spans
from repro.kernels import ops


@dataclass
class AFWeight:
    codes: jnp.ndarray      # uint8 [in, out]
    e_min: jnp.ndarray      # scalar
    bias: Optional[jnp.ndarray] = None


def _encode_w(w, fmt: AFFormat) -> AFWeight:
    codes, e_min = af_encode(jnp.asarray(w, jnp.float32), fmt)
    return AFWeight(codes=codes, e_min=e_min)


def _mm(x: jnp.ndarray, w: AFWeight) -> jnp.ndarray:
    """AF8 matmul kernel over flattened leading dims."""
    lead = x.shape[:-1]
    y = ops.af_matmul_op(x.reshape(-1, x.shape[-1]).astype(jnp.float32), w.codes, w.e_min)
    if w.bias is not None:
        y = y + w.bias
    return y.reshape(lead + (y.shape[-1],))


@dataclass
class DeployedAlbert:
    cfg: ModelConfig
    embed_tok: jnp.ndarray          # eNVM-readback embeddings
    embed_proj: Optional[AFWeight]
    embed_pos: Optional[jnp.ndarray]
    layer: Dict[str, Any]           # AF-encoded shared encoder layer
    offramp: Dict[str, Any]
    spans: np.ndarray               # integer spans (registers)
    threshold: float
    # off-ramp entropy traces of the most recent classify() batch, one list
    # per sentence — replayed by the DVFS controller (Alg. 1)
    last_entropy_traces: List[List[float]] = field(default_factory=list)

    # ------------------------------------------------------------- layers --
    def _ln(self, x, scale, bias):
        lead = x.shape[:-1]
        y = ops.layernorm_op(
            x.reshape(-1, x.shape[-1]).astype(jnp.float32),
            jnp.asarray(scale, jnp.float32), jnp.asarray(bias, jnp.float32),
        )
        return y.reshape(x.shape)

    def _encoder_layer(self, h: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        lp = self.layer
        B, S, d = h.shape
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = _mm(h, lp["wq"]).reshape(B, S, H, hd)
        k = _mm(h, lp["wk"]).reshape(B, S, KV, hd)
        v = _mm(h, lp["wv"]).reshape(B, S, KV, hd)
        attn = ops.span_attention_op(
            q, k, v, self.spans, causal=False, bq=64, bk=64
        )
        attn = _mm(attn.reshape(B, S, H * hd), lp["wo"])
        h = self._ln(h + attn, lp["norm1_scale"], lp["norm1_bias"])
        up = _mm(h, lp["w_up"])
        act = jax.nn.gelu(up)
        mo = _mm(act, lp["w_down"])
        h = self._ln(h + mo, lp["norm2_scale"], lp["norm2_bias"])
        return h

    def _offramp_entropy(self, h: jnp.ndarray):
        """Pooler + classifier + fused softmax/entropy kernel (GB unit)."""
        o = self.offramp
        pooled = jnp.tanh(_mm(h[:, 0, :], o["pooler_w"]) + o["pooler_b"])
        logits = _mm(pooled, o["cls_w"]) + o["cls_b"]
        probs, ent = ops.softmax_entropy_op(logits)
        return logits, ent

    # -------------------------------------------------------------- public --
    def classify(self, tokens: jnp.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Early-exit classification. tokens [B, S] -> (logits [B,C], exit [B]).

        Layer-by-layer host loop (the accelerator's serial schedule): lanes
        that clear the entropy threshold stop computing.  Each sentence's
        off-ramp entropy trace is kept in ``self.last_entropy_traces`` so a
        DVFS controller can replay Alg. 1 over it (``classify_with_dvfs``).
        """
        cfg = self.cfg
        h = jnp.take(self.embed_tok, tokens, axis=0)
        if self.embed_proj is not None:
            h = _mm(h, self.embed_proj)
        if self.embed_pos is not None:
            h = h + self.embed_pos[None, : tokens.shape[1]]
        B = tokens.shape[0]
        done = np.zeros(B, bool)
        out_logits = np.zeros((B, cfg.edgebert.early_exit.num_classes), np.float32)
        exit_layer = np.full(B, cfg.n_layers, np.int32)
        self.last_entropy_traces = [[] for _ in range(B)]
        h = jnp.asarray(h, jnp.float32)
        for li in range(cfg.n_layers):
            active = np.nonzero(~done)[0]
            if len(active) == 0:
                break
            h_act = self._encoder_layer(h[active])
            h = jnp.asarray(np.asarray(h).copy())
            h = h.at[jnp.asarray(active)].set(h_act)
            logits, ent = self._offramp_entropy(h_act)
            ent = np.asarray(ent)
            lg = np.asarray(logits)
            for j, i in enumerate(active):
                self.last_entropy_traces[i].append(float(ent[j]))
                if ent[j] < self.threshold or li == cfg.n_layers - 1:
                    done[i] = True
                    out_logits[i] = lg[j]
                    exit_layer[i] = li + 1
        return out_logits, exit_layer

    def classify_with_dvfs(
        self, tokens: jnp.ndarray, controller, arbiter=None, deadlines_s=None
    ):
        """Kernel-path classification + DVFS schedule.

        Returns (logits [B, C], exit_layer [B], reports) — the deployed
        counterpart of the serving engine's DVFS telemetry, with every hot op
        running on the Pallas kernels.

        Without ``arbiter``: per-sentence Alg. 1 replay (``DVFSReport`` each)
        — the single-stream analysis.  With a ``BatchedDVFSArbiter``: the
        batch shares ONE LDO/ADPLL, so the whole lock-step batch is
        arbitrated step-by-step (one (V, f) per layer step, switching stalls
        charged) and per-sentence ``LaneDVFSReport``s come back instead.
        ``deadlines_s`` (length-B, entries optional) gives each sentence its
        own latency budget; ``None`` entries use the controller target.
        """
        logits, exit_layer = self.classify(tokens)
        assert deadlines_s is None or len(deadlines_s) == len(exit_layer)
        if arbiter is not None:
            assert arbiter.c is controller, (
                "arbiter was built over a different controller than the one "
                "passed — its reports would reflect the wrong target/table"
            )
            reports = arbiter.replay_batch(
                self.last_entropy_traces, exit_layer, deadlines_s=deadlines_s
            )
        else:
            reports = [
                controller.sentence_report(
                    trace,
                    exit_layer=int(el),
                    target_latency_s=(
                        None if deadlines_s is None else deadlines_s[i]
                    ),
                )
                for i, (trace, el) in enumerate(
                    zip(self.last_entropy_traces, exit_layer)
                )
            ]
        return logits, exit_layer, reports


def deploy_albert(
    params: Dict[str, Any],
    cfg: ModelConfig,
    *,
    fmt: AFFormat = AFFormat(8, 3),
    envm_cell: str = "MLC2",
    seed: int = 0,
) -> DeployedAlbert:
    assert cfg.family == "albert" and cfg.shared_layers
    lp = params["layer"]
    enc = {
        "wq": _encode_w(lp["attn"]["wq"], fmt),
        "wk": _encode_w(lp["attn"]["wk"], fmt),
        "wv": _encode_w(lp["attn"]["wv"], fmt),
        "wo": _encode_w(lp["attn"]["wo"], fmt),
        "w_up": _encode_w(lp["mlp"]["w_up"], fmt),
        "w_down": _encode_w(lp["mlp"]["w_down"], fmt),
        # LN params stay dense/fp (paper keeps them unpruned/unquantized-critical)
        "norm1_scale": lp["norm1"]["scale"],
        "norm1_bias": lp["norm1"]["norm_bias"],
        "norm2_scale": lp["norm2"]["scale"],
        "norm2_bias": lp["norm2"]["norm_bias"],
    }
    o = params["offramp"]
    offramp = {
        "pooler_w": _encode_w(o["offramp_pooler_w"], fmt),
        "pooler_b": jnp.asarray(o["offramp_pooler_b"], jnp.float32),
        "cls_w": _encode_w(o["offramp_cls_w"], fmt),
        "cls_b": jnp.asarray(o["offramp_cls_b"], jnp.float32),
    }
    # embeddings through the eNVM round-trip (SLC bitmask + MLC data cells)
    emb_rb, _ = envm.store_and_readback(
        np.asarray(params["embed"]["tok"], np.float32), data_cell=envm_cell,
        fmt=fmt, seed=seed,
    )
    spans = (
        hard_spans(np.asarray(params["span_z"])[0])
        if "span_z" in params
        else np.full(cfg.n_heads, cfg.edgebert.span.max_span, np.int32)
    )
    return DeployedAlbert(
        cfg=cfg,
        embed_tok=jnp.asarray(emb_rb),
        embed_proj=_encode_w(params["embed"]["proj"], fmt) if "proj" in params["embed"] else None,
        embed_pos=jnp.asarray(params["embed"]["pos"], jnp.float32) if "pos" in params["embed"] else None,
        layer=enc,
        offramp=offramp,
        spans=spans,
        threshold=cfg.edgebert.early_exit.entropy_threshold,
    )
