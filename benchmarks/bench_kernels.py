"""Kernel microbenchmarks.

Interpret-mode wall time is a Python-emulation artifact, so per-kernel we
report (a) the jnp REFERENCE implementation's XLA:CPU wall time (a real
compiled baseline), (b) kernel-vs-ref max error, and (c) the kernel's modeled
TPU utility: FLOPs and the VMEM-resident traffic it avoids vs the unfused ref
(the quantity that shows up in the roofline memory term)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_us
from repro.core.adaptivfloat import af_encode
from repro.kernels import ref
from repro.kernels.adaptivfloat_k import af_matmul, quantize
from repro.kernels.block_sparse import block_sparse_matmul
from repro.kernels.layernorm import layernorm
from repro.kernels.softmax_entropy import softmax_entropy
from repro.kernels.span_attention import span_attention


def _r(shape, seed=0, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


def main() -> None:
    # layernorm
    x = _r((4096, 768), 0, 3.0)
    g, b = _r((768,), 1), _r((768,), 2)
    us = time_us(jax.jit(lambda x: ref.layernorm(x, g, b)), x)
    err = float(jnp.abs(layernorm(x[:256], g, b) - ref.layernorm(x[:256], g, b)).max())
    emit("kernel_layernorm_4096x768", us, f"ref_xla_cpu;kernel_err={err:.1e}")

    # softmax+entropy fused
    lg = _r((2048, 128), 3, 5.0)
    mask = jnp.ones_like(lg)
    us = time_us(jax.jit(lambda l: ref.softmax_entropy(l, mask)), lg)
    p1, h1 = softmax_entropy(lg[:256], mask[:256])
    p2, h2 = ref.softmax_entropy(lg[:256], mask[:256])
    emit(
        "kernel_softmax_entropy_2048x128", us,
        f"ref_xla_cpu;kernel_err={float(jnp.abs(p1-p2).max()):.1e};"
        "fused_saves=1 extra pass over scores (entropy from same tile)",
    )

    # AF quantize
    w = _r((1024, 1024), 4, 2.0)
    us = time_us(jax.jit(lambda w: ref.adaptivfloat_quantize(w)), w)
    err = float(jnp.abs(quantize(w[:128]) - ref.adaptivfloat_quantize(w[:128])).max())
    emit("kernel_af_quantize_1024x1024", us, f"ref_xla_cpu;kernel_err={err:.1e}")

    # AF8 matmul: halves weight HBM traffic
    codes, e_min = af_encode(w)
    x2 = _r((256, 1024), 5)
    us = time_us(jax.jit(lambda x, c: ref.af_matmul(x, c, e_min)), x2, codes)
    got = af_matmul(x2[:64], codes, e_min, bm=64, bk=128, bn=128)
    want = ref.af_matmul(x2[:64], codes, e_min)
    emit(
        "kernel_af_matmul_256x1024x1024", us,
        f"ref_xla_cpu;kernel_err={float(jnp.abs(got-want).max()):.1e};"
        f"hbm_weight_traffic=0.5x vs bf16 (af8 codes)",
    )

    # block-sparse matmul at 50% block density: ~2x tile skip
    rng = np.random.default_rng(6)
    bmask = rng.random((8, 8)) < 0.5
    full = np.repeat(np.repeat(bmask, 128, 0), 128, 1)
    ws = jnp.asarray(rng.normal(size=(1024, 1024)) * full, jnp.float32)
    us = time_us(
        jax.jit(lambda x, w: ref.block_sparse_matmul(x, w, jnp.asarray(bmask), 128, 128)),
        x2, ws,
    )
    density = bmask.mean()
    emit(
        "kernel_block_sparse_1024_d50", us,
        f"ref_xla_cpu;tiles_visited={density:.2f}x_dense;"
        f"modeled_tpu_speedup={1/density:.2f}x",
    )

    # span attention: windowed kv loop
    B, H, S, dh = 1, 12, 128, 64
    q, k, v = _r((B, H, S, dh), 7), _r((B, H, S, dh), 8), _r((B, H, S, dh), 9)
    spans = jnp.asarray([20, 0, 0, 0, 0, 0, 36, 81, 0, 0, 0, 10], jnp.int32)
    us = time_us(
        jax.jit(lambda q, k, v: ref.span_attention(q, k, v, spans, causal=False)),
        q, k, v,
    )
    from repro.core.adaptive_span import span_flop_factor

    f = span_flop_factor(np.asarray(spans), H, S)
    emit(
        "kernel_span_attention_albert128", us,
        f"ref_xla_cpu;score_flops_kept={f:.3f};heads_skipped=8/12;"
        "kv_blocks_visited=window-bounded",
    )


if __name__ == "__main__":
    main()
