"""Multi-device sharded serving: step-throughput scaling 1 -> 4 replicas.

The tentpole claim of the sharded serving stack is that ONE ``LaneScheduler``
can drive ``replicas x batch_lanes`` concurrent requests by ``shard_map``-ing
the fused per-bucket step over a ``("data",)`` mesh, with one DVFS clock
domain (``BatchedDVFSArbiter``) per replica and feasibility-routed admission
pinning contracts to replicas.  This benchmark measures the claim end to end:

  * the SAME mixed queue (best-effort + explicit contracts admitted at their
    OWN feasibility quote) drains through a 1-replica server and a 4-replica
    server, each in its own subprocess with the host platform forced to that
    many devices;
  * throughput is retired requests per fused dense step on a WARM server (a
    cold drain compiles first; the warm drain must add ZERO new traces per
    (bucket, replica));
  * gates: warm requests/step must scale >= --min-scaling (default 3.0x)
    from 1 to 4 replicas, zero accepted-SLO misses, zero warm-added traces,
    and at most one compile per (bucket, replica) pair.

Each run appends a ``sharded_serving`` entry to the versioned
``BENCH_serving.json`` history (see ``benchmarks.common.append_bench_history``).

Multi-device-on-CPU recipe: XLA only exposes one CPU device by default; to
get N host devices (and therefore an N-replica ``("data",)`` mesh) the flag
must be set BEFORE jax initializes::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python benchmarks/bench_sharded_serving.py --smoke

This driver sets the flag itself by re-exec'ing ``--child --replicas N``
subprocesses, so the parent process's own device count never matters.

Usage:
  python benchmarks/bench_sharded_serving.py --smoke    # untrained, CI-fast
  python benchmarks/bench_sharded_serving.py            # trained toy EdgeBERT
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)

_FORCE_FLAG = "--xla_force_host_platform_device_count"


# ---------------------------------------------------------------------------
# Child: one (replicas, forced-device-count) measurement
# ---------------------------------------------------------------------------


def _child(args) -> None:
    """Drain the queue at ``--replicas`` and print one RESULT json line."""
    from benchmarks.bench_batched_dvfs import LANES, _mixed_queue, _setup
    from repro.hwmodel.edgebert_accel import albert_layer_stats
    from repro.serving.admission import AdmissionController
    from repro.serving.dvfs import (
        BatchedDVFSArbiter,
        LatencyAwareDVFSController,
        no_early_exit_baseline,
    )
    from repro.serving.engine import ClassifierServer, Request

    model, params, cfg, data, _thr = _setup(args.smoke)
    buckets = (16, 32) if data.seq_len <= 32 else (32, 64, data.seq_len)
    stats = albert_layer_stats(seq_len=max(buckets))
    stats.n_layers = cfg.n_layers
    target = no_early_exit_baseline(stats)["latency_s"] * args.target_mult

    ctrl = LatencyAwareDVFSController(stats, target)
    srv = ClassifierServer(
        model, params, batch_lanes=LANES, arbiter=BatchedDVFSArbiter(ctrl),
        buckets=buckets, replicas=args.replicas,
    )
    assert srv.replicas == args.replicas, (srv.replicas, args.replicas)
    ac = AdmissionController(srv)

    # mixed queue, every other PAIR an explicit contract admitted at its own
    # feasibility quote (covers both buckets on both sides of the split)
    reqs = _mixed_queue(data, buckets, args.queue, seed=31)
    for i, r in enumerate(reqs):
        if i % 4 in (1, 2):
            q = ac.quote(Request(uid=r.uid, tokens=r.tokens, deadline_s=1e9))
            d = ac.submit(Request(
                uid=r.uid, tokens=r.tokens, deadline_s=q.min_deadline_s
            ))
            assert d.admitted, f"own-quote contract {r.uid} rejected"
        else:
            srv.submit(Request(uid=r.uid, tokens=r.tokens))
    srv.run()                                  # cold drain: compiles + SLO gate
    cold = srv.telemetry()

    # warm drain: identical traffic, throughput measured, ZERO new traces
    for r in reqs:
        srv.submit(Request(uid=10_000 + r.uid, tokens=r.tokens))
    t0 = time.perf_counter()
    srv.run()
    wall = time.perf_counter() - t0
    warm = srv.telemetry()

    steps = warm["dense_steps"] - cold["dense_steps"]
    traces = warm["step_traces_per_bucket_replica"]
    res = {
        "replicas": srv.replicas,
        "lanes": srv.lanes,
        "requests": 2 * len(reqs),
        "warm_requests": len(reqs),
        "warm_dense_steps": steps,
        "requests_per_step": len(reqs) / steps,
        "warm_wall_s": wall,
        "accepted": warm["accepted"],
        "accepted_slo_misses": warm["accepted_slo_misses"],
        "warm_added_step_traces": warm["step_traces"] - cold["step_traces"],
        "step_traces_per_bucket_replica": traces,
        "max_traces_per_bucket_replica": max(traces.values()),
        "bucket_count": len(buckets),
        "arb_energy_j": warm["arb_energy_j"],
    }
    print("RESULT " + json.dumps(res), flush=True)


# ---------------------------------------------------------------------------
# Parent: spawn 1- and 4-replica children, gate the scaling
# ---------------------------------------------------------------------------


def _spawn(replicas: int, args) -> dict:
    env = dict(os.environ)
    keep = [t for t in env.get("XLA_FLAGS", "").split()
            if not t.startswith(_FORCE_FLAG)]
    env["XLA_FLAGS"] = " ".join(keep + [f"{_FORCE_FLAG}={replicas}"])
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_ROOT, "src"), _ROOT,
                    env.get("PYTHONPATH", "")) if p
    )
    cmd = [
        sys.executable, os.path.abspath(__file__), "--child",
        "--replicas", str(replicas), "--queue", str(args.queue),
        "--target-mult", str(args.target_mult),
    ]
    if args.smoke:
        cmd.append("--smoke")
    proc = subprocess.run(
        cmd, env=env, capture_output=True, text=True, timeout=1800
    )
    if proc.returncode != 0:
        sys.stderr.write(proc.stdout + proc.stderr)
        raise RuntimeError(f"child (replicas={replicas}) failed")
    lines = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    assert lines, f"no RESULT line from child (replicas={replicas})"
    return json.loads(lines[-1][len("RESULT "):])


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--smoke", action="store_true",
                        help="untrained weights, CI-fast")
    parser.add_argument("--queue", type=int, default=64,
                        help="requests per drain (cold and warm each)")
    parser.add_argument("--target-mult", type=float, default=1.5)
    parser.add_argument("--min-scaling", type=float, default=3.0,
                        help="required warm requests/step ratio 4 vs 1 replica")
    parser.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    parser.add_argument("--replicas", type=int, default=1, help=argparse.SUPPRESS)
    args, _ = parser.parse_known_args()  # tolerate the suite runner's argv

    if args.child:
        _child(args)
        return

    from benchmarks.common import append_bench_history, emit, git_tag

    res = {n: _spawn(n, args) for n in (1, 4)}
    t1 = res[1]["requests_per_step"]
    t4 = res[4]["requests_per_step"]
    scaling = t4 / t1
    misses = sum(r["accepted_slo_misses"] for r in res.values())
    warm_added = sum(r["warm_added_step_traces"] for r in res.values())
    max_traces = max(r["max_traces_per_bucket_replica"] for r in res.values())
    bucket_count = res[4]["bucket_count"]

    emit(
        "sharded_serving", 0.0,
        f"requests_per_step_1={t1:.3f};requests_per_step_4={t4:.3f};"
        f"scaling={scaling:.2f};accepted_slo_misses={misses};"
        f"warm_added_traces={warm_added};"
        f"max_traces_per_bucket_replica={max_traces};"
        f"bucket_count={bucket_count};lanes_4={res[4]['lanes']};"
        f"queue={args.queue}",
    )

    append_bench_history(os.path.join(_ROOT, "BENCH_serving.json"), {
        "scenario": "sharded_serving",
        "backend": "cpu-forced-host-devices",
        "device_count": 4,
        "tag": git_tag(),
        "queue": args.queue,
        "target_mult": args.target_mult,
        "scaling_requests_per_step": scaling,
        "replicas_1": res[1],
        "replicas_4": res[4],
    })
    print("appended sharded_serving entry to BENCH_serving.json", flush=True)

    ok = True
    if scaling < args.min_scaling:
        print(
            f"FAIL: warm requests/step scaled only {scaling:.2f}x from 1 to "
            f"4 replicas ({t1:.3f} -> {t4:.3f}); want >= {args.min_scaling}x"
        )
        ok = False
    if misses:
        print(f"FAIL: {misses} accepted-SLO misses across sharded drains")
        ok = False
    if warm_added:
        print(f"FAIL: warm drain added {warm_added} fused-step traces")
        ok = False
    if max_traces > 1:
        print(
            f"FAIL: some (bucket, replica) pair compiled {max_traces}x "
            "(want exactly one trace per pair)"
        )
        ok = False
    if not ok:
        sys.exit(1)
    print("sharded_serving gates passed", flush=True)


if __name__ == "__main__":
    main()
