"""Deployed EdgeBERT (serving/deploy.py): the full accelerator dataflow on
Pallas kernels matches the quantized model within AF8 tolerance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core.adaptivfloat import AFFormat, quantize_pytree
from repro.models.model import build_model
from repro.serving.deploy import deploy_albert


def test_deployed_matches_quantized_model():
    cfg = dataclasses.replace(
        get_smoke_config("albert_edgebert"), dtype="float32", remat_policy="none"
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 32), 0, cfg.vocab_size)

    # smoke: mixed spans with dead heads (deploy gathers them out; the hard-
    # span semantics themselves are oracle-tested in test_kernels.py)
    p_mixed = dict(params, span_z=jnp.asarray([[0.0, 24.0, 0.0, 48.0]], jnp.float32))
    dep_mixed = deploy_albert(p_mixed, cfg, envm_cell="SLC")
    logits, exit_layer = dep_mixed.classify(toks)
    assert np.isfinite(logits).all()
    assert ((exit_layer >= 1) & (exit_layer <= cfg.n_layers)).all()

    # numeric comparison: spans >= S so hard (deploy) and soft (train-time
    # reference) masks are both all-ones — isolates the AF8 kernel pipeline
    params = dict(params, span_z=jnp.full((1, cfg.n_heads), 64.0, jnp.float32))
    dep = deploy_albert(params, cfg, envm_cell="SLC")  # SLC: no fault noise

    # reference: jnp model with AF8-quantized weights + hard spans baked in.
    # disable early exit in the reference by comparing the deployed run with
    # threshold 0 (never exits early) against the full-depth quantized model.
    dep.threshold = 0.0
    logits_full, exit_full = dep.classify(toks)
    assert (exit_full == cfg.n_layers).all()

    pq = quantize_pytree(
        params, AFFormat(8, 3),
        predicate=lambda p, l: "norm" not in str(p).lower(),
    )
    out = build_model(cfg).apply_train(pq, {"tokens": toks})
    want = np.asarray(out.all_cls_logits[-1])
    # AF8 activations-in-fp32 vs fake-quant paths differ slightly; decisions agree
    assert (np.argmax(logits_full, -1) == np.argmax(want, -1)).all()
    np.testing.assert_allclose(logits_full, want, atol=0.35)
