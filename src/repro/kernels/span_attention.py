"""Span-windowed flash attention Pallas kernel (paper §III-B + §V-D1).

EdgeBERT writes the learned per-head spans into accelerator registers and
predicates attention compute on them.  The TPU adaptation (DESIGN.md §2):

  * heads with span 0 are gathered OUT of the call entirely (ops.py);
  * surviving heads run this kernel with a static window W (the bucket's max
    span, rounded up to the kv block): the kv-block loop visits only
    ceil((W + bq [+W bidi]) / bk) + 1 blocks per q block instead of Sk/bk —
    block-level predication, so out-of-span tiles are never DMA'd;
  * each head's exact integer span masks element-wise inside the tile
    (spans ride in via scalar prefetch), preserving ref semantics;
  * online max/LogSumExp softmax = the paper's Algorithm 1 at tile scope.

Layout: q/k/v are [BH, S, dh] with k/v pre-expanded per active head (GQA
gather fused by XLA upstream).  fp32 accumulate (the PU's 32-bit accumulator).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _span_attn_kernel(
    meta_ref,            # scalar prefetch: [2, BH] int32 — row 0 spans,
                         # row 1 per-row valid key counts (kv_lens)
    q_ref,               # [1, bq, dh]
    k_ref,               # [1, bk, dh]
    v_ref,               # [1, bk, dh]
    o_ref,               # [1, bq, dh]
    m_ref,               # VMEM [bq]
    l_ref,               # VMEM [bq]
    acc_ref,             # VMEM [bq, dh]
    *,
    bq: int,
    bk: int,
    n_s: int,
    n_kb: int,
    sq: int,
    sk: int,
    window: int,
    causal: bool,
    scale: float,
):
    bh = pl.program_id(0)
    qi = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    base = _base_block(qi, bq, bk, window, causal)
    k_blk = base + s
    last_needed = _last_block(qi, bq, bk, window, causal, n_kb)

    @pl.when(jnp.logical_and(k_blk < n_kb, k_blk <= last_needed))
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale            # [bq, dh]
        k = k_ref[0].astype(jnp.float32)                    # [bk, dh]
        scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bk]

        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_blk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        d = q_pos - k_pos
        span = meta_ref[0, bh]
        kvl = meta_ref[1, bh]
        if causal:
            ok = (d >= 0) & (d < span)
        else:
            ok = (jnp.abs(d) < span)
        # kvl masks this ROW's padding (engine lanes are right-padded to the
        # bucket length); sk masks the call-level block padding
        ok = ok & (k_pos < kvl) & (k_pos < sk) & (q_pos < sq)
        scores = jnp.where(ok, scores, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _emit():
        l = l_ref[...]
        out = acc_ref[...] / jnp.maximum(l, 1e-20)[:, None]
        out = jnp.where((l > 0.0)[:, None], out, 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


def _base_block(qi, bq, bk, window, causal, np_mode=False):
    """First kv block a q block needs: covers q_start - (window-1) keys
    (bidirectional also looks forward, handled by last block)."""
    mx = np.maximum if np_mode else jnp.maximum
    q_start = qi * bq
    lo = q_start - (window - 1)
    return mx(lo // bk, 0)


def _last_block(qi, bq, bk, window, causal, n_kb, np_mode=False):
    mn = np.minimum if np_mode else jnp.minimum
    q_end = qi * bq + bq - 1
    hi = q_end if causal else q_end + (window - 1)
    return mn(hi // bk, n_kb - 1)


def span_attention(
    q: jnp.ndarray,              # [BH, Sq, dh]
    k: jnp.ndarray,              # [BH, Sk, dh] (expanded per head)
    v: jnp.ndarray,              # [BH, Sk, dh]
    spans: jnp.ndarray,          # [BH] int32 exact spans (> 0)
    window: int,                 # STATIC max span in this bucket
    *,
    causal: bool,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
    kv_lens: jnp.ndarray = None,  # [BH] int32 valid keys per row (right-
                                  # padded inputs); None = all Sk keys valid
) -> jnp.ndarray:
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    bq_, bk_ = min(bq, Sq), min(bk, Sk)
    pq, pk_ = (-Sq) % bq_, (-Sk) % bk_
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk_:
        k = jnp.pad(k, ((0, 0), (0, pk_), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk_), (0, 0)))
    n_qb = q.shape[1] // bq_
    n_kb = k.shape[1] // bk_

    # static worst-case kv steps per q block (the whole point of the kernel:
    # n_s << n_kb when window << Sk)
    span_blocks = (window - 1) // bk_ + 1
    if causal:
        n_s = min((bq_ - 1) // bk_ + 1 + span_blocks, n_kb)
    else:
        n_s = min((bq_ - 1) // bk_ + 1 + 2 * span_blocks, n_kb)

    kernel = functools.partial(
        _span_attn_kernel,
        bq=bq_, bk=bk_, n_s=n_s, n_kb=n_kb, sq=Sq, sk=Sk,
        window=window, causal=causal, scale=scale,
    )

    def q_index(bh, qi, s, meta):
        return (bh, qi, 0)

    def kv_index(bh, qi, s, meta):
        base = _base_block(qi, bq_, bk_, window, causal)
        return (bh, jnp.minimum(base + s, n_kb - 1), 0)

    if kv_lens is None:
        kv_lens = jnp.full((BH,), Sk, jnp.int32)
    meta = jnp.stack(
        [spans.astype(jnp.int32), jnp.broadcast_to(kv_lens, (BH,)).astype(jnp.int32)]
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(BH, n_qb, n_s),
            in_specs=[
                pl.BlockSpec((1, bq_, dh), q_index),
                pl.BlockSpec((1, bk_, dh), kv_index),
                pl.BlockSpec((1, bk_, dh), kv_index),
            ],
            out_specs=pl.BlockSpec((1, bq_, dh), q_index),
            scratch_shapes=[
                pltpu.VMEM((bq_,), jnp.float32),
                pltpu.VMEM((bq_,), jnp.float32),
                pltpu.VMEM((bq_, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(meta, q, k, v)
    return out[:, :Sq]
