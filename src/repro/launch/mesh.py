"""Production mesh construction.

A FUNCTION (never a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax use
and only then calls ``make_production_mesh``.

Single pod:  (data=16, model=16)            = 256 chips (one v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips

The ``pod`` axis is the slowest (DCN-connected) dimension: only data-parallel
gradient all-reduces cross it (and batch sharding for inference shapes), which
is the correct hierarchy for 1000+ node scale — model/expert collectives stay
inside a pod's ICI domain.
"""
from __future__ import annotations

from repro.common.jax_compat import make_auto_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU tests (requires forced host device count >= n*m)."""
    return make_auto_mesh((n_data, n_model), ("data", "model"))
