from repro.serving.engine import ClassifierServer, DecoderServer, Request, MultiTaskRouter
from repro.serving.dvfs import (
    DEFAULT_DVFS_TABLE,
    DVFSReport,
    LatencyAwareDVFSController,
    OperatingPoint,
    calibrate_predictor,
    default_albert_controller,
    no_early_exit_baseline,
)
