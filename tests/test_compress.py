"""int8 error-feedback gradient compression: unbiasedness via error feedback +
convergence parity on a toy problem (single-device axis: psum is identity,
which still exercises quantize/dequantize + EF accumulation)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.jax_compat import make_auto_mesh, shard_map
from repro.training.compress import EFState, compressed_psum, ef_init


def _dp_mesh():
    return make_auto_mesh((1,), ("dp",))


def test_error_feedback_accumulates():
    g = {"w": jnp.array([0.001, 1.0, -0.3])}
    ef = ef_init(g)

    def run(g, ef):
        return shard_map(
            lambda gg: compressed_psum(gg, ef, "dp", 1),
            mesh=_dp_mesh(),
            in_specs=(jax.sharding.PartitionSpec(),),
            out_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
        )(g)

    out, ef2 = run(g, ef)
    # quantization error captured in residual: g == out + residual
    np.testing.assert_allclose(
        np.asarray(g["w"]),
        np.asarray(out["w"]) + np.asarray(ef2.residual["w"]),
        atol=1e-6,
    )


def test_convergence_parity():
    """SGD with compressed grads converges to the same optimum (EF theory)."""
    target = jnp.array([0.5, -1.5, 2.0, 0.01])

    def loss(w):
        return 0.5 * jnp.sum((w - target) ** 2)

    mesh = _dp_mesh()
    P = jax.sharding.PartitionSpec

    w_plain = jnp.zeros(4)
    w_comp = jnp.zeros(4)
    ef = ef_init({"w": w_comp})
    lr = 0.2
    for _ in range(80):
        g_plain = jax.grad(loss)(w_plain)
        w_plain = w_plain - lr * g_plain

        g = {"w": jax.grad(loss)(w_comp)}
        out, ef = shard_map(
            lambda gg: compressed_psum(gg, ef, "dp", 1),
            mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
        )(g)
        w_comp = w_comp - lr * out["w"]

    np.testing.assert_allclose(np.asarray(w_comp), np.asarray(target), atol=1e-2)
    np.testing.assert_allclose(np.asarray(w_comp), np.asarray(w_plain), atol=1e-2)


def test_wire_payload_is_int8():
    """The all-reduced payload is the int8 code (4x compression vs fp32)."""
    g = {"w": jnp.linspace(-3, 3, 101)}
    ef = ef_init(g)

    def fake(gg):
        out, ef2 = compressed_psum(gg, ef, "dp", 1)
        return out, ef2

    jaxpr = jax.make_jaxpr(
        lambda gg: shard_map(
            fake,
            mesh=_dp_mesh(),
            in_specs=(jax.sharding.PartitionSpec(),),
            out_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
        )(gg)
    )(g)
    txt = str(jaxpr)
    assert "convert_element_type[new_dtype=int8" in txt
