"""Latency-aware sentence-level DVFS (paper Alg. 1) properties."""
import numpy as np
import pytest

from repro.core.early_exit import (
    ExitPredictor,
    fit_exit_predictor,
    predict_exit_layer,
)
from repro.hwmodel.edgebert_accel import VDD_NOM, albert_layer_stats
from repro.serving.dvfs import (
    DEFAULT_DVFS_TABLE,
    LatencyAwareDVFSController,
    OperatingPoint,
    no_early_exit_baseline,
)

N_LAYERS = 12


def _stats():
    s = albert_layer_stats(seq_len=64)
    s.n_layers = N_LAYERS
    return s


def _controller(target_mult=1.0, predictor=None):
    """Controller whose target is `target_mult` x the full-model latency."""
    target = no_early_exit_baseline(_stats())["latency_s"] * target_mult
    return LatencyAwareDVFSController(_stats(), target, predictor=predictor)


def _perfect_predictor(exit_layer: int) -> ExitPredictor:
    """A LUT that always predicts `exit_layer`."""
    return ExitPredictor(
        bin_edges=np.array([]), bin_exit=np.array([float(exit_layer)])
    )


def _trace(exit_layer: int):
    """Synthetic off-ramp entropy trace ending at `exit_layer`: entropy decays
    toward the exit (the shape the paper's Fig. 4 thresholds act on)."""
    return [1.0 * 0.8 ** i for i in range(exit_layer)]


class TestController:
    def test_meets_target_latency_without_predictor(self):
        # no predictor -> conservative full-depth prediction -> max V/f -> the
        # target (full-model latency) is met for every exit layer
        c = _controller(1.0)
        for exit_layer in (1, 4, 12):
            r = c.sentence_report(_trace(exit_layer))
            assert r.deadline_met
            assert r.latency_s <= c.target_latency_s * (1 + 1e-9)

    def test_meets_target_with_correct_prediction(self):
        c = _controller(1.0, predictor=_perfect_predictor(6))
        r = c.sentence_report(_trace(6))
        assert r.deadline_met
        assert r.exit_layer == 6 and r.predicted_exit == 6.0
        # the selected point is slower than nominal: that's the DVFS win
        assert r.op.freq_hz < c.max_op.freq_hz
        assert r.escalated_layers == 0

    def test_energy_monotone_as_budget_loosens(self):
        trace = _trace(6)
        energies = []
        for mult in (1.0, 1.25, 1.5, 2.0, 3.0, 5.0, 10.0):
            r = _controller(mult, predictor=_perfect_predictor(6)).sentence_report(trace)
            assert r.deadline_met
            energies.append(r.energy_j)
        assert all(a >= b - 1e-18 for a, b in zip(energies, energies[1:])), energies

    def test_max_freq_baseline_upper_bounds_controller(self):
        for mult in (1.0, 2.0, 5.0):
            for exit_layer in (1, 3, 9, 12):
                for pred in (None, _perfect_predictor(exit_layer)):
                    c = _controller(mult, predictor=pred)
                    r = c.sentence_report(_trace(exit_layer))
                    assert r.energy_j <= r.energy_max_freq_j * (1 + 1e-12)

    def test_misprediction_escalates_to_max_point(self):
        # predicted exit 4, actual exit 9: layers past the prediction run at
        # the max point; overshoot is bounded by the escalated layers
        c = _controller(1.5, predictor=_perfect_predictor(4))
        r = c.sentence_report(_trace(9))
        assert r.escalated_layers == 5
        t_max = c.layer_time_s(c.max_op)
        slow_budget = c.target_latency_s  # slow phase fits the target by design
        assert r.latency_s <= slow_budget + r.escalated_layers * t_max + 1e-12

    def test_select_op_is_slowest_sufficient(self):
        c = _controller(1.0)
        t_layer_max = c.layer_time_s(c.max_op)
        # 2 remaining layers, budget of 8 max-speed layers -> f >= fmax/4
        op = c.select_op(2.0, 8 * t_layer_max)
        assert op.freq_hz >= 2.0 * c.cycles_per_layer / (8 * t_layer_max)
        slower = [p for p in c.table if p.freq_hz < op.freq_hz]
        for p in slower:
            assert p.freq_hz < 2.0 * c.cycles_per_layer / (8 * t_layer_max)
        # no budget left -> max point
        assert c.select_op(2.0, 0.0) is c.max_op

    def test_table_energy_monotone_in_voltage(self):
        c = _controller(1.0)
        energies = [c.layer_energy(op) for op in c.table]
        assert all(a <= b + 1e-18 for a, b in zip(energies, energies[1:]))
        # top of table is the nominal design point
        assert c.max_op.vdd == VDD_NOM

    def test_no_early_exit_baseline_shape(self):
        c = _controller(1.0)
        b = c.no_early_exit_baseline()
        assert b["latency_s"] == pytest.approx(N_LAYERS * c.layer_time_s(c.max_op))
        assert b["energy_j"] == pytest.approx(N_LAYERS * c.layer_energy(c.max_op))

    def test_rejects_unsorted_voltage_table(self):
        bad = (OperatingPoint(0.8, 100e6), OperatingPoint(0.5, 500e6))
        with pytest.raises(AssertionError):
            LatencyAwareDVFSController(_stats(), 1.0, table=bad)


class TestExitPredictor:
    def test_fit_recovers_monotone_mapping(self):
        # low first-layer entropy -> early exit; high -> late (paper Fig. 4)
        rng = np.random.default_rng(0)
        ent = rng.uniform(0.0, 1.0, size=2000)
        exits = np.clip(np.round(1 + 10 * ent + rng.normal(0, 0.3, 2000)), 1, 12)
        p = fit_exit_predictor(ent, exits, n_bins=8)
        lo = predict_exit_layer(p, 0.05)
        hi = predict_exit_layer(p, 0.95)
        assert lo < hi
        assert abs(lo - 1.5) < 1.5 and abs(hi - 10.5) < 1.5

    def test_quantile_one_is_conservative(self):
        rng = np.random.default_rng(1)
        ent = rng.uniform(0.0, 1.0, size=500)
        exits = np.clip(np.round(1 + 10 * ent + rng.normal(0, 1.0, 500)), 1, 12)
        mean_p = fit_exit_predictor(ent, exits, n_bins=4)
        max_p = fit_exit_predictor(ent, exits, n_bins=4, quantile=1.0)
        for e in (0.1, 0.5, 0.9):
            assert predict_exit_layer(max_p, e) >= predict_exit_layer(mean_p, e)

    def test_empty_bins_interpolated(self):
        # two entropy clusters leave middle bins empty
        ent = np.concatenate([np.full(50, 0.1), np.full(50, 0.9)])
        exits = np.concatenate([np.full(50, 2.0), np.full(50, 10.0)])
        p = fit_exit_predictor(ent, exits, n_bins=16)
        mid = predict_exit_layer(p, 0.5)
        assert 2.0 <= mid <= 10.0

    def test_degenerate_single_value(self):
        p = fit_exit_predictor(np.full(10, 0.5), np.full(10, 3.0), n_bins=4)
        assert predict_exit_layer(p, 0.5) == pytest.approx(3.0)
