"""Paper Table III: MLC ReRAM fault-injection trials on the (pruned, AF8)
embedding table — mean/min accuracy per cell config + area density/latency."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, eval_accuracy, trained_albert
from repro.core import envm

N_TRIALS = 20


def main() -> None:
    model, params, _, data, cfg = trained_albert()
    emb = np.asarray(params["embed"]["tok"])
    for cell in ("SLC", "MLC2", "MLC3"):
        accs, rmses, faults = [], [], []
        for trial in range(N_TRIALS):
            rb, stats = envm.store_and_readback(emb, data_cell=cell, seed=trial)
            p = dict(params)
            p["embed"] = dict(params["embed"], tok=jnp.asarray(rb))
            accs.append(eval_accuracy(model, p, data, n_batches=2))
            rmses.append(float(np.sqrt(np.mean((rb - emb) ** 2))))
            faults.append(stats["n_code_faults"])
        cellcfg = envm.CELL_CONFIGS[cell]
        emit(
            f"table3_{cell.lower()}", 0.0,
            f"mean_acc={np.mean(accs):.3f};min_acc={np.min(accs):.3f};"
            f"readback_rmse={np.mean(rmses):.2e};code_faults={np.mean(faults):.1f};"
            f"area_mm2_per_MB={cellcfg.area_mm2_per_mb};read_ns={cellcfg.read_latency_ns}",
        )


if __name__ == "__main__":
    main()
