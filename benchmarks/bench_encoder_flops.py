"""Paper Fig. 8: ALBERT transformer-encoder compute at S=128 (~1.9 GFLOP for
the 12-layer pass) — analytic vs trip-count-aware HLO measurement of our
model, full published ALBERT dims."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs.albert_base import CONFIG as ALBERT
from repro.hwmodel.edgebert_accel import albert_layer_stats
from repro.hwmodel.hlo_analysis import analyze
from repro.models.model import build_model


def main() -> None:
    stats = albert_layer_stats(seq_len=128)
    per_layer = stats.matmul_flops + stats.attention_score_flops
    # paper Fig. 8 counts the SHARED encoder block (one layer pass) at S=128
    emit("fig8_analytic_shared_layer", 0.0, f"GFLOP={per_layer/1e9:.2f} (paper ~1.9)")
    emit("fig8_analytic_12layer_pass", 0.0, f"GFLOP={12*per_layer/1e9:.2f}")

    cfg = dataclasses.replace(ALBERT, dtype="float32", remat_policy="none",
                              num_classes=0, edgebert=ALBERT.edgebert)
    model = build_model(cfg)
    params_abs = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
    tokens = jax.ShapeDtypeStruct((1, 128), jnp.int32)
    compiled = (
        jax.jit(lambda p, t: model.apply_train(p, {"tokens": t}).logits)
        .lower(params_abs, tokens)
        .compile()
    )
    res = analyze(compiled.as_text())
    emit(
        "fig8_hlo_measured", 0.0,
        f"GFLOP={res.flops/1e9:.2f};includes_lm_head_and_embed_proj=true",
    )


if __name__ == "__main__":
    main()
