"""Serving driver: early-exit classification (the paper's workload) or LM
decode, via the continuation-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch albert_edgebert --smoke \
        --requests 32 --threshold 0.4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.common.util import logger
from repro.configs.base import get_config, get_smoke_config
from repro.data.synthetic import SyntheticCLS, SyntheticLM
from repro.models.model import build_model
from repro.serving.engine import ClassifierServer, DecoderServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="albert_edgebert")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--threshold", type=float, default=None)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    if args.threshold is not None and cfg.edgebert.early_exit.enabled:
        cfg = cfg.with_edgebert(
            early_exit=dataclasses.replace(
                cfg.edgebert.early_exit, entropy_threshold=args.threshold
            )
        )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    t0 = time.time()
    if cfg.family == "albert" and cfg.edgebert.early_exit.enabled:
        data = SyntheticCLS(cfg.vocab_size, args.seq, args.requests,
                            num_classes=cfg.edgebert.early_exit.num_classes, seed=args.seed)
        batch = data.batch(0)
        server = ClassifierServer(model, params, batch_lanes=args.lanes)
        for i in range(args.requests):
            server.submit(Request(uid=i, tokens=batch["tokens"][i]))
        stats = server.run()
        logger.info(
            "served %d sentences: avg_exit=%.2f/%d runtime_savings=%.1f%% layer_calls=%d (%.1fs)",
            stats["sentences"], stats["avg_exit_layer"], cfg.n_layers,
            100 * stats["runtime_savings"], stats["layer_calls"], time.time() - t0,
        )
    else:
        data = SyntheticLM(cfg.vocab_size, args.seq, args.requests, seed=args.seed)
        batch = data.batch(0)
        server = DecoderServer(model, params, batch_lanes=args.lanes, max_seq=args.seq + args.max_new_tokens + 8)
        for i in range(args.requests):
            server.submit(Request(uid=i, tokens=batch["tokens"][i][:16],
                                  max_new_tokens=args.max_new_tokens))
        stats = server.run()
        logger.info("decode: %s (%.1fs)", stats, time.time() - t0)


if __name__ == "__main__":
    main()
