"""whisper-medium [audio] — enc-dec, 24L each, d_model=1024 16H (kv=16) d_ff=4096
vocab=51865. Conv frontend is a STUB: ``input_specs()`` supplies precomputed
(B, 1500, d_model) frame embeddings (30 s x 50 Hz).  [arXiv:2212.04356; unverified]

Shape-sheet seq_len applies to the DECODER; encoder frames fixed at 1500.
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,          # decoder layers
    n_enc_layers=24,
    enc_seq_len=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    act="gelu",
    norm="layernorm",
    pos="learned",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        name="whisper-medium-smoke",
        n_layers=2,
        n_enc_layers=2,
        enc_seq_len=32,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        max_seq_len=256,
    )
