"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) expert d_ff=1408
vocab=151936, 60 routed experts top-4 + shared expert (4x1408=5632).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

Sharding note: 60 experts do not divide the 16-way model axis -> this arch
overrides expert-parallel with expert-TP (moe_d_ff 1408 = 16*88).
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    moe_d_ff=1408,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    shared_expert_d_ff=5632,
    vocab_size=151936,
    act="swiglu",
    norm="rms",
    pos="rope",
    qkv_bias=True,
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        name="qwen2-moe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=64,
        moe_d_ff=64,
        n_experts=6,
        top_k=2,
        n_shared_experts=1,
        shared_expert_d_ff=128,
        vocab_size=512,
        max_seq_len=256,
    )
