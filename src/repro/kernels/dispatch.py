"""Ref/Pallas dispatch for the fused serving step (`use_pallas=`).

The serving engines build their fused per-bucket step out of the model's
layer math; this module is the single seam where that math can be routed to
the Pallas kernels instead of the reference jnp ops.  Call sites guard with
``if use_pallas:`` so the ref path stays byte-identical when the flag is off.

Rules the dispatchers obey (the engine's compile guarantees depend on them):

  * `use_pallas` is a plain Python bool closed over by the engine's jit'd
    closures — static, so flipping it costs one trace per bucket, same as
    the ref path (zero-NEW-traces per request either way);
  * everything traced stays traced: per-lane `kv_len` rides into the span
    kernel via scalar prefetch, spans/shapes/block masks are static;
  * on CPU (no TPU backend) kernels run in interpret mode — the same
    `pallas_call`s execute their bodies in Python, so CI exercises the
    exact kernel code paths that Mosaic compiles on TPU.

Eligibility notes:
  * soft (trained) spans taper probabilities over a ramp; the hard-window
    span kernel cannot reproduce that, so `span_z is not None` call sites
    keep ref attention.  Dense/no-span attention routes to the span kernel
    with a full window plus per-row kv_len masking.
  * KV-cache decode attention stays ref (cache update + AF8 codec are
    fused with the attention math there).
  * block-sparse MLP needs a STATIC occupancy mask; `mlp_block_masks`
    derives one host-side from concrete (pruned) weights at server build
    time.  All-occupied masks are reported as None (dense weights gain
    nothing from tile skipping).
  * every dispatcher stays eligible INSIDE `shard_map` (the multi-device
    serving path): `pallas_call` has no replication rule, so the sharded
    fused-step wrappers must go through `jax_compat.shard_map_norep`
    (check_rep/check_vma off).  Nothing here may introduce a cross-shard
    collective — each kernel sees only its replica's `[lanes_per_replica,
    ...]` slab, which is what keeps a 1-replica mesh bit-identical to the
    unsharded step.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptivfloat import AFFormat
from repro.kernels import adaptivfloat_k, block_sparse
from repro.kernels import layernorm as _ln_k
from repro.kernels import softmax_entropy as _sm_k
from repro.kernels import span_attention as _span_k


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# LayerNorm (Eq. 5 running moments)
# ---------------------------------------------------------------------------


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              *, eps: float = 1e-6) -> jnp.ndarray:
    """Fused two-moment LayerNorm over the last axis; any leading shape."""
    shape = x.shape
    out = _ln_k.layernorm(
        x.reshape(-1, shape[-1]), scale, bias, eps=eps, interpret=_interpret()
    )
    return out.reshape(shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Off-ramp entropy (Eq. 4)
# ---------------------------------------------------------------------------


def entropy(logits: jnp.ndarray) -> jnp.ndarray:
    """Entropy of softmax(logits) over the last axis -> logits.shape[:-1].

    The all-ones mask is deliberate: off-ramp logits are [lanes, C] class
    scores with no padded positions (lane padding is masked upstream, in
    attention, via kv_len) — see `ops.softmax_entropy_op`.
    """
    shape = logits.shape
    x2 = logits.reshape(-1, shape[-1])
    _, h = _sm_k.softmax_entropy(x2, jnp.ones_like(x2), interpret=_interpret())
    return h.reshape(shape[:-1])


# ---------------------------------------------------------------------------
# AdaptivFloat activation fake-quant
# ---------------------------------------------------------------------------


def act_quantize(x: jnp.ndarray, n_bits: int, n_exp: int) -> jnp.ndarray:
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
    out = adaptivfloat_k.quantize(
        x2, fmt=AFFormat(n_bits, n_exp), interpret=_interpret()
    )
    return out.reshape(shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense (full-window) attention via the span kernel
# ---------------------------------------------------------------------------


def dense_attention(
    q: jnp.ndarray,              # [B, Sq, H, dh]
    k: jnp.ndarray,              # [B, Sk, KV, dh]
    v: jnp.ndarray,              # [B, Sk, KV, dh]
    *,
    causal: bool,
    kv_len: Any = None,          # scalar (may be traced) valid key count
    bq: int = 128,
    bk: int = 128,
) -> jnp.ndarray:
    """Span kernel with window = Sk (full attention) + per-row kv_len mask.

    This is the serving fused-step attention: lanes are right-padded to the
    bucket length and each lane's true length arrives as a traced scalar,
    which rides into the kernel through scalar prefetch.
    """
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Sk, dh)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Sk, dh)
    spans = jnp.full((B * H,), Sk, jnp.int32)
    kvl = None
    if kv_len is not None:
        kvl = jnp.broadcast_to(
            jnp.asarray(kv_len, jnp.int32).reshape(()), (B * H,)
        )
    out = _span_k.span_attention(
        qh, kh, vh, spans, Sk,
        causal=causal, bq=bq, bk=bk, interpret=_interpret(), kv_lens=kvl,
    )
    return out.reshape(B, H, Sq, dh).transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# Block-sparse MLP matmuls (§V-C tile skip)
# ---------------------------------------------------------------------------

# A derived mask entry: (occupancy [K//bk, N//bn] np.bool_, bk, n)
BlockMask = Tuple[np.ndarray, int, int]


def _block_size(dim: int, want: int) -> int:
    b = min(want, dim)
    while dim % b:
        b -= 1
    return b


def mlp_block_masks(
    mlp_params: Dict[str, Any], bk: int = 32, bn: int = 32
) -> Dict[str, Optional[BlockMask]]:
    """Host-side static occupancy masks for each MLP weight matrix.

    Must be called on CONCRETE weights (server build time, post-pruning).
    Fully-occupied matrices map to None — dense weights gain nothing from
    tile skipping, so those matmuls stay on the ref path.
    """
    masks: Dict[str, Optional[BlockMask]] = {}
    for name in ("w_gate", "w_up", "w_down"):
        w = mlp_params.get(name)
        if w is None:
            continue
        wn = np.asarray(w)
        K, N = wn.shape
        bk_, bn_ = _block_size(K, bk), _block_size(N, bn)
        occ = (
            np.abs(wn.reshape(K // bk_, bk_, N // bn_, bn_)).sum(axis=(1, 3)) > 0
        )
        masks[name] = (occ, bk_, bn_) if not occ.all() else None
    return masks


def sparse_matmul(x: jnp.ndarray, w: jnp.ndarray, mask: BlockMask) -> jnp.ndarray:
    """x @ w skipping pruned (all-zero) weight tiles; any leading shape."""
    occ, bk_, bn_ = mask
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = block_sparse.block_sparse_matmul(
        x2, w, occ, bm=128, bk=bk_, bn=bn_, interpret=_interpret()
    )
    return out.reshape(*shape[:-1], w.shape[1]).astype(x.dtype)
