"""Trace-driven load replay, end to end: generate a seeded workload, save
it, load it back, and replay it through the full serving path.

The workload subsystem (``serving/workload.py``) separates WHAT traffic
arrives from WHO serves it:

* A ``WorkloadConfig`` composes a seeded arrival process (here: a bursty
  two-state MMPP — calm stretches punctuated by arrival storms) with SLO
  tiers (explicit contracts priced as a multiple of each request's OWN
  full-depth service time, next to best-effort traffic), a Zipf-skewed
  multi-task popularity mix, and per-bucket length sampling.  The trace is
  a pure function of (config, seed) on the MODELED clock — no wall time —
  so the same config replays bit-identically anywhere.

* ``save_trace``/``load_trace`` round-trip the stream through JSONL.
  Token payloads are NOT stored: the replayer derives each request's
  tokens from ``(token_seed, uid)``, so a million-request trace stays a
  few tens of MB and a loaded trace reproduces the generated one exactly.

* ``TraceReplayer`` drives the trace through a live target in arrival
  order: it steps the stack until the modeled clock reaches each arrival
  (idle gaps fast-forward through the arbiter — idle time passes, it is
  not compressed), submits through per-task admission control, and polls
  every step so retained state stays O(outstanding) no matter how long
  the trace is.

The target here is the full multi-task path — per-task
``AdmissionController``s over a ``ResidencyRouter`` whose four task
servers share one ``BatchedDVFSArbiter`` clock and an SRAM working set
that only fits two tasks — so the replay exercises admission quotes,
eNVM swap stalls, task-affinity arbitration, EDF lane scheduling, and
shared-clock DVFS together.  The summary printed at the end is the same
structured dict the benchmark harness appends to ``BENCH_serving.json``
(run ``benchmarks/harness/run_harness.py`` for the CI-gated version).

Run:  PYTHONPATH=src python examples/replay_trace.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

REQUESTS = 2_000
SEED = 11


def main() -> None:
    from benchmarks.harness.run_harness import (
        _model_and_controller,
        build_target,
    )
    from benchmarks.harness.scenarios import (
        SCENARIOS,
        build_workload,
        full_depth_service_s,
    )
    from repro.serving.workload import (
        TraceReplayer,
        generate_trace,
        load_trace,
        save_trace,
        summaries_identical,
    )

    spec = SCENARIOS["mmpp_multitask"]
    model, params, cfg, buckets, ctrl_factory = _model_and_controller(
        spec, trained=False, target_mult=1.5
    )
    ctrl = ctrl_factory()
    svc = full_depth_service_s(ctrl, cfg.n_layers, buckets)
    wl = build_workload(spec, ctrl=ctrl, n_layers=cfg.n_layers, lanes=4,
                        seed=SEED)

    # -- generate -> save -> load: the JSONL round-trip is exact ----------
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "trace.jsonl")
        n = save_trace(path, generate_trace(wl, REQUESTS, service_s=svc))
        print(f"saved {n} events ({os.path.getsize(path) / 1024:.0f} KiB) "
              f"-> {os.path.basename(path)}")

        replayer = TraceReplayer(
            build_target(spec, model, params, cfg, buckets, ctrl_factory),
            vocab_size=cfg.vocab_size, token_seed=SEED,
        )
        summary = replayer.replay(load_trace(path))

    print(f"\n== replayed {summary['requests']} requests over "
          f"{summary['modeled_span_s']:.1f} modeled seconds ==")
    print(f"completed {summary['completed']} "
          f"({summary['completed_explicit']} explicit-SLO / "
          f"{summary['completed_best_effort']} best-effort), "
          f"rejected {summary['rejected']} at admission, "
          f"shed {summary['shed']} best-effort")
    print(f"accepted-SLO misses: {summary['accepted_slo_misses']} "
          f"(an admitted contract is a promise)")
    print(f"queue delay p50/p95/p99: {summary['queue_delay_s_p50'] * 1e3:.1f} / "
          f"{summary['queue_delay_s_p95'] * 1e3:.1f} / "
          f"{summary['queue_delay_s_p99'] * 1e3:.1f} ms")
    print(f"throughput {summary['throughput_rps']:.0f} req/s, "
          f"energy {summary['energy_per_request_j'] * 1e3:.3f} mJ/request, "
          f"{summary.get('task_swaps', 0)} task swaps")
    print(f"jit traces: {summary['step_traces']} total, max "
          f"{summary['max_traces_per_bucket_replica']} per (bucket, replica) "
          f"across {summary['requests']} requests")
    print(f"peak outstanding {summary['peak_outstanding']} requests "
          f"(retention is O(outstanding), not O(trace))")

    # -- same seed, fresh stack: the summary is bit-identical -------------
    again = TraceReplayer(
        build_target(spec, model, params, cfg, buckets, ctrl_factory),
        vocab_size=cfg.vocab_size, token_seed=SEED,
    ).replay(generate_trace(wl, REQUESTS, service_s=svc))
    assert summaries_identical(summary, again), "same-seed replays diverged"
    print("\nsame-seed regenerated replay: bit-identical summary")


if __name__ == "__main__":
    main()
