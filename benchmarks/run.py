"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; also writes
benchmarks/results/bench.csv.  Roofline rows come from the dry-run results
(run ``python -m repro.launch.dryrun --all --mesh both`` first for the full
40-cell table).
"""
from __future__ import annotations

import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

from benchmarks import common

BENCHES = [
    "bench_early_exit",        # Fig. 4
    "bench_attention_span",    # Table I
    "bench_pruning",           # Fig. 5
    "bench_quantization",      # Table II
    "bench_envm",              # Table III
    "bench_combined",          # Fig. 7
    "bench_encoder_flops",     # Fig. 8
    "bench_accelerator",       # Fig. 10 + Table V
    "bench_nvm_poweron",       # Fig. 11
    "bench_dvfs",              # Alg. 1: sentence-level DVFS vs baselines
    "bench_batched_dvfs",      # shared-clock (single LDO/ADPLL) arbitration
    "bench_kernels",           # Pallas kernel suite
    "bench_roofline",          # §Roofline table (from dry-run)
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    import importlib

    for name in BENCHES:
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.main()
        except Exception as e:  # keep the suite running
            failures.append(name)
            common.emit(f"{name}_FAILED", 0.0, str(e)[:120])
            traceback.print_exc()
    csv_path = os.path.join(common.RESULTS_DIR, "bench.csv")
    with open(csv_path, "w") as f:
        f.write("name,us_per_call,derived\n")
        f.write("\n".join(common.all_rows()) + "\n")
    print(f"# wrote {csv_path}; failures={failures or 'none'}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
