"""Paper Fig. 4: early-exit entropy-threshold sweep — accuracy, runtime
savings, and average exit layer per threshold, on a trained toy EdgeBERT."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_us, trained_albert
from repro.core import early_exit as ee


def main() -> None:
    model, params, _, data, cfg = trained_albert()
    thresholds = [0.02, 0.05, 0.1, 0.2, 0.4, 0.6, 0.8, 1.0]
    # one dense pass gives every threshold's behaviour (all-layer entropies)
    rows = []
    for i in range(4):
        b = data.batch(6000 + i)
        out = model.apply_train(params, {"tokens": jnp.asarray(b["tokens"])})
        rows.append((out.all_cls_logits, out.all_entropies, b["labels"]))

    us = time_us(
        lambda: model.apply_train(params, {"tokens": jnp.asarray(data.batch(0)["tokens"])}).all_entropies
    )
    for t in thresholds:
        exits, accs = [], []
        for logits_all, ent, labels in rows:
            exit_layer, _ = ee.exit_decisions(ent, t)
            sel = ee.select_exit_logits(logits_all, exit_layer)
            accs.append(float(jnp.mean(jnp.argmax(sel, -1) == jnp.asarray(labels))))
            exits.append(np.asarray(exit_layer))
        avg_exit = float(np.mean(np.concatenate(exits)))
        savings = 1.0 - avg_exit / cfg.n_layers
        emit(
            f"fig4_early_exit_T{t}", us,
            f"avg_exit={avg_exit:.2f}/{cfg.n_layers};savings={savings:.2%};"
            f"acc={np.mean(accs):.3f}",
        )


if __name__ == "__main__":
    main()
