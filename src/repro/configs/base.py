"""Config system: dataclass model/feature/run configs for every architecture.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exposing
``CONFIG: ModelConfig`` (full published size) and ``smoke_config()`` (a reduced
same-family config for CPU smoke tests).  EdgeBERT's own ALBERT baseline lives in
``albert_base.py`` / ``albert_edgebert.py``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# EdgeBERT feature configs (the paper's knobs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class QuantConfig:
    """AdaptivFloat quantization (paper §III-E, Table II)."""

    enabled: bool = False
    n_bits: int = 8
    n_exp: int = 3          # paper: 3-bit exponent optimal for ALBERT
    quantize_weights: bool = True
    quantize_activations: bool = True


@dataclass(frozen=True)
class SpanConfig:
    """Adaptive attention span (paper §III-B, Table I)."""

    enabled: bool = False
    max_span: int = 128      # GLUE max sentence length in the paper
    ramp: int = 32           # soft mask ramp R (Sukhbaatar et al.)
    loss_coef: float = 2e-3  # span regularizer weight
    init_span: float = 64.0


@dataclass(frozen=True)
class EarlyExitConfig:
    """Entropy-based early exit (paper §III-A, Eq. 1/4, Fig. 4)."""

    enabled: bool = False
    entropy_threshold: float = 0.3   # T_E, programmable register in the ASIC
    # classifier off-ramps after each of the first (n_layers - 1) blocks
    num_classes: int = 3
    token_level: bool = False        # beyond-paper CALM-style adaptation for LMs


@dataclass(frozen=True)
class PruneConfig:
    """Movement + magnitude pruning (paper §III-C, Fig. 5, Table IV)."""

    enabled: bool = False
    method: str = "magnitude"        # "magnitude" | "movement"
    encoder_sparsity: float = 0.5    # final encoder weight sparsity
    embedding_sparsity: float = 0.6  # paper: uniform 60% across tasks
    begin_step: int = 0
    end_step: int = 1000             # cubic schedule endpoint
    update_every: int = 10
    block_size: int = 1              # 1 = unstructured (paper); >1 = block-sparse
                                     # (beyond-paper, enables TPU tile skipping)


@dataclass(frozen=True)
class EdgeBertConfig:
    quant: QuantConfig = field(default_factory=QuantConfig)
    span: SpanConfig = field(default_factory=SpanConfig)
    early_exit: EarlyExitConfig = field(default_factory=EarlyExitConfig)
    prune: PruneConfig = field(default_factory=PruneConfig)
    distill_alpha: float = 0.0       # phase-1 KD loss mixing weight
    envm_embeddings: bool = False    # model embeddings as MLC2 ReRAM resident


# ---------------------------------------------------------------------------
# Model config — unified across the 6 assigned families
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "encdec", "hybrid", "moe", "vlm", "ssm", "albert")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    act: str = "swiglu"              # swiglu | gelu | relu2
    norm: str = "rms"                # rms | layernorm
    pos: str = "rope"                # rope | learned | none
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    dtype: str = "bfloat16"          # activation/param dtype for dry-run
    max_seq_len: int = 524288
    # --- factorized embedding (ALBERT) ---
    embed_dim: int = 0               # 0 -> d_model (no factorization)
    # --- cross-layer parameter sharing (ALBERT / zamba shared block) ---
    shared_layers: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    shared_expert_d_ff: int = 0
    router_aux_coef: float = 0.001
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0              # hybrid: shared attn block every N ssm blocks
    # --- enc-dec ---
    n_enc_layers: int = 0
    enc_seq_len: int = 1500          # whisper: 30s -> 1500 frames (frontend stub)
    # --- VLM cross-attention ---
    cross_attn_every: int = 0        # cross-attn layer inserted every N layers
    n_image_tokens: int = 1601       # stubbed patch-embedding count
    # --- classification head (EdgeBERT GLUE-style tasks) ---
    num_classes: int = 0             # 0 -> LM head only
    # --- EdgeBERT features ---
    edgebert: EdgeBertConfig = field(default_factory=EdgeBertConfig)
    # --- scan/remat ---
    scan_layers: bool = True
    remat_policy: str = "full"       # none | dots | full — "full" saves only
                                     # layer inputs (the right trade at 100B
                                     # scale; see EXPERIMENTS.md §Perf)
    # --- beyond-paper performance features (EXPERIMENTS.md §Perf) ---
    # attention body tagged as a fused Pallas kernel region: on TPU the
    # span/flash kernel keeps score tiles in VMEM; the roofline analyzer
    # excludes in-scope HBM materializations (kernels/span_attention.py is
    # the real kernel, validated in interpret mode)
    fused_attention: bool = False
    # sequence-parallel activations: h is sharded over the model axis on the
    # sequence dim between blocks (Megatron-SP) — halves TP collective volume
    sequence_parallel: bool = False
    sp_batch_axes: tuple = ("data",)
    # KV cache stored as AdaptivFloat-8 codes (uint8 + static exponent bias):
    # halves decode cache HBM traffic (paper §III-E applied to the cache)
    kv_cache_dtype: str = ""         # "" -> cfg.dtype; "af8" -> uint8 codes
    kv_af8_e_min: int = -5           # static bias: binades [2^-5, ~2^3)
    # MoE: group the top-k sort/dispatch per batch row so sorts stay local to
    # the data shard (kills the global-argsort all-gathers)
    moe_grouped_dispatch: bool = False
    # hybrid/ssm: replicate the fused in/out projections instead of sharding
    # them over model — slicing a model-sharded fused projection (z|x|B|C|dt)
    # forces XLA into replicated recompute (§Perf zamba2 iteration)
    ssm_replicated: bool = False
    # pin the MoE dispatch buffer to expert-sharding (requires mesh context)
    moe_buffer_sharded: bool = False
    # explicit-collective EP dispatch via shard_map: zero-comm dispatch under
    # model-replicated activations + ONE psum combine per layer (§Perf)
    moe_shardmap_dispatch: bool = False
    # hybrid: scan over (attn_every mamba blocks + shared attn) GROUPS instead
    # of a per-layer lax.cond — removes the both-branches-in-graph cond from
    # the scan body (§Perf zamba2 iteration 2)
    hybrid_grouped: bool = False

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.embed_dim == 0:
            object.__setattr__(self, "embed_dim", self.d_model)
        if self.n_heads and self.n_kv_heads:
            assert self.n_heads % self.n_kv_heads == 0, (self.n_heads, self.n_kv_heads)

    # ---- derived quantities -------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def num_params(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs and reporting)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * self.embed_dim
        if self.embed_dim != d:
            emb += self.embed_dim * d   # ALBERT factorization projection
        per_layer = 0
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.family == "ssm":      # rwkv6: time-mix + channel-mix
            per_layer = 4 * d * d + 2 * d * ff + d * ff  # r,k,v,o + decay lora approx
        elif self.family in ("dense", "albert", "vlm"):
            mlp = (3 if self.act == "swiglu" else 2) * d * ff
            per_layer = attn + mlp
        elif self.family == "moe":
            mlp = self.n_experts * 3 * d * self.moe_d_ff
            if self.shared_expert_d_ff:
                mlp += 3 * d * self.shared_expert_d_ff
            per_layer = attn + mlp + d * self.n_experts
        elif self.family == "hybrid":
            # mamba2 block approx: in_proj (2*d_inner + 2*n_groups*state + heads), out_proj
            d_inner = 2 * d
            per_layer = d * (2 * d_inner + 2 * self.ssm_state + d_inner // self.ssm_head_dim) + d_inner * d
        elif self.family == "encdec":
            mlp = (3 if self.act == "swiglu" else 2) * d * ff
            per_layer = attn + mlp
        n_unique = 1 if self.shared_layers else self.n_layers
        total = emb + n_unique * per_layer
        if self.family == "encdec":
            total += self.n_enc_layers * per_layer
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * attn
        if self.family == "hybrid" and self.attn_every:
            # one shared attention block on concat(h, x0): works on 2*d
            d2 = 2 * d
            total += d2 * d2 * 4 + 2 * d2 * self.d_ff
        if not self.tie_embeddings and self.vocab_size:
            total += d * v
        return int(total)

    def active_params(self) -> int:
        """Active parameters per token (= num_params for dense)."""
        if self.family != "moe":
            return self.num_params()
        d = self.d_model
        dense_moe = self.n_experts * 3 * d * self.moe_d_ff
        active_moe = (self.top_k) * 3 * d * self.moe_d_ff
        if self.shared_expert_d_ff:
            active_moe += 3 * d * self.shared_expert_d_ff
            dense_moe += 3 * d * self.shared_expert_d_ff
        return self.num_params() - self.n_layers * dense_moe + self.n_layers * active_moe

    def with_edgebert(self, **kw) -> "ModelConfig":
        return replace(self, edgebert=replace(self.edgebert, **kw))


# ---------------------------------------------------------------------------
# Input shapes (assigned per-arch shape sheet)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}

# long_500k requires sub-quadratic sequence mixing: run only for ssm/hybrid.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k" and model.family not in SUBQUADRATIC_FAMILIES:
        return False
    return True


ARCH_IDS = (
    "qwen1_5_110b",
    "minitron_8b",
    "deepseek_7b",
    "internlm2_20b",
    "whisper_medium",
    "zamba2_1p2b",
    "qwen3_moe_235b",
    "qwen2_moe_a2p7b",
    "llama3_2_vision_90b",
    "rwkv6_7b",
)


def get_config(arch: str) -> ModelConfig:
    """Load the full published config for an architecture id."""
    import importlib

    arch = arch.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    import importlib

    arch = arch.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config()
