"""Unified lane scheduler: length-bucketed fixed shapes (one compile per
bucket), bucket padding parity, and per-lane KV-length decode parity against
isolated single-request decoding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.early_exit import offramp_logits
from repro.core.entropy import entropy_from_logits
from repro.data.synthetic import SyntheticCLS
from repro.models.model import build_model
from repro.serving.engine import ClassifierServer, DecoderServer, Request
from repro.serving.scheduler import LaneScheduler


def _albert_model(threshold=0.6):
    cfg = get_smoke_config("albert_edgebert")
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    cfg = cfg.with_edgebert(
        early_exit=dataclasses.replace(
            cfg.edgebert.early_exit, entropy_threshold=threshold
        )
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params, cfg


def _decoder_model():
    cfg = dataclasses.replace(
        get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none"
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    return model, params, cfg


class TestBucketAssignment:
    def test_smallest_fitting_bucket(self):
        class _E:  # minimal engine: bucket key = token length
            def bucket_key(self, req):
                return len(req.tokens)

        sched = LaneScheduler(2, _E(), buckets=(32, 64, 128))
        assert sched.bucket_for(10) == 32
        assert sched.bucket_for(32) == 32
        assert sched.bucket_for(33) == 64
        assert sched.bucket_for(128) == 128
        with pytest.raises(ValueError):
            sched.bucket_for(129)

    def test_exact_shape_buckets_when_unconfigured(self):
        class _E:
            def bucket_key(self, req):
                return len(req.tokens)

        sched = LaneScheduler(2, _E())          # buckets=None
        assert sched.bucket_for(17) == 17       # every length its own bucket


class TestBucketedCompileCount:
    def test_one_step_trace_per_bucket_not_per_length(self):
        """Five distinct request lengths over two buckets must compile the
        fused step exactly twice — the bucketed-engine regression."""
        model, params, cfg = _albert_model(threshold=0.5)
        data = SyntheticCLS(cfg.vocab_size, 32, 10, num_classes=3, seed=0)
        batch = data.batch(0)
        server = ClassifierServer(model, params, batch_lanes=3, buckets=(16, 32))
        lengths = [10, 13, 16, 24, 32]          # 3 -> bucket 16, 2 -> bucket 32
        for i, L in enumerate(lengths * 2):
            server.submit(Request(uid=i, tokens=batch["tokens"][i % 10][:L]))
        stats = server.run()
        assert stats["sentences"] == 10
        assert stats["step_traces"] == 2
        assert stats["step_traces_per_bucket"] == {16: 1, 32: 1}
        assert stats["embed_traces"] == 2       # one embed shape per bucket
        assert stats["buckets_used"] == 2

    def test_second_drain_same_buckets_no_retrace(self):
        model, params, cfg = _albert_model(threshold=0.6)
        data = SyntheticCLS(cfg.vocab_size, 32, 4, num_classes=3, seed=1)
        batch = data.batch(0)
        server = ClassifierServer(model, params, batch_lanes=2, buckets=(16, 32))
        for i, L in enumerate((12, 30, 16, 32)):
            server.submit(Request(uid=i, tokens=batch["tokens"][i][:L]))
        server.run()
        for i, L in enumerate((11, 29, 15, 31)):
            server.submit(Request(uid=4 + i, tokens=batch["tokens"][i][:L]))
        stats = server.run()
        assert stats["sentences"] == 8
        assert stats["step_traces"] == 2        # still one per bucket

    def test_padded_result_matches_native_length_reference(self):
        """Bucket padding must NOT change the computed function: a short
        sentence padded up to its bucket produces the same logits and exit
        layer as the straight-line reference at its NATIVE length (pad
        positions are masked out of attention via per-lane kv_len)."""
        thr = 0.5
        model, params, cfg = _albert_model(threshold=thr)
        data = SyntheticCLS(cfg.vocab_size, 32, 4, num_classes=3, seed=2)
        batch = data.batch(0)
        server = ClassifierServer(model, params, batch_lanes=2, buckets=(16,))
        for i in range(4):
            server.submit(Request(uid=i, tokens=batch["tokens"][i][:11]))
        server.run()
        for i in range(4):
            # reference: UNPADDED, exact 11-token shapes, no bucket, no mask
            h = model.embed(params, jnp.asarray(batch["tokens"][i][:11])[None])
            want_exit, want_lg = None, None
            for li in range(cfg.n_layers):
                span_z = model._span_for_layer(params, 0)
                h, _, _ = model._dense_layer_step(
                    params["layer"], h, causal=False, span_z=span_z
                )
                lg = offramp_logits(h, model._offramp(params))
                ent = float(entropy_from_logits(lg)[0])
                if ent < thr or li == cfg.n_layers - 1:
                    want_exit, want_lg = li + 1, np.asarray(lg[0])
                    break
            req = server.done[i]
            assert req.exit_layer == want_exit
            np.testing.assert_allclose(req.result, want_lg, atol=5e-2)
            assert np.argmax(req.result) == np.argmax(want_lg)


class TestPerLaneKVDecode:
    def _reference_decode(self, model, params, prompt, max_new, max_seq):
        """Isolated single-request greedy decode — the ground truth a lane
        must reproduce regardless of what its neighbours are doing."""
        cache = model.init_cache(1, max_seq)
        for t in range(len(prompt) - 1):
            _, cache = model.decode_step(
                params, cache, jnp.asarray([[int(prompt[t])]]), t
            )
        pos = len(prompt) - 1
        cur = int(prompt[-1])
        outs = []
        for _ in range(max_new):
            lg, cache = model.decode_step(params, cache, jnp.asarray([[cur]]), pos)
            cur = int(jnp.argmax(lg[0, -1]))
            outs.append(cur)
            pos += 1
        return outs

    def test_staggered_lengths_with_refill_match_isolated(self):
        """Prompts of different lengths + a mid-drain refill: every lane must
        decode from its OWN position.  The old lock-step loop stepped refilled
        lanes at the max active position (burning pad positions and attending
        a zero gap) and cannot pass this."""
        model, params, cfg = _decoder_model()
        rng = np.random.default_rng(0)
        prompts = [
            rng.integers(4, cfg.vocab_size, size=L).astype(np.int32)
            for L in (6, 9, 4, 7, 5)
        ]
        server = DecoderServer(model, params, batch_lanes=2, max_seq=32, eos_id=-1)
        for i, p in enumerate(prompts):
            server.submit(Request(uid=i, tokens=p, max_new_tokens=4))
        stats = server.run()
        assert stats["completed"] == 5
        assert stats["decode_traces"] == 1 and stats["prefill_traces"] == 1
        for i, p in enumerate(prompts):
            want = self._reference_decode(model, params, p, 4, 32)
            assert server.done[i].generated == want, i

    def test_bucketed_caches_one_trace_per_bucket(self):
        model, params, cfg = _decoder_model()
        rng = np.random.default_rng(1)
        # needs (len + max_new + 1): 4+3+1=8 -> bucket 8; 10+3+1=14 -> bucket 16
        prompts = [rng.integers(4, cfg.vocab_size, size=L).astype(np.int32)
                   for L in (4, 10, 4, 10)]
        server = DecoderServer(
            model, params, batch_lanes=2, max_seq=64, eos_id=-1, buckets=(8, 16)
        )
        for i, p in enumerate(prompts):
            server.submit(Request(uid=i, tokens=p, max_new_tokens=3))
        stats = server.run()
        assert stats["completed"] == 4
        assert stats["buckets_used"] == 2
        assert stats["decode_traces"] == 2      # one per cache bucket
        assert stats["decode_traces_per_bucket"] == {8: 1, 16: 1}
        for i, p in enumerate(prompts):
            bucket = 8 if len(p) == 4 else 16
            want = self._reference_decode(model, params, p, 3, bucket)
            assert server.done[i].generated == want, i

    def test_lane_occupancy_beats_lockstep_accounting(self):
        """Per-lane positions mean decode steps track the LONGEST remaining
        lane, not a global max position; total steps equal the work of the
        slowest chain under continuation batching."""
        model, params, cfg = _decoder_model()
        rng = np.random.default_rng(2)
        prompts = [rng.integers(4, cfg.vocab_size, size=L).astype(np.int32)
                   for L in (5, 5, 5, 5)]
        server = DecoderServer(model, params, batch_lanes=2, max_seq=32, eos_id=-1)
        for i, p in enumerate(prompts):
            server.submit(Request(uid=i, tokens=p, max_new_tokens=3))
        stats = server.run()
        # 4 requests x 3 tokens over 2 lanes = 12 lane-steps in 6 fused steps
        assert stats["decode_steps"] == 6
        assert stats["lane_occupancy"] == 1.0
