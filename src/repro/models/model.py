"""Unified model zoo: one `Model` class covering all assigned families
(dense / moe / vlm / encdec / hybrid / ssm / albert) with a common API:

    init_params(rng)                          -> params pytree
    apply_train(params, batch)                -> ModelOutput (logits / cls)
    init_cache(batch, seq)                    -> decode cache pytree
    prefill(params, tokens, cache, aux)       -> (logits, cache)
    decode_step(params, cache, tokens, pos)   -> (logits, cache)

EdgeBERT features thread through: adaptive span (span_z params modulate
attention), early-exit off-ramps (albert/cls + token-level adaptation),
AdaptivFloat activation fake-quant at block boundaries, and pruning masks
applied to params upstream (training/ serving layers).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.util import ceil_div, fold_rng
from repro.configs.base import ModelConfig
from repro.core import early_exit as ee
from repro.core.adaptivfloat import AFFormat, fake_quant
from repro.core.entropy import entropy_from_logits
from repro.models import layers as L
from repro.models import mamba2, moe, rwkv6

Params = Dict[str, Any]


class ModelOutput(NamedTuple):
    logits: Optional[jnp.ndarray] = None        # LM logits [B, S, V]
    cls_logits: Optional[jnp.ndarray] = None    # [B, C]
    aux_loss: jnp.ndarray = 0.0                 # router/span regularizers
    all_cls_logits: Optional[jnp.ndarray] = None  # [L, B, C] off-ramp sweep
    all_entropies: Optional[jnp.ndarray] = None   # [L, B]
    exit_layer: Optional[jnp.ndarray] = None      # [B]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ===========================================================================
# Parameter init
# ===========================================================================


def _init_dense_layer(rng, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(rng, 4)
    p = {
        "norm1": L.init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "norm2": L.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if cfg.family == "moe":
        p["moe"] = moe.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def _init_cross_layer(rng, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(rng, 2)
    return {
        "norm1": L.init_norm(cfg.norm, cfg.d_model, dtype),
        "xattn": L.init_attention(ks[0], cfg, dtype),
        "gate_attn": jnp.zeros((), jnp.float32),
        "norm2": L.init_norm(cfg.norm, cfg.d_model, dtype),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


def _init_rwkv_layer(rng, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(rng, 2)
    return {
        "norm1": L.init_norm("layernorm", cfg.d_model, dtype),
        "tmix": rwkv6.init_rwkv6(ks[0], cfg, dtype),
        "norm2": L.init_norm("layernorm", cfg.d_model, dtype),
        "cmix": rwkv6.init_channel_mix(ks[1], cfg, dtype),
    }


def _init_mamba_block(rng, cfg: ModelConfig, dtype) -> Params:
    return {
        "norm": L.init_norm(cfg.norm, cfg.d_model, dtype),
        "mixer": mamba2.init_mamba2(rng, cfg, dtype),
    }


def _stack_init(init_one, rng, n: int):
    return jax.vmap(init_one)(jax.random.split(rng, n))


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init_params(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        dtype = _dtype(cfg)
        d = cfg.d_model
        p: Params = {}

        k_embed, k_layers, k_head, k_extra = jax.random.split(rng, 4)
        p["embed"] = {"tok": L.embed_init(k_embed, (cfg.vocab_size, cfg.embed_dim), dtype)}
        if cfg.embed_dim != d:
            p["embed"]["proj"] = L.dense_init(fold_rng(k_embed, "proj"), (cfg.embed_dim, d), dtype)
        if cfg.pos == "learned":
            p["embed"]["pos"] = L.embed_init(
                fold_rng(k_embed, "pos"), (cfg.max_seq_len, d), dtype
            )

        if cfg.family == "ssm":
            init_one = lambda k: _init_rwkv_layer(k, cfg, dtype)
        elif cfg.family == "hybrid":
            init_one = lambda k: _init_mamba_block(k, cfg, dtype)
        else:
            init_one = lambda k: _init_dense_layer(k, cfg, dtype)

        n_stack = cfg.n_layers
        if cfg.family == "vlm" and cfg.cross_attn_every:
            # n_layers counts TOTAL layers; every cross_attn_every-th is cross
            n_stack = cfg.n_layers - cfg.n_layers // cfg.cross_attn_every
        if cfg.shared_layers:
            p["layer"] = init_one(k_layers)               # one shared block
        else:
            p["layers"] = _stack_init(init_one, k_layers, n_stack)

        if cfg.family == "vlm" and cfg.cross_attn_every:
            n_cross = cfg.n_layers // cfg.cross_attn_every
            p["cross_layers"] = _stack_init(
                lambda k: _init_cross_layer(k, cfg, dtype), fold_rng(k_layers, "cross"), n_cross
            )
        if cfg.family == "encdec":
            p["enc_layers"] = _stack_init(
                lambda k: _init_dense_layer(k, cfg, dtype), fold_rng(k_layers, "enc"), cfg.n_enc_layers
            )
            p["enc_norm"] = L.init_norm(cfg.norm, d, dtype)
            p["enc_pos"] = L.embed_init(fold_rng(k_embed, "encpos"), (cfg.enc_seq_len, d), dtype)
            # decoder cross-attention weights per layer
            p["dec_cross"] = _stack_init(
                lambda k: {
                    "norm": L.init_norm(cfg.norm, d, dtype),
                    "xattn": L.init_attention(k, cfg, dtype),
                },
                fold_rng(k_layers, "deccross"),
                cfg.n_layers,
            )
        if cfg.family == "hybrid" and cfg.attn_every:
            # Zamba-style single shared attention+MLP block on concat([h, x0])
            import dataclasses

            acfg = dataclasses.replace(cfg, d_model=2 * d, qkv_bias=False)
            ks = jax.random.split(k_extra, 3)
            p["shared_attn"] = {
                "norm1": L.init_norm(cfg.norm, 2 * d, dtype),
                "attn": L.init_attention(ks[0], acfg, dtype, d_in=2 * d),
                "norm2": L.init_norm(cfg.norm, 2 * d, dtype),
                "mlp": L.init_mlp(ks[1], 2 * d, cfg.d_ff, "gelu", dtype),
                "out_proj": L.dense_init(ks[2], (2 * d, d), dtype),
            }

        p["final_norm"] = L.init_norm(cfg.norm, d, dtype)
        if not cfg.tie_embeddings and cfg.vocab_size:
            p["lm_head"] = L.dense_init(k_head, (d, cfg.vocab_size), dtype, scale=0.02)

        if cfg.num_classes:
            p["classifier"] = {
                "pooler_w": L.dense_init(fold_rng(k_head, "pool"), (d, d), dtype),
                "pooler_b": jnp.zeros((d,), dtype),
                "cls_w": L.dense_init(fold_rng(k_head, "cls"), (d, cfg.num_classes), dtype),
                "cls_b": jnp.zeros((cfg.num_classes,), dtype),
            }
        if cfg.edgebert.early_exit.enabled:
            C = cfg.edgebert.early_exit.num_classes
            op = ee.init_offramp(fold_rng(k_head, "offramp"), d, C, jnp.float32)
            p["offramp"] = {
                "offramp_pooler_w": op.pooler_w,
                "offramp_pooler_b": op.pooler_b,
                "offramp_cls_w": op.cls_w,
                "offramp_cls_b": op.cls_b,
            }
        if cfg.edgebert.span.enabled and not cfg.attention_free:
            n_span_layers = 1 if cfg.shared_layers else cfg.n_layers
            p["span_z"] = jnp.full(
                (n_span_layers, cfg.n_heads), cfg.edgebert.span.init_span, jnp.float32
            )
        return p

    # -------------------------------------------------------------- embedding
    def embed(self, p: Params, tokens: jnp.ndarray, positions=None) -> jnp.ndarray:
        cfg = self.cfg
        h = jnp.take(p["embed"]["tok"], tokens, axis=0)
        if "proj" in p["embed"]:
            h = h @ p["embed"]["proj"]
        if cfg.pos == "learned":
            if positions is None:
                positions = jnp.arange(tokens.shape[-1])
            h = h + jnp.take(p["embed"]["pos"], positions, axis=0)
        return h

    def lm_logits(self, p: Params, h: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.tie_embeddings:
            w = p["embed"]["tok"]
            if "proj" in p["embed"]:
                h = h @ p["embed"]["proj"].T
            return h @ w.T
        return h @ p["lm_head"]

    def cls_logits(self, p: Params, h: jnp.ndarray) -> jnp.ndarray:
        c = p["classifier"]
        pooled = jnp.tanh(h[..., 0, :] @ c["pooler_w"] + c["pooler_b"])
        return (pooled @ c["cls_w"] + c["cls_b"]).astype(jnp.float32)

    def _offramp(self, p: Params) -> ee.OfframpParams:
        o = p["offramp"]
        return ee.OfframpParams(
            o["offramp_pooler_w"], o["offramp_pooler_b"], o["offramp_cls_w"], o["offramp_cls_b"]
        )

    def _maybe_actquant(self, h: jnp.ndarray, use_pallas: bool = False) -> jnp.ndarray:
        q = self.cfg.edgebert.quant
        if q.enabled and q.quantize_activations:
            if use_pallas:
                from repro.kernels import dispatch

                return dispatch.act_quantize(h, q.n_bits, q.n_exp)
            return fake_quant(h, AFFormat(q.n_bits, q.n_exp))
        return h

    def _sp_constrain(self, h: jnp.ndarray) -> jnp.ndarray:
        """Sequence-parallel residual stream: [B, S, D] sharded (batch->dp,
        seq->model) between blocks — turns TP all-reduces into RS+AG at half
        the volume (Megatron-SP). No-op unless cfg.sequence_parallel."""
        cfg = self.cfg
        if not cfg.sequence_parallel or h.ndim != 3:
            return h
        from jax.sharding import PartitionSpec as P

        ba = cfg.sp_batch_axes
        batch_axis = ba if len(ba) > 1 else ba[0]
        return jax.lax.with_sharding_constraint(h, P(batch_axis, "model", None))

    # ---------------------------------------------------------- layer bodies
    def _dense_layer_step(
        self,
        lp: Params,
        h: jnp.ndarray,
        *,
        causal: bool,
        span_z=None,
        positions=None,
        cache=None,
        cache_pos=None,
        kv_len=None,
        use_pallas=False,
        block_masks=None,
    ):
        cfg = self.cfg
        post_ln = cfg.family == "albert"
        aux = jnp.zeros((), jnp.float32)
        if post_ln:
            attn_out, cache = L.attention_layer(
                lp["attn"], h, cfg, causal=causal, positions=positions,
                span_z=span_z, span_ramp=cfg.edgebert.span.ramp,
                cache=cache, cache_pos=cache_pos, kv_len=kv_len,
                use_pallas=use_pallas,
            )
            h = L.apply_norm(lp["norm1"], h + attn_out, cfg.norm, use_pallas=use_pallas)
            if "moe" in lp:
                mo, aux = moe.apply_moe(lp["moe"], h, cfg)
            else:
                mo = L.apply_mlp(
                    lp["mlp"], h, cfg.act,
                    use_pallas=use_pallas, block_masks=block_masks,
                )
            h = L.apply_norm(lp["norm2"], h + mo, cfg.norm, use_pallas=use_pallas)
        else:
            attn_out, cache = L.attention_layer(
                lp["attn"], L.apply_norm(lp["norm1"], h, cfg.norm, use_pallas=use_pallas),
                cfg,
                causal=causal, positions=positions,
                span_z=span_z, span_ramp=cfg.edgebert.span.ramp,
                cache=cache, cache_pos=cache_pos, kv_len=kv_len,
                use_pallas=use_pallas,
            )
            h = self._sp_constrain(h + attn_out)
            hn = L.apply_norm(lp["norm2"], h, cfg.norm, use_pallas=use_pallas)
            if "moe" in lp:
                mo, aux = moe.apply_moe(lp["moe"], hn, cfg)
            else:
                mo = L.apply_mlp(
                    lp["mlp"], hn, cfg.act,
                    use_pallas=use_pallas, block_masks=block_masks,
                )
            h = self._sp_constrain(h + mo)
        return self._maybe_actquant(h, use_pallas=use_pallas), aux, cache

    def _cross_layer_step(self, lp: Params, h, img, cache_kv=None):
        """Gated cross-attention layer (llama-3.2-vision style)."""
        cfg = self.cfg
        x, _ = L.attention_layer(
            lp["xattn"], L.apply_norm(lp["norm1"], h, cfg.norm), cfg,
            causal=False, kv_source=img,
        )
        h = h + jnp.tanh(lp["gate_attn"]).astype(h.dtype) * x
        m = L.apply_mlp(lp["mlp"], L.apply_norm(lp["norm2"], h, cfg.norm), cfg.act)
        h = h + jnp.tanh(lp["gate_mlp"]).astype(h.dtype) * m
        return self._maybe_actquant(h)

    def _rwkv_layer_step(self, lp: Params, h, *, states=None, decode=False):
        tm_in = L.apply_norm(lp["norm1"], h, "layernorm")
        last_tm = states["last_tm"] if states else None
        wkv = states["wkv"] if states else None
        tout, (new_last_tm, new_wkv) = rwkv6.apply_rwkv6(
            lp["tmix"], tm_in, self.cfg, last_x=last_tm, wkv_state=wkv, decode=decode
        )
        h = h + tout
        cm_in = L.apply_norm(lp["norm2"], h, "layernorm")
        last_cm = states["last_cm"] if states else None
        cout, new_last_cm = rwkv6.apply_channel_mix(lp["cmix"], cm_in, last_x=last_cm)
        h = h + cout
        new_states = {"last_tm": new_last_tm, "wkv": new_wkv, "last_cm": new_last_cm}
        return self._maybe_actquant(h), new_states

    def _mamba_block_step(self, lp: Params, h, *, states=None, decode=False):
        xin = L.apply_norm(lp["norm"], h, self.cfg.norm)
        conv_state = states["conv"] if states else None
        ssm_state = states["ssm"] if states else None
        out, (new_conv, new_ssm) = mamba2.apply_mamba2(
            lp["mixer"], xin, self.cfg, conv_state=conv_state, ssm_state=ssm_state, decode=decode
        )
        h = h + out
        return self._maybe_actquant(h), {"conv": new_conv, "ssm": new_ssm}

    def _shared_attn_step(self, sp: Params, h, x0, *, span_z=None, cache=None,
                          cache_pos=None, positions=None, use_pallas=False):
        """Zamba2 shared attention block on concat([h, x0])."""
        cfg = self.cfg
        import dataclasses

        acfg = dataclasses.replace(cfg, d_model=2 * cfg.d_model, qkv_bias=False)
        z = jnp.concatenate([h, x0], axis=-1)
        zi = L.apply_norm(sp["norm1"], z, cfg.norm, use_pallas=use_pallas)
        a, cache = L.attention_layer(
            sp["attn"], zi, acfg, causal=True, positions=positions,
            span_z=span_z, span_ramp=cfg.edgebert.span.ramp,
            cache=cache, cache_pos=cache_pos, use_pallas=use_pallas,
        )
        z = z + a
        m = L.apply_mlp(
            sp["mlp"],
            L.apply_norm(sp["norm2"], z, cfg.norm, use_pallas=use_pallas),
            "gelu",
        )
        z = z + m
        return h + z @ sp["out_proj"], cache

    # ------------------------------------------------------------- remat wrap
    def _remat(self, f):
        if self.cfg.remat_policy == "full":
            return jax.checkpoint(f)
        if self.cfg.remat_policy == "dots":
            return jax.checkpoint(
                f, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            )
        return f

    def _span_for_layer(self, p: Params, i) -> Optional[jnp.ndarray]:
        if "span_z" not in p:
            return None
        z = p["span_z"]
        if z.shape[0] == 1:
            return z[0]
        return z[i]

    # ============================================================== forward ==
    def apply_train(self, p: Params, batch: Dict[str, jnp.ndarray]) -> ModelOutput:
        cfg = self.cfg
        f = {
            "dense": self._forward_dense,
            "moe": self._forward_dense,
            "albert": self._forward_albert,
            "vlm": self._forward_vlm,
            "encdec": self._forward_encdec,
            "hybrid": self._forward_hybrid,
            "ssm": self._forward_ssm,
        }[cfg.family]
        return f(p, batch)

    # ---- dense / moe ----
    def _forward_dense(self, p: Params, batch) -> ModelOutput:
        cfg = self.cfg
        tokens = batch["tokens"]
        h = self.embed(p, tokens)
        aux_total = jnp.zeros((), jnp.float32)

        def step(carry, xs):
            h, aux = carry
            lp, span_z = xs
            h, a, _ = self._dense_layer_step(lp, h, causal=True, span_z=span_z)
            return (h, aux + a), None

        span = p.get("span_z")
        if span is None:
            step_fn = self._remat(lambda c, lp: step(c, (lp, None)))
            (h, aux_total), _ = jax.lax.scan(step_fn, (h, aux_total), p["layers"])
        else:
            span_xs = (
                span if span.shape[0] == cfg.n_layers
                else jnp.broadcast_to(span, (cfg.n_layers,) + span.shape[1:])
            )
            step_fn = self._remat(step)
            (h, aux_total), _ = jax.lax.scan(step_fn, (h, aux_total), (p["layers"], span_xs))

        h = L.apply_norm(p["final_norm"], h, cfg.norm)
        logits = self.lm_logits(p, h)
        cls = self.cls_logits(p, h) if "classifier" in p else None
        return ModelOutput(logits=logits, cls_logits=cls, aux_loss=aux_total)

    # ---- albert (shared layer, early exit) ----
    def _albert_layer_fn(self, p: Params):
        lp = p["layer"]

        def layer_fn(i, h):
            span_z = self._span_for_layer(p, 0)
            h, _, _ = self._dense_layer_step(lp, h, causal=False, span_z=span_z)
            return h

        return layer_fn

    def _forward_albert(self, p: Params, batch) -> ModelOutput:
        cfg = self.cfg
        tokens = batch["tokens"]
        h = self.embed(p, tokens)
        layer_fn = self._albert_layer_fn(p)

        if cfg.edgebert.early_exit.enabled and "offramp" in p:
            all_logits, all_ent = ee.exit_all_layers(
                layer_fn, cfg.n_layers, h, self._offramp(p)
            )
            thr = cfg.edgebert.early_exit.entropy_threshold
            exit_layer, _ = ee.exit_decisions(all_ent, thr)
            final_cls = ee.select_exit_logits(all_logits, exit_layer)
            return ModelOutput(
                cls_logits=final_cls,
                all_cls_logits=all_logits,
                all_entropies=all_ent,
                exit_layer=exit_layer,
                aux_loss=jnp.zeros((), jnp.float32),
            )

        def body(h, i):
            return layer_fn(i, h), None

        h, _ = jax.lax.scan(self._remat(body), h, jnp.arange(cfg.n_layers))
        cls = self.cls_logits(p, h) if "classifier" in p else None
        logits = self.lm_logits(p, h) if cfg.vocab_size else None
        return ModelOutput(logits=logits, cls_logits=cls, aux_loss=jnp.zeros((), jnp.float32))

    # ---- vlm: groups of (cross_attn_every-1 self layers + 1 cross layer) ----
    def _forward_vlm(self, p: Params, batch) -> ModelOutput:
        cfg = self.cfg
        tokens = batch["tokens"]
        img = batch["image_embeds"]          # [B, n_img, d] (frontend stub)
        h = self.embed(p, tokens)
        n_cross = cfg.n_layers // cfg.cross_attn_every
        n_self_per = cfg.cross_attn_every - 1

        self_layers = jax.tree_util.tree_map(
            lambda x: x.reshape((n_cross, n_self_per) + x.shape[1:]), p["layers"]
        )

        span = p.get("span_z")
        if span is None:
            def group_nospan(h, xs):
                selfs, cross = xs

                def inner(hh, lp):
                    hh, _, _ = self._dense_layer_step(lp, hh, causal=True)
                    return hh, None

                h, _ = jax.lax.scan(inner, h, selfs)
                h = self._cross_layer_step(cross, h, img)
                return h, None

            h, _ = jax.lax.scan(self._remat(group_nospan), h, (self_layers, p["cross_layers"]))
        else:
            if span.shape[0] == n_cross * n_self_per:
                span_groups = span.reshape(n_cross, n_self_per, cfg.n_heads)
            else:
                span_groups = jnp.broadcast_to(span[:1], (n_cross, n_self_per, cfg.n_heads))

            def group(h, xs):
                selfs, cross, span_g = xs

                def inner(hh, ys):
                    lp, sz = ys
                    hh, _, _ = self._dense_layer_step(lp, hh, causal=True, span_z=sz)
                    return hh, None

                h, _ = jax.lax.scan(inner, h, (selfs, span_g))
                h = self._cross_layer_step(cross, h, img)
                return h, None

            h, _ = jax.lax.scan(
                self._remat(group), h, (self_layers, p["cross_layers"], span_groups)
            )
        h = L.apply_norm(p["final_norm"], h, cfg.norm)
        return ModelOutput(logits=self.lm_logits(p, h), aux_loss=jnp.zeros((), jnp.float32))

    # ---- enc-dec (whisper) ----
    def _encode(self, p: Params, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        h = frames + p["enc_pos"][None, : frames.shape[1]].astype(frames.dtype)

        def step(hh, lp):
            hh, _, _ = self._dense_layer_step(lp, hh, causal=False)
            return hh, None

        h, _ = jax.lax.scan(self._remat(step), h, p["enc_layers"])
        return L.apply_norm(p["enc_norm"], h, cfg.norm)

    def _forward_encdec(self, p: Params, batch) -> ModelOutput:
        cfg = self.cfg
        tokens = batch["tokens"]
        frames = batch["enc_input"]          # [B, enc_seq, d] (frontend stub)
        enc = self._encode(p, frames)
        h = self.embed(p, tokens)

        def step(carry, xs):
            h = carry
            lp, xp, span_z = xs
            h, _, _ = self._dense_layer_step(lp, h, causal=True, span_z=span_z)
            x, _ = L.attention_layer(
                xp["xattn"], L.apply_norm(xp["norm"], h, cfg.norm), cfg,
                causal=False, kv_source=enc,
            )
            h = h + x
            return h, None

        span = p.get("span_z")
        if span is not None:
            span_xs = (
                jnp.broadcast_to(span[:1], (cfg.n_layers, cfg.n_heads))
                if span.shape[0] == 1 else span
            )
            h, _ = jax.lax.scan(
                self._remat(step), h, (p["layers"], p["dec_cross"], span_xs)
            )
        else:
            h, _ = jax.lax.scan(
                self._remat(lambda c, xs: step(c, (xs[0], xs[1], None))),
                h, (p["layers"], p["dec_cross"]),
            )
        h = L.apply_norm(p["final_norm"], h, cfg.norm)
        return ModelOutput(logits=self.lm_logits(p, h), aux_loss=jnp.zeros((), jnp.float32))

    # ---- hybrid (zamba2) ----
    def _forward_hybrid(self, p: Params, batch) -> ModelOutput:
        cfg = self.cfg
        tokens = batch["tokens"]
        h = self.embed(p, tokens)
        x0 = h

        if cfg.hybrid_grouped and cfg.attn_every:
            # grouped scan: (attn_every mamba blocks + 1 shared attn) per
            # group, remainder blocks after — identical semantics to the cond
            # form (attn after blocks attn_every, 2*attn_every, ...), but the
            # scan body holds ONE branch, not both (§Perf zamba2 iteration)
            n_grp = cfg.n_layers // cfg.attn_every
            n_rem = cfg.n_layers % cfg.attn_every
            main = jax.tree_util.tree_map(
                lambda x: x[: n_grp * cfg.attn_every].reshape(
                    (n_grp, cfg.attn_every) + x.shape[1:]
                ),
                p["layers"],
            )

            def group(h, grp_layers):
                def inner(hh, lp):
                    hh, _ = self._mamba_block_step(lp, hh)
                    return hh, None

                h, _ = jax.lax.scan(inner, h, grp_layers)
                h, _ = self._shared_attn_step(
                    p["shared_attn"], h, x0, span_z=self._span_for_layer(p, 0)
                )
                return h, None

            h, _ = jax.lax.scan(self._remat(group), h, main)
            if n_rem:
                rem = jax.tree_util.tree_map(
                    lambda x: x[n_grp * cfg.attn_every :], p["layers"]
                )

                def tail(hh, lp):
                    hh, _ = self._mamba_block_step(lp, hh)
                    return hh, None

                h, _ = jax.lax.scan(self._remat(tail), h, rem)
        else:
            def step(carry, xs):
                h = carry
                lp, idx = xs
                h, _ = self._mamba_block_step(lp, h)
                if cfg.attn_every:
                    def with_attn(hh):
                        out, _ = self._shared_attn_step(
                            p["shared_attn"], hh, x0, span_z=self._span_for_layer(p, 0)
                        )
                        return out

                    h = jax.lax.cond(
                        (idx + 1) % cfg.attn_every == 0, with_attn, lambda hh: hh, h
                    )
                return h, None

            h, _ = jax.lax.scan(
                self._remat(step), h, (p["layers"], jnp.arange(cfg.n_layers))
            )
        h = L.apply_norm(p["final_norm"], h, cfg.norm)
        return ModelOutput(logits=self.lm_logits(p, h), aux_loss=jnp.zeros((), jnp.float32))

    # ---- ssm (rwkv6) ----
    def _forward_ssm(self, p: Params, batch) -> ModelOutput:
        cfg = self.cfg
        tokens = batch["tokens"]
        h = self.embed(p, tokens)

        def step(h, lp):
            h, _ = self._rwkv_layer_step(lp, h)
            return h, None

        h, _ = jax.lax.scan(self._remat(step), h, p["layers"])
        h = L.apply_norm(p["final_norm"], h, "layernorm")
        return ModelOutput(logits=self.lm_logits(p, h), aux_loss=jnp.zeros((), jnp.float32))

    # ---- token-level early exit (beyond-paper CALM-style adaptation) ----
    def forward_token_exit(self, p: Params, tokens: jnp.ndarray, threshold: float):
        """Per-TOKEN early exit for decoder LMs: after each layer, tokens whose
        LM-head entropy < threshold freeze (hidden-state propagation); the
        paper's per-sentence exit generalized to generation (DESIGN.md §4).

        Returns (logits [B,S,V], exit_layer [B,S]). Dense/MoE families.
        """
        cfg = self.cfg
        assert cfg.family in ("dense", "moe"), "token exit: decoder LMs"
        h = self.embed(p, tokens)
        B, S, _ = h.shape

        def head_entropy(h):
            lg = self.lm_logits(p, L.apply_norm(p["final_norm"], h, cfg.norm))
            return lg, entropy_from_logits(lg)

        def step(carry, lp):
            h, done, exit_layer, i = carry
            h_new, _, _ = self._dense_layer_step(lp, h, causal=True)
            h = jnp.where(done[..., None], h, h_new)
            _, ent = head_entropy(h)
            exit_now = jnp.logical_and(jnp.logical_not(done), ent < threshold)
            exit_layer = jnp.where(exit_now, i + 1, exit_layer)
            done = jnp.logical_or(done, exit_now)
            return (h, done, exit_layer, i + 1), None

        init = (
            h,
            jnp.zeros((B, S), bool),
            jnp.full((B, S), cfg.n_layers, jnp.int32),
            jnp.array(0, jnp.int32),
        )
        (h, done, exit_layer, _), _ = jax.lax.scan(step, init, p["layers"])
        logits, _ = head_entropy(h)
        return logits, exit_layer

    # ============================================================ decode ====
    def init_cache(self, batch_size: int, max_seq: int) -> Params:
        cfg = self.cfg
        dtype = _dtype(cfg)
        # AF8 KV cache: uint8 codes with a static exponent bias (§Perf)
        kv_dtype = jnp.uint8 if cfg.kv_cache_dtype == "af8" else dtype
        B = batch_size
        KV, hd = cfg.n_kv_heads, cfg.head_dim
        if cfg.family in ("dense", "moe", "albert"):
            n = cfg.n_layers
            return {
                "k": jnp.zeros((n, B, max_seq, KV, hd), kv_dtype),
                "v": jnp.zeros((n, B, max_seq, KV, hd), kv_dtype),
            }
        if cfg.family == "vlm":
            n_cross = cfg.n_layers // cfg.cross_attn_every
            n = cfg.n_layers - n_cross  # self layers (cross K/V cached at prefill)
            return {
                "k": jnp.zeros((n, B, max_seq, KV, hd), kv_dtype),
                "v": jnp.zeros((n, B, max_seq, KV, hd), kv_dtype),
                "img_k": jnp.zeros((n_cross, B, cfg.n_image_tokens, KV, hd), dtype),
                "img_v": jnp.zeros((n_cross, B, cfg.n_image_tokens, KV, hd), dtype),
            }
        if cfg.family == "encdec":
            n = cfg.n_layers
            return {
                "k": jnp.zeros((n, B, max_seq, KV, hd), kv_dtype),
                "v": jnp.zeros((n, B, max_seq, KV, hd), kv_dtype),
                "enc_k": jnp.zeros((n, B, cfg.enc_seq_len, KV, hd), dtype),
                "enc_v": jnp.zeros((n, B, cfg.enc_seq_len, KV, hd), dtype),
            }
        if cfg.family == "hybrid":
            di = mamba2.d_inner(cfg)
            H = mamba2.n_ssm_heads(cfg)
            n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
            cache = {
                "conv": jnp.zeros((cfg.n_layers, B, mamba2.CONV_K - 1, di + 2 * cfg.ssm_state), dtype),
                "ssm": jnp.zeros((cfg.n_layers, B, H, cfg.ssm_head_dim, cfg.ssm_state), dtype),
            }
            if n_attn:
                cache["k"] = jnp.zeros((n_attn, B, max_seq, KV, hd), kv_dtype)
                cache["v"] = jnp.zeros((n_attn, B, max_seq, KV, hd), kv_dtype)
            return cache
        if cfg.family == "ssm":
            n, d = cfg.n_layers, cfg.d_model
            H, K = cfg.n_heads, cfg.head_dim
            return {
                "last_tm": jnp.zeros((n, B, 1, d), dtype),
                "last_cm": jnp.zeros((n, B, 1, d), dtype),
                "wkv": jnp.zeros((n, B, H, K, K), jnp.float32),
            }
        raise ValueError(cfg.family)

    def decode_step(
        self,
        p: Params,
        cache: Params,
        tokens: jnp.ndarray,          # [B, 1]
        pos,                           # scalar: current position (cache fill)
        aux: Optional[Dict[str, jnp.ndarray]] = None,
        use_pallas: bool = False,
    ) -> Tuple[jnp.ndarray, Params]:
        cfg = self.cfg
        positions = pos + jnp.arange(tokens.shape[1])
        h = self.embed(p, tokens, positions=positions)

        if cfg.family in ("dense", "moe"):
            def step(carry, xs):
                h = carry
                lp, ck, cv, span_z = xs
                h, _, c = self._dense_layer_step(
                    lp, h, causal=True, positions=positions,
                    span_z=span_z, cache=(ck, cv), cache_pos=pos,
                    use_pallas=use_pallas,
                )
                return h, (c[0], c[1])

            span = p.get("span_z")
            if span is not None:
                span_xs = (
                    jnp.broadcast_to(span[:1], (cfg.n_layers, cfg.n_heads))
                    if span.shape[0] == 1 else span
                )
                h, (ks, vs) = jax.lax.scan(step, h, (p["layers"], cache["k"], cache["v"], span_xs))
            else:
                h, (ks, vs) = jax.lax.scan(
                    lambda c, xs: step(c, (xs[0], xs[1], xs[2], None)),
                    h, (p["layers"], cache["k"], cache["v"]),
                )
            cache = dict(cache, k=ks, v=vs)
        elif cfg.family == "albert":
            lp = p["layer"]

            def step(carry, xs):
                h = carry
                ck, cv = xs
                h, _, c = self._dense_layer_step(
                    lp, h, causal=True, positions=positions,
                    span_z=self._span_for_layer(p, 0), cache=(ck, cv), cache_pos=pos,
                    use_pallas=use_pallas,
                )
                return h, (c[0], c[1])

            h, (ks, vs) = jax.lax.scan(step, h, (cache["k"], cache["v"]))
            cache = dict(cache, k=ks, v=vs)
        elif cfg.family == "vlm":
            n_cross = cfg.n_layers // cfg.cross_attn_every
            n_self_per = cfg.cross_attn_every - 1
            self_layers = jax.tree_util.tree_map(
                lambda x: x.reshape((n_cross, n_self_per) + x.shape[1:]), p["layers"]
            )
            kr = cache["k"].reshape((n_cross, n_self_per) + cache["k"].shape[1:])
            vr = cache["v"].reshape((n_cross, n_self_per) + cache["v"].shape[1:])

            def group(carry, xs):
                h = carry
                selfs, cross, ck_g, cv_g, ik, iv = xs

                def inner(hh, ys):
                    lp, ck, cv = ys
                    hh, _, c = self._dense_layer_step(
                        lp, hh, causal=True, positions=positions,
                        cache=(ck, cv), cache_pos=pos,
                    )
                    return hh, (c[0], c[1])

                h, (ck_new, cv_new) = jax.lax.scan(inner, h, (selfs, ck_g, cv_g))
                # cross attention against cached image K/V
                x = self._cross_decode(cross, h, ik, iv)
                h = h + x
                return h, (ck_new, cv_new)

            h, (ks, vs) = jax.lax.scan(
                group, h,
                (self_layers, p["cross_layers"], kr, vr, cache["img_k"], cache["img_v"]),
            )
            cache = dict(
                cache,
                k=ks.reshape(cache["k"].shape),
                v=vs.reshape(cache["v"].shape),
            )
        elif cfg.family == "encdec":
            def step(carry, xs):
                h = carry
                lp, xp, ck, cv, ek, ev = xs
                h, _, c = self._dense_layer_step(
                    lp, h, causal=True, positions=positions, cache=(ck, cv), cache_pos=pos
                )
                x = self._precomputed_cross(xp, h, ek, ev)
                h = h + x
                return h, (c[0], c[1])

            h, (ks, vs) = jax.lax.scan(
                step, h,
                (p["layers"], p["dec_cross"], cache["k"], cache["v"], cache["enc_k"], cache["enc_v"]),
            )
            cache = dict(cache, k=ks, v=vs)
        elif cfg.family == "hybrid":
            x0 = h
            n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0

            # scan mamba blocks; shared-attn invocations handled outside scan
            # via unrolled groups (attn_every static)
            new_conv, new_ssm = [], []
            ks_list, vs_list = [], []
            attn_idx = 0
            conv = cache["conv"]
            ssm = cache["ssm"]
            for i in range(cfg.n_layers):
                lp = jax.tree_util.tree_map(lambda x: x[i], p["layers"])
                h, st = self._mamba_block_step(
                    lp, h, states={"conv": conv[i], "ssm": ssm[i]}, decode=True
                )
                new_conv.append(st["conv"])
                new_ssm.append(st["ssm"])
                if cfg.attn_every and (i + 1) % cfg.attn_every == 0 and attn_idx < n_attn:
                    h, c = self._shared_attn_step(
                        p["shared_attn"], h, x0,
                        span_z=self._span_for_layer(p, 0),
                        cache=(cache["k"][attn_idx], cache["v"][attn_idx]),
                        cache_pos=pos, positions=positions,
                    )
                    ks_list.append(c[0])
                    vs_list.append(c[1])
                    attn_idx += 1
            cache = dict(
                cache,
                conv=jnp.stack(new_conv),
                ssm=jnp.stack(new_ssm),
            )
            if ks_list:
                cache["k"] = jnp.stack(ks_list)
                cache["v"] = jnp.stack(vs_list)
        elif cfg.family == "ssm":
            def step(carry, xs):
                h = carry
                lp, ltm, lcm, wkv = xs
                h, st = self._rwkv_layer_step(
                    lp, h, states={"last_tm": ltm, "last_cm": lcm, "wkv": wkv}, decode=True
                )
                return h, (st["last_tm"], st["last_cm"], st["wkv"])

            h, (ltm, lcm, wkv) = jax.lax.scan(
                step, h, (p["layers"], cache["last_tm"], cache["last_cm"], cache["wkv"])
            )
            cache = dict(cache, last_tm=ltm, last_cm=lcm, wkv=wkv)
        else:
            raise ValueError(cfg.family)

        h = L.apply_norm(
            p["final_norm"], h, "layernorm" if cfg.family == "ssm" else cfg.norm,
            use_pallas=use_pallas,
        )
        logits = self.lm_logits(p, h)
        return logits, cache

    def decode_step_ee(
        self,
        p: Params,
        cache: Params,
        tokens: jnp.ndarray,          # [B, 1]
        pos,                           # scalar or [B]: current cache position
        threshold,                     # entropy threshold (traced scalar ok)
        use_pallas: bool = False,
    ) -> Tuple[jnp.ndarray, Params, jnp.ndarray, jnp.ndarray]:
        """One decode step with PER-TOKEN early exit (EdgeBERT Alg. 1's
        entropy off-ramp generalized to autoregressive decode; the serving
        counterpart of the training-time ``forward_token_exit``).

        After every layer the shared LM head (post final-norm) is evaluated
        on the current hidden state; once its entropy drops below
        ``threshold`` the token FREEZES — hidden-state propagation: the
        remaining layers still write their KV rows (future tokens need
        something to attend to at every layer, so the frozen state is pushed
        through each remaining layer's KV projections), but the token's own
        representation stops evolving and the returned exit depth is what
        the modeled hardware actually executes (the DVFS layer charges
        layers ``1..exit`` only).  The computation is fully masked, so the
        fused serving step stays fixed-shape: one compile per bucket, and a
        batch-1 call computes bit-identically to a vmapped lane.

        Returns ``(logits [B,1,V], cache, exit_layer [B], first_entropy [B])``
        where ``exit_layer`` is 1-based and ``first_entropy`` is the LM-head
        entropy after layer 1 (the token's first off-ramp reading).
        """
        cfg = self.cfg
        assert cfg.family in ("dense", "moe", "albert"), (
            "per-token exit decode: KV-cache decoder families only"
        )
        positions = pos + jnp.arange(tokens.shape[1])
        h = self.embed(p, tokens, positions=positions)
        B = h.shape[0]
        V = cfg.vocab_size
        n_layers = cfg.n_layers

        def head_entropy(hh):
            lg = self.lm_logits(
                p, L.apply_norm(p["final_norm"], hh, cfg.norm, use_pallas=use_pallas)
            )
            if use_pallas:
                from repro.kernels import dispatch

                return lg, dispatch.entropy(lg)
            return lg, entropy_from_logits(lg)

        def body(carry, xs):
            h, done, logits, exit_layer, first_ent, i = carry
            if cfg.family == "albert":
                ck, cv = xs
                lp, span_z = p["layer"], self._span_for_layer(p, 0)
            else:
                lp, ck, cv, span_z = xs
            h_new, _, c = self._dense_layer_step(
                lp, h, causal=True, positions=positions,
                span_z=span_z, cache=(ck, cv), cache_pos=pos,
                use_pallas=use_pallas,
            )
            # frozen tokens keep their exited representation; the layer's KV
            # write above came from that frozen input (state propagation)
            h = jnp.where(done[..., None], h, h_new)
            lg, ent = head_entropy(h)                    # [B,1,V], [B,1]
            exit_now = jnp.logical_and(jnp.logical_not(done), ent < threshold)
            last = i == n_layers - 1
            take = jnp.logical_or(
                exit_now, jnp.logical_and(last, jnp.logical_not(done))
            )
            logits = jnp.where(take[..., None], lg, logits)
            exit_layer = jnp.where(take[:, 0], i + 1, exit_layer)
            first_ent = jnp.where(i == 0, ent[:, 0], first_ent)
            done = jnp.logical_or(done, exit_now)
            return (h, done, logits, exit_layer, first_ent, i + 1), (c[0], c[1])

        init = (
            h,
            jnp.zeros((B, 1), bool),
            jnp.zeros((B, 1, V), jnp.float32),
            jnp.zeros((B,), jnp.int32),
            jnp.zeros((B,), jnp.float32),
            jnp.array(0, jnp.int32),
        )
        if cfg.family == "albert":
            (h, done, logits, exit_layer, first_ent, _), (ks, vs) = jax.lax.scan(
                body, init, (cache["k"], cache["v"])
            )
        else:
            span = p.get("span_z")
            if span is not None:
                span_xs = (
                    jnp.broadcast_to(span[:1], (cfg.n_layers, cfg.n_heads))
                    if span.shape[0] == 1 else span
                )
            else:
                span_xs = None
            if span_xs is not None:
                (h, done, logits, exit_layer, first_ent, _), (ks, vs) = jax.lax.scan(
                    body, init, (p["layers"], cache["k"], cache["v"], span_xs)
                )
            else:
                (h, done, logits, exit_layer, first_ent, _), (ks, vs) = jax.lax.scan(
                    lambda cr, xs: body(cr, (xs[0], xs[1], xs[2], None)),
                    init, (p["layers"], cache["k"], cache["v"]),
                )
        cache = dict(cache, k=ks, v=vs)
        return logits, cache, exit_layer, first_ent

    def decode_step_spec(
        self,
        p: Params,
        cache: Params,
        tokens: jnp.ndarray,          # [1, 1] — one lane (see contract below)
        pos,                           # scalar cache position
        thresholds,                    # scalar, [W], or [1, W] entropy thresholds
        spec_window: int,
        eos_id: int = -1,
        use_pallas: bool = False,
    ):
        """Self-speculative fused decode step via the entropy off-ramps
        (the ROADMAP's "exit-at-k is a free draft model" item).

        Per fused step the lane runs up to ``spec_window`` slots.  Each slot
        is EXACTLY one ``decode_step_ee`` evaluation: the off-ramp at layer k
        emits the DRAFT (the frozen hidden state), the remaining layers
        k+1..L are the verifier pass (hidden-state propagation pushes the
        frozen draft through them, so the returned logits ARE the verified
        full-pipeline output), and the batched accept rule is evaluated on
        the slot outputs: a lane keeps speculating while its tokens take an
        off-ramp (``exit_layer < n_layers``) and don't emit EOS; the first
        token the verifier forces to full depth is still emitted (it is
        verified output) but TERMINATES the block.  ``accepted[j]`` marks
        the slots forming the accepted prefix; suffix slots idempotently
        recompute the lane's frozen (token, position) — the KV rows they
        write are bit-identical to what the next fused step would write, so
        KV "rollback" is simply not advancing the host position past the
        accepted prefix.  Everything is fixed-shape and masked (the batched
        accept/reject loop idiom): one compile per (bucket, spec_window).

        Because every slot is the unmodified ``decode_step_ee`` body,
        accepted tokens are bit-identical to the non-speculative path by
        construction, and ``spec_window=1`` degenerates to exactly one
        ``decode_step_ee`` call.

        Contract: one lane per call (``B == 1``) — lanes diverge in position
        as soon as acceptance diverges, and the KV write index must stay
        scalar; the serving layer vmaps this over lanes (see
        ``step_math.decoder_decode_spec``), same as the per-token EE path.

        ``thresholds`` may be a scalar (the degenerate schedule), or a
        per-slot row from an ``ExitThresholdSchedule`` (slot j gates the
        token at position ``pos + j``).

        Returns ``(tokens [1,W], logits [1,W,V], cache, exit_layers [1,W],
        first_ent [1,W], accepted [1,W])`` with ``exit_layers`` 1-based.
        """
        cfg = self.cfg
        assert cfg.family in ("dense", "moe", "albert"), (
            "speculative exit decode: KV-cache decoder families only"
        )
        W = int(spec_window)
        assert W >= 1, "spec_window must be >= 1"
        B = tokens.shape[0]
        assert B == 1, (
            "decode_step_spec is one-lane (B == 1); vmap over lanes via "
            "step_math.decoder_decode_spec"
        )
        thr = jnp.asarray(thresholds, jnp.float32)
        if thr.ndim == 0:
            thr = jnp.broadcast_to(thr, (B, W))
        elif thr.ndim == 1:
            thr = jnp.broadcast_to(thr[None, :], (B, W))
        assert thr.shape == (B, W), f"thresholds shape {thr.shape} != {(B, W)}"
        n_layers = cfg.n_layers

        def slot(carry, thr_j):
            cache_c, cur, posn, alive = carry
            accept = alive                         # accepted iff entered alive
            lg, cache_c, xl, fe = self.decode_step_ee(
                p, cache_c, cur, posn, thr_j[:, None], use_pallas=use_pallas
            )
            tok = jnp.argmax(lg[:, -1, :], axis=-1).astype(jnp.int32)
            alive = jnp.logical_and(accept, xl < n_layers)
            alive = jnp.logical_and(alive, tok != eos_id)
            cur = jnp.where(accept[:, None], tok[:, None], cur)
            posn = posn + accept[0].astype(jnp.int32)
            return (cache_c, cur, posn, alive), (tok, lg[:, -1, :], xl, fe, accept)

        init = (
            cache,
            tokens.astype(jnp.int32),
            jnp.asarray(pos, jnp.int32),
            jnp.ones((B,), bool),
        )
        (cache, _, _, _), (toks, lgs, xls, fes, accs) = jax.lax.scan(
            slot, init, jnp.moveaxis(thr, 1, 0)
        )
        return (
            jnp.moveaxis(toks, 0, 1),              # [B, W]
            jnp.moveaxis(lgs, 0, 1),               # [B, W, V]
            cache,
            jnp.moveaxis(xls, 0, 1),               # [B, W]
            jnp.moveaxis(fes, 0, 1),               # [B, W]
            jnp.moveaxis(accs, 0, 1),              # [B, W]
        )

    def _cross_decode(self, lp, h, ik, iv):
        """Cross-attention of decode queries against cached image K/V."""
        cfg = self.cfg
        B, S, _ = h.shape
        hn = L.apply_norm(lp["norm1"], h, cfg.norm)
        q = (hn @ lp["xattn"]["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        out = L.attention(q, ik, iv, causal=False)
        out = out.reshape(B, S, -1) @ lp["xattn"]["wo"]
        x = jnp.tanh(lp["gate_attn"]).astype(h.dtype) * out
        m = L.apply_mlp(lp["mlp"], L.apply_norm(lp["norm2"], h + x, cfg.norm), cfg.act)
        return x + jnp.tanh(lp["gate_mlp"]).astype(h.dtype) * m

    def _precomputed_cross(self, xp, h, ek, ev):
        cfg = self.cfg
        B, S, _ = h.shape
        hn = L.apply_norm(xp["norm"], h, cfg.norm)
        q = (hn @ xp["xattn"]["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        if "bq" in xp["xattn"]:
            q = q + xp["xattn"]["bq"].reshape(cfg.n_heads, cfg.head_dim)
        out = L.attention(q, ek, ev, causal=False)
        return out.reshape(B, S, -1) @ xp["xattn"]["wo"]

    # ---------------------------------------------------------------- prefill
    def prefill(self, p: Params, tokens: jnp.ndarray, cache: Params, aux=None):
        """Run the full prompt through the model, filling caches.

        Implemented as a full forward that also writes K/V (positions 0..S-1).
        Returns (last-token logits, cache).
        """
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "albert"):
            h = self.embed(p, tokens)
            positions = jnp.arange(tokens.shape[1])

            def step(carry, xs):
                h = carry
                if cfg.family == "albert":
                    lp, (ck, cv) = p["layer"], xs
                    span_z = self._span_for_layer(p, 0)
                else:
                    lp, ck, cv = xs
                    span_z = None
                h, _, c = self._dense_layer_step(
                    lp, h, causal=True, positions=positions,
                    span_z=span_z, cache=(ck, cv), cache_pos=0,
                )
                return h, (c[0], c[1])

            if cfg.family == "albert":
                h, (ks, vs) = jax.lax.scan(
                    self._remat(step), h, (cache["k"], cache["v"])
                )
            else:
                h, (ks, vs) = jax.lax.scan(
                    self._remat(step), h, (p["layers"], cache["k"], cache["v"])
                )
            cache = dict(cache, k=ks, v=vs)
            h = L.apply_norm(p["final_norm"], h, cfg.norm)
            return self.lm_logits(p, h[:, -1:]), cache
        if cfg.family == "encdec":
            # encode once, cache cross K/V, then prefill decoder
            frames = aux["enc_input"]
            enc = self._encode(p, frames)

            def mk_kv(xp):
                k = (enc @ xp["xattn"]["wk"]).reshape(
                    enc.shape[0], enc.shape[1], cfg.n_kv_heads, cfg.head_dim
                )
                v = (enc @ xp["xattn"]["wv"]).reshape(
                    enc.shape[0], enc.shape[1], cfg.n_kv_heads, cfg.head_dim
                )
                return k, v

            del mk_kv  # einsum over stacked cross weights instead
            ek = jnp.einsum("bsd,ldk->lbsk", enc, p["dec_cross"]["xattn"]["wk"]).reshape(
                cfg.n_layers, enc.shape[0], enc.shape[1], cfg.n_kv_heads, cfg.head_dim
            )
            ev = jnp.einsum("bsd,ldk->lbsk", enc, p["dec_cross"]["xattn"]["wv"]).reshape(
                cfg.n_layers, enc.shape[0], enc.shape[1], cfg.n_kv_heads, cfg.head_dim
            )
            cache = dict(cache, enc_k=ek.astype(_dtype(cfg)), enc_v=ev.astype(_dtype(cfg)))
            h = self.embed(p, tokens)
            positions = jnp.arange(tokens.shape[1])

            def step(carry, xs):
                h = carry
                lp, xp, ck, cv, ek_l, ev_l = xs
                h, _, c = self._dense_layer_step(
                    lp, h, causal=True, positions=positions, cache=(ck, cv), cache_pos=0
                )
                x = self._precomputed_cross(xp, h, ek_l, ev_l)
                h = h + x
                return h, (c[0], c[1])

            h, (ks, vs) = jax.lax.scan(
                self._remat(step), h,
                (p["layers"], p["dec_cross"], cache["k"], cache["v"],
                 cache["enc_k"], cache["enc_v"]),
            )
            cache = dict(cache, k=ks, v=vs)
            h = L.apply_norm(p["final_norm"], h, cfg.norm)
            return self.lm_logits(p, h[:, -1:]), cache
        if cfg.family == "vlm":
            img = aux["image_embeds"]
            n_cross = cfg.n_layers // cfg.cross_attn_every
            ik = jnp.einsum("bsd,ldk->lbsk", img, p["cross_layers"]["xattn"]["wk"]).reshape(
                n_cross, img.shape[0], img.shape[1], cfg.n_kv_heads, cfg.head_dim
            )
            iv = jnp.einsum("bsd,ldk->lbsk", img, p["cross_layers"]["xattn"]["wv"]).reshape(
                n_cross, img.shape[0], img.shape[1], cfg.n_kv_heads, cfg.head_dim
            )
            cache = dict(cache, img_k=ik.astype(_dtype(cfg)), img_v=iv.astype(_dtype(cfg)))
            h = self.embed(p, tokens)
            positions = jnp.arange(tokens.shape[1])
            n_self_per = cfg.cross_attn_every - 1
            self_layers = jax.tree_util.tree_map(
                lambda x: x.reshape((n_cross, n_self_per) + x.shape[1:]), p["layers"]
            )
            kr = cache["k"].reshape((n_cross, n_self_per) + cache["k"].shape[1:])
            vr = cache["v"].reshape((n_cross, n_self_per) + cache["v"].shape[1:])

            def group(carry, xs):
                h = carry
                selfs, cross, ck_g, cv_g, ik_l, iv_l = xs

                def inner(hh, ys):
                    lp, ck, cv = ys
                    hh, _, c = self._dense_layer_step(
                        lp, hh, causal=True, positions=positions, cache=(ck, cv), cache_pos=0
                    )
                    return hh, (c[0], c[1])

                h, (ck_new, cv_new) = jax.lax.scan(inner, h, (selfs, ck_g, cv_g))
                h = h + self._cross_decode(cross, h, ik_l, iv_l)
                return h, (ck_new, cv_new)

            h, (ks, vs) = jax.lax.scan(
                self._remat(group), h,
                (self_layers, p["cross_layers"], kr, vr, cache["img_k"], cache["img_v"]),
            )
            cache = dict(cache, k=ks.reshape(cache["k"].shape), v=vs.reshape(cache["v"].shape))
            h = L.apply_norm(p["final_norm"], h, cfg.norm)
            return self.lm_logits(p, h[:, -1:]), cache
        if cfg.family == "ssm":
            h = self.embed(p, tokens)

            def step(h, lp):
                h, st = self._rwkv_layer_step(lp, h)
                return h, (st["last_tm"], st["last_cm"], st["wkv"])

            h, (ltm, lcm, wkv) = jax.lax.scan(self._remat(step), h, p["layers"])
            cache = dict(cache, last_tm=ltm, last_cm=lcm, wkv=wkv)
            h = L.apply_norm(p["final_norm"], h, "layernorm")
            return self.lm_logits(p, h[:, -1:]), cache
        if cfg.family == "hybrid":
            h = self.embed(p, tokens)
            x0 = h
            positions = jnp.arange(tokens.shape[1])
            n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
            new_conv, new_ssm, ks_list, vs_list = [], [], [], []
            attn_idx = 0
            for i in range(cfg.n_layers):
                lp = jax.tree_util.tree_map(lambda x: x[i], p["layers"])
                h, st = self._mamba_block_step(lp, h)
                new_conv.append(st["conv"])
                new_ssm.append(st["ssm"])
                if cfg.attn_every and (i + 1) % cfg.attn_every == 0 and attn_idx < n_attn:
                    h, c = self._shared_attn_step(
                        p["shared_attn"], h, x0,
                        span_z=self._span_for_layer(p, 0),
                        cache=(cache["k"][attn_idx], cache["v"][attn_idx]),
                        cache_pos=0, positions=positions,
                    )
                    ks_list.append(c[0])
                    vs_list.append(c[1])
                    attn_idx += 1
            cache = dict(cache, conv=jnp.stack(new_conv), ssm=jnp.stack(new_ssm))
            if ks_list:
                cache["k"] = jnp.stack(ks_list)
                cache["v"] = jnp.stack(vs_list)
            h = L.apply_norm(p["final_norm"], h, cfg.norm)
            return self.lm_logits(p, h[:, -1:]), cache
        raise ValueError(cfg.family)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def count_params(params: Params) -> int:
    import numpy as np

    return int(
        sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(params) if hasattr(x, "shape"))
    )
