"""Beyond-paper §Perf features: AF8 KV cache, grouped MoE dispatch, fused-
attention tagging — correctness on CPU."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.jax_compat import HAS_AXIS_TYPES
from repro.configs.base import get_smoke_config
from repro.models.model import build_model
from repro.models import moe


def test_af8_kv_cache_decode_close():
    cfg = dataclasses.replace(get_smoke_config("qwen1_5_110b"), dtype="float32",
                              remat_policy="none")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="af8", fused_attention=True)
    m, m8 = build_model(cfg), build_model(cfg8)
    params = m.init_params(jax.random.PRNGKey(0))
    B, S = 2, 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    c, c8 = m.init_cache(B, 64), m8.init_cache(B, 64)
    assert c8["k"].dtype == jnp.uint8 and c["k"].dtype == jnp.float32
    _, c = m.prefill(params, toks[:, :-1], c)
    _, c8 = m8.prefill(params, toks[:, :-1], c8)
    d, _ = m.decode_step(params, c, toks[:, -1:], S - 1)
    d8, _ = m8.decode_step(params, c8, toks[:, -1:], S - 1)
    rel = float(jnp.abs(d - d8).max()) / float(jnp.abs(d).max())
    assert rel < 0.1
    assert (np.argmax(np.asarray(d[:, 0]), -1) == np.argmax(np.asarray(d8[:, 0]), -1)).all()


def test_grouped_moe_matches_flat():
    cfg = dataclasses.replace(get_smoke_config("qwen3_moe_235b"), dtype="float32")
    p = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model)) * 0.5
    y_flat, aux_flat = moe.apply_moe(p, x, cfg, capacity_factor=8.0)
    cfg_g = dataclasses.replace(cfg, moe_grouped_dispatch=True)
    y_grp, aux_grp = moe.apply_moe(p, x, cfg_g, capacity_factor=8.0)
    # with generous capacity no tokens drop in either scheme -> identical math
    np.testing.assert_allclose(np.asarray(y_flat), np.asarray(y_grp), atol=2e-5)


def test_fused_attention_tag_in_hlo():
    cfg = dataclasses.replace(get_smoke_config("deepseek_7b"), dtype="float32",
                              remat_policy="none", fused_attention=True)
    m = build_model(cfg)
    params_abs = jax.eval_shape(lambda: m.init_params(jax.random.PRNGKey(0)))
    toks = jax.ShapeDtypeStruct((2, 32), jnp.int32)
    txt = (
        jax.jit(lambda p, t: m.apply_train(p, {"tokens": t}).logits)
        .lower(params_abs, toks)
        .compile()
        .as_text()
    )
    assert "fused_attn_kernel" in txt
    # the analyzer sees lower HBM bytes with the tag honored
    from repro.hwmodel.hlo_analysis import analyze

    cfg0 = dataclasses.replace(cfg, fused_attention=False)
    m0 = build_model(cfg0)
    txt0 = (
        jax.jit(lambda p, t: m0.apply_train(p, {"tokens": t}).logits)
        .lower(params_abs, toks)
        .compile()
        .as_text()
    )
    b1 = analyze(txt).bytes_io
    b0 = analyze(txt0).bytes_io
    assert b1 < b0
    # FLOPs unchanged (kernel does the same math)
    assert abs(analyze(txt).flops - analyze(txt0).flops) / analyze(txt0).flops < 0.05


def test_fused_attention_same_outputs():
    cfg = dataclasses.replace(get_smoke_config("internlm2_20b"), dtype="float32",
                              remat_policy="none")
    cfg_f = dataclasses.replace(cfg, fused_attention=True)
    m, mf = build_model(cfg), build_model(cfg_f)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)}
    o1 = m.apply_train(params, batch)
    o2 = mf.apply_train(params, batch)
    np.testing.assert_allclose(np.asarray(o1.logits), np.asarray(o2.logits), atol=1e-6)


def test_hybrid_grouped_equals_cond():
    cfg = dataclasses.replace(get_smoke_config("zamba2_1p2b"), dtype="float32",
                              remat_policy="none")
    cfg_g = dataclasses.replace(cfg, hybrid_grouped=True)
    m, mg = build_model(cfg), build_model(cfg_g)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)}
    o1, o2 = m.apply_train(params, batch), mg.apply_train(params, batch)
    np.testing.assert_allclose(np.asarray(o1.logits), np.asarray(o2.logits), atol=1e-5)


@pytest.mark.multidevice
@pytest.mark.skipif(
    not HAS_AXIS_TYPES,
    reason="installed jax lacks jax.sharding.AxisType (needed by "
    "set_mesh in the forced-multi-device subprocess)",
)
def test_moe_shardmap_matches_dense():
    """Explicit shard_map EP dispatch (§Perf qwen3 A5) is bit-exact vs the
    dense reference under generous capacity (subprocess: multi-device)."""
    import os, subprocess, sys, textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_smoke_config
        from repro.models import moe
        from repro.launch.mesh import make_debug_mesh

        cfg = dataclasses.replace(get_smoke_config('qwen3_moe_235b'), dtype='float32')
        mesh = make_debug_mesh(2, 2)
        p = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model)) * 0.5
        y_ref, _ = moe.apply_moe(p, x, cfg, capacity_factor=8.0)
        cfg_s = dataclasses.replace(cfg, moe_shardmap_dispatch=True)
        with jax.set_mesh(mesh):
            y_s, _ = moe.apply_moe(p, x, cfg_s, capacity_factor=8.0)
        err = float(jnp.abs(y_ref - jnp.asarray(y_s)).max())
        assert err < 2e-5, err
        print('MOESHMAP_OK')
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stderr[-2500:]
    assert "MOESHMAP_OK" in r.stdout
