"""Movement + magnitude pruning (paper §III-C, Fig. 5, Table IV).

Magnitude pruning: keep weights with |w| above the per-tensor quantile implied
by the target sparsity; recomputed on a schedule during fine-tuning; applied
once to the (then frozen, task-shared) embedding table.

Movement pruning (Sanh et al. [47]): learnable importance scores S with the
same shape as W; forward pass uses W * TopV(S); the straight-through estimator
routes dL/dS = (dL/d(W*mask)) * W so scores accumulate the *movement* of
weights during fine-tuning.

``block_size > 1`` scores contiguous (block, block) tiles by L2 norm and prunes
whole tiles — the beyond-paper structured mode that the TPU block-sparse matmul
kernel (repro.kernels.block_sparse) can actually skip (DESIGN.md §2: element-
granular zero-skip has no MXU analogue; tile-granular does).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Sparsity schedule (cubic, Zhu & Gupta style — used by both methods)
# ---------------------------------------------------------------------------


def sparsity_schedule(step, final_sparsity: float, begin_step: int, end_step: int):
    """Cubic ramp: 0 at begin_step -> final_sparsity at end_step."""
    step = jnp.asarray(step, jnp.float32)
    t = jnp.clip((step - begin_step) / jnp.maximum(end_step - begin_step, 1), 0.0, 1.0)
    return final_sparsity * (1.0 - (1.0 - t) ** 3)


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------


def _block_reduce(score: jnp.ndarray, block: int) -> jnp.ndarray:
    """L2-reduce a 2D score tensor into (ceil(r/b), ceil(c/b)) block scores."""
    r, c = score.shape
    pr, pc = (-r) % block, (-c) % block
    s = jnp.pad(score, ((0, pr), (0, pc)))
    s = s.reshape(s.shape[0] // block, block, s.shape[1] // block, block)
    return jnp.sqrt(jnp.sum(s.astype(jnp.float32) ** 2, axis=(1, 3)))


def _expand_block_mask(bmask: jnp.ndarray, shape, block: int) -> jnp.ndarray:
    m = jnp.repeat(jnp.repeat(bmask, block, axis=0), block, axis=1)
    return m[: shape[0], : shape[1]]


def topv_mask(score: jnp.ndarray, sparsity, block_size: int = 1) -> jnp.ndarray:
    """Binary keep-mask retaining the top (1-sparsity) fraction by score."""
    if block_size > 1 and score.ndim == 2:
        bscore = _block_reduce(score, block_size)
        bmask = topv_mask(bscore, sparsity, block_size=1)
        return _expand_block_mask(bmask, score.shape, block_size)
    flat = score.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    # drop the k = floor(n*sparsity) smallest scores: threshold at the k-th
    # order statistic (sorted[k-1]); keep strictly-greater values
    sparsity = jnp.asarray(sparsity, jnp.float32)
    k = jnp.clip(jnp.floor(n * sparsity).astype(jnp.int32), 0, n)
    thresh = jnp.sort(flat)[jnp.maximum(k - 1, 0)]
    mask = (flat > thresh).astype(score.dtype)
    # sparsity == 0 (or k == 0) keeps everything
    mask = jnp.where(k <= 0, jnp.ones_like(mask), mask)
    return mask.reshape(score.shape)


def magnitude_mask(w: jnp.ndarray, sparsity, block_size: int = 1) -> jnp.ndarray:
    return topv_mask(jnp.abs(w), sparsity, block_size)


# ---------------------------------------------------------------------------
# Movement pruning STE
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def movement_masked_weight(w, scores, sparsity, block_size: int = 1):
    return w * topv_mask(scores, sparsity, block_size)


def _mm_fwd(w, scores, sparsity, block_size):
    mask = topv_mask(scores, sparsity, block_size)
    return w * mask, (w, mask)


def _mm_bwd(block_size, res, g):
    w, mask = res
    # dL/dw through the mask; dL/dscores via straight-through = g * w
    return g * mask, (g * w).astype(w.dtype), None


movement_masked_weight.defvjp(_mm_fwd, _mm_bwd)


# ---------------------------------------------------------------------------
# Pruning state plumbing over parameter pytrees
# ---------------------------------------------------------------------------

# Which leaves are prunable. The paper deliberately does NOT sparsify layer
# normalization, the early-exit off-ramp, or the final classifier (§IV-B2:
# EE_perf deteriorates 3.2x on SST-2 otherwise).
_EXCLUDE_SUBSTRINGS = ("norm", "ln_", "bias", "offramp", "classifier", "span_z", "router")


def prunable(path: str, leaf) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    lp = path.lower()
    return not any(s in lp for s in _EXCLUDE_SUBSTRINGS)


def path_str(path) -> str:
    return jax.tree_util.keystr(path)


class PruneState(NamedTuple):
    masks: Any          # pytree of {path: mask} aligned with prunable leaves
    scores: Any         # movement-pruning importance scores (None for magnitude)


def init_prune_state(params: Any, method: str) -> PruneState:
    def mk_mask(path, leaf):
        if prunable(path_str(path), leaf):
            return jnp.ones_like(leaf, dtype=jnp.float32)
        return None

    def mk_score(path, leaf):
        if method == "movement" and prunable(path_str(path), leaf):
            # init scores to |w| so early masking is magnitude-like, then moves
            return jnp.abs(leaf).astype(jnp.float32)
        return None

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    masks = jax.tree_util.tree_unflatten(treedef, [mk_mask(p, l) for p, l in flat])
    scores = jax.tree_util.tree_unflatten(treedef, [mk_score(p, l) for p, l in flat])
    return PruneState(masks=masks, scores=scores)


def update_masks(
    params: Any,
    state: PruneState,
    step,
    method: str,
    final_sparsity: float,
    begin_step: int,
    end_step: int,
    block_size: int = 1,
) -> PruneState:
    """Recompute masks at the scheduled sparsity (called every `update_every`)."""
    s = sparsity_schedule(step, final_sparsity, begin_step, end_step)

    def upd(path, leaf, mask, score):
        if mask is None:
            return None
        src = jnp.abs(leaf) if method == "magnitude" else score
        return topv_mask(src, s, block_size).astype(jnp.float32)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_masks = treedef.flatten_up_to(state.masks)
    flat_scores = treedef.flatten_up_to(state.scores)
    new_masks = [
        upd(p, l, m, sc) for (p, l), m, sc in zip(flat, flat_masks, flat_scores)
    ]
    return PruneState(
        masks=jax.tree_util.tree_unflatten(treedef, new_masks), scores=state.scores
    )


def apply_masks(params: Any, state: PruneState) -> Any:
    """params * mask for prunable leaves (identity elsewhere)."""

    def ap(leaf, mask):
        return leaf if mask is None else leaf * mask.astype(leaf.dtype)

    return jax.tree_util.tree_map(
        ap, params, state.masks, is_leaf=lambda x: x is None
    )


def update_movement_scores(state: PruneState, params: Any, grads: Any, lr) -> PruneState:
    """Movement score update: S <- S - lr * w * grad_w (first-order movement).

    (Equivalent to accumulating -(dL/dW)*W, the movement-pruning importance.)
    """

    def upd(score, w, g):
        if score is None:
            return None
        return score - lr * (w * g).astype(jnp.float32)

    new_scores = jax.tree_util.tree_map(
        upd, state.scores, params, grads, is_leaf=lambda x: x is None
    )
    return PruneState(masks=state.masks, scores=new_scores)


def measured_sparsity(params: Any, state: PruneState) -> Dict[str, float]:
    """Actual zero fraction over prunable leaves (reported in benchmarks)."""
    masked = apply_masks(params, state)
    flat, _ = jax.tree_util.tree_flatten_with_path(masked)
    zeros = total = 0
    for path, leaf in flat:
        if prunable(path_str(path), leaf):
            arr = np.asarray(leaf)
            zeros += int((arr == 0).sum())
            total += arr.size
    return {"sparsity": zeros / max(total, 1), "zeros": zeros, "total": total}
