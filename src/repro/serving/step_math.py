"""Pure math of one fused serving step, isolated from scheduling.

The engines in ``serving/engine.py`` used to build their jit'd closures
inline, entangling three concerns: the numerical step (what one fused step
computes), trace accounting (host-side counters bumped inside traced
bodies), and scheduling (which bucket steps when).  This module owns the
first concern only: every function here is pure array math — no scheduler,
no telemetry, no host state — so the engine closures reduce to thin
wrappers that bump a trace counter and delegate.

This is also where ``use_pallas`` lands.  Each function takes the flag as a
plain Python keyword (closed over by the engine's jit'd closures, hence
static): ``True`` routes the eligible inner ops — attention, layernorm,
off-ramp entropy, activation quant, pruned MLP tiles — to the Pallas
kernels via ``repro.kernels.dispatch``; ``False`` keeps the byte-identical
reference path.  Either way the step is one compile per bucket: the flag
never becomes a traced value, so flipping it cannot add traces at runtime.

Lane structure: both engines vmap a one-lane body over the lane axis.  The
per-lane kv_len / position scalars become traced per-lane operands, which
the Pallas span kernel accepts through scalar prefetch — verified to
compose with vmap+jit in interpret mode (CPU CI) and on TPU.

Multi-device sharding: the ``sharded_*`` variants wrap the same fused-step
math in ``shard_map`` over a 1-D device mesh, splitting the lane axis into
``replicas`` contiguous slabs (lane ``i`` lives on replica
``i // lanes_per_replica``).  Params and scalars replicate; the classifier's
``[lanes, S, D]`` state shards on axis 0 and the decoder KV cache on its
lane axis 1.  Because the body may dispatch ``pallas_call`` (which has no
replication rule), the wrappers go through ``jax_compat.shard_map_norep``.
Lanes are independent, so a 1-replica sharded step is bit-identical to the
unsharded step — the parity guarantee the serving tests gate.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.common.jax_compat import shard_map_norep
from repro.core.early_exit import offramp_logits
from repro.core.entropy import entropy_from_logits
from repro.models.model import Model


# ---------------------------------------------------------------------------
# Classifier (early-exit encoder) fused step
# ---------------------------------------------------------------------------


def classifier_embed(model: Model, params: Any, tokens: jnp.ndarray) -> jnp.ndarray:
    """Embed one lane's padded token row: [1, S_bucket] -> [1, S_bucket, D]."""
    return model.embed(params, tokens)


def classifier_fused_step(
    model: Model,
    params: Any,
    h: jnp.ndarray,          # [lanes, S_bucket, D] static-shape hidden states
    active: jnp.ndarray,     # [lanes] bool — inactive lanes frozen by the mask
    lengths: jnp.ndarray,    # [lanes] int32 valid token count per lane
    threshold: jnp.ndarray,  # scalar entropy threshold
    *,
    use_pallas: bool = False,
    block_masks: Optional[Dict[str, Any]] = None,
):
    """Fused: encoder layer -> off-ramp logits -> entropy -> retire mask.

    Positions beyond a lane's length are bucket padding, masked out of
    attention via kv_len so a padded sentence computes the SAME function as
    at its native length.  Returns ``(h, logits, entropy, retire)``.
    """
    span_z = model._span_for_layer(params, 0)

    def one_lane(h_l, length):
        h2, _, _ = model._dense_layer_step(
            params["layer"], h_l[None], causal=False, span_z=span_z,
            kv_len=length, use_pallas=use_pallas, block_masks=block_masks,
        )
        return h2[0]

    h_new = jax.vmap(one_lane)(h, lengths)
    h = jnp.where(active[:, None, None], h_new, h)
    lg = offramp_logits(h, model._offramp(params))
    if use_pallas:
        from repro.kernels import dispatch

        ent = dispatch.entropy(lg)
    else:
        ent = entropy_from_logits(lg)
    retire = jnp.logical_and(active, ent < threshold)
    return h, lg, ent, retire


def sharded_classifier_fused_step(
    model: Model,
    params: Any,
    h: jnp.ndarray,          # [replicas * lanes_per_replica, S_bucket, D]
    active: jnp.ndarray,
    lengths: jnp.ndarray,
    threshold: jnp.ndarray,
    *,
    mesh: Any,
    axis: str = "data",
    use_pallas: bool = False,
    block_masks: Optional[Dict[str, Any]] = None,
):
    """``classifier_fused_step`` shard_map'd over the lane axis.

    Each device computes its own contiguous ``[lanes_per_replica, S, D]``
    slab under replicated params — no collectives cross replicas, so the
    step scales linearly in device count and a 1-replica mesh reproduces
    the unsharded step bit-for-bit."""
    P = jax.sharding.PartitionSpec
    fn = shard_map_norep(
        lambda p, hh, aa, ll, th: classifier_fused_step(
            model, p, hh, aa, ll, th,
            use_pallas=use_pallas, block_masks=block_masks,
        ),
        mesh,
        in_specs=(P(), P(axis), P(axis), P(axis), P()),
        out_specs=(P(axis), P(axis), P(axis), P(axis)),
    )
    return fn(params, h, active, lengths, threshold)


def lane_insert(h: jnp.ndarray, lane: jnp.ndarray, h_new: jnp.ndarray) -> jnp.ndarray:
    """Overwrite one lane row; reused verbatim for load AND restore so
    preemption round-trips through the same compiled trace."""
    return jax.lax.dynamic_update_slice_in_dim(h, h_new, lane, axis=0)


# ---------------------------------------------------------------------------
# Decoder (LM) fused steps
# ---------------------------------------------------------------------------


def decoder_decode(
    model: Model,
    params: Any,
    cache: Any,
    tokens: jnp.ndarray,     # [lanes, 1]
    pos: jnp.ndarray,        # [lanes] per-lane cache positions
    *,
    use_pallas: bool = False,
):
    """One decode step with PER-LANE positions (vmap over the lane axis)."""
    lane_axes = jax.tree_util.tree_map(lambda _: 1, cache)

    def one_lane(cache_l, tok, p):
        cache_b = jax.tree_util.tree_map(lambda x: x[:, None], cache_l)
        lg, cache_b = model.decode_step(
            params, cache_b, tok[None, None], p, use_pallas=use_pallas
        )
        return lg[0], jax.tree_util.tree_map(lambda x: x[:, 0], cache_b)

    return jax.vmap(
        one_lane, in_axes=(lane_axes, 0, 0), out_axes=(0, lane_axes)
    )(cache, tokens[:, 0], pos)


def decoder_decode_ee(
    model: Model,
    params: Any,
    cache: Any,
    tokens: jnp.ndarray,
    pos: jnp.ndarray,
    threshold,
    *,
    use_pallas: bool = False,
):
    """Fused layer -> LM-head off-ramp -> entropy -> per-token exit.

    Same per-lane vmap as ``decoder_decode``; each lane additionally returns
    its token's 1-based exit depth and first-off-ramp entropy.
    """
    lane_axes = jax.tree_util.tree_map(lambda _: 1, cache)

    def one_lane(cache_l, tok, p):
        cache_b = jax.tree_util.tree_map(lambda x: x[:, None], cache_l)
        lg, cache_b, xl, fe = model.decode_step_ee(
            params, cache_b, tok[None, None], p, threshold,
            use_pallas=use_pallas,
        )
        return (
            lg[0],
            jax.tree_util.tree_map(lambda x: x[:, 0], cache_b),
            xl[0],
            fe[0],
        )

    return jax.vmap(
        one_lane, in_axes=(lane_axes, 0, 0), out_axes=(0, lane_axes, 0, 0)
    )(cache, tokens[:, 0], pos)


def decoder_decode_spec(
    model: Model,
    params: Any,
    cache: Any,
    tokens: jnp.ndarray,     # [lanes, 1]
    pos: jnp.ndarray,        # [lanes]
    thresholds: jnp.ndarray,  # [lanes, spec_window] per-slot entropy thresholds
    spec_window: int,
    *,
    eos_id: int = -1,
    use_pallas: bool = False,
):
    """Self-speculative fused step: per-lane vmap of the one-lane
    ``decode_step_spec`` (draft via off-ramp, verify via remaining layers,
    batched accept/rollback).  Thresholds are a per-lane, per-slot row so a
    position/entropy-band schedule prices each speculated position
    individually.

    Returns per-lane ``(tokens [lanes,W], logits [lanes,W,V], cache,
    exit_layers [lanes,W], first_ent [lanes,W], accepted [lanes,W])``.
    """
    lane_axes = jax.tree_util.tree_map(lambda _: 1, cache)

    def one_lane(cache_l, tok, p, thr):
        cache_b = jax.tree_util.tree_map(lambda x: x[:, None], cache_l)
        tk, lg, cache_b, xl, fe, acc = model.decode_step_spec(
            params, cache_b, tok[None, None], p, thr[None, :], spec_window,
            eos_id=eos_id, use_pallas=use_pallas,
        )
        return (
            tk[0],
            lg[0],
            jax.tree_util.tree_map(lambda x: x[:, 0], cache_b),
            xl[0],
            fe[0],
            acc[0],
        )

    return jax.vmap(
        one_lane, in_axes=(lane_axes, 0, 0, 0),
        out_axes=(0, 0, lane_axes, 0, 0, 0),
    )(cache, tokens[:, 0], pos, thresholds)


def sharded_decoder_decode(
    model: Model,
    params: Any,
    cache: Any,
    tokens: jnp.ndarray,
    pos: jnp.ndarray,
    *,
    mesh: Any,
    axis: str = "data",
    use_pallas: bool = False,
):
    """``decoder_decode`` shard_map'd over the KV cache's lane axis (axis 1
    of every cache leaf); tokens and positions shard with their lanes."""
    P = jax.sharding.PartitionSpec
    cache_specs = jax.tree_util.tree_map(lambda _: P(None, axis), cache)
    fn = shard_map_norep(
        lambda p, c, t, po: decoder_decode(
            model, p, c, t, po, use_pallas=use_pallas
        ),
        mesh,
        in_specs=(P(), cache_specs, P(axis), P(axis)),
        out_specs=(P(axis), cache_specs),
    )
    return fn(params, cache, tokens, pos)


def sharded_decoder_decode_ee(
    model: Model,
    params: Any,
    cache: Any,
    tokens: jnp.ndarray,
    pos: jnp.ndarray,
    threshold,
    *,
    mesh: Any,
    axis: str = "data",
    use_pallas: bool = False,
):
    """``decoder_decode_ee`` shard_map'd like ``sharded_decoder_decode``;
    the per-token exit depths and first entropies shard with their lanes."""
    P = jax.sharding.PartitionSpec
    cache_specs = jax.tree_util.tree_map(lambda _: P(None, axis), cache)
    fn = shard_map_norep(
        lambda p, c, t, po, th: decoder_decode_ee(
            model, p, c, t, po, th, use_pallas=use_pallas
        ),
        mesh,
        in_specs=(P(), cache_specs, P(axis), P(axis), P()),
        out_specs=(P(axis), cache_specs, P(axis), P(axis)),
    )
    return fn(params, cache, tokens, pos, threshold)


def sharded_decoder_decode_spec(
    model: Model,
    params: Any,
    cache: Any,
    tokens: jnp.ndarray,
    pos: jnp.ndarray,
    thresholds: jnp.ndarray,  # [lanes, spec_window]
    spec_window: int,
    *,
    mesh: Any,
    axis: str = "data",
    eos_id: int = -1,
    use_pallas: bool = False,
):
    """``decoder_decode_spec`` shard_map'd like ``sharded_decoder_decode``;
    per-slot thresholds, accept masks, depths, and entropies all shard with
    their lanes."""
    P = jax.sharding.PartitionSpec
    cache_specs = jax.tree_util.tree_map(lambda _: P(None, axis), cache)
    fn = shard_map_norep(
        lambda p, c, t, po, th: decoder_decode_spec(
            model, p, c, t, po, th, spec_window,
            eos_id=eos_id, use_pallas=use_pallas,
        ),
        mesh,
        in_specs=(P(), cache_specs, P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis), cache_specs, P(axis), P(axis), P(axis)),
    )
    return fn(params, cache, tokens, pos, thresholds)


def decoder_prefill(
    model: Model,
    params: Any,
    cache: Any,
    tokens: jnp.ndarray,     # [bucket] zero-padded prompt
    lane,                    # scalar lane index
    length,                  # scalar prompt length
    lanes: int,              # static lane count
    *,
    use_pallas: bool = False,
):
    """Write one lane's prompt[:length-1] into the KV cache (fori_loop on a
    scratch cache, merged back under a lane one-hot)."""
    lane_ids = jnp.arange(lanes)

    def body(t, c):
        tok = jnp.where(lane_ids == lane, tokens[t], 0)[:, None]
        _, c = model.decode_step(params, c, tok, t, use_pallas=use_pallas)
        return c

    scratch = jax.lax.fori_loop(0, length - 1, body, cache)

    def merge(new, old):
        mask = (lane_ids == lane).reshape((1, lanes) + (1,) * (new.ndim - 2))
        return jnp.where(mask, new, old)

    return jax.tree_util.tree_map(merge, scratch, cache)
