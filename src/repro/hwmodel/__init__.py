from repro.hwmodel.roofline import (
    TPUV5E,
    collective_bytes_from_hlo,
    roofline_report,
)
