"""AdaptivFloat quantization: properties + paper Table II qualitative check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, st

from repro.core.adaptivfloat import (
    AFFormat,
    af_decode,
    af_encode,
    af_quantize,
    quantize_pytree,
)

FMT8 = AFFormat(8, 3)


def _rand(shape, scale=1.0, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


class TestQuantize:
    @pytest.mark.parametrize("n_bits", [4, 5, 6, 7, 8])
    @pytest.mark.parametrize("scale", [1e-3, 1.0, 100.0])
    def test_error_bounded_by_mantissa_step(self, n_bits, scale):
        fmt = AFFormat(n_bits, 3)
        x = _rand((512,), scale)
        q = af_quantize(x, fmt)
        # relative error of normals <= 2^-(n_mant+1) (round-to-nearest) except
        # zero-flushed values, whose absolute error <= min_pos
        amax = float(jnp.max(jnp.abs(x)))
        e_min = np.floor(np.log2(amax)) - (2 ** fmt.n_exp - 1)
        min_pos = 2.0 ** e_min * (1 + 2.0 ** -fmt.n_mant)
        err = np.abs(np.asarray(q - x))
        rel = err / np.maximum(np.abs(np.asarray(x)), 1e-30)
        ok = (rel <= 2.0 ** -(fmt.n_mant + 1) + 1e-6) | (err <= min_pos)
        assert ok.all()

    def test_idempotent(self):
        x = _rand((256,), 3.0)
        q1 = af_quantize(x, FMT8)
        q2 = af_quantize(q1, FMT8)
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=0, atol=0)

    def test_preserves_sign_and_zero(self):
        x = jnp.array([-5.0, -1e-9, 0.0, 1e-9, 5.0])
        q = np.asarray(af_quantize(x, FMT8))
        assert q[2] == 0.0
        assert q[0] < 0 < q[4]

    @given(st.integers(4, 8), st.integers(2, 4))
    def test_encode_decode_equals_quantize(self, n_bits, n_exp):
        if n_bits - 1 - n_exp < 0:
            return
        fmt = AFFormat(n_bits, n_exp)
        x = _rand((128,), 2.0, seed=n_bits * 7 + n_exp)
        q = af_quantize(x, fmt)
        codes, e_min = af_encode(x, fmt)
        dec = af_decode(codes, e_min, fmt)
        np.testing.assert_allclose(np.asarray(q), np.asarray(dec), rtol=0, atol=0)

    def test_monotone(self):
        x = jnp.linspace(-4, 4, 513)
        q = np.asarray(af_quantize(x, FMT8, amax=jnp.asarray(4.0)))
        assert (np.diff(q) >= 0).all()

    def test_dynamic_range_vs_int8(self):
        """The paper's motivation (§III-E): within its binades AF keeps the
        RELATIVE error constant (~2^-(mant+1)) while int8's relative error
        explodes as magnitudes shrink — the failure mode on NLP weights that
        span decades."""
        # log-spaced magnitudes over ~2 decades, random signs
        mags = jnp.logspace(-2, 0.5, 512)
        signs = jnp.sign(jax.random.normal(jax.random.PRNGKey(0), (512,)))
        x = mags * signs
        q = af_quantize(x, FMT8)
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        q_int = jnp.round(x / scale) * scale
        rel = lambda q_: float(jnp.mean(jnp.abs(q_ - x) / jnp.abs(x)))
        assert rel(q) < 0.5 * rel(q_int)  # AF at least 2x better relative error

    def test_bits_sweep_error_ordering(self):
        """Table II trend: error grows as bits shrink; collapse below 5 bits."""
        x = _rand((4096,), 1.0)
        errs = []
        for bits in (8, 7, 6, 5, 4):
            q = af_quantize(x, AFFormat(bits, 3))
            errs.append(float(jnp.sqrt(jnp.mean((q - x) ** 2))))
        assert errs == sorted(errs)
        assert errs[-1] > 4 * errs[0]  # 4-bit is drastically worse

    def test_quantize_pytree_excludes(self):
        params = {"w": _rand((8, 8)), "norm_scale": jnp.ones((8,))}
        q = quantize_pytree(
            params, FMT8, predicate=lambda path, leaf: "norm" not in str(path)
        )
        assert np.allclose(np.asarray(q["norm_scale"]), 1.0)

    def test_all_zero_tensor(self):
        """Regression: all-zeros must quantize to zeros, not NaN (exp bias
        underflow -> 0/0); hit by zero-initialized biases."""
        z = jnp.zeros((16,))
        q = np.asarray(af_quantize(z, FMT8))
        assert (q == 0).all() and np.isfinite(q).all()
        codes, e_min = af_encode(z, FMT8)
        dec = np.asarray(af_decode(codes, e_min, FMT8))
        assert (dec == 0).all()
