"""JAX version compatibility shims.

The repo targets the modern public API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``) but must
also run on jax 0.4.x, where ``shard_map`` still lives under
``jax.experimental`` and meshes have neither the ``axis_types`` kwarg nor the
``AxisType`` enum (all axes behave as Auto).  Import from here instead of
feature-detecting at every call site.
"""
from __future__ import annotations

import inspect
from typing import Sequence

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.6: experimental namespace
    from jax.experimental.shard_map import shard_map  # type: ignore[no-redef]

HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")

# ``shard_map`` validates that every primitive in the body has a replication
# rule unless told not to; ``pallas_call`` has none, so the serving stack's
# Pallas-eligible fused steps MUST disable the check.  The kwarg was renamed
# ``check_rep`` -> ``check_vma`` across jax versions — detect once here.
_SM_PARAMS = frozenset(inspect.signature(shard_map).parameters)
_NOREP_KW = (
    {"check_vma": False} if "check_vma" in _SM_PARAMS
    else {"check_rep": False} if "check_rep" in _SM_PARAMS
    else {}
)


def shard_map_norep(f, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, on any supported jax.

    Required whenever the mapped body may dispatch a ``pallas_call`` (no
    replication rule exists for it) — i.e. for every serving fused step,
    since Pallas eligibility is a static engine flag, not a trace property.
    """
    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **_NOREP_KW
    )


def make_auto_mesh(shape: Sequence[int], axis_names: Sequence[str]) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` with every axis Auto, on any supported jax version.

    Newer jax wants explicit ``axis_types`` (sharding-in-types makes the
    default Explicit on some versions); older jax rejects the kwarg and is
    Auto-only anyway.
    """
    if HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(shape),
            tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axis_names)),
        )
    return jax.make_mesh(tuple(shape), tuple(axis_names))
