from repro.training.optim import AdamWConfig, adamw_init, adamw_update, lr_schedule
from repro.training.losses import lm_loss, cls_loss
