"""JAX version compatibility shims.

The repo targets the modern public API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``) but must
also run on jax 0.4.x, where ``shard_map`` still lives under
``jax.experimental`` and meshes have neither the ``axis_types`` kwarg nor the
``AxisType`` enum (all axes behave as Auto).  Import from here instead of
feature-detecting at every call site.
"""
from __future__ import annotations

from typing import Sequence

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.6: experimental namespace
    from jax.experimental.shard_map import shard_map  # type: ignore[no-redef]

HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def make_auto_mesh(shape: Sequence[int], axis_names: Sequence[str]) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` with every axis Auto, on any supported jax version.

    Newer jax wants explicit ``axis_types`` (sharding-in-types makes the
    default Explicit on some versions); older jax rejects the kwarg and is
    Auto-only anyway.
    """
    if HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(shape),
            tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axis_names)),
        )
    return jax.make_mesh(tuple(shape), tuple(axis_names))
