"""Shared neural layers: norms, rope, embeddings, GQA attention (span-aware,
flash-style chunked), MLPs.  Pure JAX; the Pallas kernels in repro.kernels
provide TPU-tiled versions of the hot paths and are validated against these.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.util import ceil_div

Params = Dict[str, Any]


def _dispatch():
    # lazy: pulls in pallas machinery only when a use_pallas=True path runs
    from repro.kernels import dispatch

    return dispatch


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


def embed_init(rng, shape, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms (paper §V-D3 computes LN as E[X^2]-E[X]^2 running moments)
# ---------------------------------------------------------------------------


def init_norm(kind: str, d: int, dtype) -> Params:
    if kind == "rms":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "norm_bias": jnp.zeros((d,), dtype)}


def apply_norm(
    p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-6,
    use_pallas: bool = False,
) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rms":
        # no Pallas kernel for RMS norm; the flag is a no-op here
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    if use_pallas:
        return _dispatch().layernorm(x, p["scale"], p["norm_bias"], eps=eps)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    # E[X^2] - E[X]^2 form (matches the accelerator's running-moment unit)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True) - mean * mean
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["norm_bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, n, head_dim]; positions: [S] or broadcastable to x[..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, span-aware, chunked online-softmax)
# ---------------------------------------------------------------------------


def init_attention(rng, cfg, dtype, d_in: Optional[int] = None) -> Params:
    """cfg needs: d_model, n_heads, n_kv_heads, head_dim, qkv_bias."""
    d = d_in if d_in is not None else cfg.d_model
    hd, H, KV = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype),
        "wk": dense_init(ks[1], (d, KV * hd), dtype),
        "wv": dense_init(ks[2], (d, KV * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _soft_span_block_mask(
    z: jnp.ndarray, ramp: int, q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool
) -> jnp.ndarray:
    """[H, qb, kb] soft span mask for one (q_block, kv_block) pair."""
    d = q_pos[:, None] - k_pos[None, :]
    if not causal:
        d = jnp.abs(d)
    m = jnp.clip((ramp + z[:, None, None] - d[None].astype(jnp.float32)) / float(ramp), 0.0, 1.0)
    return m


def attention(
    q: jnp.ndarray,              # [B, Sq, H, hd]
    k: jnp.ndarray,              # [B, Sk, KV, hd]
    v: jnp.ndarray,              # [B, Sk, KV, hd]
    *,
    causal: bool,
    q_offset: Any = 0,           # absolute position of q[0] (decode)
    span_z: Optional[jnp.ndarray] = None,   # [H] soft spans (train/eval)
    span_ramp: int = 32,
    q_block: int = 512,
    kv_block: int = 1024,
    kv_len: Optional[Any] = None,  # valid cache length for decode (<= Sk)
) -> jnp.ndarray:
    """Chunked online-softmax attention (flash-style scan; the jnp twin of the
    Pallas span_attention kernel).  Returns [B, Sq, H, hd]."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    if Sq <= 16:
        # decode fast path: no q blocking/padding; one masked softmax over the
        # whole (cache) key range. Scores [B,Sq,KV,G,Sk] — fine at decode.
        # K/V stay in their storage dtype; the dot accumulates in f32
        # (preferred_element_type) so the 16+GB cache is never up-converted.
        qf = (q * jnp.asarray(scale, q.dtype)).reshape(B, Sq, KV, G, hd)
        s = jnp.einsum(
            "bqkgd,bskd->bqkgs", qf, k, preferred_element_type=jnp.float32
        )
        q_pos = q_offset + jnp.arange(Sq)
        k_pos = jnp.arange(Sk)
        valid = (k_pos[None, :] < (jnp.asarray(kv_len) if kv_len is not None else Sk))
        if causal:
            valid = valid & (q_pos[:, None] >= k_pos[None, :])
        else:
            valid = jnp.broadcast_to(valid, (Sq, Sk))
        s = jnp.where(valid[None, :, None, None, :], s, -jnp.inf)
        if span_z is not None:
            sm = _soft_span_block_mask(span_z, span_ramp, q_pos, k_pos, causal)
            sm = sm.reshape(KV, G, Sq, Sk).transpose(2, 0, 1, 3)
            s = s + jnp.log(jnp.maximum(sm, 1e-20))[None]
        m = jnp.max(s, axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(s - m)
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        p = (p / jnp.maximum(l, 1e-20)).astype(v.dtype)
        out = jnp.einsum("bqkgs,bskd->bqkgd", p, v, preferred_element_type=jnp.float32)
        return out.reshape(B, Sq, H, hd).astype(q.dtype)

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    qf = (q * jnp.asarray(scale, q.dtype)).reshape(B, Sq, KV, G, hd)
    kf = k
    vf = v

    n_qb = ceil_div(Sq, q_block)
    n_kb = ceil_div(Sk, kv_block)
    pad_q = n_qb * q_block - Sq
    pad_k = n_kb * kv_block - Sk
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq_p, Sk_p = n_qb * q_block, n_kb * kv_block

    qf = qf.reshape(B, n_qb, q_block, KV, G, hd)
    kf = kf.reshape(B, n_kb, kv_block, KV, hd)
    vf = vf.reshape(B, n_kb, kv_block, KV, hd)

    valid_k = kv_len if kv_len is not None else Sk
    valid_k = jnp.asarray(valid_k)

    def q_chunk(qb_idx, q_tile):
        # q_tile: [B, q_block, KV, G, hd]
        q_pos = q_offset + qb_idx * q_block + jnp.arange(q_block)

        def kv_chunk(carry, inputs):
            m_run, l_run, acc = carry
            kb_idx, k_tile, v_tile = inputs
            k_pos = kb_idx * kv_block + jnp.arange(kv_block)
            # scores: [B, q_block, KV, G, kv_block] — bf16 in, f32 out (MXU)
            s = jnp.einsum(
                "bqkgd,bskd->bqkgs", q_tile, k_tile,
                preferred_element_type=jnp.float32,
            )
            mask = (k_pos[None, :] < valid_k)
            if causal:
                mask = mask & (q_pos[:, None] >= k_pos[None, :])
            else:
                mask = jnp.broadcast_to(mask, (q_block, kv_block))
            s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
            if span_z is not None:
                sm = _soft_span_block_mask(span_z, span_ramp, q_pos, k_pos, causal)
                sm = sm.reshape(KV, G, q_block, kv_block).transpose(2, 0, 1, 3)
                # span modulates probabilities (paper: mask element-wise times
                # softmax output) -> equivalent to adding log(mask) pre-softmax
                s = s + jnp.log(jnp.maximum(sm, 1e-20))[None]
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            # guard rows where everything is masked
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isfinite(m_run), m_run - m_safe, -jnp.inf))
            corr = jnp.where(jnp.isfinite(m_run), corr, 0.0)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqkgs,bskd->bqkgd", p.astype(v_tile.dtype), v_tile,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc), None

        init = (
            jnp.full((B, q_block, KV, G), -jnp.inf, jnp.float32),
            jnp.zeros((B, q_block, KV, G), jnp.float32),
            jnp.zeros((B, q_block, KV, G, hd), jnp.float32),
        )
        (m_run, l_run, acc), _ = jax.lax.scan(
            kv_chunk,
            init,
            (jnp.arange(n_kb), kf.transpose(1, 0, 2, 3, 4), vf.transpose(1, 0, 2, 3, 4)),
        )
        out = acc / jnp.maximum(l_run, 1e-20)[..., None]
        return out  # [B, q_block, KV, G, hd]

    outs = jax.lax.map(
        lambda i: q_chunk(i, qf[:, i]), jnp.arange(n_qb)
    )  # [n_qb, B, q_block, KV, G, hd]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq_p, H, hd)
    return out[:, :Sq].astype(q.dtype)


def attention_layer(
    p: Params,
    x: jnp.ndarray,                 # [B, S, d]
    cfg,
    *,
    causal: bool,
    positions: Optional[jnp.ndarray] = None,
    span_z: Optional[jnp.ndarray] = None,
    span_ramp: int = 32,
    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # (k, v) [B, Smax, KV, hd]
    cache_pos: Any = None,          # write position for decode
    kv_source: Optional[jnp.ndarray] = None,  # cross-attention keys/values input
    kv_len: Any = None,             # valid key length (right-padded inputs);
                                    # cache-free paths only — decode derives it
    use_pallas: bool = False,       # route eligible attention to the Pallas
                                    # span kernel (see kernels.dispatch)
) -> Tuple[jnp.ndarray, Optional[Tuple[jnp.ndarray, jnp.ndarray]]]:
    assert kv_len is None or cache is None, "kv_len is derived from the cache"
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = kv_source if kv_source is not None else x
    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, src.shape[1], KV, hd)
    v = v.reshape(B, src.shape[1], KV, hd)

    if positions is None:
        positions = jnp.arange(S)
    if cfg.pos == "rope" and kv_source is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    q_offset = 0
    if cache is not None:
        ck, cv = cache
        if ck.dtype == jnp.uint8:
            # AF8 KV cache: encode the new column, decode the whole cache for
            # attention (the decode is VMEM-side inside the fused kernel on
            # TPU; HBM only ever sees uint8 codes — half the traffic)
            from repro.core.adaptivfloat import af_decode_static, af_encode_static

            e_min = getattr(cfg, "kv_af8_e_min", -10)
            kc = af_encode_static(k.astype(jnp.float32), e_min)
            vc = af_encode_static(v.astype(jnp.float32), e_min)
            ck = jax.lax.dynamic_update_slice(ck, kc, (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, vc, (0, cache_pos, 0, 0))
            cache = (ck, cv)
            act_dtype = x.dtype
            k = af_decode_static(ck, e_min, dtype=act_dtype)
            v = af_decode_static(cv, e_min, dtype=act_dtype)
        else:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_pos, 0, 0))
            k, v = ck, cv
            cache = (ck, cv)
        q_offset = cache_pos
        kv_len = cache_pos + S

    import contextlib

    scope = (
        jax.named_scope("fused_attn_kernel")
        if getattr(cfg, "fused_attention", False)
        else contextlib.nullcontext()
    )
    # Pallas eligibility: the hard-window span kernel cannot reproduce the
    # soft (ramped) span mask, and cache decode fuses the KV update/codec
    # with the attention math — those stay ref.  What remains is exactly the
    # serving fused-step case: cache-free self-attention on right-padded
    # lanes, which routes to the span kernel with a full window + per-row
    # kv_len masking.
    pallas_ok = (
        use_pallas and cache is None and kv_source is None and span_z is None
    )
    with scope:
        if pallas_ok:
            out = _dispatch().dense_attention(q, k, v, causal=causal, kv_len=kv_len)
        else:
            out = attention(
                q, k, v,
                causal=causal and kv_source is None,
                q_offset=q_offset,
                span_z=span_z,
                span_ramp=span_ramp,
                kv_len=kv_len,
            )
    out = out.reshape(B, S, H * hd) @ p["wo"]
    return out, cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(rng, d: int, ff: int, act: str, dtype) -> Params:
    ks = jax.random.split(rng, 3)
    if act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d, ff), dtype),
            "w_up": dense_init(ks[1], (d, ff), dtype),
            "w_down": dense_init(ks[2], (ff, d), dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d, ff), dtype),
        "w_down": dense_init(ks[1], (ff, d), dtype),
    }


def apply_mlp(
    p: Params, x: jnp.ndarray, act: str,
    use_pallas: bool = False,
    block_masks: Optional[Dict[str, Any]] = None,  # STATIC occupancy masks
                                                   # (kernels.dispatch.mlp_block_masks)
) -> jnp.ndarray:
    def mm(h_, name):
        if use_pallas and block_masks and block_masks.get(name) is not None:
            return _dispatch().sparse_matmul(h_, p[name], block_masks[name])
        return h_ @ p[name]

    if act == "swiglu":
        g = mm(x, "w_gate")
        u = mm(x, "w_up")
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = mm(x, "w_up")
        if act == "gelu":
            h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        elif act == "relu2":
            h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
        else:
            raise ValueError(act)
    return mm(h, "w_down")
