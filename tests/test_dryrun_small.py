"""Sharding + dry-run machinery on a small forced-multi-device mesh.

These run in SUBPROCESSES because the device count must be set before jax
initializes (the main test process keeps the single real CPU device).

Marked ``multidevice`` and capability-gated: the subprocess snippets need a
jax with the modern sharding API (``jax.sharding.AxisType``); gating on the
capability (not the main process's device count — forcing host devices in
the subprocess works on single-device hosts) keeps these running wherever
they CAN run.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.common.jax_compat import HAS_AXIS_TYPES

pytestmark = [
    pytest.mark.multidevice,
    pytest.mark.skipif(
        not HAS_AXIS_TYPES,
        reason="installed jax lacks jax.sharding.AxisType, which the "
        "forced-multi-device subprocess snippets require",
    ),
]

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}\nstdout:\n{r.stdout[-1000:]}"
    return r.stdout


def test_param_sharding_rules():
    out = _run("""
        import jax, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import get_smoke_config
        from repro.models.model import build_model
        from repro.sharding.rules import param_shardings, rules_for
        import dataclasses

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = dataclasses.replace(get_smoke_config("qwen1_5_110b"),
                                  d_ff=128, n_kv_heads=4)
        model = build_model(cfg)
        abs_p = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
        sh = param_shardings(abs_p, mesh, rules_for(cfg, mesh))
        # stacked attn wq: [L, d, H*hd] -> (None, None, model)
        assert sh["layers"]["attn"]["wq"].spec == P(None, None, "model"), sh["layers"]["attn"]["wq"].spec
        # mlp down: [L, ff, d] -> (None, model, None)
        assert sh["layers"]["mlp"]["w_down"].spec == P(None, "model", None)
        # embedding: vocab sharded
        assert sh["embed"]["tok"].spec == P("model", None)
        # norm: replicated
        assert sh["layers"]["norm1"]["scale"].spec == P()
        print("RULES_OK")
    """)
    assert "RULES_OK" in out


def test_kv_indivisible_falls_back_replicated():
    out = _run("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import get_smoke_config
        from repro.models.model import build_model
        from repro.sharding.rules import param_shardings, rules_for
        import dataclasses

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        # kv out dim = 3 heads * 6 = 18, not divisible by 4 -> replicated
        # (wq = 12*6 = 72 stays sharded)
        cfg = dataclasses.replace(get_smoke_config("qwen1_5_110b"),
                                  n_heads=12, n_kv_heads=3, head_dim=6, d_model=72, d_ff=128)
        model = build_model(cfg)
        abs_p = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
        sh = param_shardings(abs_p, mesh, rules_for(cfg, mesh))
        assert sh["layers"]["attn"]["wk"].spec == P(None, None, None)
        assert sh["layers"]["attn"]["wq"].spec == P(None, None, "model")
        print("FALLBACK_OK")
    """)
    assert "FALLBACK_OK" in out


@pytest.mark.parametrize("arch", ["deepseek_7b", "qwen3_moe_235b", "rwkv6_7b"])
def test_smoke_cell_compiles_on_mesh(arch):
    """build_cell (smoke-sized config) lowers + compiles on a (2,2) mesh."""
    out = _run(f"""
        import jax, dataclasses
        import jax.numpy as jnp
        from repro.configs.base import get_smoke_config, ShapeConfig
        from repro.launch import dryrun
        from repro.launch.mesh import make_debug_mesh

        cfg = get_smoke_config("{arch}")
        shape = ShapeConfig("tiny_train", 64, 8, "train")
        mesh = make_debug_mesh(2, 2)
        fn, args, params_abs, n_tokens = dryrun.build_cell(cfg, shape, mesh, microbatches=2)
        with mesh:
            compiled = fn.lower(*args).compile()
        print("COMPILED", compiled.cost_analysis() is not None)
    """, devices=4)
    assert "COMPILED" in out


def test_decode_cell_compiles_on_mesh():
    out = _run("""
        import jax
        from repro.configs.base import get_smoke_config, ShapeConfig
        from repro.launch import dryrun
        from repro.launch.mesh import make_debug_mesh

        cfg = get_smoke_config("zamba2_1p2b")
        shape = ShapeConfig("tiny_decode", 128, 8, "decode")
        mesh = make_debug_mesh(2, 2)
        fn, args, params_abs, n_tokens = dryrun.build_cell(cfg, shape, mesh)
        with mesh:
            compiled = fn.lower(*args).compile()
        from repro.hwmodel.hlo_analysis import analyze
        res = analyze(compiled.as_text())
        assert res.flops > 0
        print("DECODE_OK")
    """, devices=4)
    assert "DECODE_OK" in out


def test_multipod_mesh_shape():
    out = _run("""
        from repro.launch.mesh import make_production_mesh
        m = make_production_mesh(multi_pod=True)
        assert m.devices.shape == (2, 16, 16)
        assert m.axis_names == ("pod", "data", "model")
        m1 = make_production_mesh()
        assert m1.devices.shape == (16, 16)
        print("MESH_OK")
    """, devices=512)
    assert "MESH_OK" in out


def test_zero1_shards_optimizer():
    out = _run("""
        import jax
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.sharding.zero1 import zero1_param_sharding
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        # param sharded on dim1 by model; zero1 adds data on dim0
        spec = zero1_param_sharding(P(None, "model"), (128, 64), mesh)
        assert spec == P("data", "model"), spec
        # indivisible dim stays unsharded
        spec2 = zero1_param_sharding(P(None,), (7,), mesh)
        assert spec2 == P(None)
        print("ZERO1_OK")
    """, devices=8)
    assert "ZERO1_OK" in out
