"""Trip-count-aware HLO analysis for roofline terms.

XLA's ``compiled.cost_analysis()`` counts a while-loop (scan) body ONCE,
ignoring the trip count — useless for layer-scanned transformers (verified:
a 10-step scan of a matmul reports 1 matmul of FLOPs).  This module parses
``compiled.as_text()`` structurally instead:

  * each computation's op lines carry their result type (`%n = TYPE op(...)`),
    giving an SSA name->shape map; call edges (fusion `calls=`, `call`
    `to_apply=`, `while` body/condition, `conditional` branches) form a DAG;
  * while trip counts come from the scheduler's
    ``backend_config={"known_trip_count":{"n":"N"}}`` (canonical for lax.scan /
    fori_loop), falling back to the loop condition's compare constant;
  * FLOPs: 2 * prod(result_dims) * prod(lhs_contracting_dims) per dot,
    accumulated bottom-up with trip multipliers (MXU work only);
  * HBM bytes: 2x result bytes per compute op (write + downstream read),
    parameters 1x, bookkeeping ops (tuple/gte/constant/bitcast) free,
    fusion-internal computations free (fused intermediates stay in registers/
    VMEM) — the fusion op's own result pays at the call site;
  * collective bytes: all-reduce 2x result, others 1x, with trip multipliers.

All quantities are per-device (the post-SPMD module is the per-device
program).  Conditionals take the max over branches.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*->.*\{\s*$")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
# result type is either a flat tuple "(s32[], bf16[..]{..}, ...)" (no nested
# parens in HLO tuple types) or a single shape; then the op name.
_OPNAME_RE = re.compile(r"^((?:\([^)=]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+([a-z][\w\-]*)\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"n":"(\d+)"')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")

_FREE_OPS = {
    "tuple", "get-tuple-element", "constant", "bitcast", "parameter",
    "after-all", "partition-id", "replica-id", "iota",
    # copies of while carries are aliased in-place on TPU (donated buffers)
    "copy", "copy-start", "copy-done",
}
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

# ops the TPU backend fuses into neighbours (the CPU HLO we inspect leaves
# them unfused): layout/dtype/elementwise — no HBM materialization of their own
_ELEMENTWISE_FREE = {
    "convert", "transpose", "reshape", "broadcast", "add", "subtract",
    "multiply", "divide", "maximum", "minimum", "exponential", "log",
    "negate", "abs", "sign", "floor", "ceil", "round-nearest-afz", "rsqrt",
    "sqrt", "power", "tanh", "logistic", "select", "compare", "and", "or",
    "not", "xor", "clamp", "concatenate", "pad", "slice", "rem", "atan2",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "is-finite",
    "reverse", "gather", "exponential-minus-one", "log-plus-one", "erf",
    "cbrt", "reduce-window", "sine", "cosine", "tan", "real", "imag",
}


def _dims(s: str) -> List[int]:
    return [int(x) for x in s.split(",") if x] if s else []


def _shape_bytes_all(text: str) -> float:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return float(total)


@dataclass
class Computation:
    name: str
    lines: List[str] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)   # ssa name -> type str


def parse_computations(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        m = _COMP_HEADER_RE.match(line)
        if m:
            cur = Computation(name=m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        s = line.strip()
        if s == "}":
            cur = None
            continue
        if cur is None or not s:
            continue
        # strip /*index=N*/ comments (they contain '=' and break type parsing)
        s = re.sub(r"/\*.*?\*/", "", s)
        cur.lines.append(s)
        dm = _DEF_RE.match(s)
        if dm:
            rest = dm.group(2)
            om = _OPNAME_RE.match(rest)
            if om:
                cur.types[dm.group(1)] = om.group(1)
            else:
                # e.g. "%x = f32[1,2]{1,0} parameter(0)" matches; tuples too
                tm = re.match(r"^(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)", rest)
                if tm:
                    cur.types[dm.group(1)] = tm.group(1)
    return comps, entry


@dataclass
class HloCosts:
    flops: float = 0.0
    bytes_io: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    n_while: int = 0
    max_trip: int = 1

    def add(self, other: "HloCosts", mult: float = 1.0, with_bytes: bool = True):
        self.flops += mult * other.flops
        if with_bytes:
            self.bytes_io += mult * other.bytes_io
        self.coll_bytes += mult * other.coll_bytes
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + mult * v
        self.n_while += other.n_while
        self.max_trip = max(self.max_trip, other.max_trip)


def _op_of(line: str) -> Tuple[str, str]:
    """(op_name, result_type_str) of a def line, or ("", "")."""
    dm = _DEF_RE.match(line)
    if not dm:
        return "", ""
    rest = dm.group(2)
    om = _OPNAME_RE.match(rest)
    if om:
        return om.group(2), om.group(1)
    if " parameter(" in rest:
        return "parameter", rest.split(" parameter(")[0]
    return "", ""


def _operand_bytes(ln: str, comp: Computation, op: str) -> float:
    """Sum of operand sizes (HBM reads) via the computation's SSA type map."""
    m = re.search(rf"\b{re.escape(op)}\((.*?)\)[,)]?", ln)
    seg = m.group(1) if m else ""
    total = 0.0
    for name in _OPERANDS_RE.findall(seg):
        t = comp.types.get(name)
        if t:
            total += _shape_bytes_all(t)
    return total


def _fusion_called(comps: Dict[str, Computation]) -> Set[str]:
    called = set()
    for comp in comps.values():
        for ln in comp.lines:
            if "fusion(" in ln:
                m = re.search(r"calls=%?([\w\.\-]+)", ln)
                if m:
                    called.add(m.group(1))
    return called


_LAYOUT_OPS = None  # computed lazily: _FREE_OPS | _ELEMENTWISE_FREE


def _layout_only(comp: Computation) -> bool:
    """True if a computation contains only layout/elementwise/bookkeeping ops
    — XLA:CPU wraps single converts/transposes/broadcasts into kLoop fusions
    ('wrapped_convert' of a whole KV cache etc.); on TPU these fold into the
    consumer's tiling (MXU reads bf16 natively) and cost no HBM pass."""
    for ln in comp.lines:
        op, _ = _op_of(ln)
        if not op:
            continue
        if op not in _FREE_OPS and op not in _ELEMENTWISE_FREE:
            return False
    return True


def analyze(text: str) -> HloCosts:
    comps, entry = parse_computations(text)
    fused = _fusion_called(comps)
    layout_only = {name for name, c in comps.items() if _layout_only(c)}
    tagged = {
        name
        for name, c in comps.items()
        if any("fused_attn_kernel" in l for l in c.lines)
    }
    memo: Dict[str, HloCosts] = {}

    def cost_of(name: str, stack=()) -> HloCosts:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return HloCosts()
        comp = comps[name]
        total = HloCosts()
        in_fusion = name in fused
        for ln in comp.lines:
            op, rtype = _op_of(ln)
            if not op:
                continue
            # ops tagged by the fused-attention named_scope live in VMEM in
            # the real Pallas kernel: FLOPs/collectives count, HBM bytes don't
            line_fused = in_fusion or ("fused_attn_kernel" in ln)

            # ---------- control flow ----------
            if op == "while":
                cond_m = re.search(r"condition=%?([\w\.\-]+)", ln)
                body_m = re.search(r"body=%?([\w\.\-]+)", ln)
                tm = _TRIP_RE.search(ln)
                if tm:
                    trip = int(tm.group(1))
                elif cond_m:
                    cond = comps.get(cond_m.group(1))
                    trip = 1
                    if cond:
                        for cl in cond.lines:
                            for c in _CONST_RE.findall(cl):
                                trip = max(trip, int(c))
                else:
                    trip = 1
                total.n_while += 1
                total.max_trip = max(total.max_trip, trip)
                if body_m:
                    total.add(cost_of(body_m.group(1), stack + (name,)), mult=trip)
                if cond_m:
                    total.add(cost_of(cond_m.group(1), stack + (name,)), mult=trip)
                continue
            if op == "conditional":
                branches = re.search(r"branch_computations=\{([^}]*)\}", ln)
                names = []
                if branches:
                    names = [b.strip().lstrip("%") for b in branches.group(1).split(",")]
                else:
                    names = re.findall(r"(?:true_computation|false_computation)=%?([\w\.\-]+)", ln)
                subs = [cost_of(b, stack + (name,)) for b in names if b]
                if subs:
                    best = max(subs, key=lambda c: c.flops + c.bytes_io)
                    total.add(best)
                continue
            if op == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", ln)
                if m:
                    total.add(cost_of(m.group(1), stack + (name,)), with_bytes=False)
                    # fusion belongs to the fused-kernel scope if its callee
                    # carries the tag; pure-layout fusions fold on TPU
                    if m.group(1) in tagged or m.group(1) in layout_only:
                        line_fused = True
                if not line_fused:
                    dm = _DEF_RE.match(ln)
                    ssa_name = dm.group(1) if dm else ""
                    opnd = [
                        _shape_bytes_all(comp.types.get(n, ""))
                        for n in _OPERANDS_RE.findall(
                            re.search(r"fusion\(([^)]*)\)", ln).group(1)
                        )
                    ] if re.search(r"fusion\(([^)]*)\)", ln) else []
                    if "dynamic-update-slice" in ssa_name and opnd:
                        # in-place update (aliased on TPU): pay the update
                        # slice (everything but the largest operand), not the
                        # whole buffer
                        total.bytes_io += 2.0 * (sum(opnd) - max(opnd))
                    elif sum(opnd) < 1024 and "broadcast" in ssa_name:
                        # zero-init of an aliased output buffer: elided
                        pass
                    else:
                        # materialization point: result write + downstream read
                        total.bytes_io += 2.0 * _shape_bytes_all(rtype)
                continue
            if op in ("call", "custom-call", "map", "reduce", "sort", "scatter"):
                for ref in re.findall(r"(?:to_apply|called_computations?)=\{?%?([\w\.\-]+)\}?", ln):
                    total.add(cost_of(ref, stack + (name,)))
                if not line_fused and op != "call":
                    total.bytes_io += 2.0 * _shape_bytes_all(rtype)
                continue

            # ---------- collectives ----------
            matched_coll = None
            for coll in _COLL_OPS:
                if op == coll or op == coll + "-start":
                    matched_coll = coll
                    break
                if op == coll + "-done":
                    matched_coll = "skip"
                    break
            if matched_coll == "skip":
                continue
            if matched_coll:
                size = _shape_bytes_all(rtype)
                w = 2.0 if matched_coll == "all-reduce" else 1.0
                total.coll_bytes += w * size
                total.coll_by_kind[matched_coll] = (
                    total.coll_by_kind.get(matched_coll, 0.0) + w * size
                )
                if not line_fused:
                    total.bytes_io += 2.0 * size
                continue

            # ---------- dot ----------
            if op == "dot":
                res_dims: List[int] = []
                sm = _SHAPE_RE.search(rtype)
                if sm:
                    res_dims = _dims(sm.group(2))
                flops = 2.0
                for d in res_dims:
                    flops *= d
                cm = _CONTRACT_RE.search(ln)
                lhs_dims: List[int] = []
                ops_m = re.search(r"dot\(([^)]*)\)", ln)
                if ops_m:
                    operand_names = _OPERANDS_RE.findall(ops_m.group(1))
                    if operand_names:
                        lhs_t = comp.types.get(operand_names[0], "")
                        lm = _SHAPE_RE.search(lhs_t)
                        if lm:
                            lhs_dims = _dims(lm.group(2))
                if cm and lhs_dims:
                    for i in _dims(cm.group(1)):
                        if i < len(lhs_dims):
                            flops *= lhs_dims[i]
                total.flops += flops
                if not line_fused:
                    total.bytes_io += 2.0 * _shape_bytes_all(rtype)
                continue

            # ---------- in-place update: pays the update column only ----------
            if op in ("dynamic-update-slice",):
                if not line_fused:
                    ops_m = re.search(r"dynamic-update-slice\(([^)]*)\)", ln)
                    upd = 0.0
                    if ops_m:
                        names = _OPERANDS_RE.findall(ops_m.group(1))
                        if len(names) >= 2:
                            upd = _shape_bytes_all(comp.types.get(names[1], ""))
                    total.bytes_io += 2.0 * upd
                continue

            # ---------- everything else ----------
            if op in _FREE_OPS:
                if op == "parameter" and not line_fused and name == entry:
                    total.bytes_io += _shape_bytes_all(rtype)
                continue
            if op == "dynamic-slice":
                # a read materialization (weight/cache slice out of a stack)
                if not line_fused:
                    total.bytes_io += _shape_bytes_all(rtype)
                continue
            if op in _ELEMENTWISE_FREE:
                continue  # fused into neighbours on TPU
            if not line_fused:
                total.bytes_io += 2.0 * _shape_bytes_all(rtype)

        memo[name] = total
        return total

    if entry is None and comps:
        entry = max(comps, key=lambda n: len(comps[n].lines))
    if entry is None:
        return HloCosts()
    return cost_of(entry)
