"""AdamW + LR schedules from scratch (no optax in this environment).

Functional: ``state = adamw_init(params)``; ``params, state = adamw_update(
grads, state, params, cfg, lr)``.  Moments are fp32 regardless of param dtype
(bf16-safe).  Weight decay is masked off 1-D leaves (biases, norms, spans).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"     # cosine | linear | constant
    # span parameters move O(tens of tokens) while weights move O(1e-2):
    # Adam normalizes magnitudes away, so spans get their own LR multiplier
    # (Sukhbaatar et al. train spans with a much larger effective step)
    span_lr_mult: float = 1.0


class AdamWState(NamedTuple):
    count: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32) if hasattr(p, "shape") else jnp.zeros((), jnp.float32)
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        t = jnp.clip((step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 1.0 - t
    else:  # cosine
        t = jnp.clip((step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def _decay_mask(path, leaf) -> bool:
    """True if weight decay applies (2D+ weights only; not norms/biases/spans)."""
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    p = jax.tree_util.keystr(path).lower()
    return not any(s in p for s in ("norm", "span_z", "bias"))


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    cfg: AdamWConfig,
    lr: Optional[jnp.ndarray] = None,
):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    count = state.count + 1
    if lr is None:
        lr = lr_schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)

    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mhat = m / b1c
        vhat = v / b2c
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path, p):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        if cfg.span_lr_mult != 1.0 and "span_z" in jax.tree_util.keystr(path):
            upd = upd * cfg.span_lr_mult
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m)
        new_v.append(v)

    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        AdamWState(
            count=count,
            m=jax.tree_util.tree_unflatten(treedef, new_m),
            v=jax.tree_util.tree_unflatten(treedef, new_v),
        ),
        {"grad_norm": gnorm, "lr": lr},
    )
