"""Entropy-based early exit (§III-A): mode equivalence + threshold semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import early_exit as ee


def _setup(d=16, C=3, L=6, B=4, S=8, seed=0):
    rng = jax.random.PRNGKey(seed)
    offramp = ee.init_offramp(rng, d, C)
    ws = jax.random.normal(jax.random.PRNGKey(seed + 1), (L, d, d)) * (1.0 / np.sqrt(d))

    def layer_fn(i, h):
        w = ws[i]
        return jnp.tanh(h @ w)

    h0 = jax.random.normal(jax.random.PRNGKey(seed + 2), (B, S, d))
    return layer_fn, offramp, h0, L


class TestModes:
    def test_all_layers_shapes(self):
        layer_fn, offramp, h0, L = _setup()
        logits, ent = ee.exit_all_layers(layer_fn, L, h0, offramp)
        assert logits.shape == (L, 4, 3) and ent.shape == (L, 4)
        assert np.isfinite(np.asarray(ent)).all()

    def test_threshold_semantics(self):
        layer_fn, offramp, h0, L = _setup()
        _, ent = ee.exit_all_layers(layer_fn, L, h0, offramp)
        # infinite threshold -> exit at layer 1; zero threshold -> last layer
        exit_inf, _ = ee.exit_decisions(ent, np.inf)
        exit_zero, _ = ee.exit_decisions(ent, 0.0)
        assert (np.asarray(exit_inf) == 1).all()
        assert (np.asarray(exit_zero) == L).all()

    def test_monotone_in_threshold(self):
        layer_fn, offramp, h0, L = _setup()
        _, ent = ee.exit_all_layers(layer_fn, L, h0, offramp)
        prev = None
        for t in (0.01, 0.3, 0.6, 1.0, np.inf):
            el = np.asarray(ee.exit_decisions(ent, t)[0])
            if prev is not None:
                assert (el <= prev).all()
            prev = el

    def test_while_loop_matches_all_layers(self):
        layer_fn, offramp, h0, L = _setup()
        logits_all, ent = ee.exit_all_layers(layer_fn, L, h0, offramp)
        threshold = float(np.median(np.asarray(ent)))
        exit_layer, _ = ee.exit_decisions(ent, threshold)
        sel = ee.select_exit_logits(logits_all, exit_layer)
        for b in range(h0.shape[0]):
            lg, el, e = ee.exit_while_loop(
                lambda i, h: layer_fn(i, h[None])[0], L, h0[b], offramp, threshold
            )
            assert int(el) == int(exit_layer[b])
            np.testing.assert_allclose(np.asarray(lg), np.asarray(sel[b]), atol=1e-5)

    def test_batched_masked_matches_all_layers(self):
        layer_fn, offramp, h0, L = _setup()
        logits_all, ent = ee.exit_all_layers(layer_fn, L, h0, offramp)
        threshold = float(np.median(np.asarray(ent)))
        exit_layer, _ = ee.exit_decisions(ent, threshold)
        lg, el = ee.exit_batched_masked(layer_fn, L, h0, offramp, threshold)
        np.testing.assert_array_equal(np.asarray(el), np.asarray(exit_layer))
        sel = ee.select_exit_logits(logits_all, exit_layer)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(sel), atol=1e-5)

    def test_runtime_savings_eq2(self):
        el = jnp.array([6, 6, 6, 6])
        assert abs(float(ee.runtime_savings(el, 12)) - 0.5) < 1e-6
        assert abs(ee.ee_perf(0.9, 0.5) - 1.8) < 1e-9


class TestTokenLevelExit:
    """Beyond-paper CALM-style per-token exit for decoder LMs."""

    def _model(self):
        import dataclasses
        from repro.configs.base import get_smoke_config
        from repro.models.model import build_model

        cfg = dataclasses.replace(
            get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none"
        )
        m = build_model(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
        return m, params, toks, cfg

    def test_zero_threshold_equals_full_forward(self):
        m, params, toks, cfg = self._model()
        logits, exit_layer = m.forward_token_exit(params, toks, threshold=0.0)
        full = m.apply_train(params, {"tokens": toks}).logits
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full), atol=1e-5)
        assert (np.asarray(exit_layer) == cfg.n_layers).all()

    def test_inf_threshold_exits_first_layer(self):
        m, params, toks, cfg = self._model()
        logits, exit_layer = m.forward_token_exit(params, toks, threshold=np.inf)
        assert (np.asarray(exit_layer) == 1).all()
        assert np.isfinite(np.asarray(logits)).all()


class TestOnlineCalibratorDrift:
    """Per-bin quantile convergence of ``OnlineExitCalibrator`` when the
    entropy -> exit-layer relationship DRIFTS mid-stream: the bounded window
    must forget the old regime and converge to the new one."""

    def test_per_bin_quantile_converges_under_drift(self):
        cal = ee.OnlineExitCalibrator(
            12, lo=0.0, hi=1.0, n_bins=4, quantile=0.9, window=64
        )
        rng = np.random.default_rng(0)
        # regime A: entropies in bin 1 (~0.3) exit shallow (2..4)
        for _ in range(200):
            cal.observe(float(rng.uniform(0.25, 0.45)), int(rng.integers(2, 5)))
        pred_a = cal.predict(0.3)
        assert pred_a <= 4.0
        # regime B (drift): the SAME entropies now exit deep (9..11); after
        # >= window observations the old regime has fully aged out
        exits_b = []
        for _ in range(200):
            e = float(rng.uniform(0.25, 0.45))
            x = int(rng.integers(9, 12))
            cal.observe(e, x)
            exits_b.append(x)
        pred_b = cal.predict(0.3)
        assert pred_b >= 9.0
        # converged exactly to the window quantile of the NEW regime
        want = float(np.quantile(exits_b[-64:], 0.9))
        assert pred_b == pytest.approx(want)
        # untouched bins keep the conservative cold start throughout
        assert cal.predict(0.9) == 12.0

    def test_drift_does_not_leak_across_bins(self):
        """Drift observed in one entropy bin must not move another bin's
        prediction (the LUT is per-bin, not global)."""
        cal = ee.OnlineExitCalibrator(
            12, lo=0.0, hi=1.0, n_bins=4, quantile=1.0, window=32
        )
        for _ in range(40):
            cal.observe(0.1, 3)          # bin 0
        before = cal.predict(0.6)        # bin 2: cold
        for _ in range(40):
            cal.observe(0.6, 8)          # drift lands in bin 2 only
        assert cal.predict(0.1) == 3.0   # bin 0 unchanged
        assert before == 12.0 and cal.predict(0.6) == 8.0


class TestPositionBinnedCalibrator:
    """Decode-side LUT variant: same running-quantile machinery, keyed by
    token POSITION bin instead of first-off-ramp entropy — mirrors the
    sentence-bin drift/leak/cold-start suite above."""

    def test_per_position_quantile_converges_under_drift(self):
        cal = ee.PositionBinnedExitCalibrator(
            12, max_pos=32, n_bins=4, quantile=0.9, window=64
        )
        rng = np.random.default_rng(0)
        # regime A: tokens at positions ~10 (bin 1) exit shallow (2..4)
        for _ in range(200):
            cal.observe(int(rng.integers(8, 15)), int(rng.integers(2, 5)))
        assert cal.predict(10) <= 4.0
        # regime B (drift): the SAME positions now exit deep (9..11); the
        # bounded window must forget regime A completely
        exits_b = []
        for _ in range(200):
            x = int(rng.integers(9, 12))
            cal.observe(int(rng.integers(8, 15)), x)
            exits_b.append(x)
        pred_b = cal.predict(10)
        assert pred_b >= 9.0
        assert pred_b == pytest.approx(float(np.quantile(exits_b[-64:], 0.9)))
        # untouched position bins keep the conservative cold start
        assert cal.predict(30) == 12.0

    def test_drift_does_not_leak_across_position_bins(self):
        cal = ee.PositionBinnedExitCalibrator(
            12, max_pos=32, n_bins=4, quantile=1.0, window=32
        )
        for _ in range(40):
            cal.observe(2, 3)            # bin 0: early tokens exit shallow
        before = cal.predict(20)         # bin 2: cold
        for _ in range(40):
            cal.observe(20, 8)           # drift lands in bin 2 only
        assert cal.predict(2) == 3.0     # bin 0 unchanged
        assert before == 12.0 and cal.predict(20) == 8.0

    def test_cold_start_quotes_full_depth(self):
        """A cold calibrator must quote the conservative full depth at EVERY
        position, and ``predicted_token_layers`` must therefore price a cold
        request at tokens x n_layers — the admission-side guarantee."""
        cal = ee.PositionBinnedExitCalibrator(12, max_pos=32)
        for pos in (0, 7, 31):
            assert cal.predict(pos) == 12.0
        assert ee.predicted_token_layers(cal.predict, 0, 5, 12) == 60.0

    def test_predicted_token_layers_clamps_and_sums(self):
        # predictions below 1 / above n_layers are clamped per token
        assert ee.predicted_token_layers(lambda t: 0.0, 0, 3, 12) == 3.0
        assert ee.predicted_token_layers(lambda t: 99.0, 0, 3, 12) == 36.0
        # empty ranges cost nothing; sums follow the per-position LUT
        assert ee.predicted_token_layers(lambda t: 4.0, 5, 5, 12) == 0.0
        assert ee.predicted_token_layers(
            lambda t: 2.0 if t < 2 else 6.0, 0, 4, 12
        ) == pytest.approx(2 * 2.0 + 2 * 6.0)

    def test_monotone_escalation_of_windowed_max(self):
        """quantile=1.0 (the safe default): the per-bin prediction is the
        windowed MAX of realized depths — it escalates monotonically as
        deeper exits are observed and never dips below a depth still in the
        window (the decode-side misprediction guard)."""
        cal = ee.PositionBinnedExitCalibrator(
            12, max_pos=16, n_bins=2, quantile=1.0, window=64
        )
        prev = 0.0
        for depth in (2, 3, 3, 5, 8, 8, 11):
            cal.observe(1, depth)
            pred = cal.predict(1)
            assert pred >= depth          # never below a windowed observation
            assert pred >= prev           # monotone escalation
            prev = pred
        assert cal.predict(1) == 11.0


class TestEscalationMonotone:
    """``predicted_remaining_layers`` past a mispredicted exit: once a
    sentence overruns its prediction, the remaining-work estimate escalates
    to the conservative full-depth remainder and then decreases MONOTONICALLY
    with depth (floored at 1) — it never dips back to the optimistic LUT
    value, so EDF cannot starve an escalated lane."""

    def test_escalation_is_monotone_in_depth(self):
        n_layers, predicted = 12, 4
        predict_fn = lambda e: float(predicted)
        trace = [0.5]
        # before the predicted exit: LUT remainder
        for depth in range(0, predicted - 1):
            rem = ee.predicted_remaining_layers(
                trace, depth, n_layers, predict_fn=predict_fn
            )
            assert rem == pytest.approx(predicted - depth)
        # past it: escalated to the full-depth remainder, strictly
        # non-increasing step to step, floored at 1
        prev = None
        for depth in range(predicted, n_layers + 1):
            rem = ee.predicted_remaining_layers(
                trace, depth, n_layers, predict_fn=predict_fn
            )
            assert rem == pytest.approx(max(float(n_layers - depth), 1.0))
            if prev is not None:
                assert rem <= prev
            prev = rem

    def test_escalated_remainder_never_below_one(self):
        rem = ee.predicted_remaining_layers(
            [0.5], 12, 12, predict_fn=lambda e: 4.0
        )
        assert rem == 1.0                # the step that retires it

    def test_cold_start_full_depth_without_trace_or_fn(self):
        assert ee.predicted_remaining_layers([], 0, 12) == 12.0
        assert ee.predicted_remaining_layers([0.3], 2, 12) == 10.0
