"""RWKV6 "Finch" block — attention-free time-mix with data-dependent decay.

Per head (k-dim = v-dim = head_dim):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state in R^{K x V})
    y_t = ((S_{t-1} + diag(u) k_t v_t^T)^T r_t)

with w_t = exp(-exp(w0 + lora_w(x_t))) in (0, 1) — the *data-dependent decay*
that distinguishes RWKV6 from RWKV5.  Token-shift lerps use data-dependent
mixing coefficients (low-rank).  We implement an exact recurrent scan
(oracle, decode path) and a chunked parallel form used for training/prefill;
their equivalence is property-tested.

Adaptive attention span is INAPPLICABLE here (no attention heads) — the decay
w_t is the native span analogue; see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Params = Dict[str, Any]

LORA_R = 32


def init_rwkv6(rng, cfg, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    K = cfg.head_dim
    ks = jax.random.split(rng, 12)
    return {
        # time-mix projections
        "w_r": dense_init(ks[0], (d, d), dtype),
        "w_k": dense_init(ks[1], (d, d), dtype),
        "w_v": dense_init(ks[2], (d, d), dtype),
        "w_g": dense_init(ks[3], (d, d), dtype),
        "w_o": dense_init(ks[4], (d, d), dtype),
        # data-dependent decay lora: d -> r -> d
        "decay_lora_a": dense_init(ks[5], (d, LORA_R), dtype),
        "decay_lora_b": dense_init(ks[6], (LORA_R, d), dtype),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),  # w0: slow decay init
        "bonus_u": (jax.random.normal(ks[7], (H, K)) * 0.1).astype(jnp.float32),
        # token-shift mix coefficients (static part; rwkv6 adds lora on these,
        # we keep one shared data-dependent lora for economy)
        "mix_rkvg": (0.5 * jnp.ones((4, d))).astype(dtype),
        "ts_lora_a": dense_init(ks[8], (d, LORA_R), dtype),
        "ts_lora_b": dense_init(ks[9], (LORA_R, 4 * d), dtype),
        "ln_x_scale": jnp.ones((d,), jnp.float32),  # group-norm on wkv output
    }


def _wkv_recurrent(r, k, v, w, u, init_state=None):
    """Exact scan. r,k,v: [B,S,H,K]; w: [B,S,H,K] decay in (0,1); u: [H,K].

    Returns y [B,S,H,K], final state [B,H,K,K]  (state[k_dim, v_dim])."""
    B, S, H, K = r.shape
    if init_state is None:
        init_state = jnp.zeros((B, H, K, K), jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp  # [B,H,K]
        kv = k_t[..., :, None] * v_t[..., None, :]            # [B,H,K,K]
        y = jnp.einsum("bhkv,bhk->bhv", state + u[None, :, :, None] * kv, r_t)
        state = state * w_t[..., :, None] + kv
        return state, y

    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32) for a in (r, k, v, w))
    final, ys = jax.lax.scan(step, init_state.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3), final


def _wkv_chunked(r, k, v, w, u, chunk: int, init_state=None):
    """Chunked-parallel WKV (flash-linear-attention style). Same contract."""
    B, S, H, K = r.shape
    pad = (-S) % chunk
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    Sp = S + pad
    nc = Sp // chunk
    Q = chunk
    shp = (B, nc, Q, H, K)
    rc, kc, vc, wc = (a.reshape(shp).astype(jnp.float32) for a in (r, k, v, w))

    logw = jnp.log(jnp.maximum(wc, 1e-38))
    cum = jnp.cumsum(logw, axis=2)                    # [B,nc,Q,H,K] inclusive
    tot = cum[:, :, -1]                               # [B,nc,H,K]

    # intra-chunk: y_t = r_t . (S_{t-1} + u k_t v_t); step s<t contributes with
    # decay prod_{i=s+1..t-1} w_i = exp(cum_{t-1} - cum_s).  Fold the decay into
    # r and k (FLA-style) so the [Q,Q] score is a plain matmul (MXU-friendly):
    #   r' = r * exp(cum_{t-1})   (<= 1, relative to chunk start)
    #   k' = k * exp(-cum_s)      (>= 1; clamped — with realistic decays
    #                              |cum| over a chunk stays small; the exact
    #                              recurrent oracle covers adversarial decay)
    r_fold = rc * jnp.exp(cum - logw)
    k_fold = kc * jnp.exp(jnp.minimum(-cum, 40.0))
    att = jnp.einsum("bcqhk,bcshk->bcqsh", r_fold, k_fold)   # [B,nc,Q,Q,H]
    strict = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    att = jnp.where(strict[None, None, :, :, None], att, 0.0)
    # diagonal (s == q) with bonus u
    diag = jnp.einsum("bcqhk,hk,bcqhk->bcqh", rc, u, kc)
    y_intra = jnp.einsum("bcqsh,bcshv->bcqhv", att, vc) + diag[..., None] * vc

    # chunk-end states: S_end = S_init * prod(w) + sum_s (prod_{i>s} w_i) k_s v_s
    state_decay = jnp.exp(tot[:, :, None] - cum)       # [B,nc,Q,H,K]
    su = jnp.einsum("bcshk,bcshv->bchkv", kc * state_decay, vc)

    def scan_fn(prev, inp):
        su_c, tot_c = inp
        new = prev * jnp.exp(tot_c)[..., None] + su_c
        return new, prev

    if init_state is None:
        init_state = jnp.zeros((B, H, K, K), jnp.float32)
    final, prevs = jax.lax.scan(
        scan_fn,
        init_state.astype(jnp.float32),
        (su.transpose(1, 0, 2, 3, 4), tot.transpose(1, 0, 2, 3)),
    )
    prevs = prevs.transpose(1, 0, 2, 3, 4)             # [B,nc,H,K,V]

    # inter-chunk: y_q += r_q * exp(cum_{q-1}) @ S_prev;  cum_{q-1} = cum_q - logw_q
    rdec = rc * jnp.exp(cum - logw)
    y_inter = jnp.einsum("bcqhk,bchkv->bcqhv", rdec, prevs)

    y = (y_intra + y_inter).reshape(B, Sp, H, K)[:, :S]
    return y, final


def apply_rwkv6(
    p: Params,
    x: jnp.ndarray,          # [B, S, d] (already layer-normed)
    cfg,
    *,
    last_x: Optional[jnp.ndarray] = None,   # [B, 1, d] token-shift state
    wkv_state: Optional[jnp.ndarray] = None,  # [B, H, K, K]
    decode: bool = False,
    chunked: bool = True,
):
    """Time-mix block. Returns (out, (new_last_x, new_wkv_state))."""
    B, S, d = x.shape
    H, K = cfg.n_heads, cfg.head_dim

    if last_x is None:
        last_x = jnp.zeros((B, 1, d), x.dtype)
    x_prev = jnp.concatenate([last_x, x[:, :-1]], axis=1)
    new_last_x = x[:, -1:, :]

    # data-dependent token-shift mixing
    lora = jnp.tanh((x @ p["ts_lora_a"]).astype(jnp.float32)) @ p["ts_lora_b"].astype(jnp.float32)
    mix = p["mix_rkvg"].astype(jnp.float32)[None, None] + lora.reshape(B, S, 4, d)
    mix = jax.nn.sigmoid(mix).astype(x.dtype)
    xr = x * mix[:, :, 0] + x_prev * (1 - mix[:, :, 0])
    xk = x * mix[:, :, 1] + x_prev * (1 - mix[:, :, 1])
    xv = x * mix[:, :, 2] + x_prev * (1 - mix[:, :, 2])
    xg = x * mix[:, :, 3] + x_prev * (1 - mix[:, :, 3])

    r = (xr @ p["w_r"]).reshape(B, S, H, K)
    k = (xk @ p["w_k"]).reshape(B, S, H, K)
    v = (xv @ p["w_v"]).reshape(B, S, H, K)
    g = jax.nn.silu((xg @ p["w_g"]).astype(jnp.float32))

    # data-dependent decay
    dlora = jnp.tanh((xk @ p["decay_lora_a"]).astype(jnp.float32)) @ p["decay_lora_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["decay_base"][None, None] + dlora))  # (0,1)
    w = w.reshape(B, S, H, K)

    u = p["bonus_u"]
    if decode and S == 1:
        y, state = _wkv_recurrent(r, k, v, w, u, init_state=wkv_state)
    elif chunked:
        y, state = _wkv_chunked(r, k, v, w, u, cfg.ssm_chunk, init_state=wkv_state)
    else:
        y, state = _wkv_recurrent(r, k, v, w, u, init_state=wkv_state)

    # per-head group norm then gate
    y = y.reshape(B, S, H, K)
    mean = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 1e-5)
    y = y.reshape(B, S, d) * p["ln_x_scale"][None, None]
    y = (y * g).astype(x.dtype)
    out = y @ p["w_o"]
    return out, (new_last_x, state)


def init_channel_mix(rng, cfg, dtype) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "mix_k": (0.5 * jnp.ones((d,))).astype(dtype),
        "w_k": dense_init(ks[0], (d, ff), dtype),
        "w_v": dense_init(ks[1], (ff, d), dtype),
        "w_r": dense_init(ks[2], (d, d), dtype),
    }


def apply_channel_mix(p: Params, x: jnp.ndarray, last_x: Optional[jnp.ndarray] = None):
    """RWKV channel-mix (squared-relu FFN with token shift + receptance gate)."""
    B, S, d = x.shape
    if last_x is None:
        last_x = jnp.zeros((B, 1, d), x.dtype)
    x_prev = jnp.concatenate([last_x, x[:, :-1]], axis=1)
    new_last = x[:, -1:, :]
    xk = x * p["mix_k"] + x_prev * (1 - p["mix_k"])
    k = jnp.square(jax.nn.relu((xk @ p["w_k"]).astype(jnp.float32)))
    kv = k.astype(x.dtype) @ p["w_v"]
    rgate = jax.nn.sigmoid((x @ p["w_r"]).astype(jnp.float32)).astype(x.dtype)
    return rgate * kv, new_last
