"""Training loop: pjit-able step functions + the EdgeBERT two-phase trainer.

``make_train_step`` builds the generic distributed step (grad accumulation via
microbatch scan, AdamW, span-z projection).  ``EdgeBertTrainer`` orchestrates
the paper's Fig. 6 procedure: phase 1 fine-tunes with pruning (magnitude or
movement) + adaptive-span learning + optional distillation; phase 2 freezes
the backbone and trains the early-exit off-ramp.  Pruning masks are updated
on a host-side schedule (every ``update_every`` steps) and passed into the
jitted step as arguments, keeping one compiled executable throughout.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.util import logger
from repro.configs.base import ModelConfig
from repro.core import adaptive_span, pruning
from repro.core.early_exit import exit_all_layers, OfframpParams
from repro.models.model import Model
from repro.training import losses as losses_mod
from repro.training.optim import AdamWConfig, AdamWState, adamw_init, adamw_update


# ---------------------------------------------------------------------------
# Loss functions
# ---------------------------------------------------------------------------


def make_loss_fn(model: Model) -> Callable:
    cfg = model.cfg

    def loss_fn(params, batch, teacher_logits=None):
        out = model.apply_train(params, batch)
        if cfg.num_classes and "labels" in batch:
            eb = cfg.edgebert
            if out.all_cls_logits is not None:
                # early-exit enabled: train against the FINAL layer's off-ramp
                cls = out.all_cls_logits[-1]
            else:
                cls = out.cls_logits
            total, metrics = losses_mod.edgebert_phase1_loss(
                cls,
                batch["labels"],
                teacher_logits=teacher_logits,
                distill_alpha=eb.distill_alpha,
                span_z=params.get("span_z"),
                max_span=eb.span.max_span,
                span_coef=eb.span.loss_coef if eb.span.enabled else 0.0,
                aux=out.aux_loss,
            )
        else:
            total, metrics = losses_mod.lm_loss(out.logits, batch["tokens"])
            total = total + out.aux_loss
            if cfg.edgebert.span.enabled and "span_z" in params:
                sl = adaptive_span.span_loss(
                    params["span_z"], cfg.edgebert.span.max_span, cfg.edgebert.span.loss_coef
                )
                total = total + sl
                metrics["mean_span"] = jnp.mean(params["span_z"])
            metrics["loss"] = total
        return total, metrics

    return loss_fn


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
    with_masks: bool = False,
) -> Callable:
    """Returns train_step(params, opt_state, batch[, masks]) -> (params,
    opt_state, metrics).  Microbatching: the global batch's leading dim is
    split into `microbatches` chunks scanned with gradient accumulation —
    activation memory scales down by the same factor."""
    loss_fn = make_loss_fn(model)
    cfg = model.cfg

    def effective_params(params, masks):
        if masks is None:
            return params
        return pruning.apply_masks(params, pruning.PruneState(masks=masks, scores=None))

    def grads_of(params, batch, masks):
        def inner(p):
            return loss_fn(effective_params(p, masks), batch)

        (loss, metrics), grads = jax.value_and_grad(inner, has_aux=True)(params)
        return grads, metrics

    def train_step(params, opt_state, batch, masks=None):
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            mb = jax.tree_util.tree_map(split, batch)

            def acc_fn(carry, mb_batch):
                acc = carry
                g, metrics = grads_of(params, mb_batch, masks)
                acc = jax.tree_util.tree_map(lambda a, b: a + b.astype(jnp.float32), acc, g)
                return acc, metrics

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, metrics = jax.lax.scan(acc_fn, zero, mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            metrics = jax.tree_util.tree_map(lambda m: jnp.mean(m), metrics)
        else:
            grads, metrics = grads_of(params, batch, masks)

        params, opt_state, om = adamw_update(grads, opt_state, params, opt_cfg)
        # span projection: z stays in [0, max_span]
        if "span_z" in params and cfg.edgebert.span.enabled:
            params = dict(
                params,
                span_z=adaptive_span.clamp_spans(params["span_z"], cfg.edgebert.span.max_span),
            )
        metrics = dict(metrics)
        metrics.update(om)
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# EdgeBERT two-phase trainer (paper Fig. 6)
# ---------------------------------------------------------------------------


@dataclass
class TrainerConfig:
    phase1_steps: int = 200
    phase2_steps: int = 100
    opt: AdamWConfig = None           # type: ignore

    def __post_init__(self):
        if self.opt is None:
            object.__setattr__(self, "opt", AdamWConfig())


class EdgeBertTrainer:
    """Host-side orchestration of phase 1 (prune + span + KD) and phase 2
    (off-ramp highway fine-tuning with frozen backbone)."""

    def __init__(self, model: Model, tcfg: TrainerConfig, teacher_params=None):
        self.model = model
        self.cfg = model.cfg
        self.tcfg = tcfg
        self.teacher_params = teacher_params
        self.loss_fn = make_loss_fn(model)
        self._step1 = None
        self._step2 = None

    # ---------------- phase 1 ----------------
    def phase1(self, params, data, log_every: int = 50, callbacks=()):
        eb = self.cfg.edgebert
        opt_state = adamw_init(params)
        prune_state = (
            pruning.init_prune_state(params, eb.prune.method) if eb.prune.enabled else None
        )
        loss_fn = self.loss_fn
        teacher = self.teacher_params
        model = self.model

        @jax.jit
        def step_fn(params, opt_state, batch, masks):
            def inner(p):
                pm = (
                    pruning.apply_masks(p, pruning.PruneState(masks=masks, scores=None))
                    if masks is not None
                    else p
                )
                tl = None
                if teacher is not None:
                    t_out = model.apply_train(teacher, batch)
                    tl = jax.lax.stop_gradient(
                        t_out.all_cls_logits[-1] if t_out.all_cls_logits is not None else t_out.cls_logits
                    )
                return loss_fn(pm, batch, teacher_logits=tl)

            (loss, metrics), grads = jax.value_and_grad(inner, has_aux=True)(params)
            params, opt_state, om = adamw_update(grads, opt_state, params, self.tcfg.opt)
            if "span_z" in params and eb.span.enabled:
                params = dict(
                    params,
                    span_z=adaptive_span.clamp_spans(params["span_z"], eb.span.max_span),
                )
            metrics = dict(metrics)
            metrics.update(om)
            return params, opt_state, grads, metrics

        history = []
        masks = prune_state.masks if prune_state else None
        for step in range(self.tcfg.phase1_steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch(step).items() if k != "signal_ratio"}
            params, opt_state, grads, metrics = step_fn(params, opt_state, batch, masks)
            if prune_state is not None:
                if eb.prune.method == "movement":
                    prune_state = pruning.update_movement_scores(
                        prune_state, params, grads, float(metrics["lr"])
                    )
                if step % eb.prune.update_every == 0 or step == self.tcfg.phase1_steps - 1:
                    prune_state = pruning.update_masks(
                        params, prune_state, step, eb.prune.method,
                        eb.prune.encoder_sparsity, eb.prune.begin_step,
                        eb.prune.end_step, eb.prune.block_size,
                    )
                    masks = prune_state.masks
            if step % log_every == 0:
                logger.info(
                    "phase1 step=%d loss=%.4f acc=%.3f", step,
                    float(metrics["loss"]), float(metrics.get("acc", 0.0)),
                )
            history.append({k: float(v) for k, v in metrics.items()})
            for cb in callbacks:
                cb(step, params, metrics)
        # bake masks in (deploy form)
        if prune_state is not None:
            params = pruning.apply_masks(params, prune_state)
        return params, prune_state, history

    # ---------------- phase 2 ----------------
    def phase2(self, params, data, log_every: int = 50):
        """Freeze everything except the off-ramp; train off-ramps at every
        layer (DeeBERT).  Requires early_exit enabled + albert-family model."""
        assert "offramp" in params, "phase2 needs early-exit off-ramp params"
        model = self.model
        opt_state = adamw_init(params["offramp"])

        @jax.jit
        def step_fn(offramp, opt_state, frozen, batch):
            def inner(oramp):
                p = dict(frozen, offramp=oramp)
                out = model.apply_train(p, batch)
                return losses_mod.offramp_loss(out.all_cls_logits, batch["labels"]), out

            (loss, out), grads = jax.value_and_grad(inner, has_aux=True)(offramp)
            offramp, opt_state, om = adamw_update(grads, opt_state, offramp, self.tcfg.opt)
            return offramp, opt_state, {"loss": loss, **om}

        frozen = {k: v for k, v in params.items() if k != "offramp"}
        offramp = params["offramp"]
        history = []
        for step in range(self.tcfg.phase2_steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch(10_000 + step).items() if k != "signal_ratio"}
            offramp, opt_state, metrics = step_fn(offramp, opt_state, frozen, batch)
            if step % log_every == 0:
                logger.info("phase2 step=%d loss=%.4f", step, float(metrics["loss"]))
            history.append({k: float(v) for k, v in metrics.items()})
        return dict(frozen, offramp=offramp), history
