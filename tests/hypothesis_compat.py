"""Optional-`hypothesis` shim: property tests degrade to skips, not errors.

The CI image does not always ship `hypothesis`; hard-importing it made the
whole tier-1 suite fail at *collection*.  Test modules import `given` /
`st` / `settings` from here instead:

  * with hypothesis installed everything passes straight through;
  * without it, ``@given(...)`` turns the test into a single
    ``pytest.mark.skip``-ed function and ``st.<anything>(...)`` returns inert
    placeholders, so modules still import and the rest of their (plain
    pytest) tests run.

``requires_hypothesis`` is a ``skipif`` marker for tests that use hypothesis
APIs imperatively rather than as decorators.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

    class settings:  # noqa: N801 - mirrors hypothesis.settings
        """No-op stand-in: usable as decorator and for profile registration."""

        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass

    class _Strategy:
        """Inert placeholder for any `st.*(...)` strategy expression."""

        def __getattr__(self, name):
            return _Strategy()

        def __call__(self, *args, **kwargs):
            return _Strategy()

    st = _Strategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.hypothesis
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped(*a, **k):  # pragma: no cover - never runs
                pass

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco


requires_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS, reason="hypothesis not installed"
)
