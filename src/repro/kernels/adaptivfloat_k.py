"""AdaptivFloat Pallas kernels (paper §III-E + §V-C FP8 datapath).

1. ``quantize``  — tile-wise quantize-dequantize with the per-tensor exponent
   bias (amax is a scalar computed outside, matching the PU's per-tensor bias
   register).
2. ``af_matmul`` — weight-quantized matmul: AF8 codes are stored as uint8 in
   HBM (halving weight traffic), decoded at the VMEM edge, and fed to the MXU
   with fp32 accumulation — the TPU rendition of the paper's 8-bit multiply /
   32-bit accumulate processing unit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.adaptivfloat import AFFormat


def _quant_body(x, e_min, fmt: AFFormat):
    """Quantize-dequantize math on a tile (same algebra as core.af_quantize)."""
    n_mant_scale = float(2 ** fmt.n_mant)
    e_min_f = e_min.astype(jnp.float32)
    e_max_f = e_min_f + (fmt.n_levels_exp - 1)
    a = jnp.abs(x)
    sign = jnp.sign(x)
    safe_a = jnp.maximum(a, 1e-38)
    e = jnp.clip(jnp.floor(jnp.log2(safe_a)), e_min_f, e_max_f)
    scale = jnp.exp2(e)
    mant = jnp.round(a / scale * n_mant_scale) / n_mant_scale
    val = mant * scale
    max_val = (2.0 - 1.0 / n_mant_scale) * jnp.exp2(e_max_f)
    val = jnp.minimum(val, max_val)
    min_pos = jnp.exp2(e_min_f) * (1.0 + 1.0 / n_mant_scale)
    val = jnp.where(a < 0.5 * min_pos, 0.0, jnp.maximum(val, min_pos))
    return sign * val


def _quantize_kernel(x_ref, emin_ref, o_ref, *, fmt: AFFormat):
    x = x_ref[...].astype(jnp.float32)
    e_min = emin_ref[0]
    o_ref[...] = _quant_body(x, e_min, fmt).astype(o_ref.dtype)


def quantize(
    x: jnp.ndarray,           # [rows, d]
    *,
    fmt: AFFormat = AFFormat(),
    block_rows: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Quantize-dequantize to the AdaptivFloat grid; per-tensor bias."""
    rows, d = x.shape
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    amax = jnp.maximum(amax, 1e-30)
    e_min = jnp.clip(
        jnp.floor(jnp.log2(amax)) - (fmt.n_levels_exp - 1), -120.0, 120.0
    ).astype(jnp.float32)

    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    n_blocks = x.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_quantize_kernel, fmt=fmt),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, e_min[None])
    return out[:rows]


# ---------------------------------------------------------------------------
# AF8-weight matmul
# ---------------------------------------------------------------------------


def _decode_tile(codes: jnp.ndarray, e_min, fmt: AFFormat) -> jnp.ndarray:
    c = codes.astype(jnp.int32)
    sign_bit = (c >> (fmt.n_bits - 1)) & 1
    e_field = (c >> fmt.n_mant) & (fmt.n_levels_exp - 1)
    m_field = c & ((1 << fmt.n_mant) - 1)
    n_mant_scale = float(2 ** fmt.n_mant)
    e = e_field.astype(jnp.float32) + e_min.astype(jnp.float32)
    val = jnp.exp2(e) * (1.0 + m_field.astype(jnp.float32) / n_mant_scale)
    val = jnp.where((e_field == 0) & (m_field == 0), 0.0, val)
    return jnp.where(sign_bit == 1, -val, val)


def _af_matmul_kernel(x_ref, w_ref, emin_ref, o_ref, acc_ref, *, fmt: AFFormat, n_k: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decode_tile(w_ref[...], emin_ref[0], fmt)          # [bk, bn] fp32
    x = x_ref[...].astype(jnp.float32)                      # [bm, bk]
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k_step == n_k - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def af_matmul(
    x: jnp.ndarray,            # [M, K] float
    w_codes: jnp.ndarray,      # [K, N] uint8
    e_min: jnp.ndarray,        # scalar
    *,
    fmt: AFFormat = AFFormat(),
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    M, K = x.shape
    K2, N = w_codes.shape
    assert K == K2
    bm_, bk_, bn_ = min(bm, M), min(bk, K), min(bn, N)
    pm, pk, pn = (-M) % bm_, (-K) % bk_, (-N) % bn_
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w_codes = jnp.pad(w_codes, ((0, pk), (0, pn)))  # code 0 decodes to 0.0
    Mp, Kp, Np = x.shape[0], x.shape[1], w_codes.shape[1]
    n_k = Kp // bk_

    # scratch via pltpu VMEM (works in interpret mode too)
    from jax.experimental.pallas import tpu as pltpu

    out = pl.pallas_call(
        functools.partial(_af_matmul_kernel, fmt=fmt, n_k=n_k),
        grid=(Mp // bm_, Np // bn_, n_k),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(x, w_codes, e_min.reshape(1).astype(jnp.float32))
    return out[:M, :N]
