"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — 80 self-attn layers + 20 gated cross-attn layers (every 5th).
Vision frontend STUB: input_specs() supplies (B, 1601, d_model) patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,            # 80 self + 20 cross (cross_attn_every=5)
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    act="swiglu",
    norm="rms",
    pos="rope",
    rope_theta=500000.0,
    cross_attn_every=5,
    n_image_tokens=1601,
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        name="llama-3.2-vision-smoke",
        n_layers=4,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=128,
        vocab_size=512,
        cross_attn_every=2,
        n_image_tokens=16,
        max_seq_len=256,
    )
