"""Pipeline parallelism (PP) via shard_map + collective_permute.

GPipe-style microbatch pipeline over a `stage` mesh axis: each device owns a
contiguous block of layers; activations flow stage->stage with
``jax.lax.ppermute`` while microbatches stream through, so the bubble is
(S-1)/(S-1+M) of the schedule.  Provided as the PP building block for meshes
where a pod axis is better spent on pipeline stages than data parallelism
(very deep models / small global batch); the production dry-run uses DP×TP×EP
which is the right config for the assigned sizes on 256 chips — PP is
demonstrated and tested on a small mesh (tests/test_pipeline.py).

The implementation is deliberately model-agnostic: it pipelines any
``layer_fn(stage_params, h) -> h``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.common.jax_compat import shard_map


def pipeline_forward(
    layer_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,          # pytree with leading [n_stages, ...] axis
    x: jnp.ndarray,             # [n_micro, mb, ...] microbatched input
    mesh: Mesh,
    *,
    axis: str = "stage",
) -> jnp.ndarray:
    """Run a GPipe forward over the `axis` mesh dimension.

    Returns [n_micro, mb, ...] outputs (as produced by the LAST stage).
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    assert n_micro >= n_stages, "need >= n_stages microbatches to fill the pipe"

    def stage_prog(params, xs):
        # params arrive with a leading sharded [1, ...] stage dim — drop it
        params = jax.tree_util.tree_map(lambda a: a[0], params)
        stage_id = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (if in range); others use buf
            inject = jnp.where(t < n_micro, t, n_micro - 1)
            h_in = jnp.where(stage_id == 0, xs[inject], buf)
            h_out = layer_fn(params, h_in)
            # pass to the next stage (last stage's output wraps, unused)
            buf_next = jax.lax.ppermute(
                h_out, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            # last stage commits its result for microbatch (t - n_stages + 1)
            commit = t - (n_stages - 1)
            do_commit = jnp.logical_and(commit >= 0, stage_id == n_stages - 1)
            idx = jnp.clip(commit, 0, n_micro - 1)
            outs = jnp.where(
                do_commit,
                outs.at[idx].set(h_out),
                outs,
            )
            return (buf_next, outs), None

        # mark carries as device-varying (shard_map VMA typing)
        buf0 = jax.lax.pvary(jnp.zeros_like(xs[0]), (axis,))
        outs0 = jax.lax.pvary(jnp.zeros_like(xs), (axis,))
        (buf, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(n_ticks))
        # broadcast the last stage's outputs to everyone (psum of one-hot)
        mask = (stage_id == n_stages - 1).astype(outs.dtype)
        return jax.lax.psum(outs * mask, axis)

    return shard_map(
        stage_prog,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )(stage_params, x)
