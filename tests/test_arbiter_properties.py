"""Property tests for the shared-clock arbiter's serving invariants.

Random lane mixes of CLASSIFIER traffic (entropy chain, one encoder layer
per fused step) and DECODER traffic (token-level predicted remainder, one
token of ``exit_depth`` layers per fused step) driven straight through
``BatchedDVFSArbiter``, stepped the way the real stack steps them — the
scheduler advances ONE bucket per step, so classifier lanes and decoder
lanes arbitrate in separate fused calls interleaved on one shared clock:

  * the COLD admission quote (``min_latency_quote`` at conservative full
    depth — exactly what admission prices before the calibrator warms) is
    never below the latency a lane realizes at zero slack, i.e. with the
    clock pinned at the maximum operating point — the one-sided contract
    admission control rests on;
  * SLOs priced against cross-traffic's WORST-CASE stretched occupancy are
    never missed, for any mix and any extra slack multiplier.  The pricing
    detail matters: Alg. 1 deliberately stretches slack-rich lanes
    just-in-time, so another lane's clock occupancy is bounded by its work
    at the table's SLOWEST point, not the fastest — cross-traffic priced at
    max op (the naive reading of the admission formula) is refutably
    optimistic on a shared clock (counterexample: a 12-layer classifier
    sharing the clock with two full-depth decode tokens whose own deadline
    lets them crawl).  ``AdmissionController`` now prices exactly this way:
    cross-bucket backlog at slowest-op stretched occupancy capped by the
    bucket's deadline structure, plus a cross-ENGINE term summing foreign
    arbiter lanes' remaining layers at the slowest point —
    ``TestCrossEngineAdmissionRegression`` pins the counterexample
    end-to-end through the real servers;
  * per-step energy is monotone nonincreasing in slack — at fixed remaining
    work a larger remaining-time budget never selects a higher-energy
    operating point — and a LANE's drain energy is monotone nonincreasing
    in its deadline.  (Total energy of a multi-lane mix is deliberately NOT
    claimed: interleaved groups re-shape each other's step timing, and a
    globally slower schedule can hold a lane at a mid-table point longer —
    slack monotonicity is a per-decision and per-lane property.)

Runs under ``tests/hypothesis_compat`` so the suite degrades to skips when
hypothesis is absent; every invariant here is additionally fuzz-validated
(2-3k random trials incl. the mult=1.0 boundary) since CI images may lack
hypothesis.
"""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.hwmodel.edgebert_accel import albert_layer_stats
from repro.serving.dvfs import (
    BatchedDVFSArbiter,
    LatencyAwareDVFSController,
    no_early_exit_baseline,
)

N_LAYERS = 12
HEADROOM = 1.25          # AdmissionController's default feasibility margin


def _controller(target_mult=1.5):
    stats = albert_layer_stats(seq_len=32)
    stats.n_layers = N_LAYERS
    target = no_early_exit_baseline(stats)["latency_s"] * target_mult
    return LatencyAwareDVFSController(stats, target)


def _cold_layers(lane):
    """Conservative full-depth work: what a cold quote prices.  Classifier
    lanes are quoted at their (known-bound) depth; decoder lanes at full
    depth per remaining token — the cold position-calibrator behavior."""
    kind, work = lane
    return float(work) if kind == "cls" else len(work) * float(N_LAYERS)


def _actual_layers(lane):
    kind, work = lane
    return work if kind == "cls" else int(sum(work))


def _drive(arb, mix, deadline_of):
    """Admit + run a mixed lane set to completion on ONE shared clock.

    ``mix``: list of ("cls", exit_layer) / ("dec", [token exit depths]).
    Mirrors the real step topology: each round, the classifier lanes step
    together (one encoder layer each), then the decoder lanes step together
    (one token each, charged at its realized exit depth) — two servers
    sharing one arbiter, bucket-at-a-time.  Classifier lanes ride the
    controller's conservative full-depth prediction (no LUT); decoder lanes
    refresh their predicted remainder at full depth per remaining token,
    exactly like a cold position calibrator.  Returns retire reports.
    """
    for i, (kind, work) in enumerate(mix):
        arb.admit(i, deadline_s=deadline_of(i))
        if kind == "dec":
            arb.set_remaining_layers(i, len(work) * N_LAYERS)
    done = {}
    progress = [0] * len(mix)            # layers done (cls) / tokens done (dec)
    while len(done) < len(mix):
        for kind_sel in ("cls", "dec"):
            active = [
                i for i in range(len(mix))
                if i not in done and mix[i][0] == kind_sel
            ]
            if not active:
                continue
            layers = {
                i: 1 if kind_sel == "cls" else int(mix[i][1][progress[i]])
                for i in active
            }
            arb.step(active, layers=layers)
            for i in active:
                kind, work = mix[i]
                progress[i] += 1
                if kind == "cls":
                    if progress[i] == work:
                        done[i] = arb.retire(i, work)
                else:
                    arb.set_remaining_layers(
                        i, (len(work) - progress[i]) * N_LAYERS
                    )
                    if progress[i] == len(work):
                        done[i] = arb.retire(i, int(sum(work)))
    return done


_LANE = st.one_of(
    st.tuples(st.just("cls"), st.integers(min_value=1, max_value=N_LAYERS)),
    st.tuples(
        st.just("dec"),
        st.lists(
            st.integers(min_value=1, max_value=N_LAYERS), min_size=1, max_size=6
        ),
    ),
)
_MIX = st.lists(_LANE, min_size=1, max_size=4)


def _admission_deadline(arb, ctrl, mix, i, mult=1.0):
    """Price lane i conservatively for a SHARED clock: own cold service
    quote plus cross-traffic's worst-case stretched occupancy — serialized
    full-depth work at the table's SLOWEST operating point (a slack-rich
    lane may legitimately crawl there; pricing it at max op under-quotes),
    x the admission headroom."""
    service = arb.min_latency_quote(_cold_layers(mix[i]))
    wait = sum(
        _cold_layers(mix[j]) for j in range(len(mix)) if j != i
    ) * ctrl.cycles_per_layer / ctrl.table[0].freq_hz
    return (wait + service) * HEADROOM * mult


@pytest.mark.hypothesis
class TestQuoteFloor:
    @given(mix=_MIX)
    @settings(max_examples=40, deadline=None)
    def test_cold_quote_never_below_realized_latency_at_max_op(self, mix):
        """Zero-slack deadlines pin the shared clock at the maximum point —
        the fastest any schedule can run — and even then every lane's
        realized latency stays at or below its cold admission quote.  Each
        kind drains as its own homogeneous lane group (fresh clock), the
        bucket topology the scheduler actually produces; within a decode
        group a shallow token still waits out its neighbours' deeper tokens,
        which is exactly why the quote prices full depth when cold."""
        for kind_sel in ("cls", "dec"):
            grp = [l for l in mix if l[0] == kind_sel]
            if not grp:
                continue
            arb = BatchedDVFSArbiter(_controller())
            quotes = {
                i: arb.min_latency_quote(_cold_layers(l))
                for i, l in enumerate(grp)
            }
            done = _drive(arb, grp, deadline_of=lambda i: 1e-12)
            for i, rep in done.items():
                assert rep.latency_s <= quotes[i] * (1 + 1e-9), (
                    f"{kind_sel} lane {i}: realized {rep.latency_s} above "
                    f"cold quote {quotes[i]}"
                )


@pytest.mark.hypothesis
class TestAcceptedSLONeverMissed:
    @given(
        mix=_MIX,
        mult=st.floats(min_value=1.0, max_value=8.0),
    )
    @settings(max_examples=40, deadline=None)
    def test_admission_priced_deadlines_met_for_any_mix(self, mix, mult):
        """Deadlines priced conservatively for the shared clock (own cold
        service quote + cross-traffic's slowest-op stretched occupancy,
        headroom included, any extra slack on top) are contracts: zero
        misses for random classifier+decoder mixes sharing one clock —
        including the mult=1.0 boundary."""
        ctrl = _controller()
        arb = BatchedDVFSArbiter(ctrl)
        dls = {
            i: _admission_deadline(arb, ctrl, mix, i, mult)
            for i in range(len(mix))
        }
        done = _drive(arb, mix, deadline_of=lambda i: dls[i])
        for i, rep in done.items():
            assert rep.deadline_met, (
                f"lane {i}: {rep.latency_s} missed admission-priced {dls[i]}"
            )


@pytest.mark.hypothesis
class TestEnergyMonotoneInSlack:
    @given(
        lane=_LANE,
        m_lo=st.floats(min_value=1.0, max_value=4.0),
        m_hi=st.floats(min_value=1.0, max_value=4.0),
    )
    @settings(max_examples=40, deadline=None)
    def test_lane_drain_energy_nonincreasing_in_deadline(self, lane, m_lo, m_hi):
        """A lane with a larger deadline never retires with MORE compute
        energy: slack only ever moves the clock down the table.  Claimed per
        LANE — multi-lane totals are not monotone, because interleaved
        groups re-shape each other's step timing (a slower global schedule
        can hold a neighbour at a mid-table point for more layers)."""
        lo, hi = sorted((m_lo, m_hi))
        energies = []
        for mult in (lo, hi):
            arb = BatchedDVFSArbiter(_controller())
            done = _drive(
                arb, [lane],
                deadline_of=lambda i: (
                    arb.min_latency_quote(_cold_layers(lane)) * HEADROOM * mult
                ),
            )
            energies.append(done[0].energy_j)
        assert energies[1] <= energies[0] * (1 + 1e-9)

    def test_per_step_op_energy_monotone_in_slack(self):
        """The single-step form of the invariant, deterministically: at
        fixed remaining work, a larger remaining-time budget never selects
        a higher-energy operating point."""
        ctrl = _controller()
        work_cycles = 5 * ctrl.cycles_per_layer
        prev_e = float("inf")
        for t_rem in np.linspace(1e-6, 50 * ctrl.layer_time_s(ctrl.max_op), 200):
            op = ctrl.op_for_freq(work_cycles / t_rem)
            e = ctrl.layer_energy(op)
            assert e <= prev_e * (1 + 1e-12)
            prev_e = e


class TestInvariantsDeterministic:
    """The same three invariants exercised WITHOUT hypothesis, so a CI image
    missing the package still runs them: the two adversarial mixes that
    refute naive max-op cross-traffic pricing (a slack-rich lane stretches
    just-in-time and occupies the shared clock far longer than its max-op
    work), plus a seeded random sweep."""

    HARD_MIXES = [
        # 12-layer classifier sharing the clock with two full-depth decode
        # tokens: under max-op pricing the classifier misses; slowest-op
        # stretched-occupancy pricing must hold
        [("cls", 12), ("dec", [12, 12])],
        [("dec", [3, 5, 11, 12]), ("cls", 11)],
    ]

    def _seeded_mixes(self, n=40, seed=9):
        rng = np.random.default_rng(seed)
        out = []
        for _ in range(n):
            mix = []
            for _ in range(int(rng.integers(1, 5))):
                if rng.random() < 0.5:
                    mix.append(("cls", int(rng.integers(1, N_LAYERS + 1))))
                else:
                    mix.append(("dec", [
                        int(rng.integers(1, N_LAYERS + 1))
                        for _ in range(int(rng.integers(1, 7)))
                    ]))
            out.append(mix)
        return out

    def test_hard_mixes_meet_stretch_priced_deadlines_at_boundary(self):
        for mix in self.HARD_MIXES:
            for mult in (1.0, 1.07, 2.0):
                ctrl = _controller()
                arb = BatchedDVFSArbiter(ctrl)
                dls = {
                    i: _admission_deadline(arb, ctrl, mix, i, mult)
                    for i in range(len(mix))
                }
                done = _drive(arb, mix, deadline_of=lambda i: dls[i])
                assert all(r.deadline_met for r in done.values()), (mix, mult)

    def test_seeded_sweep_quote_floor_and_slo(self):
        rng = np.random.default_rng(10)
        for mix in self._seeded_mixes():
            # quote floor per homogeneous kind group at zero slack
            for kind_sel in ("cls", "dec"):
                grp = [l for l in mix if l[0] == kind_sel]
                if not grp:
                    continue
                arb = BatchedDVFSArbiter(_controller())
                quotes = {
                    i: arb.min_latency_quote(_cold_layers(l))
                    for i, l in enumerate(grp)
                }
                done = _drive(arb, grp, deadline_of=lambda i: 1e-12)
                for i, rep in done.items():
                    assert rep.latency_s <= quotes[i] * (1 + 1e-9), (grp, i)
            # accepted SLOs at stretch pricing, boundary-heavy multipliers
            mult = 1.0 if rng.random() < 0.3 else float(1.0 + 7.0 * rng.random())
            ctrl = _controller()
            arb = BatchedDVFSArbiter(ctrl)
            dls = {
                i: _admission_deadline(arb, ctrl, mix, i, mult)
                for i in range(len(mix))
            }
            done = _drive(arb, mix, deadline_of=lambda i: dls[i])
            assert all(r.deadline_met for r in done.values()), (mix, mult)

    def test_seeded_sweep_lane_energy_monotone(self):
        rng = np.random.default_rng(11)
        for mix in self._seeded_mixes(n=20, seed=12):
            lane = mix[0]
            lo, hi = sorted(
                (float(1 + 3 * rng.random()), float(1 + 3 * rng.random()))
            )
            energies = []
            for mult in (lo, hi):
                arb = BatchedDVFSArbiter(_controller())
                done = _drive(
                    arb, [lane],
                    deadline_of=lambda i: (
                        arb.min_latency_quote(_cold_layers(lane))
                        * HEADROOM * mult
                    ),
                )
                energies.append(done[0].energy_j)
            assert energies[1] <= energies[0] * (1 + 1e-9), (lane, lo, hi)

class TestCrossEngineAdmissionRegression:
    """The pinned counterexample, end-to-end through the REAL stack: a
    classifier sharing one arbiter clock with slack-rich decoder contracts
    that Alg. 1 stretches to crawl at the slowest operating point.

    Under the old max-op pricing the classifier's quote ignored the foreign
    lanes entirely (its own bucket queue is empty, cross-bucket sees no
    classifier work) and an SLO accepted at that quote was missed.  With
    cross-engine backlog priced at slowest-op remaining layers the quote
    covers the steal and the contract holds — the one-sided guarantee
    ``accepted => met`` that admission control rests on."""

    def _servers(self):
        import dataclasses

        import jax

        from repro.configs.base import get_smoke_config
        from repro.data.synthetic import SyntheticCLS
        from repro.models.model import build_model
        from repro.serving.engine import ClassifierServer, DecoderServer

        ccfg = get_smoke_config("albert_edgebert")
        ccfg = dataclasses.replace(ccfg, dtype="float32", remat_policy="none")
        ccfg = ccfg.with_edgebert(          # threshold ~0: deterministic full depth
            early_exit=dataclasses.replace(
                ccfg.edgebert.early_exit, entropy_threshold=1e-9
            )
        )
        cmodel = build_model(ccfg)
        cparams = cmodel.init_params(jax.random.PRNGKey(0))

        dcfg = dataclasses.replace(
            get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none"
        )
        dmodel = build_model(dcfg)
        dparams = dmodel.init_params(jax.random.PRNGKey(1))

        stats = albert_layer_stats(seq_len=16)
        stats.n_layers = ccfg.n_layers
        ctrl = LatencyAwareDVFSController(
            stats, no_early_exit_baseline(stats)["latency_s"] * 1.5
        )
        arb = BatchedDVFSArbiter(ctrl)
        dec = DecoderServer(dmodel, dparams, batch_lanes=2, max_seq=32,
                            buckets=(16,), arbiter=arb)
        cls = ClassifierServer(cmodel, cparams, batch_lanes=2, buckets=(16,),
                               arbiter=arb)
        batch = SyntheticCLS(ccfg.vocab_size, 32, 8, num_classes=3,
                             seed=0).batch(0)
        return arb, ctrl, dec, cls, batch

    def test_accepted_classifier_slo_survives_crawling_decoder_lanes(self):
        from repro.serving.admission import AdmissionController
        from repro.serving.engine import Request

        arb, ctrl, dec, cls, batch = self._servers()
        # slack-rich decoder contracts: deadline = 4x their own slowest-op
        # work, so Alg. 1 stretches them onto the table's slowest point
        prompt = np.arange(1, 6, dtype=np.int32)
        # one request's slowest-op work: 10 tokens of full-depth decode
        # steps (plus margin for the un-charged prefill rounds)
        slow = dec._cycles_for(16) * 12 / ctrl.table[0].freq_hz
        for i in range(2):
            dec.submit(Request(uid=100 + i, tokens=prompt, max_new_tokens=10,
                               deadline_s=slow * 4.0))
        dec.step()                     # foreign lanes in flight on the clock

        ac = AdmissionController(cls)
        # the quote must see the foreign occupancy (old pricing: exactly 0)
        xterm = ac._cross_engine_backlog_s()
        assert xterm > 0.0
        req = Request(uid=0, tokens=batch["tokens"][0][:12], deadline_s=1e9)
        q = ac.quote(req)
        assert q.wait_s >= xterm

        # WITHOUT the cross-engine term the same mix misses the accepted
        # SLO — the refutation the module docstring pins; keep it live so a
        # pricing regression resurfaces as a failure here, not in prod
        q_old_deadline = (q.wait_s - xterm + q.service_s) * ac.headroom
        assert q_old_deadline < q.min_deadline_s

        d = ac.submit(Request(uid=0, tokens=batch["tokens"][0][:12],
                              deadline_s=q.min_deadline_s))
        assert d.admitted
        while not (cls.sched.idle and dec.sched.idle):
            dec.step()
            cls.step()
        assert cls.telemetry()["accepted_slo_misses"] == 0
        assert dec.telemetry()["accepted_slo_misses"] == 0
        r = cls.done[0]
        assert r.retire_s - r.arrival_s <= r.deadline_s * (1 + 1e-9)
        # and the fix was load-bearing: realized latency exceeds what the
        # old optimistic quote promised
        assert r.retire_s - r.arrival_s > q_old_deadline

    def test_old_pricing_counterexample_still_refuted(self):
        """Suppress the cross-engine term (restoring the old optimistic
        quote) and drive the identical mix: the accepted SLO MUST miss.
        Guards the test itself — if the scenario ever stops distinguishing
        the two pricings, this fails instead of silently passing."""
        from repro.serving.admission import AdmissionController
        from repro.serving.engine import Request

        arb, ctrl, dec, cls, batch = self._servers()
        prompt = np.arange(1, 6, dtype=np.int32)
        # one request's slowest-op work: 10 tokens of full-depth decode
        # steps (plus margin for the un-charged prefill rounds)
        slow = dec._cycles_for(16) * 12 / ctrl.table[0].freq_hz
        for i in range(2):
            dec.submit(Request(uid=100 + i, tokens=prompt, max_new_tokens=10,
                               deadline_s=slow * 4.0))
        dec.step()

        ac = AdmissionController(cls)
        ac._cross_engine_backlog_s = lambda: 0.0     # old pricing
        q = ac.quote(Request(uid=0, tokens=batch["tokens"][0][:12],
                             deadline_s=1e9))
        d = ac.submit(Request(uid=0, tokens=batch["tokens"][0][:12],
                              deadline_s=q.min_deadline_s))
        assert d.admitted
        while not (cls.sched.idle and dec.sched.idle):
            dec.step()
            cls.step()
        assert cls.telemetry()["accepted_slo_misses"] >= 1


class TestCrossEngineDeadlineCapRegression:
    """The OTHER failure mode of cross-engine pricing: slow-op-only
    serialization OVER-rejects.  A foreign decode lane with a TIGHT deadline
    cannot crawl — Alg. 1 pins it at (or near) the max operating point and
    its lane clears by its own absolute deadline — yet the uncapped term
    still priced its deep remaining work at the table's SLOWEST point,
    rejecting classifier SLOs the mix trivially meets.  Each foreign lane is
    now priced ``min(slow-op serialization, deadline + max-op tail)``; both
    are one-sided upper bounds (the tail covers post-deadline escalation),
    so the accepted=>met contract is preserved while the spurious
    rejections disappear."""

    def test_tight_foreign_deadlines_no_longer_over_reject(self):
        from repro.serving.admission import AdmissionController
        from repro.serving.engine import Request

        # reuse the PR 6 scenario builder, but admit the decoder contracts
        # TIGHT instead of slack-rich
        arb, ctrl, dec, cls, batch = (
            TestCrossEngineAdmissionRegression()._servers()
        )
        prompt = np.arange(1, 6, dtype=np.int32)
        fast = dec._cycles_for(16) * 12 / ctrl.max_op.freq_hz
        for i in range(2):
            dec.submit(Request(uid=100 + i, tokens=prompt, max_new_tokens=10,
                               deadline_s=fast * 2.0))
        dec.step()                     # foreign lanes in flight, zero slack

        ac = AdmissionController(cls)
        x_new = ac._cross_engine_backlog_s()
        # the retired slow-op-only pricing, recomputed from the same state
        slow_hz = ctrl.table[0].freq_hz
        x_old = 0.0
        for key, clk in arb._lanes.items():
            if isinstance(key, tuple) and len(key) == 3 and key[0] == cls._sid:
                continue
            rem = (float(clk.pred_layers_remaining)
                   if clk.pred_layers_remaining is not None
                   else max(float(ctrl.stats.n_layers - clk.depth), 0.0))
            x_old += rem * clk.cycles_per_layer / slow_hz
        # tight deadlines make the cap bind: the new term must be strictly
        # cheaper, or this scenario no longer distinguishes the pricings
        assert x_new < x_old * 0.9, (x_new, x_old)

        q = ac.quote(Request(uid=0, tokens=batch["tokens"][0][:12],
                             deadline_s=1e9))
        old_min_deadline = (q.wait_s - x_new + x_old + q.service_s) * ac.headroom
        # an SLO between the two quotes: over-rejected before, admitted now
        slo = (q.min_deadline_s + old_min_deadline) / 2.0
        assert q.min_deadline_s <= slo < old_min_deadline
        d = ac.submit(Request(uid=0, tokens=batch["tokens"][0][:12],
                              deadline_s=slo))
        assert d.admitted, "deadline-capped pricing must admit this contract"
        # and the admission was SOUND: the accepted CLASSIFIER SLO is met.
        # (The decoder contracts were submitted directly — never quoted — and
        # may miss their own aggressive deadlines; the cap stays a valid
        # bound regardless, because a deadline-missing foreign lane runs its
        # leftover work at MAX op, which is exactly the tail term.)
        while not (cls.sched.idle and dec.sched.idle):
            dec.step()
            cls.step()
        assert cls.telemetry()["accepted_slo_misses"] == 0
        r = cls.done[0]
        assert r.retire_s - r.arrival_s <= r.deadline_s * (1 + 1e-9)
