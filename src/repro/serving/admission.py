"""Admission control: SLO feasibility quoting, load shedding, preemption.

EdgeBERT's sentence-level DVFS (paper Alg. 1) only saves energy when the
prescribed target latency is ACHIEVABLE — the controller scales (V, f) down
into the slack between the predicted exit and the deadline.  A serving stack
that accepts every ``Request.deadline_s`` unconditionally therefore fails in
the exact regime edge deployments live in: under oversubscription there is no
slack, the arbiter pins the clock at the maximum point, and accepted SLOs are
missed anyway — the worst of both worlds (max energy AND broken contracts).

``AdmissionController`` sits in FRONT of ``LaneScheduler.submit()`` and
closes that gap with three mechanisms:

* **Feasibility quoting** — at submission time, every explicit SLO is priced
  against the same models the runtime schedules with: the per-bucket cycle
  model (``LatencyAwareDVFSController.cycles_for_seq_len`` /
  ``hwmodel.scale_stats_to_seq_len``), the arbiter's MAXIMUM operating point
  (``BatchedDVFSArbiter.min_latency_quote`` — no schedule can beat the top
  table entry, plus one worst-case LDO/ADPLL switching stall), the
  entropy-LUT predicted exit depth (``predict_remaining_steps``; cold
  requests quote the conservative full depth), and the CURRENT queue state.
  Decoder SLOs price the same way off the TOKEN-level predictor: the
  engine's ``predict_remaining_steps`` returns fractional full-depth fused
  steps from the position-binned exit LUT and ``_cycles_for`` the
  full-depth fused-step cycles, so a warm calibrator tightens decode quotes
  while a cold one quotes every remaining token at full depth.
  Self-speculative decode (``DecoderServer(spec_window=...)``) needs no
  quote-side special case, by construction: quotes price predicted LAYERS,
  and a speculative fused step runs the same accepted-token exit depths in
  fewer, proportionally longer steps — the modeled compute time is
  identical and the saved per-step switch-stall opportunities only shorten
  realized latency.  The quote therefore stays one-sided under
  speculation (never under-prices realized latency), which
  tests/test_spec_properties.py pins for random cls+dec mixes on a shared
  clock; the calibrator those quotes read is fed EVERY accepted token's
  realized depth (one observation per token, not per block).
  Lane availability is priced by the deadline structure, not by max-op
  completion times: Alg. 1 deliberately stretches every slack-rich lane to
  finish JUST IN TIME, so an outstanding contract occupies its lane up to
  its own absolute deadline and a new arrival waits (at worst) for the
  lanes-th largest outstanding deadline in its bucket, plus other buckets'
  serialized explicit backlog.  Cross-traffic (other buckets, and other
  ENGINES sharing the arbiter's clock) is priced by the same stretched-
  occupancy logic: its remaining work at the SLOWEST operating point,
  capped by its deadline structure — max-op pricing there was refutably
  optimistic (the pinned counterexample in tests/test_arbiter_properties.py,
  now a passing regression test).  An SLO below the quote is **rejected** — the
  caller receives the minimum feasible deadline — or, with
  ``on_infeasible="requote"``, admitted at that quoted deadline instead of
  the infeasible one.

* **Load shedding** — best-effort (deadline-free) traffic gets a bounded
  per-bucket queue with an oldest-drop policy: under a sustained tight-SLO
  storm the best-effort backlog stays bounded (bounded queue => bounded
  queueing delay for everything that DOES run) instead of growing without
  limit behind an endless stream of contracts.  Explicit SLOs are never shed
  (they were quoted), and neither are preempted requests holding a
  checkpoint (their completed layers would be wasted).

* **Preemption awareness** — when the scheduler runs with ``preempt=True``
  (lane checkpointing), an explicit request's lane wait is bounded by ONE
  fused step (evict a budget-free lane, restore it later) instead of one
  retire, and the quote prices it that way.

The quote is deliberately CONSERVATIVE — cold requests are priced at full
depth, accepted explicit work is serialized — because the contract it backs
is one-sided: a quote may overestimate (we reject work we could have served)
but must not underestimate (an accepted SLO must be met).  The benchmark
gate is exactly that asymmetry: ``accepted_slo_misses == 0`` with
``rejected > 0`` under an oversubscribed storm.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Protocol, TYPE_CHECKING

import numpy as np

from repro.serving.scheduler import LaneScheduler

if TYPE_CHECKING:  # circular: engine imports scheduler
    from repro.serving.engine import Request


@dataclass
class Quote:
    """Feasibility quote for one explicit-SLO request at submission time.

    All figures are RELATIVE modeled seconds from the submission instant
    (an SLO is submission-anchored, so arrival == now at quote time).
    """

    bucket: int
    service_s: float        # own predicted compute at the max operating point
    wait_s: float           # modeled wait for a lane (explicit backlog +
                            # lane availability, preemption-bounded)
    min_deadline_s: float   # earliest feasible relative deadline, headroom
                            # included — an SLO >= this is accepted
    feasible: bool          # requested deadline_s >= min_deadline_s
    replica: Optional[int] = None   # clock domain this quote priced (None =
                                    # whole fleet / single-replica server)


class PlacementPolicy(Protocol):
    """Chooses which per-replica quote an accepted contract is routed to.

    ``choose`` receives one ``Quote`` per replica (all for the SAME request,
    priced against that replica's lanes, queue share, and clock domain) and
    returns the one to route to — the request is then PINNED to
    ``quote.replica`` so the scheduler only refills that domain's lanes with
    it.  Called only when at least one quote is feasible."""

    def choose(self, quotes: List[Quote]) -> Quote: ...


class LeastLoadedPlacement:
    """Route to the replica quoting the earliest feasible deadline.

    Greedy latency-optimal: the chosen replica is the one that can serve the
    request SOONEST, which spreads load and maximizes each arrival's own
    slack (hence the DVFS arbiter's energy headroom on that replica)."""

    def choose(self, quotes: List[Quote]) -> Quote:
        return min(quotes, key=lambda q: (q.min_deadline_s, q.wait_s))


class DeadlinePackedPlacement:
    """Route to the BUSIEST replica that still quotes the SLO feasible.

    Best-fit packing: concentrating contracts on already-loaded domains
    keeps the remaining replicas slack-rich — their arbiters can hold deep
    low-(V, f) points (or the fleet can later park them entirely), and
    future tight SLOs still find an empty domain to land on."""

    def choose(self, quotes: List[Quote]) -> Quote:
        return max(quotes, key=lambda q: (q.min_deadline_s, q.wait_s))


@dataclass
class AdmissionDecision:
    """What ``AdmissionController.submit`` did with a request."""

    admitted: bool
    action: str                       # "accepted" | "requoted" | "rejected"
    bucket: int
    quote: Optional[Quote] = None     # explicit-SLO requests only
    shed: List["Request"] = field(default_factory=list)  # best-effort victims
                                      # dropped to bound the queue


class AdmissionController:
    """Feasibility gate in front of a serving engine's ``submit()``.

    Parameters
    ----------
    server:  a serving engine (``ClassifierServer`` / ``DecoderServer`` —
             anything exposing ``.sched`` and ``.submit``) or a bare
             ``LaneScheduler``.
    headroom:
             multiplier applied to the raw (wait + service) estimate before
             the feasibility comparison; absorbs scheduling granularity and
             arbitration stalls the analytic quote cannot see.  The quote
             handed back to callers (``min_deadline_s``) includes it, so a
             rejected caller who resubmits at the quote is accepted.
    on_infeasible:
             ``"reject"`` (default) refuses the request — it never enters a
             queue and the decision carries the minimum feasible deadline —
             or ``"requote"``: admit at the quoted deadline instead (the
             original SLO is preserved on ``req.quoted_deadline_s``).
    max_best_effort_queue:
             bounded-queue depth for deadline-free traffic, per bucket
             (``None`` = unbounded).  Submitting past the bound sheds the
             OLDEST queued best-effort request(s) first.
    fallback_steps:
             predicted steps for a request when the engine offers no
             ``predict_remaining_steps`` hook (bare schedulers in tests).
    placement:
             ``PlacementPolicy`` routing accepted contracts across a
             sharded server's replicas (default ``LeastLoadedPlacement``).
             Ignored on single-replica servers.
    extra_wait_s:
             optional zero-arg callable priced into every quote's wait term.
             This is the cross-SERVER demand hook: sibling engines' QUEUED
             work is invisible through the shared arbiter (only their
             in-flight lanes are), so a multi-server router that can see its
             siblings' queues prices them here — without it, sustained
             bursty multi-task load admits contracts whose wait the sibling
             backlog then overruns (found by the trace-replay harness).
             Must return an upper bound in modeled seconds; conservative
             over-pricing only costs rejections, never a broken contract.
    """

    def __init__(
        self,
        server: Any,
        *,
        headroom: float = 1.25,
        on_infeasible: str = "reject",
        max_best_effort_queue: Optional[int] = None,
        fallback_steps: float = 1.0,
        placement: Optional[PlacementPolicy] = None,
        extra_wait_s: Optional[Callable[[], float]] = None,
    ):
        assert headroom >= 1.0, "headroom < 1 would quote below the estimate"
        assert on_infeasible in ("reject", "requote")
        assert max_best_effort_queue is None or max_best_effort_queue >= 1
        self.server = server
        self.sched: LaneScheduler = (
            server if isinstance(server, LaneScheduler) else server.sched
        )
        self.headroom = float(headroom)
        self.on_infeasible = on_infeasible
        self.max_best_effort_queue = max_best_effort_queue
        self.fallback_steps = float(fallback_steps)
        self.placement: PlacementPolicy = (
            LeastLoadedPlacement() if placement is None else placement
        )
        self.extra_wait_s = extra_wait_s

    # ----------------------------------------------------------- replicas
    def _replicas(self) -> int:
        return int(getattr(self.server, "replicas", 1) or 1)

    def _lane_range(self, replica: Optional[int]) -> range:
        """Lane indices a quote scans: one replica's contiguous slab, or
        every lane when ``replica`` is None (single-domain pricing)."""
        if replica is None:
            return range(self.sched.lanes)
        lpr = int(
            getattr(self.server, "lanes_per_replica", self.sched.lanes)
        )
        return range(replica * lpr, (replica + 1) * lpr)

    @staticmethod
    def _pin_ok(req: "Request", replica: Optional[int]) -> bool:
        """A queued contract competes for a replica's lanes iff unpinned or
        pinned to that replica (the scheduler enforces the same rule)."""
        if replica is None:
            return True
        pin = getattr(req, "replica", None)
        return pin is None or pin == replica

    # ------------------------------------------------------------- quoting
    def _predict_steps(self, bucket: int, req: "Request", depth: int) -> float:
        rem = self.sched._predict_remaining(bucket, req, depth)
        if rem is None:
            rem = self.fallback_steps
        # a preempted request only needs its remaining depth
        return max(float(rem), 1.0)

    def _service_s(self, bucket: int, steps: float) -> float:
        """Own compute floor: ``steps`` fused steps at the max operating
        point.  With a shared-clock arbiter this is the arbiter's quote (per
        -bucket cycles at max V/f plus one worst-case switching stall);
        otherwise the scheduler's nominal per-bucket step time, which engines
        with a hw model already define as the max-op layer time.

        ``steps`` is fractional full-depth fused steps, i.e. LAYERS over
        n_layers — deliberately invariant under speculative blocking: a
        spec-enabled server repacks the same layers into fewer, longer
        steps, so this floor remains one-sided (see module docstring)."""
        arb = getattr(self.server, "arbiter", None)
        cycles_for = getattr(self.server, "_cycles_for", None)
        if arb is not None and cycles_for is not None:
            return arb.min_latency_quote(
                steps, cycles_per_layer=cycles_for(bucket)
            )
        return steps * float(self.sched.step_time_fn(bucket))

    def _outstanding_deadlines(
        self, bucket: int, replica: Optional[int] = None
    ) -> List[float]:
        """Absolute deadlines of every outstanding explicit contract in a
        bucket — in-flight lanes AND queued (already-accepted) requests.
        With ``replica``, only that domain's lanes and the queued contracts
        that could land on them (unpinned or same-pin)."""
        sched = self.sched
        out = []
        run = sched._open.get(bucket)
        if run is not None:
            for i in self._lane_range(replica):
                r = run.lane_req[i]
                if r is not None and r.deadline_s is not None:
                    out.append(r.arrival_s + r.deadline_s)
        out.extend(
            r.arrival_s + r.deadline_s
            for r in sched.queues.get(bucket, ())
            if r.deadline_s is not None and self._pin_ok(r, replica)
        )
        return out

    def _own_bucket_wait_s(
        self, bucket: int, replica: Optional[int] = None
    ) -> float:
        """Upper bound on the wait for a lane in the request's OWN bucket.

        The key subtlety is that accepted contracts do NOT free their lanes
        at max-op speed: the DVFS arbiter deliberately stretches slack-rich
        lanes to finish JUST IN TIME (that is Alg. 1's energy mechanism), so
        a lane holding a contract is occupied up to that contract's absolute
        deadline.  Every outstanding contract was admission-quoted feasible
        (completes by its own deadline), hence with ``lanes`` lane slots a
        new arrival waits at most until the lanes-th LARGEST outstanding
        deadline — before that instant at least one slot must have cleared.

        With fewer outstanding contracts than lanes, the arrival takes the
        (k+1)-th lane to come free, where k is the number of QUEUED
        contracts — EDF pops them first, so they claim the first freed
        lanes.  Per-lane free times: zero for a free lane, the contract's
        own absolute deadline for an in-flight explicit lane, one fused
        step for a preemptible budget-free lane, else that lane's predicted
        retire.

        With ``replica``, the same pricing restricted to that clock domain:
        its lane slab, and only the queued contracts that could land there
        (unpinned or same-pin) count toward the backlog."""
        sched = self.sched
        dt = float(sched.step_time_fn(bucket))
        lanes_idx = self._lane_range(replica)
        lanes_n = len(lanes_idx)
        deadlines = self._outstanding_deadlines(bucket, replica)
        if len(deadlines) >= lanes_n:
            d_l = sorted(deadlines, reverse=True)[lanes_n - 1]
            return max(0.0, d_l - sched.now_s)
        k = sum(
            1
            for r in sched.queues.get(bucket, ())
            if r.deadline_s is not None and self._pin_ok(r, replica)
        )
        run = sched._open.get(bucket)
        free_at = []
        for i in lanes_idx:
            req = run.lane_req[i] if run is not None else None
            if req is None:
                free_at.append(0.0)
            elif req.deadline_s is not None:
                free_at.append(
                    max(0.0, req.arrival_s + req.deadline_s - sched.now_s)
                )
            elif sched.preempt:
                free_at.append(dt)      # checkpoint-evict at the next refill
            else:
                rem = self._predict_steps(bucket, req, int(run.lane_depth[i]))
                free_at.append(rem * dt)
        return sorted(free_at)[min(k, lanes_n - 1)]

    def _slow_step_time_s(self, bucket: int) -> Optional[float]:
        """One fused step of ``bucket`` at the SLOWEST operating point — the
        unconditional occupancy bound for cross-traffic on a shared clock
        (every step the arbiter schedules runs at >= table[0].freq_hz, so no
        contract can hold the clock longer than its work priced here).
        None without a hw model (bare schedulers have no op table)."""
        ctrl = getattr(self.server, "_ctrl", None)
        cycles_for = getattr(self.server, "_cycles_for", None)
        if ctrl is None or cycles_for is None:
            return None
        cyc = cycles_for(bucket)
        return None if cyc is None else cyc / ctrl.table[0].freq_hz

    def _cross_bucket_backlog_s(self, bucket: int) -> float:
        """Clock time OTHER buckets' explicit work steals before ours runs:
        the scheduler advances one bucket per step and EDF ranks explicit
        work above everything, so a contract conservatively waits for other
        buckets' contracts too.  In-flight lanes advance together (max
        remaining steps), queued contracts share lanes (summed work over the
        lane count).

        Pricing: Alg. 1 STRETCHES slack-rich cross-traffic toward its
        deadline, so max-op step times are refutably optimistic here (the
        pinned counterexample in tests/test_arbiter_properties.py).  With a
        hw model each bucket's steal is priced as the smaller of two valid
        upper bounds: its work serialized at the SLOWEST operating point
        (no schedule can run slower), capped by its deadline structure (an
        admitted contract's lane is occupied at most until its own absolute
        deadline, exactly as ``_own_bucket_wait_s`` prices lanes).  Bare
        schedulers keep the nominal step-time pricing."""
        sched = self.sched
        total = 0.0
        for b in set(sched.queues) | set(sched._open):
            if b == bucket:
                continue
            dt_slow = self._slow_step_time_s(b)
            dt = float(sched.step_time_fn(b)) if dt_slow is None else dt_slow
            max_rem = 0.0
            latest_deadline = None
            run = sched._open.get(b)
            if run is not None:
                for i in range(sched.lanes):
                    req = run.lane_req[i]
                    if req is not None and req.deadline_s is not None:
                        rem = self._predict_steps(b, req, int(run.lane_depth[i]))
                        max_rem = max(max_rem, rem)
                        d_abs = req.arrival_s + req.deadline_s
                        if latest_deadline is None or d_abs > latest_deadline:
                            latest_deadline = d_abs
            q_steps = 0.0
            for r in sched.queues.get(b, ()):
                if r.deadline_s is None:
                    continue
                q_steps += self._predict_steps(b, r, r.ckpt_depth)
                d_abs = r.arrival_s + r.deadline_s
                if latest_deadline is None or d_abs > latest_deadline:
                    latest_deadline = d_abs
            steal = (max_rem + np.ceil(q_steps / sched.lanes)) * dt
            if dt_slow is not None and latest_deadline is not None:
                # after the latest outstanding deadline the bucket holds no
                # explicit work — whichever bound is tighter is still valid
                steal = min(steal, max(0.0, latest_deadline - sched.now_s))
            total += steal
        return total

    def _cross_engine_backlog_s(self, replica: Optional[int] = None) -> float:
        """Clock time OTHER ENGINES' in-flight lanes steal on the shared
        arbiter.  One LDO/ADPLL pair serves every server on the arbiter, so
        a classifier quote that ignores a co-resident decoder's contracts
        (or vice versa) is optimistic on exactly the shared-clock mixes the
        arbiter exists for — the cross-ENGINE half of the pinned
        counterexample.

        Each foreign lane is priced by the SMALLER of two valid upper
        bounds: its remaining work serialized at the SLOWEST operating point
        (predicted remaining layers when the lane publishes them, else the
        conservative full remaining depth, times the lane's admitted
        per-layer cycle cost — no arbiter schedule runs slower), capped by
        the lane's own deadline structure — an admitted contract occupies
        the clock at most until its own absolute deadline, after which only
        its max-op escalation tail remains (the arbiter pins overdue lanes
        at the top table entry).  Slow-op-only pricing over-rejected
        feasible mixes whenever a tight-deadline foreign lane carried deep
        remaining work: its deadline already bounds the steal far below the
        slow-op serialization.  Summed per lane — lanes stepping together
        are charged the max, so the sum over-counts concurrency, which only
        errs conservative (the quote contract is one-sided).  Foreign queued
        work is not visible through the arbiter; the headroom multiplier
        absorbs it.

        With ``replica``, prices that clock domain's OWN arbiter — each
        replica carries an independent LDO/ADPLL pair, so foreign lanes on
        other replicas' arbiters steal nothing here."""
        arbs = getattr(self.server, "arbiters", None)
        if replica is not None and arbs:
            arb = arbs[replica]
        else:
            arb = getattr(self.server, "arbiter", None)
        if arb is None:
            return 0.0
        sid = getattr(self.server, "_sid", None)
        ctrl = arb.c
        slow_hz = ctrl.table[0].freq_hz
        max_hz = ctrl.max_op.freq_hz
        n_layers = ctrl.stats.n_layers
        total = 0.0
        for key, clk in arb._lanes.items():
            own = (
                isinstance(key, tuple) and len(key) == 3 and key[0] == sid
            )
            if own:
                continue        # own-sid lanes are priced by the scheduler-
                                # side scans above — never double-count
            if clk.pred_layers_remaining is not None:
                rem = float(clk.pred_layers_remaining)
            else:
                rem = max(float(n_layers - clk.depth), 0.0)
            serial = rem * clk.cycles_per_layer / slow_hz
            capped = (
                max(0.0, clk.deadline_s - arb.now_s)
                + rem * clk.cycles_per_layer / max_hz
            )
            total += min(serial, capped)
        return total

    def quote(self, req: "Request", replica: Optional[int] = None) -> Quote:
        """Price an explicit-SLO request against the current system state.
        Pure — does not enqueue anything.

        On a sharded server (``server.replicas > 1``) and with no explicit
        ``replica``, every clock domain is quoted independently and the
        placement policy picks among the feasible ones (the request would be
        pinned there on admission); with no feasible domain the quote with
        the earliest ``min_deadline_s`` is returned, so a rejected caller
        resubmitting at the quote lands on the least-bad replica.  A request
        already pinned (``req.replica``) is only quoted against its domain.

        Assumes EDF ties resolve in arrival order (they do: the queue pop
        keeps the first of equal deadlines), i.e. a later arrival with the
        same relative SLO cannot displace an earlier accepted contract; a
        strictly TIGHTER later arrival can, which the per-arrival d_l bound
        prices for the arrival itself but not retroactively for the displaced
        contract — the headroom absorbs that second-order effect."""
        sched = self.sched
        sched.sync_clock()      # shared-arbiter time may have moved while
                                # this server was idle: price waits from the
                                # true now, not a stale clock
        if replica is None:
            pin = getattr(req, "replica", None)
            if pin is not None:
                replica = int(pin)
            elif self._replicas() > 1:
                quotes = [
                    self.quote(req, replica=r) for r in range(self._replicas())
                ]
                feasible = [q for q in quotes if q.feasible]
                if feasible:
                    return self.placement.choose(feasible)
                return min(quotes, key=lambda q: q.min_deadline_s)
        bucket = sched.bucket_for(sched.engine.bucket_key(req))
        steps = self._predict_steps(bucket, req, req.ckpt_depth)
        service = self._service_s(bucket, steps)
        wait = (
            self._own_bucket_wait_s(bucket, replica)
            + self._cross_bucket_backlog_s(bucket)
            + (
                self._cross_engine_backlog_s()
                if replica is None
                else self._cross_engine_backlog_s(replica)
            )
        )
        # eNVM task residency: a non-resident task's first refill stalls the
        # shared clock for its swap-in, so the quote must carry it — the
        # identical request is quoted strictly cheaper when its task is
        # already SRAM-resident
        res = getattr(self.server, "residency", None)
        if res is not None:
            wait += res.pending_swap_stall_s(getattr(self.server, "task", None))
        # cross-server queued demand the arbiter cannot surface (see ctor)
        if self.extra_wait_s is not None:
            wait += max(0.0, float(self.extra_wait_s()))
        min_deadline = (wait + service) * self.headroom
        feasible = (
            req.deadline_s is not None
            and req.deadline_s >= min_deadline * (1 - 1e-9)
        )
        return Quote(
            bucket=bucket,
            service_s=service,
            wait_s=wait,
            min_deadline_s=min_deadline,
            feasible=feasible,
            replica=replica,
        )

    # ----------------------------------------------------------- admission
    def _do_submit(self, req: "Request") -> None:
        # the engine's submit() also stamps req.bucket; a bare scheduler
        # only returns it
        if self.server is self.sched:
            req.bucket = self.sched.submit(req)
        else:
            self.server.submit(req)

    def _bound_best_effort(self, bucket: int) -> List["Request"]:
        shed: List["Request"] = []
        if self.max_best_effort_queue is None:
            return shed
        sched = self.sched
        excess = (
            sched.queued_best_effort(bucket) + 1 - self.max_best_effort_queue
        )
        if excess > 0:
            shed = sched.shed_oldest(bucket, n=excess)
        return shed

    def submit(self, req: "Request") -> AdmissionDecision:
        """Admit, re-quote, reject, or shed-and-admit one request.

        Best-effort (``deadline_s is None``): always admitted, but the
        bucket's bounded queue may shed its OLDEST queued best-effort
        requests to make room (returned on the decision).  Explicit SLO:
        quoted; infeasible SLOs are rejected (decision carries the minimum
        feasible deadline) or admitted at the quote per ``on_infeasible``.
        """
        sched = self.sched
        bucket = sched.bucket_for(sched.engine.bucket_key(req))
        if req.deadline_s is None:
            shed = self._bound_best_effort(bucket)
            self._do_submit(req)
            sched.admission_stats["accepted"] += 1
            return AdmissionDecision(True, "accepted", bucket, None, shed)
        q = self.quote(req)
        if q.feasible:
            if q.replica is not None:
                req.replica = q.replica     # placement pin: the scheduler
                                            # only refills that domain's lanes
            self._do_submit(req)
            sched.admission_stats["accepted"] += 1
            return AdmissionDecision(True, "accepted", bucket, q)
        if self.on_infeasible == "requote":
            req.quoted_deadline_s = req.deadline_s
            req.deadline_s = q.min_deadline_s
            if q.replica is not None:
                req.replica = q.replica
            self._do_submit(req)
            sched.admission_stats["requoted"] += 1
            return AdmissionDecision(True, "requoted", bucket, q)
        sched.admission_stats["rejected"] += 1
        return AdmissionDecision(False, "rejected", bucket, q)
