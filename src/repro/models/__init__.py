from repro.models.model import Model, ModelOutput, build_model
