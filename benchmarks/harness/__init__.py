"""Config-driven serving workload harness.

``scenarios``  — named, JSON-able workload recipes (arrival process x tier
                 mix x task popularity x length buckets) and the converter
                 that turns one into a live ``WorkloadConfig`` calibrated
                 against the hardware model's capacity.
``traffic``    — shared request-queue builders (the storm boilerplate the
                 per-scenario benchmarks used to duplicate).
``run_harness``— the CLI: generate a seeded trace, replay it through the
                 full admission -> residency -> schedule -> DVFS path, emit
                 a structured summary and append it to BENCH_serving.json.
"""
