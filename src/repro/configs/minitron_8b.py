"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.

Pruned Nemotron-4; squared-ReLU MLP per Nemotron family. [arXiv:2407.14679; hf]
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    act="relu2",
    norm="layernorm",
    pos="rope",
)


def smoke_config() -> ModelConfig:
    return replace(
        CONFIG,
        name="minitron-8b-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=128,
        vocab_size=512,
        max_seq_len=256,
    )
