"""Fixed-shape continuation-batching engine: parity with a straight-line
per-lane reference, compile-count regression, and router telemetry."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.early_exit import offramp_logits
from repro.core.entropy import entropy_from_logits
from repro.data.synthetic import SyntheticCLS
from repro.models.model import build_model
from repro.serving.engine import ClassifierServer, DecoderServer, MultiTaskRouter, Request


def _albert_model(threshold=0.6):
    cfg = get_smoke_config("albert_edgebert")
    cfg = dataclasses.replace(cfg, dtype="float32", remat_policy="none")
    cfg = cfg.with_edgebert(
        early_exit=dataclasses.replace(
            cfg.edgebert.early_exit, entropy_threshold=threshold
        )
    )
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params, cfg


def _reference_per_lane(model, params, tokens, threshold):
    """Straight-line single-sentence reference: embed, then layer -> off-ramp
    -> entropy, exiting the Python loop at the threshold — no masking, no
    batching, no lane recycling."""
    cfg = model.cfg
    h = model.embed(params, jnp.asarray(tokens)[None])
    for li in range(cfg.n_layers):
        span_z = model._span_for_layer(params, 0)
        h, _, _ = model._dense_layer_step(
            params["layer"], h, causal=False, span_z=span_z
        )
        lg = offramp_logits(h, model._offramp(params))
        ent = float(entropy_from_logits(lg)[0])
        if ent < threshold or li == cfg.n_layers - 1:
            return np.asarray(lg[0]), li + 1
    raise AssertionError("unreachable")


class TestFusedStepParity:
    def test_matches_per_lane_reference(self):
        thr = 0.5
        model, params, cfg = _albert_model(threshold=thr)
        data = SyntheticCLS(cfg.vocab_size, 32, 8, num_classes=3, seed=0)
        batch = data.batch(0)
        server = ClassifierServer(model, params, batch_lanes=3)
        for i in range(8):
            server.submit(Request(uid=i, tokens=batch["tokens"][i]))
        server.run()
        for i in range(8):
            want_logits, want_exit = _reference_per_lane(
                model, params, batch["tokens"][i], thr
            )
            req = server.done[i]
            assert req.exit_layer == want_exit
            # masked batched lanes vs batch-1 reference: XLA:CPU drift only
            assert np.argmax(req.result) == np.argmax(want_logits)
            np.testing.assert_allclose(req.result, want_logits, atol=5e-2)

    def test_entropy_trace_length_matches_exit(self):
        model, params, cfg = _albert_model(threshold=0.5)
        data = SyntheticCLS(cfg.vocab_size, 32, 6, num_classes=3, seed=2)
        batch = data.batch(0)
        server = ClassifierServer(model, params, batch_lanes=2)
        for i in range(6):
            server.submit(Request(uid=i, tokens=batch["tokens"][i]))
        server.run()
        for i in range(6):
            req = server.done[i]
            assert len(req.entropy_trace) == req.exit_layer


class TestCompileCount:
    def test_layer_step_traces_exactly_once(self, monkeypatch):
        """The fused masked step must compile ONCE for a whole queue drain,
        regardless of how the active-lane set evolves (the old engine
        recompiled per distinct active count)."""
        real_jit = jax.jit
        trace_counts = {}

        def counting_jit(fn, *a, **kw):
            name = getattr(fn, "__name__", repr(fn))

            def counted(*args, **kwargs):
                trace_counts[name] = trace_counts.get(name, 0) + 1
                return fn(*args, **kwargs)

            counted.__name__ = name
            return real_jit(counted, *a, **kw)

        # median off-ramp entropy as threshold -> retirements spread across
        # layers -> the active-lane set takes many distinct shapes during the
        # drain (threshold profiling runs BEFORE the jit counter is armed)
        model, params, cfg = _albert_model(threshold=0.5)
        data = SyntheticCLS(cfg.vocab_size, 32, 10, num_classes=3, seed=1)
        batch = data.batch(0)
        probe = model.apply_train(params, {"tokens": jnp.asarray(batch["tokens"])})
        # threshold between the 40th pct of first-off-ramp entropies and the
        # global median: some sentences retire at layer 1, others deeper
        thr = float(np.quantile(np.asarray(probe.all_entropies[0]), 0.4))
        model, params, cfg = _albert_model(threshold=thr)

        monkeypatch.setattr(jax, "jit", counting_jit)
        server = ClassifierServer(model, params, batch_lanes=3)
        for i in range(10):
            server.submit(Request(uid=i, tokens=batch["tokens"][i]))
        stats = server.run()
        assert stats["sentences"] == 10
        exits = {server.done[i].exit_layer for i in range(10)}
        assert len(exits) > 1, "test needs varied exit layers to be meaningful"
        assert trace_counts["step_fn"] == 1
        assert stats["step_traces"] == 1
        assert stats["embed_traces"] == 1
        assert stats["insert_traces"] == 1

    def test_telemetry_counters_across_two_drains(self):
        """A second drain at the same shapes must not retrace."""
        model, params, cfg = _albert_model(threshold=0.6)
        server = ClassifierServer(model, params, batch_lanes=2)
        data = SyntheticCLS(cfg.vocab_size, 32, 4, num_classes=3, seed=3)
        batch = data.batch(0)
        for i in range(4):
            server.submit(Request(uid=i, tokens=batch["tokens"][i]))
        server.run()
        for i in range(4, 8):
            server.submit(Request(uid=i, tokens=batch["tokens"][i - 4]))
        stats = server.run()
        assert stats["sentences"] == 8
        assert stats["step_traces"] == 1
        assert stats["embed_traces"] == 1

    def test_decoder_prefill_traces_once(self):
        cfg = dataclasses.replace(
            get_smoke_config("deepseek_7b"), dtype="float32", remat_policy="none"
        )
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(1))
        server = DecoderServer(model, params, batch_lanes=2, max_seq=32, eos_id=-1)
        rng = np.random.default_rng(0)
        for i in range(3):  # 3 requests > 2 lanes -> one mid-drain refill
            server.submit(
                Request(
                    uid=i,
                    tokens=rng.integers(4, cfg.vocab_size, size=6).astype(np.int32),
                    max_new_tokens=3,
                )
            )
        stats = server.run()
        assert stats["completed"] == 3
        assert stats["prefill_traces"] == 1
        assert stats["decode_traces"] == 1


class TestPerRequestDeadlineTelemetry:
    def test_misses_counted_against_each_requests_own_deadline(self):
        """telemetry()['deadline_misses'] must judge every request against
        ITS OWN deadline_s; only deadline-free requests fall back to the
        controller-global target.  A slack-free global target with never-
        early-exiting sentences misses for default requests, but an
        identical request with a generous per-request deadline must NOT be
        counted."""
        from repro.hwmodel.edgebert_accel import albert_layer_stats
        from repro.serving.dvfs import (
            LatencyAwareDVFSController,
            no_early_exit_baseline,
        )

        model, params, cfg = _albert_model(threshold=1e-9)  # full depth always
        stats = albert_layer_stats(seq_len=32)
        stats.n_layers = cfg.n_layers
        # target below one layer's latency: every default request must miss
        tight = no_early_exit_baseline(stats)["latency_s"] / (2 * cfg.n_layers)
        ctrl = LatencyAwareDVFSController(stats, tight)
        server = ClassifierServer(model, params, batch_lanes=2, dvfs=ctrl)
        data = SyntheticCLS(cfg.vocab_size, 32, 4, num_classes=3, seed=9)
        batch = data.batch(0)
        loose = no_early_exit_baseline(stats)["latency_s"] * 10
        server.submit(Request(uid=0, tokens=batch["tokens"][0]))  # global target
        server.submit(Request(uid=1, tokens=batch["tokens"][1], deadline_s=loose))
        st = server.run()
        assert st["sentences"] == 2
        assert st["deadline_misses"] == 1          # only the default request
        # the per-sentence Alg.1 report saw the per-request budget too: the
        # loose-deadline request could afford a slower operating point
        assert server.done[1].op_freq_hz <= server.done[0].op_freq_hz


class TestRouterTelemetry:
    def test_task_switch_preserves_shared_embedding_identity(self):
        model, params, cfg = _albert_model()
        p2 = build_model(cfg).init_params(jax.random.PRNGKey(2))
        router = MultiTaskRouter(
            model,
            shared_embed=params["embed"],
            task_params={"mnli": params, "qqp": p2},
        )
        data = SyntheticCLS(cfg.vocab_size, 32, 4, num_classes=3, seed=3)
        b = data.batch(0)
        for round_ in range(3):  # repeated run_all(): switches grow, reloads don't
            router.submit("mnli", Request(uid=2 * round_, tokens=b["tokens"][0]))
            router.submit("qqp", Request(uid=2 * round_ + 1, tokens=b["tokens"][1]))
            out = router.run_all()
            assert set(out) == {"mnli", "qqp"}
            # switching tasks swapped ONLY task weights: both servers still
            # point at the SAME embedding object (eNVM residency)
            assert (
                router.tasks["mnli"].params["embed"]
                is router.tasks["qqp"].params["embed"]
            )
            assert router.tasks["mnli"].params["embed"] is params["embed"]
            assert router.embed_reloads == 1
        assert router.switches == 6
        # task weights genuinely differ (it's not one server aliased twice)
        assert router.tasks["mnli"].params["layer"] is not router.tasks["qqp"].params["layer"]
