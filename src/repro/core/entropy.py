"""Numerically-stable entropy of a categorical distribution from logits.

Paper Eq. 1 defines H(x) from raw logits; Eq. 4 is the hardware form using the
max trick + LogSumExp. We implement the algebraically-correct stable form

    H = ln(sum e^z) - sum(z * e^z) / sum(e^z),   z = x - max(x)

which equals lse(x) - E_p[x] (the paper's Eq. 4 is this same quantity; its
rendering drops a sign on the MAX term, we use the correct algebra and verify
H in [0, ln n] by property test).
"""
from __future__ import annotations

import jax.numpy as jnp


def entropy_from_logits(logits: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Shannon entropy (nats) of softmax(logits) along `axis`, max/LSE-stable."""
    x = logits.astype(jnp.float32)
    m = jnp.max(x, axis=axis, keepdims=True)
    z = x - m
    e = jnp.exp(z)
    s = jnp.sum(e, axis=axis, keepdims=True)
    h = jnp.log(s) - jnp.sum(z * e, axis=axis, keepdims=True) / s
    h = jnp.squeeze(h, axis=axis)
    # clamp tiny negative rounding residue
    return jnp.maximum(h, 0.0)
