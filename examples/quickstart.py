"""Quickstart: build an EdgeBERT-optimized ALBERT, run one training step, and
watch sentences exit early.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.data.synthetic import SyntheticCLS
from repro.models.model import build_model
from repro.training.optim import AdamWConfig, adamw_init
from repro.training.train_loop import make_train_step

# 1. config: ALBERT + the full EdgeBERT feature stack (early exit, adaptive
#    span, pruning, AdaptivFloat) — smoke-sized for CPU
cfg = dataclasses.replace(
    get_smoke_config("albert_edgebert"), dtype="float32", remat_policy="none"
)
print(f"model: {cfg.name}  d_model={cfg.d_model} layers={cfg.n_layers} "
      f"(shared weights: {cfg.shared_layers})")

# 2. build + init
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))

# 3. one train step on the synthetic GLUE-like task
data = SyntheticCLS(cfg.vocab_size, seq_len=32, global_batch=8, num_classes=3)
batch = {k: jnp.asarray(v) for k, v in data.batch(0).items() if k != "signal_ratio"}
step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
params, opt_state, metrics = step(params, adamw_init(params), batch)
print(f"train step: loss={float(metrics['loss']):.3f}")

# 4. forward with early exit: per-sentence exit layers + entropies
out = model.apply_train(params, batch)
print(f"exit layers (T_E={cfg.edgebert.early_exit.entropy_threshold}): "
      f"{np.asarray(out.exit_layer)}")
print(f"final-layer entropies: {np.round(np.asarray(out.all_entropies[-1]), 3)}")

# 5. the learned attention spans (they shrink during fine-tuning)
print(f"span_z init: {np.round(np.asarray(params['span_z'][0]), 1)}")
