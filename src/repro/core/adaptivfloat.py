"""AdaptivFloat quantization (paper §III-E; Tambe et al. [52]).

An n-bit floating-point format (1 sign, ``n_exp`` exponent, rest mantissa)
whose exponent *bias* adapts per tensor to its dynamic range:

    e_max = floor(log2(amax));  e_min = e_max - (2**n_exp - 1)
    normals: +/- 2^e * (1 + m / 2^n_mant),  e in [e_min, e_max]

Zero is represented by the all-zero exponent+mantissa code (for either sign),
sacrificing the two +/-2^e_min*(1.0) slots — this keeps ``af_encode`` /
``af_decode`` exactly invertible, which matters because the eNVM fault
injection (paper Table III) flips bits of the *stored codes*.

``af_quantize`` == ``af_decode(af_encode(x))`` (property-tested).  The Pallas
kernels in ``repro.kernels.adaptivfloat_k`` implement the same math tile-wise.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AFFormat:
    n_bits: int = 8
    n_exp: int = 3

    @property
    def n_mant(self) -> int:
        return self.n_bits - 1 - self.n_exp

    @property
    def n_levels_exp(self) -> int:
        return 2 ** self.n_exp

    def __post_init__(self):
        assert 1 <= self.n_exp <= 5
        assert self.n_bits - 1 - self.n_exp >= 0, "need >=0 mantissa bits"
        assert self.n_bits <= 8, "codes stored as uint8"


def _exp_bias_from_amax(amax: jnp.ndarray, fmt: AFFormat) -> jnp.ndarray:
    """e_min (the adaptive bias) chosen so the top binade covers amax.

    Clamped to +/-120 so exp2(e_min) never underflows to 0 (an all-zero
    tensor would otherwise produce 0/0 = NaN in the mantissa division)."""
    amax = jnp.maximum(amax.astype(jnp.float32), 1e-30)
    e_max = jnp.floor(jnp.log2(amax))
    bias = e_max - (fmt.n_levels_exp - 1)
    return jnp.clip(bias, -120.0, 120.0).astype(jnp.int32)


def af_quantize(
    x: jnp.ndarray,
    fmt: AFFormat = AFFormat(),
    amax: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Quantize-dequantize x to the AdaptivFloat grid (per-tensor bias).

    `amax` may be supplied (e.g. calibrated activation stats); defaults to the
    tensor's own max-abs (the paper's post-finetuning weight quantization).
    """
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    if amax is None:
        amax = jnp.max(jnp.abs(xf))
    e_min = _exp_bias_from_amax(amax, fmt)
    e_max = e_min + fmt.n_levels_exp - 1
    two_pow_emin = jnp.exp2(e_min.astype(jnp.float32))

    a = jnp.abs(xf)
    sign = jnp.sign(xf)
    # exponent of each element, clamped to representable binades
    safe_a = jnp.maximum(a, 1e-38)
    e = jnp.clip(jnp.floor(jnp.log2(safe_a)), e_min.astype(jnp.float32), e_max.astype(jnp.float32))
    scale = jnp.exp2(e)
    n_mant_scale = float(2 ** fmt.n_mant)
    # round mantissa; rounding to 2.0 naturally carries into the next binade
    mant = jnp.round(a / scale * n_mant_scale) / n_mant_scale
    val = mant * scale
    # clamp to the largest representable magnitude
    max_val = (2.0 - 1.0 / n_mant_scale) * jnp.exp2(e_max.astype(jnp.float32))
    val = jnp.minimum(val, max_val)
    # smallest representable magnitude is 2^e_min*(1 + 1/2^n_mant) because the
    # all-zero code is reserved for 0: round-to-nearest between 0 and min_pos
    min_pos = two_pow_emin * (1.0 + 1.0 / n_mant_scale)
    val = jnp.where(a < 0.5 * min_pos, 0.0, jnp.maximum(val, min_pos))
    return (sign * val).astype(orig_dtype)


def af_encode(
    x: jnp.ndarray,
    fmt: AFFormat = AFFormat(),
    amax: Optional[jnp.ndarray] = None,
):
    """Encode to (codes: uint8, e_min: int32 scalar). Bit layout [s|e|m]."""
    xf = x.astype(jnp.float32)
    if amax is None:
        amax = jnp.max(jnp.abs(xf))
    e_min = _exp_bias_from_amax(amax, fmt)
    e_max = e_min + fmt.n_levels_exp - 1
    n_mant_scale = float(2 ** fmt.n_mant)

    a = jnp.abs(xf)
    sign = (xf < 0).astype(jnp.uint8)
    safe_a = jnp.maximum(a, 1e-38)
    e = jnp.clip(jnp.floor(jnp.log2(safe_a)), e_min.astype(jnp.float32), e_max.astype(jnp.float32))
    scale = jnp.exp2(e)
    # significand = round(a/scale * 2^nm) in [2^nm .. 2^(nm+1)] for normals
    sig = jnp.round(a / scale * n_mant_scale)
    m = sig - n_mant_scale                      # mantissa field, may hit 2^nm (carry)
    carry = m >= n_mant_scale
    e = jnp.where(carry, e + 1, e)
    m = jnp.where(carry, 0.0, m)
    # saturate anything past the top representable value
    max_val = (2.0 - 1.0 / n_mant_scale) * jnp.exp2(e_max.astype(jnp.float32))
    sat = jnp.logical_or(a > max_val, e > e_max.astype(jnp.float32))
    e = jnp.where(sat, e_max.astype(jnp.float32), e)
    m = jnp.where(sat, n_mant_scale - 1, m)
    m = jnp.clip(m, 0.0, n_mant_scale - 1)      # sub-min garbage overridden below

    e_field = (e - e_min.astype(jnp.float32)).astype(jnp.uint8)
    m_field = m.astype(jnp.uint8)
    code = (sign << (fmt.n_bits - 1)) | (e_field << fmt.n_mant) | m_field
    # zero: |x| below half of min positive -> all-zero exp+mant (keep sign bit 0)
    min_pos = jnp.exp2(e_min.astype(jnp.float32)) * (1.0 + 1.0 / n_mant_scale)
    is_zero = a < 0.5 * min_pos
    # sub-min values round up to min_pos (code e=0, m=1)
    sub = jnp.logical_and(~is_zero, a < min_pos)
    code = jnp.where(sub, (sign << (fmt.n_bits - 1)) | jnp.uint8(1), code)
    code = jnp.where(is_zero, jnp.uint8(0), code)
    return code.astype(jnp.uint8), e_min


def af_decode(codes: jnp.ndarray, e_min: jnp.ndarray, fmt: AFFormat = AFFormat(), dtype=jnp.float32):
    """Decode uint8 codes back to floats."""
    codes = codes.astype(jnp.uint32)
    sign_bit = (codes >> (fmt.n_bits - 1)) & 1
    e_field = (codes >> fmt.n_mant) & (fmt.n_levels_exp - 1)
    m_field = codes & ((1 << fmt.n_mant) - 1)
    n_mant_scale = float(2 ** fmt.n_mant)
    e = e_field.astype(jnp.float32) + e_min.astype(jnp.float32)
    val = jnp.exp2(e) * (1.0 + m_field.astype(jnp.float32) / n_mant_scale)
    is_zero = (e_field == 0) & (m_field == 0)
    val = jnp.where(is_zero, 0.0, val)
    val = jnp.where(sign_bit == 1, -val, val)
    return val.astype(dtype)


def af_encode_static(x: jnp.ndarray, e_min: int, fmt: AFFormat = AFFormat()):
    """Encode with a STATIC exponent bias (no per-tensor scale storage) —
    used for the AF8 KV cache where per-written-column dynamic biases would
    need a scale plane; dynamic range is fixed by config instead."""
    amax = jnp.asarray(2.0 ** (e_min + fmt.n_levels_exp - 1), jnp.float32)
    codes, _ = af_encode(x, fmt, amax=amax * 1.5)  # amax inside top binade
    return codes


def af_decode_static(codes: jnp.ndarray, e_min: int, fmt: AFFormat = AFFormat(), dtype=jnp.float32):
    return af_decode(codes, jnp.asarray(e_min, jnp.int32), fmt, dtype)


def fake_quant(x: jnp.ndarray, fmt: AFFormat, enabled: bool = True) -> jnp.ndarray:
    """Straight-through fake-quant for activations (QAT / eval emulation)."""
    if not enabled:
        return x
    q = af_quantize(x, fmt)
    # straight-through estimator: identity gradient
    return x + jax.lax.stop_gradient(q - x)


def quantize_pytree(params: Any, fmt: AFFormat = AFFormat(), predicate=None) -> Any:
    """Quantize-dequantize every float leaf of a pytree (per-leaf bias).

    `predicate(path, leaf) -> bool` can exclude leaves (e.g. layernorm params).
    """
    flat = jax.tree_util.tree_flatten_with_path(params)
    leaves, treedef = flat
    out = []
    for path, leaf in leaves:
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            if predicate is None or predicate(path, leaf):
                leaf = af_quantize(leaf, fmt)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, [l for l in out])


def encode_pytree(params: Any, fmt: AFFormat = AFFormat()):
    """Encode every float leaf to (codes, e_min) — the on-eNVM storage form."""
    return jax.tree_util.tree_map(
        lambda l: af_encode(l, fmt)
        if hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.floating)
        else l,
        params,
        is_leaf=lambda l: hasattr(l, "dtype"),
    )
