"""Paper Fig. 10 + Table V: accelerator latency/energy vs MAC vector size,
optimization ablations (AAS / EE / sparsity), mGPU comparison, and the
area/power breakdown — from the analytical model driven by measured workload
stats (hwmodel/edgebert_accel.py)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.hwmodel import edgebert_accel as acc

# Table IV-style deployed operating point (MNLI row): 50% MaP, span avg 12.7,
# 8/12 heads off, exit threshold 0.4 -> avg exit 8.02
STATS = acc.albert_layer_stats(seq_len=128)
STATS.avg_exit_layer = 8.02
STATS.span_factor = 12.7 / 128.0
STATS.heads_active_frac = 4 / 12
STATS.weight_sparsity = 0.5
STATS.act_sparsity = 0.3


def main() -> None:
    # --- Fig 10: MAC vector size sweep ---
    for n in (4, 8, 16, 32):
        r = acc.simulate(STATS, n)
        emit(
            f"fig10_mac_n{n}", r.latency_s * 1e6,
            f"energy_uJ={r.energy_j*1e6:.1f};power_mW={r.breakdown_mw['total']:.1f};"
            f"entropy_overhead={r.entropy_overhead_frac:.4%}",
        )
    energies = {n: acc.simulate(STATS, n).energy_j for n in (4, 8, 16, 32)}
    optimal = min(energies, key=energies.get)
    note = "" if optimal == 16 else (
        ";model_limit=first-order power scaling under-counts the n=32 "
        "wiring/control penalty the paper's post-HLS netlist measures — "
        "deviation documented, not curve-fitted"
    )
    emit("fig10_energy_optimal_n", 0.0,
         f"n={optimal} (paper: 16);E32/E16={energies[32]/energies[16]:.2f}{note}")

    # --- Fig 10 ablations at n=16 ---
    full = acc.simulate(STATS, 16)
    no_ee = acc.simulate(STATS, 16, use_early_exit=False)
    no_span = acc.simulate(STATS, 16, use_span=False)
    no_sparse = acc.simulate(STATS, 16, use_sparsity=False)
    emit("fig10_ablation_early_exit", full.latency_s * 1e6,
         f"latency_gain={no_ee.latency_s/full.latency_s:.2f}x;"
         f"energy_gain={no_ee.energy_j/full.energy_j:.2f}x (paper 1.3-2.0x)")
    emit("fig10_ablation_span", full.latency_s * 1e6,
         f"latency_gain={no_span.latency_s/full.latency_s:.2f}x;"
         f"energy_gain={no_span.energy_j/full.energy_j:.2f}x (paper ~1.2/1.1x)")
    emit("fig10_ablation_sparsity", full.latency_s * 1e6,
         f"energy_gain={no_sparse.energy_j/full.energy_j:.2f}x (paper 1.9-2.6x)")

    # --- mGPU comparison ---
    gpu = acc.simulate_mgpu(STATS)
    gpu_unopt = acc.simulate_mgpu(STATS, use_early_exit=False, use_span=False)
    emit("fig10_vs_mgpu", gpu["latency_s"] * 1e6,
         f"energy_ratio={gpu['energy_j']/full.energy_j:.0f}x (paper 163x);"
         f"gpu_selfgain={gpu_unopt.get('latency_s')/gpu['latency_s']:.2f}x")

    # --- Table V breakdown at n=16 ---
    area = full.area_mm2
    emit("tableV_area", 0.0,
         f"pu={area['pu_datapath']:.2f};gb={area['gb_periph']:.2f};"
         f"sram={area['sram']:.2f};reram={area['reram']:.2f};"
         f"total={area['total']:.2f}mm2 (paper 5.11)")
    p = full.breakdown_mw
    emit("tableV_power", 0.0,
         f"pu={p['pu_datapath']:.1f};gb={p['gb_periph']:.1f};sram={p['sram']:.1f};"
         f"reram={p['reram']:.1f};total={p['total']:.1f}mW (paper 110.5)")


if __name__ == "__main__":
    main()
