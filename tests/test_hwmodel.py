"""HLO analysis (trip-count-aware) + roofline report unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.hwmodel.hlo_analysis import analyze
from repro.hwmodel.roofline import (
    TPUV5E,
    collective_bytes_from_hlo,
    model_flops,
    roofline_report,
)


class TestHloAnalysis:
    def test_scan_trip_counts(self):
        def f(x, w):
            def body(h, _):
                return h @ w, None
            h, _ = jax.lax.scan(body, x, None, length=10)
            return h

        x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        c = jax.jit(f).lower(x, w).compile()
        res = analyze(c.as_text())
        expected = 2 * 128 * 256 * 256 * 10
        assert abs(res.flops - expected) / expected < 1e-6
        assert res.n_while == 1 and res.max_trip == 10

    def test_nested_scans_multiply(self):
        def f(x, w):
            def outer(h, _):
                def inner(hh, _):
                    return hh @ w, None
                h2, _ = jax.lax.scan(inner, h, None, length=3)
                return h2, None
            h, _ = jax.lax.scan(outer, x, None, length=5)
            return h

        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        c = jax.jit(f).lower(x, w).compile()
        res = analyze(c.as_text())
        expected = 2 * 64 * 64 * 64 * 15
        assert abs(res.flops - expected) / expected < 1e-6

    def test_xla_cost_analysis_underreports(self):
        """Documents WHY hlo_analysis exists: XLA counts scan bodies once."""
        def f(x, w):
            def body(h, _):
                return h @ w, None
            h, _ = jax.lax.scan(body, x, None, length=10)
            return h

        x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        c = jax.jit(f).lower(x, w).compile()
        ca = c.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        assert float(ca["flops"]) == 2 * 128 * 256 * 256  # 1x, not 10x

    def test_grad_counts_backward(self):
        def f(a, b):
            return jnp.sum(jnp.tanh(a @ b))

        a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        b = jax.ShapeDtypeStruct((64, 16), jnp.float32)
        c = jax.jit(jax.grad(f, argnums=(0, 1))).lower(a, b).compile()
        res = analyze(c.as_text())
        one = 2 * 32 * 64 * 16
        assert res.flops >= 3 * one - 1  # fwd + two bwd dots


class TestRoofline:
    def test_dominance(self):
        r = roofline_report(
            hlo_flops_per_device=197e12,      # exactly 1s of compute
            hlo_bytes_per_device=819e9 / 2,   # 0.5s of memory
            collective_bytes_per_device=5e9,  # 0.1s of collective
            n_chips=256,
            model_flops_global=197e12 * 256,
        )
        assert r["dominant"] == "compute"
        assert abs(r["t_compute_s"] - 1.0) < 1e-9
        assert abs(r["roofline_fraction"] - 1.0) < 1e-9

    def test_memory_dominant_uses_byte_efficiency(self):
        r = roofline_report(
            hlo_flops_per_device=1e9,
            hlo_bytes_per_device=819e9,       # 1s memory
            collective_bytes_per_device=0,
            n_chips=4,
            model_flops_global=4e9,
            useful_bytes_per_device=819e9 / 4,
        )
        assert r["dominant"] == "memory"
        assert abs(r["roofline_fraction"] - 0.25) < 1e-9

    def test_model_flops(self):
        assert model_flops(1e9, 1e6, "train") == 6e15
        assert model_flops(1e9, 1e6, "prefill") == 2e15

    def test_collective_regex(self):
        hlo = """
  %all-reduce.1 = bf16[1024]{0} all-reduce(%x), replica_groups={}
  %ag = f32[64,32]{1,0} all-gather(%y), dimensions={0}
  %done = f32[8]{0} all-gather-done(%z)
"""
        out = collective_bytes_from_hlo(hlo)
        assert out["bytes_all-reduce"] == 2 * 1024 * 2
        assert out["bytes_all-gather"] == 64 * 32 * 4
