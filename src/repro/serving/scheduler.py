"""Serving-layer lifecycle: ``submit() -> step() -> poll() -> telemetry()``.

``LaneScheduler`` is the single continuously-clocked loop every serving engine
rides.  A caller may submit a request AT ANY TIME — before a drain, or between
two ``step()`` calls while other buckets are mid-flight — and the request
lands in a later refill of its length bucket with no new compiled traces (the
fused step's shapes are fixed per bucket, so interleaving and mid-flight
admission never retrace).  Each ``step()`` advances EXACTLY ONE bucket by one
fused step, chosen by a pluggable ``SchedulingPolicy``; ``poll()`` drains the
requests that retired since the last poll; ``run()`` is a thin back-compat
wrapper (``while work remains: step()``) for callers that still want the
drain-the-world API.  ``telemetry()`` reports lifetime counters, including
per-request queue delay (``arrival_step -> first_compute_step``) percentiles.

Engine hooks
------------
``ClassifierServer`` and ``DecoderServer`` used to each own a private copy of
the same loop — submit -> queue -> refill free lanes -> fused step -> retire.
``EngineHooks`` is that lifecycle's explicit contract: the engine owns all
device state (hidden tensors, KV caches, jitted functions) and supplies the
compute; the scheduler owns queues, lane bookkeeping, the modeled clock, and
telemetry.  Because ``step()`` time-slices across buckets, MULTIPLE buckets
may be open at once: an engine must keep its per-bucket state keyed by bucket
(``bucket_begin``/``bucket_end`` bracket a bucket's lifetime, not the drain's).

Length buckets
--------------
The queue is partitioned by *bucket*: a request is assigned the smallest
configured bucket that fits its shape key (sequence length for the
classifier, prompt + generation budget for the decoder), and its tokens are
padded up to the bucket size by the engine.  Each bucket drains as its own
fixed-shape ``[lanes, S_bucket]`` engine state, so jit compiles EXACTLY ONE
step per bucket instead of one per distinct request length.  ``buckets=None``
keeps the legacy behavior: every distinct shape key is its own bucket.

Deadlines and the modeled clock
-------------------------------
``Request.deadline_s`` is a per-request SLO measured from SUBMISSION on the
scheduler's modeled clock, which advances by ``step_time_fn(bucket)`` per
fused step (default 1.0 — deadlines in "steps"; engines with a hardware model
pass the per-bucket layer time so deadlines are in modeled seconds).  The
default ``EDFPolicy`` ranks buckets by the least slack among their work:
absolute deadline minus the modeled now minus the predicted remaining work,
where remaining work comes from the engine's entropy-LUT exit prediction
(``predict_remaining_steps`` hook -> ``core.early_exit``).  Buckets whose
work carries no deadline fall back to weighted-round-robin time slicing, so a
deep 128-token drain can no longer starve queued 32-token traffic.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import (
    Any,
    Callable,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    TYPE_CHECKING,
)

import numpy as np

if TYPE_CHECKING:  # circular: engine imports scheduler
    from repro.serving.engine import Request


class EngineHooks(Protocol):
    """Compute hooks a serving engine implements to ride the scheduler.

    The engine owns all device state (hidden tensors, KV caches, jitted
    functions); the scheduler owns queues, lane bookkeeping, the modeled
    clock, and telemetry.  Cross-bucket time slicing means several buckets
    can be open simultaneously — implementations must key their state by
    bucket.
    """

    def bucket_key(self, req: "Request") -> int:
        """Shape key of a request (e.g. sequence length) used for bucketing."""
        ...

    def bucket_begin(self, bucket: int) -> None:
        """Allocate the fixed-shape ``[lanes, bucket]`` state for this bucket."""
        ...

    def lane_load(self, bucket: int, lane: int, req: "Request") -> None:
        """Insert a request into a free lane (embed / prefill)."""
        ...

    def lanes_step(self, bucket: int, active: np.ndarray) -> Any:
        """Run ONE fused step over all lanes; returns host-side step outputs."""
        ...

    # -- optional (resolved via getattr; engines may omit it) ---------------
    def step_dt_s(self, bucket: int) -> Optional[float]:
        """ACTUAL modeled duration of the step just run (e.g. the DVFS
        arbiter's chosen-op period plus any switching stall).  When provided,
        the scheduler's clock advances by this instead of the nominal
        ``step_time_fn`` estimate, keeping the EDF clock and the DVFS clock
        from drifting apart.  ``None``/absent = use ``step_time_fn``."""
        ...

    def lane_advance(
        self, bucket: int, lane: int, req: "Request", out: Any, depth: int
    ) -> bool:
        """Per-lane host postprocess after a step; True retires the lane."""
        ...

    def lane_finish(self, bucket: int, lane: int, req: "Request", depth: int) -> None:
        """Retirement bookkeeping (final logits, DVFS report, ...)."""
        ...

    def bucket_end(self, bucket: int) -> None:
        """Release / park the bucket state once its queue + lanes drained."""
        ...

    # -- optional (resolved via getattr; engines may omit it) ---------------
    def predict_remaining_steps(
        self, bucket: int, req: "Request", depth: int
    ) -> Optional[float]:
        """Predicted fused steps this request still needs (entropy-LUT exit
        prediction for the classifier, generation budget for the decoder).
        ``None``/absent = unknown; the EDF policy then uses the bare deadline."""
        ...


# Back-compat alias: PR 2 exported the protocol under this name.
LaneEngine = EngineHooks


@dataclass
class BucketView:
    """Per-bucket snapshot handed to a ``SchedulingPolicy``."""

    bucket: int
    queued: int                     # requests waiting in this bucket's queue
    active: int                     # lanes currently in flight
    step_time_s: float              # modeled duration of one fused step
    earliest_deadline_s: float      # min absolute deadline (inf if none),
                                    # explicit SLOs and implicit budgets alike
    min_slack_s: float              # min(deadline - now - predicted remaining)
    earliest_seq: int               # submission order of the oldest work item
    # explicit per-request SLOs only (requests with their own deadline_s):
    # EDF ranks these STRICTLY above implicit controller-target budgets — a
    # per-request SLO is a contract, the global target is best-effort shaping
    explicit_deadline_s: float = float("inf")
    explicit_slack_s: float = float("inf")


class SchedulingPolicy(Protocol):
    """Picks which candidate bucket the next ``step()`` advances."""

    def choose(self, views: Sequence[BucketView], now_s: float) -> int:
        ...


class WeightedRoundRobinPolicy:
    """Deficit-style weighted round robin over the candidate buckets.

    Each bucket accrues ``weights[bucket]`` credits (default 1.0) whenever
    every candidate is out of credit; the richest candidate runs ``quantum``
    consecutive steps before the next arbitration.  With default weights this
    is fair time slicing — a deep drain and a short queue alternate instead
    of the deep drain running to completion first.
    """

    def __init__(
        self, weights: Optional[Dict[int, float]] = None, quantum: int = 1
    ):
        assert quantum >= 1
        self.weights = dict(weights or {})
        self.quantum = int(quantum)
        self._credit: Dict[int, float] = {}
        self._last: Optional[int] = None
        self._ran = 0

    def choose(self, views: Sequence[BucketView], now_s: float) -> int:
        byb = {v.bucket: v for v in views}
        if self._last in byb and self._ran < self.quantum:
            self._ran += 1
            return self._last
        for b in byb:
            self._credit.setdefault(b, 0.0)
        if all(self._credit[b] <= 0 for b in byb):
            for b in byb:
                self._credit[b] += self.weights.get(b, 1.0)
        choice = max(byb, key=lambda b: (self._credit[b], -b))
        self._credit[choice] -= 1.0
        self._last, self._ran = choice, 1
        return choice


class EDFPolicy:
    """Earliest-deadline-first across buckets, slack-ranked by the predicted
    exit depth; deadline-free work falls back to ``fallback`` (WRR).

    A bucket's urgency is the least slack among its queued + in-flight
    requests: absolute deadline minus the modeled now minus the predicted
    remaining work (the engine's entropy-LUT exit prediction times the
    bucket's step time).  Deadlines come in two strengths and EDF ranks them
    in strict tiers: buckets holding EXPLICIT per-request SLOs (contracts,
    queue-wait-inclusive) preempt buckets whose urgency is only the implicit
    controller-target budget (best-effort energy shaping), which in turn
    preempt deadline-free work — the property that lets a tight-SLO 32-token
    request retire in the middle of a deep 128-token drain.
    """

    def __init__(self, fallback: Optional[SchedulingPolicy] = None):
        self.fallback = fallback if fallback is not None else WeightedRoundRobinPolicy()

    def choose(self, views: Sequence[BucketView], now_s: float) -> int:
        contracted = [v for v in views if np.isfinite(v.explicit_deadline_s)]
        if contracted:
            return min(
                contracted,
                key=lambda v: (v.explicit_slack_s, v.explicit_deadline_s, v.bucket),
            ).bucket
        dated = [v for v in views if np.isfinite(v.earliest_deadline_s)]
        if not dated:
            return self.fallback.choose(views, now_s)
        return min(
            dated,
            key=lambda v: (v.min_slack_s, v.earliest_deadline_s, v.bucket),
        ).bucket


class FIFOPolicy:
    """Strict arrival order: always advance the bucket holding the oldest
    unfinished request — the sequential drain-the-world behavior, kept as the
    baseline the EDF tests beat."""

    def choose(self, views: Sequence[BucketView], now_s: float) -> int:
        return min(views, key=lambda v: (v.earliest_seq, v.bucket)).bucket


@dataclass
class _BucketRun:
    """Scheduler-side lane bookkeeping of one OPEN bucket."""

    lane_req: List[Optional["Request"]]
    lane_depth: np.ndarray
    active: np.ndarray


@dataclass
class StepReport:
    """What one ``step()`` did (host-side, for callers driving the loop)."""

    bucket: int
    n_active: int
    retired: List["Request"] = field(default_factory=list)


class LaneScheduler:
    """Length-bucketed, continuously-clocked continuation-batching scheduler.

    Parameters
    ----------
    lanes:        number of hardware lanes (the fixed batch dimension).
    engine:       the ``EngineHooks`` implementation supplying compute.
    buckets:      ascending bucket sizes (e.g. ``(32, 64, 128)``); a request
                  lands in the smallest bucket >= its shape key.  ``None`` =
                  exact-shape buckets (one per distinct key).
    policy:       ``SchedulingPolicy`` picking the bucket each ``step()``
                  advances.  Default: ``EDFPolicy`` (WRR fallback when no
                  deadlines are in play).
    step_time_fn: modeled seconds one fused step of a bucket takes (drives
                  the modeled clock the EDF slack computation runs on).
                  Default: 1.0 per step — deadlines measured in steps.
    default_deadline_s: implicit latency budget for IN-FLIGHT requests that
                  carry no ``deadline_s`` (engines pass the DVFS controller's
                  global target).  Anchored at lane ADMISSION — the clock the
                  DVFS layer judges — so once a lane is loaded, EDF slack
                  (not blind round robin) decides which bucket gets each time
                  slice and the lane closest to its budget runs next.
                  QUEUED deadline-free requests stay undated: their budget
                  has not started, so an explicit (submission-anchored,
                  queue-wait-inclusive) per-request SLO always outranks a
                  backlog of budget-free work.  ``None`` keeps deadline-free
                  requests out of the EDF ranking entirely (WRR fallback
                  when nothing carries a deadline).
    """

    def __init__(
        self,
        lanes: int,
        engine: EngineHooks,
        buckets=None,
        *,
        policy: Optional[SchedulingPolicy] = None,
        step_time_fn: Optional[Callable[[int], float]] = None,
        default_deadline_s: Optional[float] = None,
    ):
        assert lanes >= 1
        self.lanes = lanes
        self.engine = engine
        self.buckets = tuple(sorted(int(b) for b in buckets)) if buckets else None
        assert self.buckets is None or len(set(self.buckets)) == len(self.buckets)
        self.policy: SchedulingPolicy = policy if policy is not None else EDFPolicy()
        self.step_time_fn = step_time_fn if step_time_fn is not None else (lambda b: 1.0)
        self.default_deadline_s = default_deadline_s
        self.queues: Dict[int, deque] = {}
        self.done: Dict[int, "Request"] = {}
        self.now_s = 0.0                # modeled clock (sum of step times)
        self._open: Dict[int, _BucketRun] = {}
        self._completed: deque = deque()  # retired since the last poll()
        self._seq = 0                   # global submission order
        # min absolute EXPLICIT deadline among each bucket's QUEUED requests,
        # maintained incrementally so _view() stays O(lanes) per step instead
        # of rescanning the whole queue (recomputed only when the minimum
        # element itself is admitted)
        self._qmin_deadline: Dict[int, float] = {}
        # ---- lifetime telemetry (persists across run()/step() calls) ----
        self._sentences = 0
        self._dense_steps = 0
        self._lane_steps = 0            # ACTIVE lane x step executions
        self._refills = 0
        self._bucket_steps: Dict[int, int] = {}

    # ------------------------------------------------------------- queueing
    def bucket_for(self, key: int) -> int:
        if self.buckets is None:
            return int(key)
        for b in self.buckets:
            if key <= b:
                return b
        raise ValueError(
            f"shape key {key} exceeds the largest bucket {self.buckets[-1]}"
        )

    def submit(self, req: "Request") -> int:
        """Queue a request — at any time, including between steps of an
        in-flight drain; it lands in a later refill of its bucket.  Returns
        the bucket it landed in."""
        req.submit_time = time.time()
        req.arrival_step = self._dense_steps
        req.arrival_s = self.now_s
        req.seq = self._seq
        self._seq += 1
        b = self.bucket_for(self.engine.bucket_key(req))
        self.queues.setdefault(b, deque()).append(req)
        if req.deadline_s is not None:
            d_abs = req.arrival_s + req.deadline_s
            if d_abs < self._qmin_deadline.get(b, float("inf")):
                self._qmin_deadline[b] = d_abs
        return b

    @property
    def pending(self) -> int:
        """Queued requests not yet loaded into a lane."""
        return sum(len(q) for q in self.queues.values())

    @property
    def in_flight(self) -> int:
        """Requests currently occupying a lane."""
        return sum(int(run.active.sum()) for run in self._open.values())

    @property
    def idle(self) -> bool:
        return self.pending == 0 and self.in_flight == 0

    # ---------------------------------------------------------- the clock
    def _predict_remaining(self, bucket: int, req: "Request", depth: int):
        hook = getattr(self.engine, "predict_remaining_steps", None)
        if hook is None:
            return None
        return hook(bucket, req, depth)

    def _recompute_qmin(self, bucket: int) -> None:
        m = float("inf")
        for r in self.queues.get(bucket, ()):
            if r.deadline_s is not None:
                m = min(m, r.arrival_s + r.deadline_s)
        if np.isfinite(m):
            self._qmin_deadline[bucket] = m
        else:
            self._qmin_deadline.pop(bucket, None)

    def _pop_next(self, bucket: int) -> "Request":
        """Next request to admit from a bucket's queue: the earliest-deadline
        EXPLICIT-SLO request if any (so a contract jumps the queue inside its
        own bucket, not just across buckets), else plain FIFO.  The O(queue)
        scan runs once per lane admission, not per step."""
        q = self.queues[bucket]
        best, best_d = None, float("inf")
        for idx, r in enumerate(q):
            if r.deadline_s is not None:
                d = r.arrival_s + r.deadline_s
                if d < best_d:
                    best, best_d = idx, d
        if best is None:
            return q.popleft()
        q.rotate(-best)
        req = q.popleft()
        q.rotate(best)
        self._recompute_qmin(bucket)       # the minimum just left the queue
        return req

    def _view(self, bucket: int) -> BucketView:
        """Per-bucket urgency snapshot — O(lanes), not O(queue): in-flight
        lanes are enumerated, while the queue contributes its (incrementally
        maintained) min explicit deadline and its FIFO head's cold-start
        remaining-work estimate (queued requests have no entropy trace yet,
        so the head's prediction stands in for all of them)."""
        run = self._open.get(bucket)
        q = self.queues.get(bucket)
        dt = float(self.step_time_fn(bucket))
        queued = len(q) if q else 0
        active = int(run.active.sum()) if run is not None else 0
        earliest_deadline = float("inf")
        min_slack = float("inf")
        explicit_deadline = float("inf")
        explicit_slack = float("inf")
        earliest_seq = np.iinfo(np.int64).max
        if run is not None:
            for i in range(self.lanes):
                if not run.active[i]:
                    continue
                req, depth = run.lane_req[i], int(run.lane_depth[i])
                earliest_seq = min(earliest_seq, req.seq)
                explicit = req.deadline_s is not None
                if explicit:
                    # explicit SLO: submission-anchored — queue wait counts
                    d_abs = req.arrival_s + req.deadline_s
                elif self.default_deadline_s is not None:
                    # implicit budget: admission-anchored — the DVFS clock
                    d_abs = req.admit_s + self.default_deadline_s
                else:
                    continue
                rem = self._predict_remaining(bucket, req, depth)
                slack = d_abs - self.now_s - (rem or 0.0) * dt
                earliest_deadline = min(earliest_deadline, d_abs)
                min_slack = min(min_slack, slack)
                if explicit:
                    explicit_deadline = min(explicit_deadline, d_abs)
                    explicit_slack = min(explicit_slack, slack)
        if q:
            # queued budget-free work stays undated (its implicit budget has
            # not started); queued explicit SLOs enter via the running min
            earliest_seq = min(earliest_seq, q[0].seq)
            d_abs = self._qmin_deadline.get(bucket, float("inf"))
            if np.isfinite(d_abs):
                rem = self._predict_remaining(bucket, q[0], 0)
                slack = d_abs - self.now_s - (rem or 0.0) * dt
                earliest_deadline = min(earliest_deadline, d_abs)
                min_slack = min(min_slack, slack)
                explicit_deadline = min(explicit_deadline, d_abs)
                explicit_slack = min(explicit_slack, slack)
        return BucketView(
            bucket=bucket,
            queued=queued,
            active=active,
            step_time_s=dt,
            earliest_deadline_s=earliest_deadline,
            min_slack_s=min_slack,
            earliest_seq=int(earliest_seq),
            explicit_deadline_s=explicit_deadline,
            explicit_slack_s=explicit_slack,
        )

    def _candidates(self) -> List[BucketView]:
        out = []
        seen = set()
        for b, q in self.queues.items():
            if q:
                seen.add(b)
        for b, run in self._open.items():
            if run.active.any():
                seen.add(b)
        for b in sorted(seen):
            out.append(self._view(b))
        return out

    # ----------------------------------------------------------- stepping
    def step(self) -> Optional[StepReport]:
        """Advance ONE bucket by one fused step; returns what happened, or
        ``None`` when no work remains anywhere."""
        views = self._candidates()
        if not views:
            return None
        bucket = self.policy.choose(views, self.now_s)
        assert any(v.bucket == bucket for v in views), (
            f"policy chose bucket {bucket} which has no queued or active work"
        )
        eng = self.engine
        run = self._open.get(bucket)
        if run is None:
            eng.bucket_begin(bucket)
            run = _BucketRun(
                lane_req=[None] * self.lanes,
                lane_depth=np.zeros(self.lanes, np.int32),
                active=np.zeros(self.lanes, bool),
            )
            self._open[bucket] = run

        # refill every free lane from this bucket's queue (continuation
        # batching: retired lanes never idle while work is queued)
        q = self.queues.get(bucket)
        step_idx = self._dense_steps
        for i in range(self.lanes):
            if run.lane_req[i] is None and q:
                req = self._pop_next(bucket)
                eng.lane_load(bucket, i, req)
                req.first_compute_step = step_idx
                req.admit_s = self.now_s
                run.lane_req[i] = req
                run.lane_depth[i] = 0
                run.active[i] = True
                self._refills += 1
        assert run.active.any(), "candidate bucket must have work after refill"

        out = eng.lanes_step(bucket, run.active.copy())
        n_active = int(run.active.sum())
        self._dense_steps += 1
        self._lane_steps += n_active
        self._bucket_steps[bucket] = self._bucket_steps.get(bucket, 0) + 1
        # the engine may report the step's ACTUAL modeled duration (DVFS op
        # period + switching stalls); fall back to the nominal estimate so
        # the EDF clock cannot drift from the clock deadlines are judged by
        dt_hook = getattr(eng, "step_dt_s", None)
        dt = dt_hook(bucket) if dt_hook is not None else None
        self.now_s += float(dt) if dt is not None else float(self.step_time_fn(bucket))
        run.lane_depth[run.active] += 1

        report = StepReport(bucket=bucket, n_active=n_active)
        for i in range(self.lanes):
            if not run.active[i]:
                continue
            req = run.lane_req[i]
            if eng.lane_advance(bucket, i, req, out, int(run.lane_depth[i])):
                eng.lane_finish(bucket, i, req, int(run.lane_depth[i]))
                req.retire_step = step_idx
                self.done[req.uid] = req
                self._completed.append(req)
                self._sentences += 1
                report.retired.append(req)
                run.lane_req[i] = None
                run.active[i] = False

        if not run.active.any() and not self.queues.get(bucket):
            eng.bucket_end(bucket)
            del self._open[bucket]
        return report

    def poll(self) -> List["Request"]:
        """Requests retired since the last ``poll()`` (completion order)."""
        out = list(self._completed)
        self._completed.clear()
        return out

    def run(self) -> Dict[str, float]:
        """Back-compat drain-the-world wrapper: step until idle.

        The bucket ORDER now follows the configured policy (EDF/WRR time
        slicing instead of ascending sequential drains).  Per-request COMPUTE
        results (logits, exit layers, generated tokens) are identical — lanes
        are independent and each bucket's shapes are fixed, so no new traces
        either — but shared-clock DVFS accounting (energy_j / latency_s /
        operating points) legitimately differs from the sequential order: the
        arbiter sees a different lane mix and admission timeline.
        """
        while not self.idle:
            self.step()
        return self.telemetry()

    # ------------------------------------------------------------ telemetry
    def telemetry(self) -> Dict[str, float]:
        delays = [
            r.first_compute_step - r.arrival_step
            for r in self.done.values()
            if r.first_compute_step is not None
        ]
        return {
            "sentences": self._sentences,
            "dense_steps": self._dense_steps,
            "lane_steps": self._lane_steps,
            "refills": self._refills,
            "buckets_used": len(self._bucket_steps),
            "bucket_steps": dict(self._bucket_steps),
            "lane_occupancy": (
                self._lane_steps / (self._dense_steps * self.lanes)
                if self._dense_steps
                else 0.0
            ),
            "modeled_now_s": self.now_s,
            "queue_delay_steps_p50": float(np.percentile(delays, 50)) if delays else 0.0,
            "queue_delay_steps_p95": float(np.percentile(delays, 95)) if delays else 0.0,
            "queue_delay_steps_max": float(max(delays)) if delays else 0.0,
        }
