"""Data pipeline: determinism (restart-exact), host sharding, learnability
structure, and dry-run input specs."""
import numpy as np
import jax.numpy as jnp

from repro.configs.base import SHAPES_BY_NAME, get_smoke_config
from repro.data.synthetic import SyntheticCLS, SyntheticLM, make_batch_specs


class TestDeterminism:
    def test_lm_restart_exact(self):
        a = SyntheticLM(1000, 64, 8, seed=3)
        b = SyntheticLM(1000, 64, 8, seed=3)
        for step in (0, 7, 123):
            np.testing.assert_array_equal(a.batch(step)["tokens"], b.batch(step)["tokens"])

    def test_steps_differ(self):
        d = SyntheticLM(1000, 64, 8, seed=0)
        assert not np.array_equal(d.batch(0)["tokens"], d.batch(1)["tokens"])

    def test_cls_restart_exact(self):
        a = SyntheticCLS(512, 32, 8, seed=1)
        b = SyntheticCLS(512, 32, 8, seed=1)
        for k in ("tokens", "labels"):
            np.testing.assert_array_equal(a.batch(5)[k], b.batch(5)[k])


class TestHostSharding:
    def test_shards_partition_the_batch(self):
        """Each host draws an independent slice; union has the global size and
        shards are deterministic per (host, step)."""
        full = SyntheticLM(1000, 32, 8, seed=0, shard=(0, 1)).batch(2)["tokens"]
        s0 = SyntheticLM(1000, 32, 8, seed=0, shard=(0, 2)).batch(2)["tokens"]
        s1 = SyntheticLM(1000, 32, 8, seed=0, shard=(1, 2)).batch(2)["tokens"]
        assert s0.shape[0] == s1.shape[0] == 4 and full.shape[0] == 8
        assert not np.array_equal(s0, s1)
        # shard draws are reproducible
        s0b = SyntheticLM(1000, 32, 8, seed=0, shard=(0, 2)).batch(2)["tokens"]
        np.testing.assert_array_equal(s0, s0b)


class TestStructure:
    def test_lm_induction_planted(self):
        d = SyntheticLM(1000, 256, 4, seed=0, induction_period=64)
        t = d.batch(0)["tokens"]
        np.testing.assert_array_equal(t[:, 64:128], t[:, 0:64])

    def test_cls_signal_band(self):
        d = SyntheticCLS(400, 64, 16, num_classes=4, seed=0,
                         signal_ratio_range=(0.5, 0.5))
        b = d.batch(0)
        band = (400 - 4) // 16
        for i in range(16):
            base = 4 + int(b["labels"][i]) * band
            in_band = ((b["tokens"][i] >= base) & (b["tokens"][i] < base + band)).mean()
            assert in_band > 0.3  # planted signal is present

    def test_cls_token(self):
        b = SyntheticCLS(512, 32, 4, seed=0).batch(0)
        assert (b["tokens"][:, 0] == 1).all()


class TestBatchSpecs:
    def test_specs_cover_families(self):
        for arch, shape in (("whisper_medium", "train_4k"),
                            ("llama3_2_vision_90b", "prefill_32k"),
                            ("deepseek_7b", "decode_32k")):
            cfg = get_smoke_config(arch)
            specs = make_batch_specs(cfg, SHAPES_BY_NAME[shape])
            assert "tokens" in specs
            if cfg.family == "encdec" and shape != "decode_32k":
                assert "enc_input" in specs
            if cfg.family == "vlm" and shape != "decode_32k":
                assert "image_embeds" in specs
            if shape == "decode_32k":
                assert specs["tokens"].shape[1] == 1
