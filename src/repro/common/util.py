"""Small shared utilities: pytree accounting, rng folding, logging."""
from __future__ import annotations

import logging
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

logger = logging.getLogger("repro")
if not logger.handlers:
    _h = logging.StreamHandler()
    _h.setFormatter(logging.Formatter("[%(levelname)s %(name)s] %(message)s"))
    logger.addHandler(_h)
    logger.setLevel(logging.INFO)


def tree_num_params(tree: Any) -> int:
    """Total number of scalar parameters in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) if hasattr(l, "shape") else 1 for l in leaves))


def tree_size_bytes(tree: Any) -> int:
    """Total byte size of a pytree of arrays (or ShapeDtypeStructs)."""
    total = 0
    for l in jax.tree_util.tree_leaves(tree):
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            total += int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
    return total


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def fold_rng(rng: jax.Array, *names: str) -> jax.Array:
    """Deterministically derive a child rng from string names."""
    for name in names:
        # stable 32-bit hash of the name
        h = 2166136261
        for ch in name.encode():
            h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
        rng = jax.random.fold_in(rng, h)
    return rng


def assert_finite(tree: Any, where: str = "") -> None:
    """Host-side check (for tests / eager debugging) that a pytree is finite."""
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if arr.dtype.kind == "f" and not np.all(np.isfinite(arr)):
            raise AssertionError(f"non-finite values at {where}{jax.tree_util.keystr(path)}")


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def log2_int(x: int) -> int:
    assert x > 0 and (x & (x - 1)) == 0, f"{x} not a power of two"
    return int(math.log2(x))
