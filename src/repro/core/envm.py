"""Embedded non-volatile memory (eNVM) model: MLC ReRAM storage of the frozen,
task-shared embedding table (paper §III-D, Table III, Fig. 11).

The paper stores 8-bit AdaptivFloat codes of the 60%-pruned embeddings with
the bitmask in low-risk SLC and the non-zero codes in MLC2, and quantifies
robustness with Ares-style fault injection [41], [43].  We reproduce that:
faults are injected into the *stored uint8 AF codes*, grouped into 1/2/3-bit
cells; a faulty cell's level shifts by +/-1 (the dominant MLC disturb mode).

Cell characteristics follow paper Table III (28nm ReRAM scaled): area density
and read latency are the paper's numbers; bit-error rates are calibration
anchors chosen to reproduce the paper's qualitative result (SLC/MLC2 safe,
MLC3 occasionally catastrophic) from the MLC reliability study [11].
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from repro.core.adaptivfloat import AFFormat
from repro.core import adaptivfloat as af
from repro.core import bitmask as bm

import jax.numpy as jnp


@dataclass(frozen=True)
class CellConfig:
    name: str
    bits_per_cell: int
    area_mm2_per_mb: float   # paper Table III
    read_latency_ns: float   # paper Table III
    ber: float               # per-cell fault probability (calibration anchor)


CELL_CONFIGS: Dict[str, CellConfig] = {
    "SLC": CellConfig("SLC", 1, 0.28, 1.21, 1e-8),
    "MLC2": CellConfig("MLC2", 2, 0.08, 1.54, 1e-6),
    "MLC3": CellConfig("MLC3", 3, 0.04, 2.96, 2e-3),
}


def inject_cell_faults(
    codes: np.ndarray, cell: CellConfig, rng: np.random.Generator
) -> np.ndarray:
    """Flip MLC levels of stored uint8 codes.

    Each code is split into cells of `bits_per_cell`; a faulty cell's stored
    level moves +/-1 (saturating), modelling resistance-drift into an adjacent
    level — the dominant MLC ReRAM error mode.
    """
    codes = np.asarray(codes, dtype=np.uint8).copy()
    bpc = cell.bits_per_cell
    n_cells_per_code = -(-8 // bpc)
    flat = codes.reshape(-1)
    for ci in range(n_cells_per_code):
        shift = ci * bpc
        n_bits = min(bpc, 8 - shift)
        if n_bits <= 0:
            continue
        mask = (1 << n_bits) - 1
        level = (flat >> shift) & mask
        faulty = rng.random(flat.shape) < cell.ber
        direction = rng.integers(0, 2, flat.shape) * 2 - 1
        new_level = np.clip(level.astype(np.int32) + direction, 0, mask).astype(np.uint8)
        level = np.where(faulty, new_level, level)
        flat = (flat & ~np.uint8(mask << shift)) | (level << np.uint8(shift))
    return flat.reshape(codes.shape).astype(np.uint8)


def store_and_readback(
    embedding: np.ndarray,
    data_cell: str = "MLC2",
    mask_cell: str = "SLC",
    fmt: AFFormat = AFFormat(),
    seed: int = 0,
) -> Tuple[np.ndarray, dict]:
    """Full eNVM round-trip for the embedding table.

    1. bitmask-encode the (pruned) embedding;
    2. AF8-encode non-zero values -> uint8 codes;
    3. inject faults: bitmask bits in `mask_cell` (SLC), codes in `data_cell`;
    4. decode back to floats (what the accelerator reads after power-on).
    """
    rng = np.random.default_rng(seed)
    enc = bm.encode(embedding)
    codes, e_min = af.af_encode(jnp.asarray(enc.values), fmt)
    codes = np.asarray(codes)

    faulty_mask_bits = inject_cell_faults(enc.bitmask, CELL_CONFIGS[mask_cell], rng)
    faulty_codes = inject_cell_faults(codes, CELL_CONFIGS[data_cell], rng)

    values = np.asarray(af.af_decode(jnp.asarray(faulty_codes), e_min, fmt))
    n = int(np.prod(enc.shape))
    nz = np.unpackbits(faulty_mask_bits, count=n).astype(bool)
    out = np.zeros(n, dtype=np.float32)
    # a flipped bitmask bit changes which slots receive values: faithful to
    # the format, values stream fills 'on' bits in order
    n_vals = min(int(nz.sum()), len(values))
    idx = np.nonzero(nz)[0][:n_vals]
    out[idx] = values[:n_vals]
    stats = {
        "n_mask_bit_flips": int(
            (np.unpackbits(faulty_mask_bits, count=n) != np.unpackbits(enc.bitmask, count=n)).sum()
        ),
        "n_code_faults": int((faulty_codes != codes).sum()),
        "storage": bm.storage_bytes(enc, value_bits=fmt.n_bits),
    }
    return out.reshape(enc.shape), stats


def area_mm2(n_bytes: int, cell: str) -> float:
    return CELL_CONFIGS[cell].area_mm2_per_mb * n_bytes / (1024 * 1024)


def read_latency_ns(cell: str) -> float:
    return CELL_CONFIGS[cell].read_latency_ns
