"""Pallas TPU kernels for EdgeBERT hot paths + jnp oracles.

Kernels (each <name>.py with pl.pallas_call + BlockSpec, validated in
interpret mode against ref.py):
  span_attention   — windowed flash attention with per-head span predication
  adaptivfloat_k   — AF quantize + AF8-weight matmul (8b mult / 32b acc)
  block_sparse     — CSR-of-blocks sparse matmul (pruning tile skip)
  softmax_entropy  — fused Algorithm-1 softmax + Eq.-4 entropy
  layernorm        — fused two-moment LayerNorm (Eq. 5)
"""
from repro.kernels import ref
